//! Regenerates **Fig. 4**: DSE allocation for a sparse ResNet-18 workload
//! — MAC per SPE and #SPEs across the 16 3×3 convolutional layers.
//!
//! The paper's observations to reproduce:
//! * higher per-layer sparsity → fewer MACs per SPE, and
//! * deeper layers (more filters, fewer spatial positions) → more
//!   parallel SPEs to match the inter-layer rate.
//!
//! Output: `results/fig4_alloc.csv` (layer, sparsity, mac_per_spe, spes).

use hass::arch::{networks, Op};
use hass::dse::{explore, DseConfig};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::Table;
use hass::pruning::PruningPlan;
use hass::sparsity::synthesize;

fn main() {
    let net = networks::resnet18();
    let sp = synthesize(&net, 42);
    let n = sp.layers.len();
    // a "specific sparse workload": 70% weight-sparsity target, natural+
    // mild activation pruning — per-layer statistics still differ
    let mut x = vec![0.0; 2 * n];
    for i in 0..n {
        x[2 * i] = 0.7 / hass::pruning::MAX_SPARSITY;
        x[2 * i + 1] = 0.3 / hass::pruning::MAX_SPARSITY;
    }
    let plan = PruningPlan::from_unit_point(&x, &sp);
    let points = plan.points(&sp);

    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
    eprintln!(
        "[fig4] resnet18 DSE: {:.0} img/s, {} DSP",
        d.images_per_sec(&dev),
        d.resources.dsp
    );

    let mut t = Table::new(&["layer", "pair_sparsity", "mac_per_spe", "i_par", "o_par", "spes"]);
    let mut rows: Vec<(f64, u64, u64)> = Vec::new(); // (sparsity, mac, spes)
    for ((l, des), pt) in net.compute_layers().iter().zip(&d.designs).zip(&points) {
        if let Op::Conv { kernel: 3, groups: 1, .. } = l.op {
            t.row(vec![
                l.name.clone(),
                format!("{:.4}", pt.pair_sparsity()),
                des.n_mac.to_string(),
                des.i_par.to_string(),
                des.o_par.to_string(),
                des.engines().to_string(),
            ]);
            rows.push((pt.pair_sparsity(), des.n_mac as u64, des.engines()));
        }
    }
    assert_eq!(rows.len(), 16, "ResNet-18 has 16 3x3 conv layers");
    print!("{}", t.to_markdown());
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    t.write_files(&dir, "fig4_alloc").expect("write results");
    eprintln!("[fig4] -> results/fig4_alloc.csv");

    // shape checks (rank correlations over the 16 layers)
    let spear_s_mac = spearman(
        &rows.iter().map(|r| r.0).collect::<Vec<_>>(),
        &rows.iter().map(|r| r.1 as f64).collect::<Vec<_>>(),
    );
    let depth: Vec<f64> = (0..rows.len()).map(|i| i as f64).collect();
    let spear_depth_spes = spearman(&depth, &rows.iter().map(|r| r.2 as f64).collect::<Vec<_>>());
    eprintln!(
        "[fig4] rank-corr(sparsity, MAC/SPE) = {spear_s_mac:.2} (paper: negative); \
         rank-corr(depth, #SPE trend) = {spear_depth_spes:.2}"
    );
    assert!(
        spear_s_mac < 0.1,
        "MAC/SPE should anti-correlate with sparsity: {spear_s_mac}"
    );
}

/// Spearman rank correlation.
fn spearman(a: &[f64], b: &[f64]) -> f64 {
    let rank = |v: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&x, &y| v[x].total_cmp(&v[y]));
        let mut r = vec![0.0; v.len()];
        for (rankpos, &i) in idx.iter().enumerate() {
            r[i] = rankpos as f64;
        }
        r
    };
    let ra = rank(a);
    let rb = rank(b);
    let n = a.len() as f64;
    let ma = ra.iter().sum::<f64>() / n;
    let mb = rb.iter().sum::<f64>() / n;
    let cov: f64 = ra.iter().zip(&rb).map(|(x, y)| (x - ma) * (y - mb)).sum();
    let va: f64 = ra.iter().map(|x| (x - ma).powi(2)).sum();
    let vb: f64 = rb.iter().map(|y| (y - mb).powi(2)).sum();
    cov / (va.sqrt() * vb.sqrt()).max(1e-12)
}
