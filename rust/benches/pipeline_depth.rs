//! §Perf harness for the cross-generation lookahead pipeline and the
//! per-layer parallel simulator.
//!
//! **Depth sweep.**  A sharded search drives a slow evaluator (fixed
//! wall-clock delay per candidate, concurrent within a generation — the
//! measured-backend regime where evaluation latency dominates the
//! propose/price loop).  At `--pipeline-depth 0` every generation drains
//! at the reduce barrier before the next is proposed; at depth D up to
//! D+1 generations are in flight, so the barrier idle time collapses and
//! steady-state throughput approaches (D+1) generations per evaluation
//! latency.  The sweep measures wall time at depths 0/1/2 and asserts
//! the fixed-depth determinism contract (two depth-1 runs agree
//! bit-for-bit on every journal).
//!
//! **Per-layer simulation.**  One promoted resnet18 candidate (frontier
//! `explore` at uniform sparsity, the same promotion path the fidelity
//! ladder uses) is simulated serially and with `simulate_par` at the
//! host's parallelism.  Candidate-only parallelism cannot split a single
//! candidate, so the serial run *is* that baseline; the parallel run
//! chunks the deterministic core's per-group feasibility scans over
//! scoped workers.  Deep FIFOs keep the scans long enough to matter —
//! the regime where a lone promoted candidate otherwise leaves every
//! other core idle.
//!
//! Output: `results/BENCH_pipeline.json` (+ a table on stderr).
//! Run: `cargo bench --bench pipeline_depth [-- --quick]`.

use std::time::{Duration, Instant};

use hass::arch::networks;
use hass::coordinator::{
    search_sharded, CandidateEvaluator, EngineConfig, EvalPoint, SearchConfig,
};
use hass::dse::{explore, DseConfig};
use hass::engine::ShardedSearchResult;
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::pruning::PruningPlan;
use hass::simulator::{simulate, simulate_par, stages_from_design, SparsityDynamics};
use hass::sparsity::{synthesize, NetworkSparsity, SparsityPoint};

/// Stub evaluator with a fixed wall-clock delay per `eval`.  Unlike the
/// mutex-serialized `SlowEvaluator` in `engine_scaling`, evaluations
/// within (and across) generations sleep concurrently — the regime a
/// farm of measurement boards or remote workers presents, where the
/// pipeline's cross-generation overlap pays directly.
struct SlowStub {
    sparsity: NetworkSparsity,
    delay: Duration,
}

impl CandidateEvaluator for SlowStub {
    fn sparsity_model(&self) -> &NetworkSparsity {
        &self.sparsity
    }

    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        std::thread::sleep(self.delay);
        let points = plan.points(&self.sparsity);
        let s = points.iter().map(|p| (p.s_w + p.s_a) * 0.5).sum::<f64>()
            / points.len() as f64;
        EvalPoint { accuracy: 92.0 - 30.0 * s * s, points, sim: Vec::new() }
    }

    fn base_accuracy(&self) -> f64 {
        92.0
    }
}

fn journal_bits(r: &ShardedSearchResult) -> Vec<u64> {
    r.per_device
        .iter()
        .flat_map(|d| d.result.records.iter().map(|x| x.objective.to_bits()))
        .collect()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // ---- depth sweep: slow evaluator, 2 shards ------------------------
    let iters = if quick { 12 } else { 24 };
    let batch = 4usize;
    let delay = Duration::from_millis(if quick { 15 } else { 40 });
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let ev = SlowStub { sparsity: synthesize(&net, 7), delay };

    let run_depth = |depth: usize| {
        let cfg = SearchConfig {
            iterations: iters,
            seed: 3,
            pipeline_depth: depth,
            engine: EngineConfig {
                batch,
                threads: 0,
                cache: true,
                quant_bits: 12,
                async_eval: false,
            },
            ..Default::default()
        };
        let t0 = Instant::now();
        let r = search_sharded(&ev, &net, &rm, &devices, &cfg);
        (t0.elapsed().as_secs_f64() * 1e3, r)
    };

    run_depth(0); // warmup (thread pool, allocator, frontier store)
    let (d0_ms, d0) = run_depth(0);
    eprintln!(
        "[pipeline_depth] depth 0 (drained): {iters} iters x {} devices, \
         {} ms/eval -> {d0_ms:.0} ms ({cores} cores)",
        devices.len(),
        delay.as_millis(),
    );

    let mut sweep: Vec<(usize, f64, f64, usize, u64, u64)> = Vec::new();
    sweep.push((0, d0_ms, 1.0, d0.stats.pipelined_generations, d0.stats.lookahead_proposals, d0.stats.barrier_wait_ns));
    for depth in [1usize, 2] {
        let (ms, r) = run_depth(depth);
        eprintln!(
            "[pipeline_depth] depth {depth}: {ms:.0} ms ({:.2}x vs drained) | \
             {} generations overlapped, {} lookahead proposals, \
             {:.1} ms at the reduce barrier",
            d0_ms / ms,
            r.stats.pipelined_generations,
            r.stats.lookahead_proposals,
            r.stats.barrier_wait_ns as f64 / 1e6,
        );
        sweep.push((
            depth,
            ms,
            d0_ms / ms,
            r.stats.pipelined_generations,
            r.stats.lookahead_proposals,
            r.stats.barrier_wait_ns,
        ));
    }

    // fixed-depth determinism: a depth-1 rerun must journal bit-identically
    let (_, a) = run_depth(1);
    let (_, b) = run_depth(1);
    assert_eq!(
        journal_bits(&a),
        journal_bits(&b),
        "depth-1 reruns diverged: the pipeline is not deterministic"
    );

    let depth1_speedup = sweep[1].2;
    if cores > 1 && depth1_speedup < 1.5 {
        eprintln!(
            "[pipeline_depth] WARNING: expected > 1.5x at depth 1 under a \
             {} ms evaluator, measured {depth1_speedup:.2}x",
            delay.as_millis(),
        );
    }

    // ---- per-layer simulation: one promoted resnet18 candidate --------
    let rnet = networks::resnet18();
    let n = rnet.compute_layers().len();
    let points = vec![SparsityPoint { s_w: 0.55, s_a: 0.44 }; n];
    let design = explore(&rnet, &points, &rm, &DeviceBudget::u250(), &DseConfig::default());
    // deep FIFOs: long feasibility scans, the chunked workers' regime
    let cfgs = stages_from_design(&rnet, &design.designs, &points, 8192);
    let images = if quick { 1 } else { 2 };
    let reps = if quick { 2 } else { 3 };
    let time_sim = |threads: usize| {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            let rep = if threads <= 1 {
                simulate(&rnet, &cfgs, images, SparsityDynamics::Deterministic)
            } else {
                simulate_par(&rnet, &cfgs, images, SparsityDynamics::Deterministic, threads)
            };
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            assert!(!rep.deadlocked, "resnet18 candidate deadlocked in the bench");
            best = best.min(ms);
        }
        best
    };
    let serial_ms = time_sim(1);
    let par_ms = time_sim(cores);
    let serial_rep = simulate(&rnet, &cfgs, images, SparsityDynamics::Deterministic);
    let par_rep = simulate_par(&rnet, &cfgs, images, SparsityDynamics::Deterministic, cores);
    assert_eq!(
        serial_rep.total_cycles, par_rep.total_cycles,
        "per-layer parallel simulation diverged from the serial core"
    );
    eprintln!(
        "[pipeline_depth] resnet18 promoted candidate ({images} images): \
         serial {serial_ms:.1} ms vs {cores}-thread per-layer {par_ms:.1} ms \
         ({:.2}x; candidate-only parallelism = serial on a lone candidate)",
        serial_ms / par_ms,
    );

    // ---- results ------------------------------------------------------
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir).expect("results dir");
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"cores\": {cores},\n"));
    json.push_str("  \"depth_sweep\": {\n");
    json.push_str(&format!("    \"network\": \"{}\",\n", net.name));
    json.push_str(&format!("    \"iterations\": {iters},\n"));
    json.push_str(&format!("    \"batch\": {batch},\n"));
    json.push_str(&format!("    \"devices\": {},\n", devices.len()));
    json.push_str(&format!("    \"eval_delay_ms\": {},\n", delay.as_millis()));
    json.push_str("    \"runs\": [\n");
    for (i, (depth, ms, speedup, pipelined, lookahead, barrier_ns)) in
        sweep.iter().enumerate()
    {
        json.push_str(&format!(
            "      {{\"pipeline_depth\": {depth}, \"wall_ms\": {ms:.3}, \
             \"speedup_vs_drained\": {speedup:.3}, \
             \"pipelined_generations\": {pipelined}, \
             \"lookahead_proposals\": {lookahead}, \
             \"barrier_wait_ms\": {:.3}}}{}\n",
            *barrier_ns as f64 / 1e6,
            if i + 1 == sweep.len() { "" } else { "," }
        ));
    }
    json.push_str("    ],\n");
    json.push_str("    \"depth1_rerun_bit_identical\": true\n");
    json.push_str("  },\n");
    json.push_str("  \"per_layer_sim\": {\n");
    json.push_str(&format!("    \"network\": \"{}\",\n", rnet.name));
    json.push_str(&format!("    \"images\": {images},\n"));
    json.push_str("    \"fifo_depth\": 8192,\n");
    json.push_str(&format!("    \"serial_ms\": {serial_ms:.3},\n"));
    json.push_str(&format!("    \"threads\": {cores},\n"));
    json.push_str(&format!("    \"parallel_ms\": {par_ms:.3},\n"));
    json.push_str(&format!("    \"speedup\": {:.3},\n", serial_ms / par_ms));
    json.push_str(&format!(
        "    \"total_cycles_match\": {}\n",
        serial_rep.total_cycles == par_rep.total_cycles
    ));
    json.push_str("  }\n}\n");
    let path = dir.join("BENCH_pipeline.json");
    std::fs::write(&path, json).expect("write json");
    eprintln!("[pipeline_depth] -> {}", path.display());
}
