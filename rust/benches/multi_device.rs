//! §Perf harness for the sharded multi-device search: one `ShardedEngine`
//! run over N device budgets vs. the serial status quo (one standalone
//! `Engine::search` per device, back to back).
//!
//! The sharded run must *win on wall time* (device shards overlap on the
//! shared thread pool) while *changing nothing*: per-device journals are
//! asserted bit-identical between the two modes — the engine's
//! determinism contract extended across devices.
//!
//! Output: `results/multi_device.json` (+ a human-readable table on
//! stderr).  Run: `cargo bench --bench multi_device [-- --quick]`.

use std::time::Instant;

use hass::coordinator::{Engine, EngineConfig, SearchConfig, SurrogateEvaluator};
use hass::engine::ShardedEngine;
use hass::arch::networks;
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::Table;
use hass::sparsity::synthesize;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 10 } else { 24 };
    let seed = 1u64;

    let net = networks::resnet18();
    let ev = SurrogateEvaluator {
        net: net.clone(),
        sparsity: synthesize(&net, 1),
        base_acc: 69.75,
    };
    let rm = ResourceModel::default();
    let devices =
        [DeviceBudget::u250(), DeviceBudget::v7_690t(), DeviceBudget::stratix10()];
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // a deliberately narrow per-device generation (batch 2): a standalone
    // run underuses a multi-core host, which is exactly the idle capacity
    // device sharding reclaims
    let cfg = SearchConfig {
        iterations: iters,
        seed,
        engine: EngineConfig {
            batch: 2,
            threads: 0,
            cache: true,
            quant_bits: 12,
            async_eval: false,
        },
        ..Default::default()
    };

    // warmup (allocator + branch caches)
    Engine::new(&ev, &net, &rm, &devices[0]).search(&cfg);

    // ---- serial baseline: one standalone search per device ------------
    let mut serial_ms: Vec<f64> = Vec::new();
    let mut serial_results = Vec::new();
    for dev in &devices {
        let t0 = Instant::now();
        let r = Engine::new(&ev, &net, &rm, dev).search(&cfg);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        eprintln!(
            "[multi_device] serial {}: {iters} iters in {ms:.0} ms (best objective {:.4})",
            dev.name,
            r.best_record().objective
        );
        serial_ms.push(ms);
        serial_results.push(r);
    }
    let serial_sum_ms: f64 = serial_ms.iter().sum();

    // ---- sharded: one search over all devices, shared cache -----------
    let t0 = Instant::now();
    let sharded = ShardedEngine::new(&ev, &net, &rm, &devices).search(&cfg);
    let sharded_ms = t0.elapsed().as_secs_f64() * 1e3;
    let speedup = serial_sum_ms / sharded_ms;
    eprintln!(
        "[multi_device] sharded {} devices: {sharded_ms:.0} ms vs serial sum \
         {serial_sum_ms:.0} ms -> {speedup:.2}x ({cores} cores, pool of {} threads)",
        devices.len(),
        sharded.stats.threads
    );
    eprintln!(
        "[multi_device] shared stores: {} designs ({} hit / {} miss), {} frontiers \
         ({} hit / {} miss), {} measurements deduped across shards",
        sharded.stats.cache_entries,
        sharded.stats.cache_hits,
        sharded.stats.cache_misses,
        sharded.stats.frontier_entries,
        sharded.stats.frontier_hits,
        sharded.stats.frontier_misses,
        sharded.stats.dedup_evals
    );

    // ---- frontier reuse: every device that actually priced must have hit
    // the shared frontier store (ResNet-18 repeats block shapes; URAM-less
    // devices early-out of the DSE and legitimately show zero traffic)
    for r in &sharded.per_device {
        let s = &r.result.stats;
        eprintln!(
            "[multi_device] {}: frontier {} hit / {} miss, {} deduped measurements",
            r.device, s.frontier_hits, s.frontier_misses, s.dedup_evals
        );
        if s.frontier_misses > 0 {
            assert!(
                s.frontier_hits > 0,
                "{}: a pricing device must re-use frontiers across candidates",
                r.device
            );
        }
    }
    assert!(
        sharded.stats.frontier_hits > 0,
        "warm-path frontier re-use must show up in per-device stats"
    );

    // ---- determinism: per-device journals must be bit-identical --------
    for (dev, serial) in devices.iter().zip(&serial_results) {
        let shard = sharded.by_device(&dev.name).expect("device in sharded result");
        assert_eq!(serial.records.len(), shard.records.len());
        for (a, b) in serial.records.iter().zip(&shard.records) {
            assert_eq!(
                a.objective.to_bits(),
                b.objective.to_bits(),
                "{}: sharded journal diverged from standalone",
                dev.name
            );
        }
        assert_eq!(serial.best, shard.best);
    }
    eprintln!(
        "[multi_device] determinism: all {} per-device journals bit-identical",
        devices.len()
    );

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir).expect("results dir");

    // human-readable table
    let mut t = Table::new(&[
        "device", "serial_ms", "best_objective", "sharded_cache_hits",
        "sharded_cache_misses", "frontier_hits", "frontier_misses", "dedup_evals",
    ]);
    for ((dev, ms), r) in devices.iter().zip(&serial_ms).zip(&sharded.per_device) {
        t.row(vec![
            dev.name.clone(),
            format!("{ms:.1}"),
            format!("{:.4}", r.result.best_record().objective),
            r.result.stats.cache_hits.to_string(),
            r.result.stats.cache_misses.to_string(),
            r.result.stats.frontier_hits.to_string(),
            r.result.stats.frontier_misses.to_string(),
            r.result.stats.dedup_evals.to_string(),
        ]);
    }
    t.write_files(&dir, "multi_device").expect("write results");

    // JSON summary for the bench trajectory
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"network\": \"{}\",\n", net.name));
    json.push_str(&format!("  \"iterations\": {iters},\n"));
    json.push_str(&format!("  \"seed\": {seed},\n"));
    json.push_str(&format!("  \"cores\": {cores},\n"));
    json.push_str(&format!("  \"pool_threads\": {},\n", sharded.stats.threads));
    json.push_str(&format!("  \"serial_sum_ms\": {serial_sum_ms:.3},\n"));
    json.push_str(&format!("  \"sharded_ms\": {sharded_ms:.3},\n"));
    json.push_str(&format!("  \"speedup_sharded_vs_serial\": {speedup:.3},\n"));
    json.push_str(&format!(
        "  \"journals_bit_identical\": true,\n  \"pareto_points\": {},\n",
        sharded.pareto.len()
    ));
    json.push_str(&format!(
        "  \"frontier_entries\": {},\n  \"frontier_hits\": {},\n  \
         \"frontier_misses\": {},\n  \"dedup_evals\": {},\n",
        sharded.stats.frontier_entries,
        sharded.stats.frontier_hits,
        sharded.stats.frontier_misses,
        sharded.stats.dedup_evals
    ));
    json.push_str("  \"devices\": [\n");
    let n_dev = devices.len();
    for (i, ((dev, ms), r)) in
        devices.iter().zip(&serial_ms).zip(&sharded.per_device).enumerate()
    {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"serial_ms\": {ms:.3}, \"best_objective\": {:.6}, \
             \"cache_hits\": {}, \"cache_misses\": {}, \"frontier_hits\": {}, \
             \"frontier_misses\": {}, \"dedup_evals\": {}}}{}\n",
            dev.name,
            r.result.best_record().objective,
            r.result.stats.cache_hits,
            r.result.stats.cache_misses,
            r.result.stats.frontier_hits,
            r.result.stats.frontier_misses,
            r.result.stats.dedup_evals,
            if i + 1 == n_dev { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = dir.join("multi_device.json");
    std::fs::write(&path, json).expect("write json");
    eprintln!("[multi_device] -> {}", path.display());

    if cores > 1 && speedup < 1.2 {
        eprintln!(
            "[multi_device] WARNING: expected > 1.2x over the serial sum on a \
             multi-core host, measured {speedup:.2}x"
        );
    }
}
