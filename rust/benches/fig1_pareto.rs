//! Regenerates **Fig. 1**: accuracy vs operation-density trade-off for
//! MobileNetV2 — the HASS search's Pareto front against prior sparse
//! implementations (dense, PASS-like, HPIPE-like, non-dataflow [6]).
//!
//! Output: `results/fig1_pareto.csv` with one labelled point per row
//! (`series, op_density, accuracy`), plus the extracted front.

use hass::arch::networks;
use hass::baselines::{self, MemoryModel};
use hass::coordinator::{search, SearchConfig, SearchMode, SurrogateEvaluator};
use hass::dse::DseConfig;
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::{pareto_front, Point2, Table};
use hass::sparsity::synthesize;

fn main() {
    let net = networks::mobilenet_v2();
    let sp = synthesize(&net, 1);
    let base_acc = 71.88; // torchvision MobileNetV2 top-1
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let dse = DseConfig::default();
    let quick = std::env::args().any(|a| a == "--quick");

    // HASS search trace: every evaluated operating point is a candidate
    let ev = SurrogateEvaluator { net: net.clone(), sparsity: sp.clone(), base_acc };
    let cfg = SearchConfig {
        iterations: if quick { 24 } else { 96 },
        mode: SearchMode::HardwareAware,
        seed: 1,
        ..Default::default()
    };
    eprintln!("[fig1] running {}-iteration HASS search on mobilenet_v2 ...", cfg.iterations);
    let r = search(&ev, &net, &rm, &dev, &cfg);

    let mut t = Table::new(&["series", "op_density", "accuracy"]);
    let mut cloud: Vec<Point2> = Vec::new();
    for rec in &r.records {
        t.row(vec![
            "hass".into(),
            format!("{:.4}", rec.op_density),
            format!("{:.3}", rec.accuracy),
        ]);
        cloud.push(Point2 {
            label: format!("iter{}", rec.iter),
            // Pareto: maximize accuracy AND maximize *sparsity* = 1-density
            x: 1.0 - rec.op_density,
            y: rec.accuracy,
        });
    }

    // comparator points
    let dense = baselines::dense_dataflow(&net, base_acc, &rm, &dev, &dse);
    let pass = baselines::pass_like(&net, &sp, base_acc, &rm, &dev, &dse);
    let hpipe = baselines::hpipe_like(&net, &sp, base_acc, 0.6, &rm, &dev, &dse);
    let nd = baselines::non_dataflow_sparse(
        &net,
        &sp,
        base_acc,
        0.5,
        2_048,
        &MemoryModel::default(),
        &rm,
        &DeviceBudget::v7_690t(),
    );
    for b in [&dense, &pass, &hpipe, &nd] {
        t.row(vec![
            b.name.clone(),
            format!("{:.4}", b.op_density),
            format!("{:.3}", b.accuracy),
        ]);
    }

    // extracted HASS front
    let front = pareto_front(&cloud);
    for &i in &front {
        t.row(vec![
            "hass-front".into(),
            format!("{:.4}", 1.0 - cloud[i].x),
            format!("{:.3}", cloud[i].y),
        ]);
    }

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    t.write_files(&dir, "fig1_pareto").expect("write results");
    eprintln!(
        "[fig1] {} search points, {} on the front -> results/fig1_pareto.csv",
        r.records.len(),
        front.len()
    );

    // shape checks.  HPIPE prunes: the front must dominate it outright
    // (as sparse, within noise of its accuracy).  PASS does not prune at
    // all, so its accuracy is exact by construction — the paper's claim
    // there is that HASS trades ≲1 accuracy point (with the real model;
    // our one-shot surrogate is harsher) for *far* lower density.
    let dominated = front.iter().any(|&i| {
        (1.0 - cloud[i].x) <= hpipe.op_density + 1e-9 && cloud[i].y >= hpipe.accuracy - 0.75
    });
    assert!(
        dominated,
        "hpipe: not dominated by the HASS front (density {:.3}, acc {:.2})",
        hpipe.op_density, hpipe.accuracy
    );
    let beats_pass = front.iter().any(|&i| {
        (1.0 - cloud[i].x) <= pass.op_density - 0.15 && cloud[i].y >= pass.accuracy - 3.0
    });
    assert!(
        beats_pass,
        "pass: HASS front should reach far lower density at small accuracy cost"
    );
    eprintln!("[fig1] shape checks passed (front dominates hpipe; far sparser than pass)");
}
