//! Ablations of HASS's design choices (DESIGN.md §4, "extra"):
//!
//! 1. **Balancing strategy** (§IV): SA assignment of imbalanced channels/
//!    filters to engines vs naive contiguous folding — measured as the
//!    simulated throughput of a layer with per-engine density imbalance.
//! 2. **Buffering strategy** (§IV): moving-window-derived FIFO depths vs
//!    minimal FIFOs under stochastic sparsity dynamics.
//! 3. **Per-layer vs uniform thresholds** (§III): accuracy at equal
//!    network sparsity.
//! 4. **TPE vs random search** (§V-B): best Eq. 6 objective at equal
//!    budget.
//!
//! Output: `results/ablations.csv`.

use hass::arch::networks;
use hass::coordinator::{
    search_with_cache, DesignCache, Evaluate, SearchConfig, SearchMode, SurrogateEvaluator,
};
use hass::dse::balance::{balance, contiguous_assignment, imbalance};
use hass::dse::{explore, DseConfig};
use hass::engine::{cache_file_from_args, save_cache_file};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::Table;
use hass::optim::anneal::AnnealSchedule;
use hass::optim::RandomSearch;
use hass::pruning::{self, PruningPlan};
use hass::simulator::{simulate, stages_from_design, SparsityDynamics};
use hass::sparsity::synthesize;
use hass::util::rng::Rng;

fn main() {
    let mut t = Table::new(&["ablation", "variant", "metric", "value"]);
    // `--cache-file <path>`: warm design cache for the TPE ablation's
    // searches, saved back at exit so repeat sweeps run warm
    let (cache, cache_path) = cache_file_from_args("[ablations]");

    ablate_balancing(&mut t);
    ablate_buffering(&mut t);
    ablate_thresholds(&mut t);
    ablate_tpe(&mut t, &cache);

    print!("{}", t.to_markdown());
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    t.write_files(&dir, "ablations").expect("write results");
    eprintln!("[ablations] -> results/ablations.csv");
    save_cache_file(&cache, &cache_path, "[ablations]");
}

/// §IV Balancing strategy: simulated pipeline throughput of CalibNet with
/// per-engine imbalance, naive vs SA-balanced assignment.
fn ablate_balancing(t: &mut Table) {
    let net = networks::calibnet();
    let sp = synthesize(&net, 7);
    let n = sp.layers.len();
    let points: Vec<_> = (0..n)
        .map(|i| sp.layers[i].point(sp.layers[i].weight_curve.tau_for(0.5), 0.0))
        .collect();
    let rm = ResourceModel::default();
    // full budget: every layer gets i×o engines, so the imbalance (and
    // the balancing fix) is visible at the bottleneck too
    let dev = DeviceBudget::u250();
    let d = explore(&net, &points, &rm, &dev, &DseConfig::default());

    // per-engine density multipliers from the per-channel imbalance:
    // naive = contiguous grouping, balanced = SA assignment
    let mut rng = Rng::new(3);
    let mut naive_cfg = stages_from_design(&net, &d.designs, &points, rm.fifo_depth);
    let mut bal_cfg = naive_cfg.clone();
    let mut spread_naive = 0.0;
    let mut spread_bal = 0.0;
    for (li, prof) in sp.layers.iter().enumerate() {
        let des = &d.designs[li];
        let (ip, op) = (des.i_par, des.o_par);
        if ip * op <= 1 {
            continue;
        }
        // structured imbalance: density varies smoothly across channel /
        // filter index (real feature maps cluster — e.g. early channels
        // encode low-frequency content with more live activations), so
        // *contiguous* grouping is pathological while SA can interleave
        let mut chan: Vec<f64> = (0..ip.max(prof.channel_imbalance.len()))
            .map(|c| prof.channel_imbalance[c % prof.channel_imbalance.len()])
            .collect();
        chan.sort_by(f64::total_cmp);
        // two filters per output group so the assignment has freedom
        // (with one filter per engine there is nothing to balance)
        let nf = (2 * op).max(8);
        let filt: Vec<f64> = (0..nf).map(|f| (0.8 * f as f64 / nf as f64 - 0.4).exp()).collect();
        let naive = contiguous_assignment(chan.len(), filt.len(), ip, op);
        let imb_naive = imbalance(&chan, &filt, &naive, ip, op);
        let res = balance(
            &chan,
            &filt,
            ip,
            op,
            &AnnealSchedule { iters: 3_000, ..Default::default() },
            &mut rng,
        );
        spread_naive = f64::max(spread_naive, imb_naive);
        spread_bal = f64::max(spread_bal, res.imbalance_after);
        // engine multiplier = its group's share over the perfect share
        let eng = |asg: &hass::dse::balance::Assignment| -> Vec<f64> {
            let mut chan_load = vec![0.0; ip];
            for (c, &g) in asg.chan_group.iter().enumerate() {
                chan_load[g] += chan[c];
            }
            let mut filt_load = vec![0.0; op];
            for (f, &g) in asg.filt_group.iter().enumerate() {
                filt_load[g] += filt[f];
            }
            let mean: f64 = chan_load.iter().sum::<f64>() * filt_load.iter().sum::<f64>()
                / (ip * op) as f64;
            let mut v = Vec::with_capacity(ip * op);
            for &cl in &chan_load {
                for &fl in &filt_load {
                    v.push(cl * fl / mean.max(1e-12));
                }
            }
            v
        };
        naive_cfg[li].engine_imbalance = eng(&naive);
        bal_cfg[li].engine_imbalance = eng(&res.assignment);
    }
    let avg = |cfg: &[hass::simulator::StageConfig]| -> f64 {
        (1..=3)
            .map(|s| simulate(&net, cfg, 4, SparsityDynamics::Stochastic { seed: s }).throughput)
            .sum::<f64>()
            / 3.0
    };
    let thr_naive = avg(&naive_cfg);
    let thr_bal = avg(&bal_cfg);
    let gain = thr_bal / thr_naive;
    eprintln!(
        "[ablations] balancing: naive {thr_naive:.3e} -> SA {thr_bal:.3e} img/cyc (x{gain:.3}); \
         worst engine-load spread {spread_naive:.3} -> {spread_bal:.3}"
    );
    t.row(vec![
        "balancing".into(),
        "contiguous".into(),
        "img_per_cycle".into(),
        format!("{thr_naive:.4e}"),
    ]);
    t.row(vec![
        "balancing".into(),
        "sa_balanced".into(),
        "img_per_cycle".into(),
        format!("{thr_bal:.4e}"),
    ]);
    t.row(vec![
        "balancing".into(),
        "contiguous".into(),
        "worst_spread".into(),
        format!("{spread_naive:.4}"),
    ]);
    t.row(vec![
        "balancing".into(),
        "sa_balanced".into(),
        "worst_spread".into(),
        format!("{spread_bal:.4}"),
    ]);
    assert!(
        spread_bal <= spread_naive + 1e-9,
        "SA must not worsen the worst engine-load spread"
    );
    assert!(gain > 0.97, "SA balancing must not hurt throughput ({gain})");
}

/// §IV Buffering strategy: heuristic FIFO depths vs bare minimum.
///
/// Uses a pointwise (1×1) conv chain: 3×3 stages have a (k−1)-row line
/// buffer that already absorbs rate variance, so inter-layer FIFO depth
/// only binds on window-less consumers — exactly where PASS's
/// moving-window heuristic applies.
fn ablate_buffering(t: &mut Table) {
    use hass::arch::{LayerDesc, Network, Op};
    let mk = |i: usize| LayerDesc {
        name: format!("pw{i}"),
        op: Op::Conv { kernel: 1, stride: 1, pad: 0, cin: 64, cout: 64, groups: 1 },
        in_hw: 16,
        branch: false,
    };
    let net = Network {
        name: "pw-chain".into(),
        input_hw: 16,
        input_channels: 64,
        layers: (0..8).map(mk).collect(),
    };
    net.validate().unwrap();
    let n = net.compute_layers().len();
    let points = vec![hass::sparsity::SparsityPoint { s_w: 0.45, s_a: 0.45 }; n];
    let rm = ResourceModel::default();
    let dev = DeviceBudget { dsp: 512, ..DeviceBudget::u250() };
    let d = explore(&net, &points, &rm, &dev, &DseConfig::default());

    let mut tiny = stages_from_design(&net, &d.designs, &points, 0);
    for c in tiny.iter_mut() {
        c.fifo_capacity = c.design.o_par as u64; // bare minimum
    }
    let sizes = hass::simulator::buffer_sizes(&net, &d.designs, &points, 32, 5);
    let mut tuned = stages_from_design(&net, &d.designs, &points, 0);
    for (c, &s) in tuned.iter_mut().zip(&sizes) {
        c.fifo_capacity = s.max(c.design.o_par as u64);
    }
    let rep_tiny = simulate(&net, &tiny, 6, SparsityDynamics::Stochastic { seed: 2 });
    let rep_tuned = simulate(&net, &tuned, 6, SparsityDynamics::Stochastic { seed: 2 });
    eprintln!(
        "[ablations] buffering: minimal {:.3e} -> heuristic {:.3e} img/cyc (x{:.3}), depths {:?}...",
        rep_tiny.throughput,
        rep_tuned.throughput,
        rep_tuned.throughput / rep_tiny.throughput,
        &sizes[..4.min(sizes.len())]
    );
    t.row(vec![
        "buffering".into(),
        "minimal_fifo".into(),
        "img_per_cycle".into(),
        format!("{:.4e}", rep_tiny.throughput),
    ]);
    t.row(vec![
        "buffering".into(),
        "heuristic_fifo".into(),
        "img_per_cycle".into(),
        format!("{:.4e}", rep_tuned.throughput),
    ]);
    assert!(
        rep_tuned.throughput >= rep_tiny.throughput * 0.98,
        "buffering heuristic must not lose throughput"
    );
}

/// §III: per-layer thresholds preserve accuracy better than a uniform
/// threshold at the same network sparsity.
fn ablate_thresholds(t: &mut Table) {
    let net = networks::resnet18();
    let sp = synthesize(&net, 11);
    let n = sp.layers.len();
    let natural = sp.natural_points();
    // uniform THRESHOLD: one tau_w for all layers, chosen to land the
    // network at the same *weight* sparsity (0.6) as the per-layer plan —
    // the fair axis for the §III claim
    let wc: Vec<f64> = net.compute_layers().iter().map(|l| l.weight_count() as f64).collect();
    let wc_tot: f64 = wc.iter().sum();
    let mut lo = 0.0f64;
    let mut hi = 1.0f64;
    for _ in 0..50 {
        let mid = 0.5 * (lo + hi);
        let s: f64 = sp
            .layers
            .iter()
            .zip(&wc)
            .map(|(p, w)| p.weight_curve.sparsity_at(mid) * w)
            .sum::<f64>()
            / wc_tot;
        if s < 0.6 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let uni = PruningPlan::uniform(n, 0.5 * (lo + hi), 0.0);
    let uni_pts = uni.points(&sp);
    let uni_acc = pruning::surrogate_accuracy(69.75, &net, &uni_pts, &natural);
    let uni_m = pruning::metrics(&net, &uni_pts);

    // per-layer thresholds *searched* (§III + §V-B): TPE over per-layer
    // weight targets maximizing accuracy subject to the same total weight
    // sparsity.  The uniform plan is a point of this space, so the search
    // can only match or beat it.
    let mut tpe = hass::optim::TpeOptimizer::with_defaults(n, 17);
    let mut best_acc = f64::NEG_INFINITY;
    let mut best_sw = 0.0;
    for _ in 0..120 {
        let xs = tpe.ask();
        let mut x = vec![0.0; 2 * n];
        for i in 0..n {
            x[2 * i] = xs[i];
        }
        let plan = PruningPlan::from_unit_point(&x, &sp);
        let pts = plan.points(&sp);
        let acc = pruning::surrogate_accuracy(69.75, &net, &pts, &natural);
        let m = pruning::metrics(&net, &pts);
        let obj = acc - 200.0 * (0.6 - m.weight_sparsity).max(0.0);
        if m.weight_sparsity >= 0.598 && acc > best_acc {
            best_acc = acc;
            best_sw = m.weight_sparsity;
        }
        tpe.tell(xs, obj);
    }
    eprintln!(
        "[ablations] thresholds @ S_w=0.6: best uniform tau -> acc {uni_acc:.2} (S_w {:.3}); \
         searched per-layer -> acc {best_acc:.2} (S_w {best_sw:.3})",
        uni_m.weight_sparsity
    );
    t.row(vec![
        "thresholds".into(),
        "uniform_tau".into(),
        "accuracy".into(),
        format!("{uni_acc:.3}"),
    ]);
    t.row(vec![
        "thresholds".into(),
        "per_layer_searched".into(),
        "accuracy".into(),
        format!("{best_acc:.3}"),
    ]);
    t.row(vec![
        "thresholds".into(),
        "uniform_tau".into(),
        "weight_sparsity".into(),
        format!("{:.4}", uni_m.weight_sparsity),
    ]);
    t.row(vec![
        "thresholds".into(),
        "per_layer_searched".into(),
        "weight_sparsity".into(),
        format!("{best_sw:.4}"),
    ]);
    assert!(
        best_acc >= uni_acc - 0.25,
        "searched per-layer thresholds should match/beat uniform: {best_acc} vs {uni_acc}"
    );
}

/// §V-B: TPE vs random search on the actual Eq. 6 objective.
fn ablate_tpe(t: &mut Table, cache: &DesignCache) {
    let net = networks::calibnet();
    let sp = synthesize(&net, 5);
    let ev = SurrogateEvaluator { net: net.clone(), sparsity: sp, base_acc: 90.0 };
    let rm = ResourceModel::default();
    let dev = DeviceBudget { dsp: 768, ..DeviceBudget::u250() };
    let iters = 40;
    let mut tpe_best = 0.0;
    let mut rnd_best = 0.0;
    for seed in [1u64, 2, 3] {
        // TPE (warm start off: measure the optimizer, not the anchors)
        let cfg = SearchConfig {
            iterations: iters,
            mode: SearchMode::HardwareAware,
            seed,
            warm_start: false,
            ..Default::default()
        };
        let r = search_with_cache(&ev, &net, &rm, &dev, &cfg, cache);
        tpe_best += r.best_record().objective / 3.0;
        // random: same budget, same objective pipeline
        let n = ev.sparsity_model().layers.len();
        let mut rs = RandomSearch::new(2 * n, seed);
        let mut best = f64::NEG_INFINITY;
        let dense = explore(
            &net,
            &vec![hass::sparsity::SparsityPoint::DENSE; n],
            &rm,
            &dev,
            &cfg.dse,
        );
        let dense_ips = dense.images_per_sec(&dev);
        for _ in 0..iters {
            let x = rs.ask();
            let plan = PruningPlan::from_unit_point(&x, ev.sparsity_model());
            let e = ev.eval(&plan);
            let m = pruning::metrics(&net, &e.points);
            let d = explore(&net, &e.points, &rm, &dev, &cfg.dse);
            let raw = d.images_per_sec(&dev) / dense_ips;
            let obj = e.accuracy / 90.0
                + cfg.lambda[0] * m.avg_sparsity
                + cfg.lambda[1] * 2.0 * raw / (1.0 + raw)
                - cfg.lambda[2] * d.resources.dsp as f64 / dev.dsp as f64;
            best = best.max(obj);
        }
        rnd_best += best / 3.0;
    }
    eprintln!("[ablations] search: TPE best {tpe_best:.4} vs random best {rnd_best:.4}");
    t.row(vec![
        "search".into(),
        "tpe".into(),
        "best_objective".into(),
        format!("{tpe_best:.4}"),
    ]);
    t.row(vec![
        "search".into(),
        "random".into(),
        "best_objective".into(),
        format!("{rnd_best:.4}"),
    ]);
    assert!(tpe_best >= rnd_best - 0.02, "TPE {tpe_best} well below random {rnd_best}");
}
