//! Regenerates **Table II**: comparison with state-of-the-art sparse
//! DNN-FPGA accelerators across ResNet-18/50, MobileNetV2, MobileNetV3-S/L.
//!
//! Columns mirror the paper: accuracy, platform, DSPs, kLUTs, BRAM18k,
//! images/s, images/cycle/DSP.  Rows per network: Dense dataflow,
//! non-dataflow sparse ([6]-style, on its 7V690T), HPIPE-like [5],
//! PASS-like [4], and Ours (HASS search).  Absolute numbers come from our
//! calibrated models, not the authors' testbeds — the claim reproduced is
//! the *shape*: dataflow ≫ non-dataflow in throughput, sparse > dense in
//! efficiency, HASS > single-axis baselines (DESIGN.md §4).
//!
//! ResNet-50 exceeds a single U250 (408 Mb of 16-bit weights vs 360 Mb
//! URAM), so — like fpgaConvNet — it maps through §V-A.4 partitioning
//! with full reconfiguration; its row reports the folded pipeline.

use hass::arch::networks;
use hass::baselines::{self, MemoryModel};
use hass::coordinator::{search_with_cache, SearchConfig, SearchMode, SurrogateEvaluator};
use hass::dse::{explore, partition::partition, partition::DEFAULT_RECONFIG_SECS, DseConfig};
use hass::engine::{cache_file_from_args, save_cache_file};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::Table;
use hass::sparsity::synthesize;
use hass::util::rng::Rng;

/// Paper Table II dense top-1 accuracies (our surrogate base points).
fn base_acc(net: &str) -> f64 {
    match net {
        "resnet18" => 69.75,
        "resnet50" => 76.13,
        "mobilenet_v2" => 71.88,
        "mobilenet_v3_small" => 67.42,
        "mobilenet_v3_large" => 74.04,
        _ => 75.0,
    }
}

fn main() {
    let rm = ResourceModel::default();
    let u250 = DeviceBudget::u250();
    let v7 = DeviceBudget::v7_690t();
    let dse = DseConfig::default();
    let nets = ["resnet18", "resnet50", "mobilenet_v2", "mobilenet_v3_small", "mobilenet_v3_large"];

    let mut t = Table::new(&[
        "network", "work", "accuracy", "platform", "dsp", "klut", "bram18k", "images_per_s",
        "images_per_cycle_per_dsp",
    ]);
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 16 } else { 64 };
    // `--cache-file <path>`: warm design cache shared by the HASS search
    // of every network row (the multi-fingerprint cache keys per
    // network), saved back at exit so repeat sweeps run warm
    let (cache, cache_path) = cache_file_from_args("[table2]");

    for name in nets {
        let net = networks::by_name(name).unwrap();
        let sp = synthesize(&net, 1);
        let acc0 = base_acc(name);
        let single_device_fits = {
            let n = net.compute_layers().len();
            let minimal = vec![hass::hardware::LayerDesign::MINIMAL; n];
            u250.fits(&rm.network(&net, &minimal))
        };
        eprintln!("[table2] {name} (single-device: {single_device_fits}) ...");

        // when the network exceeds one U250 every dataflow design maps
        // through §V-A.4 partitioning — baselines included, for fairness
        let repartition = |b: &baselines::BaselineResult,
                           points: &[hass::sparsity::SparsityPoint],
                           seed: u64|
         -> baselines::BaselineResult {
            if single_device_fits {
                return b.clone();
            }
            let mut rng = Rng::new(seed);
            let part = partition(
                &net, points, &rm, &u250, &dse, 4_096, DEFAULT_RECONFIG_SECS, &mut rng,
            )
            .expect("partitioned mapping");
            let dsp = part.designs.iter().map(|d| d.resources.dsp).max().unwrap_or(0);
            let lut = part.designs.iter().map(|d| d.resources.lut).max().unwrap_or(0);
            let bram = part.designs.iter().map(|d| d.resources.bram18k).max().unwrap_or(0);
            baselines::BaselineResult {
                images_per_sec: part.images_per_sec,
                resources: hass::hardware::resources::Resources {
                    dsp,
                    lut,
                    bram18k: bram,
                    uram: 0,
                },
                efficiency: part.images_per_sec / u250.freq_hz() / dsp.max(1) as f64,
                ..b.clone()
            }
        };

        // ---- Dense dataflow -----------------------------------------
        let n_l = net.compute_layers().len();
        let dense_pts = vec![hass::sparsity::SparsityPoint::DENSE; n_l];
        let dense = repartition(
            &baselines::dense_dataflow(&net, acc0, &rm, &u250, &dse),
            &dense_pts,
            11,
        );
        push(&mut t, name, "dense", &dense, "u250");

        // ---- non-dataflow sparse ([6]-style, 7V690T) ------------------
        let nd = baselines::non_dataflow_sparse(
            &net, &sp, acc0, 0.5, 2_048, &MemoryModel::default(), &rm, &v7,
        );
        push(&mut t, name, "non-dataflow[6]", &nd, "7v690t");

        // ---- HPIPE-like (weight sparsity only) ------------------------
        let hp_pts: Vec<hass::sparsity::SparsityPoint> = {
            let mut x = vec![0.0; 2 * n_l];
            for i in 0..n_l {
                x[2 * i] = 0.6 / hass::pruning::MAX_SPARSITY;
            }
            hass::pruning::PruningPlan::from_unit_point(&x, &sp)
                .points(&sp)
                .iter()
                .map(|p| hass::sparsity::SparsityPoint { s_a: 0.0, ..*p })
                .collect()
        };
        let hp = repartition(
            &baselines::hpipe_like(&net, &sp, acc0, 0.6, &rm, &u250, &dse),
            &hp_pts,
            12,
        );
        push(&mut t, name, "hpipe[5]", &hp, "u250");

        // ---- PASS-like (activation sparsity only) ---------------------
        let pa_pts: Vec<hass::sparsity::SparsityPoint> = sp
            .natural_points()
            .into_iter()
            .map(|p| hass::sparsity::SparsityPoint { s_w: 0.0, ..p })
            .collect();
        let pa = repartition(
            &baselines::pass_like(&net, &sp, acc0, &rm, &u250, &dse),
            &pa_pts,
            13,
        );
        push(&mut t, name, "pass[4]", &pa, "u250");

        // ---- Ours: HASS ------------------------------------------------
        let ev = SurrogateEvaluator { net: net.clone(), sparsity: sp.clone(), base_acc: acc0 };
        let cfg = SearchConfig {
            iterations: iters,
            mode: SearchMode::HardwareAware,
            seed: 3,
            ..Default::default()
        };
        let r = search_with_cache(&ev, &net, &rm, &u250, &cfg, &cache);
        let b = r.best_record();
        let pts = hass::coordinator::Evaluate::eval(&ev, &b.plan).points;
        let ours = if single_device_fits {
            baselines::BaselineResult {
                name: "hass".into(),
                accuracy: b.accuracy,
                images_per_sec: b.images_per_sec,
                resources: explore(&net, &pts, &rm, &u250, &dse).resources,
                op_density: b.op_density,
                efficiency: b.efficiency,
            }
        } else {
            // partitioned mapping (ResNet-50 path)
            let mut rng = Rng::new(5);
            let part = partition(
                &net, &pts, &rm, &u250, &dse, 4_096, DEFAULT_RECONFIG_SECS, &mut rng,
            )
            .expect("partitioned mapping");
            let dsp = part.designs.iter().map(|d| d.resources.dsp).max().unwrap_or(0);
            let lut = part.designs.iter().map(|d| d.resources.lut).max().unwrap_or(0);
            let bram = part.designs.iter().map(|d| d.resources.bram18k).max().unwrap_or(0);
            baselines::BaselineResult {
                name: "hass".into(),
                accuracy: b.accuracy,
                images_per_sec: part.images_per_sec,
                resources: hass::hardware::resources::Resources {
                    dsp,
                    lut,
                    bram18k: bram,
                    uram: 0,
                },
                op_density: b.op_density,
                efficiency: part.images_per_sec / u250.freq_hz() / dsp.max(1) as f64,
            }
        };
        push(&mut t, name, "ours(HASS)", &ours, "u250");
    }

    print!("{}", t.to_markdown());
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    t.write_files(&dir, "table2").expect("write results");
    eprintln!("[table2] -> results/table2.{{csv,md}}");

    // sanity of the reproduced shape (who wins)
    // save before the shape checks: a failing run is exactly when the
    // diagnostic rerun wants its pricings back warm
    save_cache_file(&cache, &cache_path, "[table2]");
    check_shape(&t);
}

fn push(t: &mut Table, net: &str, work: &str, b: &baselines::BaselineResult, platform: &str) {
    t.row(vec![
        net.to_string(),
        work.to_string(),
        format!("{:.2}", b.accuracy),
        platform.to_string(),
        b.resources.dsp.to_string(),
        (b.resources.lut / 1000).to_string(),
        b.resources.bram18k.to_string(),
        format!("{:.0}", b.images_per_sec),
        format!("{:.3e}", b.efficiency),
    ]);
}

fn check_shape(t: &Table) {
    // for every network: ours(HASS) efficiency >= dense, and the dataflow
    // designs beat the non-dataflow one on throughput
    let mut by_net: std::collections::HashMap<String, Vec<&Vec<String>>> = Default::default();
    for r in &t.rows {
        by_net.entry(r[0].clone()).or_default().push(r);
    }
    for (net, rows) in by_net {
        let get = |work: &str, idx: usize| -> f64 {
            rows.iter()
                .find(|r| r[1] == work)
                .map(|r| r[idx].parse().unwrap_or(0.0))
                .unwrap_or(0.0)
        };
        let eff_ours = get("ours(HASS)", 8);
        let eff_dense = get("dense", 8);
        let thr_ours = get("ours(HASS)", 7);
        let thr_nd = get("non-dataflow[6]", 7);
        assert!(
            eff_ours > eff_dense,
            "{net}: HASS efficiency {eff_ours} !> dense {eff_dense}"
        );
        assert!(
            thr_ours > thr_nd,
            "{net}: dataflow throughput {thr_ours} !> non-dataflow {thr_nd}"
        );
    }
    eprintln!("[table2] shape checks passed (HASS > dense efficiency; dataflow > non-dataflow)");
}
