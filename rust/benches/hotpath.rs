//! §Perf harness: wall-clock measurements of every hot path in the L3
//! coordinator, plus the PJRT evaluation latency that dominates a
//! measured search iteration.  Criterion is unavailable offline, so this
//! is a manual steady-state timer (warmup + median of repeated runs).
//!
//! Targets (DESIGN.md §8):
//! * DSE of a ResNet-50-scale graph   < 100 ms
//! * frontier `explore` vs seed scan  ≥ 5x median speedup (bit-identical)
//! * simulator                        ≥ 10 M SPE-cycles/s
//! * search-iteration overhead (everything but PJRT) < 10 % of iteration
//!
//! Output: `results/hotpath.csv` + machine-readable
//! `results/BENCH_hotpath.json` (explore scan/frontier split, simulator
//! rate, TPE ask latency) so the perf trajectory is tracked across PRs.

use std::time::Instant;

use hass::arch::networks;
use hass::coordinator::{Engine, EngineConfig, SearchConfig, SurrogateEvaluator};
use hass::dse::{build_frontiers, explore, explore_scan, explore_with_frontiers, DseConfig};
use hass::engine::DesignCache;
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::Table;
use hass::optim::tpe::TpeOptimizer;
use hass::simulator::{simulate, stages_from_design, SparsityDynamics};
use hass::sparsity::{synthesize, SparsityPoint};

fn median_ms(mut f: impl FnMut(), reps: usize) -> f64 {
    f(); // warmup
    let mut xs: Vec<f64> = (0..reps)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    xs.sort_by(f64::total_cmp);
    xs[xs.len() / 2]
}

fn main() {
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let mut t = Table::new(&["path", "metric", "value", "target", "pass"]);

    // ---- DSE hot path -------------------------------------------------
    // ResNet-50 does not fit one U250 (URAM), which would short-circuit
    // the DSE; exercise its 54-layer graph on a two-device-class budget
    let big = DeviceBudget {
        name: "2xu250".into(),
        dsp: 24_576,
        lut: 3_456_000,
        bram18k: 10_752,
        uram: 2_560,
        freq_mhz: 250.0,
    };
    let mut dse_ms: Vec<(String, f64)> = Vec::new();
    for name in ["resnet18", "resnet50", "mobilenet_v2"] {
        let net = networks::by_name(name).unwrap();
        let n = net.compute_layers().len();
        let points = vec![SparsityPoint { s_w: 0.6, s_a: 0.4 }; n];
        let d = if name == "resnet50" { &big } else { &dev };
        let ms = median_ms(
            || {
                std::hint::black_box(explore(&net, &points, &rm, d, &DseConfig::default()));
            },
            9,
        );
        let pass = ms < 100.0;
        eprintln!("[hotpath] dse/{name}: {ms:.2} ms (target <100 ms) {}", ok(pass));
        t.row(vec![
            format!("dse/{name}"),
            "median_ms".into(),
            format!("{ms:.3}"),
            "<100".into(),
            pass.to_string(),
        ]);
        dse_ms.push((name.to_string(), ms));
    }

    // ---- explore: frontier kernel vs seed scan (ResNet-50 scale) ------
    let scan_ms: f64;
    let frontier_ms: f64;
    let build_ms: f64;
    let lookup_ms: f64;
    let explore_speedup: f64;
    {
        let net = networks::resnet50();
        let n = net.compute_layers().len();
        let points = vec![SparsityPoint { s_w: 0.6, s_a: 0.4 }; n];
        let cfg = DseConfig::default();
        // differential first: the two paths must agree bit for bit
        let a = explore(&net, &points, &rm, &big, &cfg);
        let b = explore_scan(&net, &points, &rm, &big, &cfg);
        assert_eq!(a.designs, b.designs, "frontier explore diverged from scan");
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
        assert_eq!(a.resources, b.resources);

        scan_ms = median_ms(
            || {
                std::hint::black_box(explore_scan(&net, &points, &rm, &big, &cfg));
            },
            9,
        );
        frontier_ms = median_ms(
            || {
                std::hint::black_box(explore(&net, &points, &rm, &big, &cfg));
            },
            9,
        );
        // build vs lookup split: one-time enumeration cost vs the cost of
        // a whole bisection run on prebuilt frontiers
        build_ms = median_ms(
            || {
                std::hint::black_box(build_frontiers(&net, &points, &rm, &big));
            },
            9,
        );
        let frontiers = build_frontiers(&net, &points, &rm, &big);
        lookup_ms = median_ms(
            || {
                std::hint::black_box(explore_with_frontiers(
                    &net, &points, &rm, &big, &cfg, &frontiers,
                ));
            },
            9,
        );
        explore_speedup = scan_ms / frontier_ms;
        let pass = explore_speedup >= 5.0;
        eprintln!(
            "[hotpath] explore/resnet50: scan {scan_ms:.2} ms vs frontier {frontier_ms:.2} ms \
             -> {explore_speedup:.1}x (build {build_ms:.2} ms + lookups {lookup_ms:.3} ms) {}",
            ok(pass)
        );
        t.row(vec![
            "explore/resnet50_scan".into(),
            "median_ms".into(),
            format!("{scan_ms:.3}"),
            "-".into(),
            "true".into(),
        ]);
        t.row(vec![
            "explore/resnet50_frontier".into(),
            "median_ms".into(),
            format!("{frontier_ms:.3}"),
            "-".into(),
            "true".into(),
        ]);
        t.row(vec![
            "explore/speedup_vs_scan".into(),
            "ratio".into(),
            format!("{explore_speedup:.3}"),
            ">=5".into(),
            pass.to_string(),
        ]);
    }

    // ---- simulator throughput ------------------------------------------
    let sim_eps: f64;
    {
        let net = networks::calibnet();
        let n = net.compute_layers().len();
        let points = vec![SparsityPoint { s_w: 0.4, s_a: 0.4 }; n];
        let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
        let cfgs = stages_from_design(&net, &d.designs, &points, rm.fifo_depth);
        // measure simulated *hardware work* per wall second: a highly
        // parallel design packs thousands of busy engines into each
        // pipeline cycle, so wall-cycles alone would under-credit the
        // simulator exactly when it simulates the most
        let mut engine_cycles = 0f64;
        let images = 8;
        let wall = median_ms(
            || {
                let rep = simulate(&net, &cfgs, images, SparsityDynamics::Stochastic { seed: 1 });
                engine_cycles = rep
                    .busy
                    .iter()
                    .zip(&d.designs)
                    .map(|(b, des)| b * rep.total_cycles as f64 * des.engines() as f64)
                    .sum();
            },
            5,
        );
        let eps = engine_cycles / (wall / 1e3);
        sim_eps = eps;
        let pass = eps > 10e6;
        eprintln!(
            "[hotpath] simulator: {:.1} M simulated SPE-cycles/s ({:.2e} SPE-cycles in {wall:.1} ms) {}",
            eps / 1e6,
            engine_cycles,
            ok(pass)
        );
        t.row(vec![
            "simulator".into(),
            "spe_cycles_per_sec".into(),
            format!("{eps:.3e}"),
            ">1e7".into(),
            pass.to_string(),
        ]);
    }

    // ---- TPE ask/tell ----------------------------------------------------
    let tpe_ask_ms: f64;
    {
        let dim = 42; // 2 x 21 layers (ResNet-18)
        let mut tpe = TpeOptimizer::with_defaults(dim, 1);
        // preload a realistic history
        for i in 0..96 {
            let x: Vec<f64> = (0..dim).map(|d| ((i * d + 7) % 100) as f64 / 100.0).collect();
            tpe.tell(x, -((i % 10) as f64));
        }
        let ms = median_ms(
            || {
                let x = tpe.ask();
                std::hint::black_box(&x);
            },
            20,
        );
        tpe_ask_ms = ms;
        let pass = ms < 10.0;
        eprintln!("[hotpath] tpe/ask(dim=42,96obs): {ms:.3} ms {}", ok(pass));
        t.row(vec![
            "tpe/ask".into(),
            "median_ms".into(),
            format!("{ms:.4}"),
            "<10".into(),
            pass.to_string(),
        ]);
    }

    // ---- cache persistence: cold search vs warm-from-disk ----------------
    let cache_cold_ms: f64;
    let cache_warm_ms: f64;
    let cache_speedup: f64;
    {
        let net = networks::calibnet();
        let ev = SurrogateEvaluator {
            net: net.clone(),
            sparsity: synthesize(&net, 3),
            base_acc: 85.0,
        };
        let cfg = SearchConfig {
            iterations: 24,
            seed: 1,
            engine: EngineConfig::batched(4),
            ..Default::default()
        };
        let eng = Engine::new(&ev, &net, &rm, &dev);
        // cold: a fresh cache per rep, every pricing paid from scratch
        cache_cold_ms = median_ms(
            || {
                let cache = DesignCache::new();
                std::hint::black_box(eng.search_with_cache(&cfg, &cache));
            },
            5,
        );
        // warm-from-disk: each rep loads the snapshot and repeats the
        // search — the timed path a sweep's second run actually takes
        let cache = DesignCache::new();
        let cold = eng.search_with_cache(&cfg, &cache);
        let snap = std::env::temp_dir().join("hass_hotpath_cache.json");
        cache.save(&snap).expect("write cache snapshot");
        let mut warm_misses = u64::MAX;
        let mut warm_identical = false;
        cache_warm_ms = median_ms(
            || {
                let (warm_cache, _) = DesignCache::load(&snap).expect("read cache snapshot");
                let warm = eng.search_with_cache(&cfg, &warm_cache);
                warm_misses = warm.stats.cache_misses;
                warm_identical = warm
                    .records
                    .iter()
                    .zip(&cold.records)
                    .all(|(a, b)| a.objective.to_bits() == b.objective.to_bits());
                std::hint::black_box(&warm);
            },
            5,
        );
        std::fs::remove_file(&snap).ok();
        assert_eq!(warm_misses, 0, "warm-from-disk repeat must not miss");
        assert!(warm_identical, "warm-from-disk journal diverged from cold");
        cache_speedup = cache_cold_ms / cache_warm_ms;
        let pass = cache_speedup >= 1.0;
        eprintln!(
            "[hotpath] cache/calibnet_search24: cold {cache_cold_ms:.2} ms vs warm-from-disk \
             {cache_warm_ms:.2} ms (load + search) -> {cache_speedup:.1}x, 0 misses {}",
            ok(pass)
        );
        t.row(vec![
            "cache/cold_search".into(),
            "median_ms".into(),
            format!("{cache_cold_ms:.3}"),
            "-".into(),
            "true".into(),
        ]);
        t.row(vec![
            "cache/warm_from_disk".into(),
            "median_ms".into(),
            format!("{cache_warm_ms:.3}"),
            "-".into(),
            "true".into(),
        ]);
        t.row(vec![
            "cache/warm_speedup".into(),
            "ratio".into(),
            format!("{cache_speedup:.3}"),
            ">=1".into(),
            pass.to_string(),
        ]);
    }

    // ---- PJRT evaluation + search-iteration overhead ---------------------
    if hass::runtime::available(&hass::runtime::default_dir()) {
        let rt = hass::runtime::ModelRuntime::load_default().expect("artifact");
        let l = rt.n_layers();
        let tau = vec![0.03; l];
        let eval_ms = median_ms(
            || {
                std::hint::black_box(rt.evaluate(&tau, &tau, 1).unwrap());
            },
            5,
        );
        // coordinator overhead: everything a measured iteration does
        // besides the PJRT evaluate (plan decode + DSE + objective)
        let net = networks::calibnet();
        let sp = rt.meta.measured_sparsity();
        let n = sp.layers.len();
        let points = vec![SparsityPoint { s_w: 0.5, s_a: 0.4 }; n];
        let overhead_ms = median_ms(
            || {
                let plan = hass::pruning::PruningPlan::from_unit_point(&vec![0.5; 2 * n], &sp);
                std::hint::black_box(&plan);
                let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
                std::hint::black_box(&d);
            },
            9,
        );
        let frac = overhead_ms / (overhead_ms + eval_ms);
        let pass = frac < 0.10;
        eprintln!(
            "[hotpath] pjrt/evaluate(64 imgs): {eval_ms:.1} ms; coordinator overhead {overhead_ms:.2} ms = {:.1}% of iteration {}",
            frac * 100.0,
            ok(pass)
        );
        t.row(vec![
            "pjrt/evaluate_batch64".into(),
            "median_ms".into(),
            format!("{eval_ms:.2}"),
            "-".into(),
            "true".into(),
        ]);
        t.row(vec![
            "search/overhead_fraction".into(),
            "fraction".into(),
            format!("{frac:.4}"),
            "<0.10".into(),
            pass.to_string(),
        ]);
    } else {
        eprintln!("[hotpath] artifacts missing: skipping PJRT timings");
    }

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    t.write_files(&dir, "hotpath").expect("write results");
    eprintln!("[hotpath] -> results/hotpath.csv");

    // ---- machine-readable summary for cross-PR perf tracking ------------
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"hotpath\",\n");
    json.push_str("  \"dse_ms\": {");
    for (i, (name, ms)) in dse_ms.iter().enumerate() {
        json.push_str(&format!(
            "\"{name}\": {ms:.3}{}",
            if i + 1 == dse_ms.len() { "" } else { ", " }
        ));
    }
    json.push_str("},\n");
    json.push_str(&format!(
        "  \"explore_resnet50\": {{\"scan_ms\": {scan_ms:.3}, \"frontier_ms\": {frontier_ms:.3}, \
         \"speedup\": {explore_speedup:.3}, \"frontier_build_ms\": {build_ms:.3}, \
         \"frontier_lookup_ms\": {lookup_ms:.3}, \"bit_identical\": true, \
         \"pass_5x\": {}}},\n",
        explore_speedup >= 5.0
    ));
    json.push_str(&format!("  \"simulator_spe_cycles_per_sec\": {sim_eps:.3e},\n"));
    json.push_str(&format!(
        "  \"cache_persistence\": {{\"cold_search_ms\": {cache_cold_ms:.3}, \
         \"warm_from_disk_ms\": {cache_warm_ms:.3}, \"speedup\": {cache_speedup:.3}, \
         \"warm_misses\": 0, \"bit_identical\": true}},\n"
    ));
    json.push_str(&format!("  \"tpe_ask_ms\": {tpe_ask_ms:.4}\n"));
    json.push_str("}\n");
    let path = dir.join("BENCH_hotpath.json");
    std::fs::write(&path, json).expect("write BENCH_hotpath.json");
    eprintln!("[hotpath] -> {}", path.display());
}

fn ok(b: bool) -> &'static str {
    if b {
        "[ok]"
    } else {
        "[MISS]"
    }
}
