//! §V-A sanity (extra experiment from DESIGN.md): the analytical model
//! (Eq. 1–3) against the cycle-level simulator over randomized layer
//! configurations and sparsity points.
//!
//! Deterministic dynamics must track the model within a few percent
//! (pipeline-fill effects only); stochastic dynamics quantify what
//! run-time sparsity variance costs without the paper's buffering.
//!
//! Output: `results/model_vs_sim.csv` (one row per random config).

use hass::arch::networks;
use hass::dse::{explore, DseConfig};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::Table;
use hass::simulator::{simulate, simulate_scan, stages_from_design, SparsityDynamics};
use hass::sparsity::SparsityPoint;
use hass::util::rng::Rng;

fn main() {
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let n = net.compute_layers().len();
    let quick = std::env::args().any(|a| a == "--quick");
    let cases = if quick { 6 } else { 20 };

    let mut rng = Rng::new(0xC0FFEE);
    let mut t = Table::new(&[
        "case", "s_w", "s_a", "dsp_budget", "model_thr", "sim_det_thr", "det_err_pct",
        "sim_sto_thr", "sto_gap_pct",
    ]);
    let mut max_det_err: f64 = 0.0;
    for case in 0..cases {
        let s_w = rng.range(0.0, 0.8);
        let s_a = rng.range(0.0, 0.7);
        let dsp_budget = 64 + rng.below(2_000) as u64;
        let dev = DeviceBudget {
            name: "rand".into(),
            dsp: dsp_budget,
            lut: 2_000_000,
            bram18k: 4_000,
            uram: 512,
            freq_mhz: 250.0,
        };
        // per-layer jitter around the uniform point
        let points: Vec<SparsityPoint> = (0..n)
            .map(|_| SparsityPoint {
                s_w: (s_w + 0.1 * rng.gauss()).clamp(0.0, 0.9),
                s_a: (s_a + 0.1 * rng.gauss()).clamp(0.0, 0.9),
            })
            .collect();
        let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
        let cfgs = stages_from_design(&net, &d.designs, &points, rm.fifo_depth);
        let det = simulate(&net, &cfgs, 4, SparsityDynamics::Deterministic);
        let sto = simulate(&net, &cfgs, 4, SparsityDynamics::Stochastic { seed: case as u64 });
        assert!(!det.deadlocked && !sto.deadlocked, "case {case} deadlocked");
        // differential gate: the event-driven core must reproduce the scan
        // reference bit for bit on every randomized case, both dynamics
        assert_eq!(
            det,
            simulate_scan(&net, &cfgs, 4, SparsityDynamics::Deterministic),
            "case {case}: event-driven sim diverged from the scan reference (det)"
        );
        assert_eq!(
            sto,
            simulate_scan(&net, &cfgs, 4, SparsityDynamics::Stochastic { seed: case as u64 }),
            "case {case}: event-driven sim diverged from the scan reference (stochastic)"
        );
        let det_err = (det.throughput / d.throughput - 1.0) * 100.0;
        let sto_gap = (sto.throughput / d.throughput - 1.0) * 100.0;
        max_det_err = max_det_err.max(det_err.abs());
        t.row(vec![
            case.to_string(),
            format!("{s_w:.3}"),
            format!("{s_a:.3}"),
            dsp_budget.to_string(),
            format!("{:.4e}", d.throughput),
            format!("{:.4e}", det.throughput),
            format!("{det_err:.2}"),
            format!("{:.4e}", sto.throughput),
            format!("{sto_gap:.2}"),
        ]);
        eprintln!(
            "[model_vs_sim] case {case}: model {:.3e}, det {:+.2}%, stochastic {:+.2}%",
            d.throughput, det_err, sto_gap
        );
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    t.write_files(&dir, "model_vs_sim").expect("write results");
    eprintln!(
        "[model_vs_sim] max |deterministic error| = {max_det_err:.2}% -> results/model_vs_sim.csv"
    );
    // --quick is the CI drift gate: a few percent of pipeline-fill effect
    // is expected, more means the analytic model and the simulator have
    // drifted apart.  The full sweep keeps the looser historical bound
    // (it visits harsher random geometries).
    let det_gate = if quick { 5.0 } else { 10.0 };
    assert!(
        max_det_err < det_gate,
        "analytical model deviates from the simulator by {max_det_err}% (gate {det_gate}%)"
    );
}
