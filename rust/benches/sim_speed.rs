//! Event-driven simulator speed: scan reference vs. event core vs. group
//! coalescing, plus the wall-clock cost of a fidelity-laddered search.
//!
//! Every timed pair is first asserted **bit-identical** (`SimReport`
//! equality) — the speedups below are never bought with drift.  The
//! deterministic coalesced core must clear >=10x over the scan on at
//! least one workload (the tentpole gate).
//!
//! Output: `results/sim_speed.csv` + machine-readable
//! `results/BENCH_sim_speed.json`.

use std::time::Instant;

use hass::arch::{networks, Network};
use hass::coordinator::{
    search, EngineConfig, SearchConfig, SimulatedEvaluator, SurrogateEvaluator,
};
use hass::dse::{explore, DseConfig};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::Table;
use hass::simulator::{simulate_events, simulate_scan, stages_from_design, SparsityDynamics};
use hass::sparsity::{synthesize, SparsityPoint};

fn median_ms(mut f: impl FnMut(), reps: usize) -> f64 {
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    times.sort_by(|a, b| a.total_cmp(b));
    times[times.len() / 2]
}

struct Workload {
    name: &'static str,
    net: Network,
    s_w: f64,
    s_a: f64,
    images: usize,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 3 } else { 5 };
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();

    let workloads = vec![
        Workload {
            name: "calibnet_dense",
            net: networks::calibnet(),
            s_w: 0.0,
            s_a: 0.0,
            images: if quick { 4 } else { 8 },
        },
        Workload {
            name: "calibnet_s05",
            net: networks::calibnet(),
            s_w: 0.5,
            s_a: 0.4,
            images: if quick { 8 } else { 16 },
        },
        Workload {
            name: "resnet18_s05",
            net: networks::resnet18(),
            s_w: 0.5,
            s_a: 0.4,
            images: if quick { 2 } else { 4 },
        },
    ];

    let mut t = Table::new(&["workload", "engine", "dynamics", "median_ms", "speedup_vs_scan"]);
    let mut json_rows: Vec<String> = Vec::new();
    let mut best_coalesced_speedup: f64 = 0.0;

    for w in &workloads {
        let n = w.net.compute_layers().len();
        let points = vec![SparsityPoint { s_w: w.s_w, s_a: w.s_a }; n];
        let d = explore(&w.net, &points, &rm, &dev, &DseConfig::default());
        let cfgs = stages_from_design(&w.net, &d.designs, &points, rm.fifo_depth);

        // --- deterministic: scan vs event vs coalesced ------------------
        let det = SparsityDynamics::Deterministic;
        let scan = simulate_scan(&w.net, &cfgs, w.images, det);
        let event = simulate_events(&w.net, &cfgs, w.images, det, false);
        let coal = simulate_events(&w.net, &cfgs, w.images, det, true);
        assert_eq!(scan, event, "{}: event core diverged from scan", w.name);
        assert_eq!(scan, coal, "{}: coalesced core diverged from scan", w.name);

        let scan_ms = median_ms(
            || {
                std::hint::black_box(simulate_scan(&w.net, &cfgs, w.images, det));
            },
            reps,
        );
        let event_ms = median_ms(
            || {
                std::hint::black_box(simulate_events(&w.net, &cfgs, w.images, det, false));
            },
            reps,
        );
        let coal_ms = median_ms(
            || {
                std::hint::black_box(simulate_events(&w.net, &cfgs, w.images, det, true));
            },
            reps,
        );
        let sp_event = scan_ms / event_ms.max(1e-6);
        let sp_coal = scan_ms / coal_ms.max(1e-6);
        best_coalesced_speedup = best_coalesced_speedup.max(sp_coal);

        // --- stochastic: scan vs event (coalescing is det-only) ---------
        let sto = SparsityDynamics::Stochastic { seed: 7 };
        let scan_sto = simulate_scan(&w.net, &cfgs, w.images, sto);
        let event_sto = simulate_events(&w.net, &cfgs, w.images, sto, true);
        assert_eq!(scan_sto, event_sto, "{}: stochastic event core diverged", w.name);
        let scan_sto_ms = median_ms(
            || {
                std::hint::black_box(simulate_scan(&w.net, &cfgs, w.images, sto));
            },
            reps,
        );
        let event_sto_ms = median_ms(
            || {
                std::hint::black_box(simulate_events(&w.net, &cfgs, w.images, sto, true));
            },
            reps,
        );
        let sp_sto = scan_sto_ms / event_sto_ms.max(1e-6);

        for (engine, dynamics, ms, sp) in [
            ("scan", "det", scan_ms, 1.0),
            ("event", "det", event_ms, sp_event),
            ("coalesced", "det", coal_ms, sp_coal),
            ("scan", "stochastic", scan_sto_ms, 1.0),
            ("event", "stochastic", event_sto_ms, sp_sto),
        ] {
            t.row(vec![
                w.name.into(),
                engine.into(),
                dynamics.into(),
                format!("{ms:.3}"),
                format!("{sp:.2}"),
            ]);
        }
        eprintln!(
            "[sim_speed] {} ({} images): scan {scan_ms:.2} ms | event {event_ms:.2} ms \
             ({sp_event:.1}x) | coalesced {coal_ms:.2} ms ({sp_coal:.1}x) | \
             stochastic event {sp_sto:.1}x",
            w.name, w.images
        );
        json_rows.push(format!(
            "    {{\"workload\": \"{}\", \"images\": {}, \"scan_ms\": {scan_ms:.3}, \
             \"event_ms\": {event_ms:.3}, \"coalesced_ms\": {coal_ms:.3}, \
             \"speedup_event\": {sp_event:.2}, \"speedup_coalesced\": {sp_coal:.2}, \
             \"scan_stochastic_ms\": {scan_sto_ms:.3}, \
             \"event_stochastic_ms\": {event_sto_ms:.3}, \
             \"speedup_stochastic\": {sp_sto:.2}, \"bit_identical\": true}}",
            w.name, w.images
        ));
    }

    // --- fidelity-laddered search wall time -----------------------------
    let net = networks::calibnet();
    let iters = if quick { 8 } else { 16 };
    let cfg = SearchConfig {
        iterations: iters,
        seed: 5,
        dse: DseConfig { max_iters: 1_500, ..Default::default() },
        engine: EngineConfig { batch: 4, threads: 0, cache: true, quant_bits: 12, async_eval: true },
        ..Default::default()
    };
    let surrogate = || SurrogateEvaluator {
        net: net.clone(),
        sparsity: synthesize(&net, 3),
        base_acc: 76.0,
    };
    let t0 = Instant::now();
    let base = search(&surrogate(), &net, &rm, &dev, &cfg);
    let base_ms = t0.elapsed().as_secs_f64() * 1e3;
    let ladder = SimulatedEvaluator {
        inner: Box::new(surrogate()),
        target: net.clone(),
        rm: rm.clone(),
        devices: vec![dev.clone()],
        dse: cfg.dse.clone(),
        top_k: 2,
        sim_images: 3,
    };
    let t0 = Instant::now();
    let lad = search(&ladder, &net, &rm, &dev, &cfg);
    let lad_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(lad.stats.sim_evals > 0, "laddered search must re-score some records");
    assert_eq!(base.records.len(), lad.records.len());
    let overhead = lad_ms / base_ms.max(1e-6);
    t.row(vec![
        "laddered_search".into(),
        "analytic".into(),
        "-".into(),
        format!("{base_ms:.1}"),
        "1.00".into(),
    ]);
    t.row(vec![
        "laddered_search".into(),
        "sim_top2".into(),
        "-".into(),
        format!("{lad_ms:.1}"),
        format!("{:.2}", 1.0 / overhead.max(1e-6)),
    ]);
    eprintln!(
        "[sim_speed] laddered search ({iters} iters): analytic {base_ms:.0} ms, \
         laddered {lad_ms:.0} ms ({overhead:.2}x) | {} sim-scored, {} promotions",
        lad.stats.sim_evals, lad.stats.sim_promotions
    );

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    t.write_files(&dir, "sim_speed").expect("write results");

    let pass_10x = best_coalesced_speedup >= 10.0;
    let mut json = String::from("{\n  \"bench\": \"sim_speed\",\n  \"workloads\": [\n");
    json.push_str(&json_rows.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str(&format!(
        "  \"laddered_search\": {{\"iters\": {iters}, \"analytic_ms\": {base_ms:.1}, \
         \"laddered_ms\": {lad_ms:.1}, \"overhead_x\": {overhead:.2}, \
         \"sim_evals\": {}, \"sim_promotions\": {}}},\n",
        lad.stats.sim_evals, lad.stats.sim_promotions
    ));
    json.push_str(&format!(
        "  \"best_coalesced_speedup\": {best_coalesced_speedup:.2},\n  \"pass_10x\": {pass_10x}\n}}\n"
    ));
    let path = dir.join("BENCH_sim_speed.json");
    std::fs::write(&path, json).expect("write BENCH_sim_speed.json");
    eprintln!(
        "[sim_speed] best coalesced speedup {best_coalesced_speedup:.1}x -> {}",
        path.display()
    );
    assert!(
        pass_10x,
        "coalesced event core must be >=10x over the scan on some workload \
         (best {best_coalesced_speedup:.1}x)"
    );
}
