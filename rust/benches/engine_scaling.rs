//! §Perf harness for the batched search engine: wall-clock of the full
//! search loop at generation sizes 1/2/4/8 with the design cache on and
//! off, against the serial seed path (batch 1, no cache, exact pricing).
//!
//! The engine's determinism contract says thread count and cache state
//! never change results; this bench exercises that end to end (cache
//! on/off at the same batch must agree bit-for-bit on the best objective)
//! while measuring what batching + memoization buy in wall time.
//!
//! Output: `results/engine_scaling.json` (+ a human-readable table on
//! stderr).  Run: `cargo bench --bench engine_scaling [-- --quick]`.

use std::time::Instant;

use hass::arch::networks;
use hass::coordinator::{search, EngineConfig, SearchConfig, SurrogateEvaluator};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::Table;
use hass::sparsity::synthesize;

struct Run {
    batch: usize,
    cache: bool,
    quant_bits: u32,
    wall_ms: f64,
    speedup: f64,
    cache_hits: u64,
    cache_misses: u64,
    best_objective: f64,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 12 } else { 32 };
    let seed = 1u64;

    let net = networks::resnet18();
    let ev = SurrogateEvaluator {
        net: net.clone(),
        sparsity: synthesize(&net, 1),
        base_acc: 69.75,
    };
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let run_once = |engine: EngineConfig| {
        let cfg = SearchConfig { iterations: iters, seed, engine, ..Default::default() };
        let t0 = Instant::now();
        let r = search(&ev, &net, &rm, &dev, &cfg);
        (t0.elapsed().as_secs_f64() * 1e3, r)
    };

    // serial seed path: one candidate at a time, every pricing from scratch
    let serial_cfg = EngineConfig { batch: 1, threads: 1, cache: false, quant_bits: 0 };
    run_once(serial_cfg); // warmup
    let (baseline_ms, baseline) = run_once(serial_cfg);
    eprintln!(
        "[engine_scaling] serial baseline: {iters} iters in {baseline_ms:.0} ms \
         (best objective {:.4}, {cores} cores available)",
        baseline.best_record().objective
    );

    let mut runs: Vec<Run> = Vec::new();
    for &batch in &[1usize, 2, 4, 8] {
        for &cache in &[false, true] {
            let engine = EngineConfig {
                batch,
                threads: 0, // auto: min(batch, cores)
                cache,
                quant_bits: 12,
            };
            let (wall_ms, r) = run_once(engine);
            eprintln!(
                "[engine_scaling] batch {batch} cache {}: {wall_ms:.0} ms \
                 ({:.2}x vs serial) | cache {} hit / {} miss",
                if cache { "on " } else { "off" },
                baseline_ms / wall_ms,
                r.stats.cache_hits,
                r.stats.cache_misses,
            );
            runs.push(Run {
                batch,
                cache,
                quant_bits: 12,
                wall_ms,
                speedup: baseline_ms / wall_ms,
                cache_hits: r.stats.cache_hits,
                cache_misses: r.stats.cache_misses,
                best_objective: r.best_record().objective,
            });
        }
    }

    // determinism spot-check: at the same batch + quantization, cache
    // on/off must agree bit-for-bit on the journal's best objective
    for pair in runs.chunks(2) {
        assert_eq!(
            pair[0].best_objective.to_bits(),
            pair[1].best_objective.to_bits(),
            "cache changed results at batch {}",
            pair[0].batch
        );
    }

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir).expect("results dir");

    // human-readable table
    let mut t = Table::new(&["batch", "cache", "wall_ms", "speedup_vs_serial", "hits", "misses"]);
    for r in &runs {
        t.row(vec![
            r.batch.to_string(),
            r.cache.to_string(),
            format!("{:.1}", r.wall_ms),
            format!("{:.2}", r.speedup),
            r.cache_hits.to_string(),
            r.cache_misses.to_string(),
        ]);
    }
    t.write_files(&dir, "engine_scaling").expect("write results");

    // JSON summary for the bench trajectory
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"network\": \"{}\",\n", net.name));
    json.push_str(&format!("  \"iterations\": {iters},\n"));
    json.push_str(&format!("  \"seed\": {seed},\n"));
    json.push_str(&format!("  \"cores\": {cores},\n"));
    json.push_str(&format!("  \"serial_baseline_ms\": {baseline_ms:.3},\n"));
    json.push_str("  \"runs\": [\n");
    for (i, r) in runs.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"batch\": {}, \"cache\": {}, \"quant_bits\": {}, \"wall_ms\": {:.3}, \
             \"speedup_vs_serial\": {:.3}, \"cache_hits\": {}, \"cache_misses\": {}, \
             \"best_objective\": {:.6}}}{}\n",
            r.batch,
            r.cache,
            r.quant_bits,
            r.wall_ms,
            r.speedup,
            r.cache_hits,
            r.cache_misses,
            r.best_objective,
            if i + 1 == runs.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = dir.join("engine_scaling.json");
    std::fs::write(&path, json).expect("write json");

    let k4 = runs
        .iter()
        .find(|r| r.batch == 4 && r.cache)
        .expect("k=4 cached run present");
    eprintln!(
        "[engine_scaling] batch 4 + cache: {:.2}x vs the serial seed path -> {}",
        k4.speedup,
        path.display()
    );
    if cores > 1 && k4.speedup < 1.5 {
        eprintln!(
            "[engine_scaling] WARNING: expected > 1.5x at batch 4 on a \
             multi-core host, measured {:.2}x",
            k4.speedup
        );
    }
}
