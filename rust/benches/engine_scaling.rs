//! §Perf harness for the batched search engine: wall-clock of the full
//! search loop at generation sizes 1/2/4/8 with the design cache on and
//! off, against the serial seed path (batch 1, no cache, exact pricing) —
//! plus a **slow-evaluator section** quantifying what the async
//! completion-queue pipeline buys when measurement latency dominates.
//!
//! The engine's determinism contract says thread count, cache state and
//! the generation pipeline (sync barrier vs. async completion queue)
//! never change results; this bench exercises that end to end (cache
//! on/off and sync/async at the same batch must agree bit-for-bit on the
//! best objective) while measuring what batching + memoization +
//! measurement/pricing overlap buy in wall time.
//!
//! The slow evaluator models the measured (PJRT) backend: each `eval`
//! serializes behind an internal mutex (like `MeasuredEvaluator`'s
//! runtime lock) and takes a fixed wall-clock delay.  Under the two-phase
//! barrier the pricing threads idle behind that lock for the whole
//! measurement phase; the async pipeline prices completed candidates
//! while the rest are still in flight, hiding (up to) the whole pricing
//! phase inside the measurement latency.
//!
//! Output: `results/engine_scaling.json` (+ a human-readable table on
//! stderr).  Run: `cargo bench --bench engine_scaling [-- --quick]`.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use hass::arch::networks;
use hass::coordinator::{search, EngineConfig, SearchConfig, SurrogateEvaluator};
use hass::engine::{CandidateEvaluator, EvalPoint};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::Table;
use hass::pruning::PruningPlan;
use hass::sparsity::{synthesize, NetworkSparsity};

struct Run {
    batch: usize,
    cache: bool,
    quant_bits: u32,
    wall_ms: f64,
    speedup: f64,
    cache_hits: u64,
    cache_misses: u64,
    best_objective: f64,
}

/// Surrogate wrapped in a measured-backend cost model: every `eval`
/// grabs a mutex (evaluations serialize, like PJRT's shared executable
/// handle) and sleeps `delay` of wall clock.
struct SlowEvaluator {
    inner: SurrogateEvaluator,
    delay: Duration,
    lock: Mutex<()>,
}

impl CandidateEvaluator for SlowEvaluator {
    fn sparsity_model(&self) -> &NetworkSparsity {
        self.inner.sparsity_model()
    }

    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        let _serialized = hass::util::lock_clean(&self.lock);
        std::thread::sleep(self.delay);
        self.inner.eval(plan)
    }

    fn base_accuracy(&self) -> f64 {
        self.inner.base_accuracy()
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 12 } else { 32 };
    let seed = 1u64;

    let net = networks::resnet18();
    let ev = SurrogateEvaluator {
        net: net.clone(),
        sparsity: synthesize(&net, 1),
        base_acc: 69.75,
    };
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let run_once = |engine: EngineConfig| {
        let cfg = SearchConfig { iterations: iters, seed, engine, ..Default::default() };
        let t0 = Instant::now();
        let r = search(&ev, &net, &rm, &dev, &cfg);
        (t0.elapsed().as_secs_f64() * 1e3, r)
    };

    // serial seed path: one candidate at a time, every pricing from scratch
    let serial_cfg = EngineConfig {
        batch: 1,
        threads: 1,
        cache: false,
        quant_bits: 0,
        async_eval: false,
    };
    run_once(serial_cfg); // warmup
    let (baseline_ms, baseline) = run_once(serial_cfg);
    eprintln!(
        "[engine_scaling] serial baseline: {iters} iters in {baseline_ms:.0} ms \
         (best objective {:.4}, {cores} cores available)",
        baseline.best_record().objective
    );

    let mut runs: Vec<Run> = Vec::new();
    for &batch in &[1usize, 2, 4, 8] {
        for &cache in &[false, true] {
            let engine = EngineConfig {
                batch,
                threads: 0, // auto: min(batch, cores)
                cache,
                quant_bits: 12,
                async_eval: false,
            };
            let (wall_ms, r) = run_once(engine);
            eprintln!(
                "[engine_scaling] batch {batch} cache {}: {wall_ms:.0} ms \
                 ({:.2}x vs serial) | cache {} hit / {} miss",
                if cache { "on " } else { "off" },
                baseline_ms / wall_ms,
                r.stats.cache_hits,
                r.stats.cache_misses,
            );
            runs.push(Run {
                batch,
                cache,
                quant_bits: 12,
                wall_ms,
                speedup: baseline_ms / wall_ms,
                cache_hits: r.stats.cache_hits,
                cache_misses: r.stats.cache_misses,
                best_objective: r.best_record().objective,
            });
        }
    }

    // determinism spot-check: at the same batch + quantization, cache
    // on/off must agree bit-for-bit on the journal's best objective
    for pair in runs.chunks(2) {
        assert_eq!(
            pair[0].best_objective.to_bits(),
            pair[1].best_objective.to_bits(),
            "cache changed results at batch {}",
            pair[0].batch
        );
    }

    // ---- slow-evaluator section: sync barrier vs. async pipeline -------
    // Measurement dominates (the measured-PJRT regime): under the barrier
    // every generation pays measure-all *then* price-all; the async
    // pipeline hides pricing inside the in-flight measurements.
    let slow_iters = if quick { 8 } else { 16 };
    let slow_batch = 8usize;
    let delay = Duration::from_millis(if quick { 10 } else { 25 });
    let slow_ev = SlowEvaluator {
        inner: SurrogateEvaluator {
            net: net.clone(),
            sparsity: synthesize(&net, 1),
            base_acc: 69.75,
        },
        delay,
        lock: Mutex::new(()),
    };
    let run_slow = |async_eval: bool| {
        let cfg = SearchConfig {
            iterations: slow_iters,
            seed,
            engine: EngineConfig {
                batch: slow_batch,
                threads: 0,
                cache: true,
                quant_bits: 0, // exact pricing: every candidate is a miss
                async_eval,
            },
            ..Default::default()
        };
        let t0 = Instant::now();
        let r = search(&slow_ev, &net, &rm, &dev, &cfg);
        (t0.elapsed().as_secs_f64() * 1e3, r)
    };
    let (sync_ms, sync_r) = run_slow(false);
    let (async_ms, async_r) = run_slow(true);
    assert_eq!(
        sync_r.best_record().objective.to_bits(),
        async_r.best_record().objective.to_bits(),
        "async pipeline changed results under the slow evaluator"
    );
    let overlap = async_r.stats.overlap_pricings;
    eprintln!(
        "[engine_scaling] slow evaluator ({} ms/eval, batch {slow_batch}, \
         {slow_iters} iters): sync barrier {sync_ms:.0} ms vs async pipeline \
         {async_ms:.0} ms ({:.2}x) | {overlap}/{} pricings overlapped \
         in-flight measurements, {} completions out of order",
        delay.as_millis(),
        sync_ms / async_ms,
        async_r.stats.evaluations,
        async_r.stats.ooo_completions,
    );

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir).expect("results dir");

    // human-readable table
    let mut t = Table::new(&["batch", "cache", "wall_ms", "speedup_vs_serial", "hits", "misses"]);
    for r in &runs {
        t.row(vec![
            r.batch.to_string(),
            r.cache.to_string(),
            format!("{:.1}", r.wall_ms),
            format!("{:.2}", r.speedup),
            r.cache_hits.to_string(),
            r.cache_misses.to_string(),
        ]);
    }
    t.write_files(&dir, "engine_scaling").expect("write results");

    // JSON summary for the bench trajectory
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"network\": \"{}\",\n", net.name));
    json.push_str(&format!("  \"iterations\": {iters},\n"));
    json.push_str(&format!("  \"seed\": {seed},\n"));
    json.push_str(&format!("  \"cores\": {cores},\n"));
    json.push_str(&format!("  \"serial_baseline_ms\": {baseline_ms:.3},\n"));
    json.push_str("  \"runs\": [\n");
    for (i, r) in runs.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"batch\": {}, \"cache\": {}, \"quant_bits\": {}, \"wall_ms\": {:.3}, \
             \"speedup_vs_serial\": {:.3}, \"cache_hits\": {}, \"cache_misses\": {}, \
             \"best_objective\": {:.6}}}{}\n",
            r.batch,
            r.cache,
            r.quant_bits,
            r.wall_ms,
            r.speedup,
            r.cache_hits,
            r.cache_misses,
            r.best_objective,
            if i + 1 == runs.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"slow_evaluator\": {\n");
    json.push_str(&format!("    \"delay_ms\": {},\n", delay.as_millis()));
    json.push_str(&format!("    \"iterations\": {slow_iters},\n"));
    json.push_str(&format!("    \"batch\": {slow_batch},\n"));
    json.push_str(&format!("    \"sync_wall_ms\": {sync_ms:.3},\n"));
    json.push_str(&format!("    \"async_wall_ms\": {async_ms:.3},\n"));
    json.push_str(&format!(
        "    \"async_speedup\": {:.3},\n",
        sync_ms / async_ms
    ));
    json.push_str(&format!("    \"overlap_pricings\": {overlap},\n"));
    json.push_str(&format!(
        "    \"ooo_completions\": {},\n",
        async_r.stats.ooo_completions
    ));
    json.push_str(&format!(
        "    \"best_objective_bits_match\": {}\n",
        sync_r.best_record().objective.to_bits() == async_r.best_record().objective.to_bits()
    ));
    json.push_str("  }\n}\n");
    let path = dir.join("engine_scaling.json");
    std::fs::write(&path, json).expect("write json");

    let k4 = runs
        .iter()
        .find(|r| r.batch == 4 && r.cache)
        .expect("k=4 cached run present");
    eprintln!(
        "[engine_scaling] batch 4 + cache: {:.2}x vs the serial seed path -> {}",
        k4.speedup,
        path.display()
    );
    if cores > 1 && k4.speedup < 1.5 {
        eprintln!(
            "[engine_scaling] WARNING: expected > 1.5x at batch 4 on a \
             multi-core host, measured {:.2}x",
            k4.speedup
        );
    }
}
