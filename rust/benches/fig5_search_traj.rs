//! Regenerates **Fig. 5**: hardware-aware vs software-metrics-only search
//! trajectories on ResNet-18 — computation efficiency (images/cycle/DSP,
//! running best) against iteration count, 96 TPE steps each, as in the
//! paper.
//!
//! The paper's shape: the hardware-aware curve starts slower (the Eq. 6
//! objective is harder) but overtakes and ends at a better computation
//! efficiency.  Output: `results/fig5_traj.csv` (iter, hw_aware, sw_only).

use hass::arch::networks;
use hass::coordinator::{
    search_with_cache, EngineConfig, SearchConfig, SearchMode, SurrogateEvaluator,
};
use hass::engine::{cache_file_from_args, save_cache_file};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::Table;
use hass::sparsity::synthesize;

fn main() {
    let net = networks::resnet18();
    let sp = synthesize(&net, 1);
    let rm = ResourceModel::default();
    // budget-bound device: on a full U250 efficiency tracks total
    // sparsity (which the software objective also maximizes); hardware-
    // awareness pays when the budget forces *placement* decisions —
    // sparsity in the pipeline-bottleneck layers vs anywhere
    let dev = DeviceBudget { dsp: 2_048, lut: 400_000, bram18k: 1_500, ..DeviceBudget::u250() };
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 24 } else { 96 };
    // one cache across both modes and all seeds: every search prices
    // identical points on one device, so repeat sweeps run warm
    let (cache, cache_path) = cache_file_from_args("[fig5]");

    let ev = SurrogateEvaluator { net: net.clone(), sparsity: sp, base_acc: 69.75 };
    // several seeds, averaged — single-seed trajectories are noisy
    let seeds: &[u64] = if quick { &[1, 2] } else { &[1, 2, 3, 4, 5] };
    let mut hw_avg = vec![0.0f64; iters];
    let mut sw_avg = vec![0.0f64; iters];
    for &seed in seeds {
        for (mode, avg) in [
            (SearchMode::HardwareAware, &mut hw_avg),
            (SearchMode::SoftwareOnly, &mut sw_avg),
        ] {
            // no warm-start anchors: Fig. 5 measures the *objective*
            // difference between the two searches, not the anchoring.
            // 4-candidate generations evaluated in parallel with memoized
            // pricings.  Note batching IS algorithmic (frozen-model
            // generations after TPE startup, 2^-12 pricing grid), so the
            // curves are the batched engine's trajectories, not the seed's
            // serial ones — the hw-vs-sw comparison itself is unaffected
            // because both arms run the identical configuration.
            let cfg = SearchConfig {
                iterations: iters,
                mode,
                seed,
                warm_start: false,
                engine: EngineConfig::batched(4),
                ..Default::default()
            };
            let r = search_with_cache(&ev, &net, &rm, &dev, &cfg, &cache);
            for (a, v) in avg.iter_mut().zip(r.efficiency_trajectory()) {
                *a += v / seeds.len() as f64;
            }
            eprintln!("[fig5] {mode:?} seed {seed} done");
        }
    }

    let mut t = Table::new(&["iter", "hw_aware_eff", "sw_only_eff"]);
    for i in 0..iters {
        t.row(vec![
            i.to_string(),
            format!("{:.4e}", hw_avg[i]),
            format!("{:.4e}", sw_avg[i]),
        ]);
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    t.write_files(&dir, "fig5_traj").expect("write results");
    eprintln!(
        "[fig5] final efficiency: hw-aware {:.3e} vs sw-only {:.3e} ({:+.0}%) -> results/fig5_traj.csv",
        hw_avg[iters - 1],
        sw_avg[iters - 1],
        (hw_avg[iters - 1] / sw_avg[iters - 1] - 1.0) * 100.0
    );
    // save before the shape assert: a failing run is exactly when the
    // diagnostic rerun wants its pricings back warm
    save_cache_file(&cache, &cache_path, "[fig5]");
    assert!(
        hw_avg[iters - 1] >= sw_avg[iters - 1],
        "hardware-aware search must end at better efficiency (Fig. 5)"
    );
}
