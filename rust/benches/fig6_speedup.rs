//! Regenerates **Fig. 6**: speedup of the sparse dataflow architecture
//! over the dense implementation, per model (both weights *and*
//! activations sparsity exploited, as HASS does).
//!
//! Output: `results/fig6_speedup.csv` (network, dense_ips, sparse_ips,
//! speedup, dense_eff, sparse_eff, eff_gain).

use hass::arch::networks;
use hass::baselines;
use hass::coordinator::{search, SearchConfig, SearchMode, SurrogateEvaluator};
use hass::dse::DseConfig;
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::Table;
use hass::sparsity::synthesize;

fn main() {
    let rm = ResourceModel::default();
    // a budget-capped device makes throughput the discriminator (on a
    // full U250 the small models saturate their spatial parallelism cap
    // in both dense and sparse forms, which is the paper's MBv3
    // observation: "throughput remains similar, fewer DSPs used")
    let dev = DeviceBudget { dsp: 3_072, lut: 850_000, ..DeviceBudget::u250() };
    let dse = DseConfig::default();
    let quick = std::env::args().any(|a| a == "--quick");
    let nets = ["resnet18", "mobilenet_v2", "mobilenet_v3_small", "mobilenet_v3_large"];

    let mut t = Table::new(&[
        "network", "dense_ips", "sparse_ips", "speedup", "dense_eff", "sparse_eff", "eff_gain",
    ]);
    for name in nets {
        let net = networks::by_name(name).unwrap();
        let sp = synthesize(&net, 1);
        let dense = baselines::dense_dataflow(&net, 75.0, &rm, &dev, &dse);
        let ev = SurrogateEvaluator { net: net.clone(), sparsity: sp, base_acc: 75.0 };
        let cfg = SearchConfig {
            iterations: if quick { 16 } else { 48 },
            mode: SearchMode::HardwareAware,
            seed: 2,
            ..Default::default()
        };
        let r = search(&ev, &net, &rm, &dev, &cfg);
        let b = r.best_record();
        let speedup = b.images_per_sec / dense.images_per_sec;
        let eff_gain = b.efficiency / dense.efficiency;
        eprintln!(
            "[fig6] {name}: dense {:.0} -> sparse {:.0} img/s ({speedup:.2}x), eff x{eff_gain:.2}",
            dense.images_per_sec, b.images_per_sec
        );
        t.row(vec![
            name.to_string(),
            format!("{:.0}", dense.images_per_sec),
            format!("{:.0}", b.images_per_sec),
            format!("{:.3}", speedup),
            format!("{:.3e}", dense.efficiency),
            format!("{:.3e}", b.efficiency),
            format!("{:.3}", eff_gain),
        ]);
        // Fig. 6 shape: sparse never loses, and wins clearly somewhere
        assert!(speedup > 0.95, "{name}: sparse slower than dense ({speedup})");
    }
    let any_big = t.rows.iter().any(|r| r[3].parse::<f64>().unwrap() > 1.5);
    assert!(any_big, "no model shows a clear sparse speedup");

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    t.write_files(&dir, "fig6_speedup").expect("write results");
    eprintln!("[fig6] -> results/fig6_speedup.csv");
}
