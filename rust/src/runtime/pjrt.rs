//! The real PJRT-backed [`ModelRuntime`] (build feature `pjrt`).
//!
//! Compiles `model.hlo.txt` on the PJRT CPU client (`xla` crate), keeps
//! the weights resident as literals, and serves batched
//! `(accuracy, S_w, S_a, pair-density)` evaluations to the search loop.
//! Thresholds are *runtime inputs* of the artifact, so every TPE iteration
//! reuses one compiled executable — no recompilation, no Python.
//!
//! The HLO interchange is **text** (`HloModuleProto::from_text_file`): the
//! image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit
//! instruction ids); the text parser reassigns ids (see aot_recipe.md).

use std::path::Path;

use anyhow::{Context, Result};

use super::artifacts::{default_dir, CalibData, Meta, Weights};
use super::{EvalResult, InferOutput};

/// The compiled model + resident weights + calibration data.
pub struct ModelRuntime {
    pub meta: Meta,
    pub data: CalibData,
    exe: xla::PjRtLoadedExecutable,
    /// interleaved (w, b) literals in artifact order, resident across calls
    weight_literals: Vec<xla::Literal>,
}

// SAFETY: the PJRT C API is documented thread-compatible — client,
// executable and literal handles are not thread-affine, they just must not
// be used concurrently.  The `xla` bindings simply never declare auto
// traits for their raw-pointer wrappers.  `Send` (move/borrow from one
// thread at a time) is therefore sound; concurrent use is prevented by
// callers holding the runtime in a `Mutex` (see
// `coordinator::MeasuredEvaluator`), which the compiler enforces because
// this type is deliberately NOT `Sync`.
unsafe impl Send for ModelRuntime {}

pub(crate) fn f32_literal(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape {:?} vs {} values", dims, data.len());
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

impl ModelRuntime {
    /// Load everything from an artifact directory (see `make artifacts`).
    pub fn load(dir: &Path) -> Result<ModelRuntime> {
        let meta = Meta::load(dir).map_err(anyhow::Error::msg)?;
        let weights = Weights::load(dir, &meta).map_err(anyhow::Error::msg)?;
        let data = CalibData::load(dir, &meta).map_err(anyhow::Error::msg)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            dir.join("model.hlo.txt").to_str().unwrap(),
        )
        .context("parse model.hlo.txt")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile model")?;
        let mut weight_literals = Vec::with_capacity(meta.layers.len() * 2);
        for (l, (w, b)) in meta.layers.iter().zip(&weights.params) {
            weight_literals.push(f32_literal(&l.weight_shape, w)?);
            weight_literals.push(f32_literal(&[l.b_size], b)?);
        }
        Ok(ModelRuntime { meta, data, exe, weight_literals })
    }

    /// Load from the default artifact directory.
    pub fn load_default() -> Result<ModelRuntime> {
        Self::load(&default_dir())
    }

    /// Number of compute layers (threshold vector length).
    pub fn n_layers(&self) -> usize {
        self.meta.num_layers
    }

    /// Run one batch (must be exactly `meta.export_batch` images).
    pub fn infer(&self, images: &[f32], tau_w: &[f64], tau_a: &[f64]) -> Result<InferOutput> {
        let m = &self.meta;
        let img_dims = [m.export_batch, m.img_size, m.img_size, m.img_channels];
        anyhow::ensure!(
            images.len() == img_dims.iter().product::<usize>(),
            "batch must be exactly export_batch={}",
            m.export_batch
        );
        anyhow::ensure!(tau_w.len() == m.num_layers && tau_a.len() == m.num_layers);
        let img_lit = f32_literal(&img_dims, images)?;
        let tw: Vec<f32> = tau_w.iter().map(|&v| v as f32).collect();
        let ta: Vec<f32> = tau_a.iter().map(|&v| v as f32).collect();
        let tw_lit = f32_literal(&[m.num_layers], &tw)?;
        let ta_lit = f32_literal(&[m.num_layers], &ta)?;

        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 + self.weight_literals.len());
        args.push(&img_lit);
        for w in &self.weight_literals {
            args.push(w);
        }
        args.push(&tw_lit);
        args.push(&ta_lit);

        let result = self.exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (logits, s_w, s_a, dens) = result.to_tuple4()?;
        Ok(InferOutput {
            logits: logits.to_vec::<f32>()?,
            s_w: s_w.to_vec::<f32>()?,
            s_a: s_a.to_vec::<f32>()?,
            pair_density: dens.to_vec::<f32>()?,
        })
    }

    /// Top-1 accuracy of a logits block against labels.
    pub fn accuracy(&self, logits: &[f32], labels: &[i32]) -> f64 {
        super::top1_accuracy(logits, labels, self.meta.num_classes)
    }

    /// Evaluate thresholds over `n_batches` calibration batches — the
    /// search loop's inner measurement (accuracy + measured sparsity).
    pub fn evaluate(&self, tau_w: &[f64], tau_a: &[f64], n_batches: usize) -> Result<EvalResult> {
        let batch = self.meta.export_batch;
        let avail = self.data.n_batches(batch);
        let n_batches = n_batches.min(avail).max(1);
        let l = self.meta.num_layers;
        let mut s_w = vec![0.0f64; l];
        let mut s_a = vec![0.0f64; l];
        let mut dens = vec![0.0f64; l];
        let mut hits = 0.0f64;
        let mut total = 0usize;
        for b in 0..n_batches {
            let (imgs, labels) = self.data.batch(b, batch);
            let out = self.infer(imgs, tau_w, tau_a)?;
            hits += self.accuracy(&out.logits, labels) * labels.len() as f64;
            total += labels.len();
            for i in 0..l {
                s_w[i] += out.s_w[i] as f64;
                s_a[i] += out.s_a[i] as f64;
                dens[i] += out.pair_density[i] as f64;
            }
        }
        let k = n_batches as f64;
        for i in 0..l {
            s_w[i] /= k;
            s_a[i] /= k;
            dens[i] /= k;
        }
        Ok(EvalResult {
            accuracy: hits / total as f64,
            s_w,
            s_a,
            pair_density: dens,
            images: total,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::artifacts::available;
    use super::*;

    fn runtime() -> Option<ModelRuntime> {
        let dir = default_dir();
        if !available(&dir) {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(ModelRuntime::load(&dir).expect("runtime load"))
    }

    #[test]
    fn loads_and_matches_golden_accuracy() {
        let Some(rt) = runtime() else { return };
        let l = rt.n_layers();
        let out = rt.evaluate(&vec![0.0; l], &vec![0.0; l], 1).unwrap();
        let want = rt.meta.golden.acc_tau0;
        assert!(
            (out.accuracy - want).abs() < 1e-6,
            "batch-0 accuracy {} vs golden {want}",
            out.accuracy
        );
    }

    #[test]
    fn golden_logits_match_python() {
        let Some(rt) = runtime() else { return };
        let l = rt.n_layers();
        let tau = vec![rt.meta.golden.tau_ref; l];
        let (imgs, _) = rt.data.batch(0, rt.meta.export_batch);
        let out = rt.infer(imgs, &tau, &tau).unwrap();
        for (i, &want) in rt.meta.golden.logits_first8_tau_ref.iter().enumerate() {
            let got = out.logits[i] as f64;
            assert!(
                (got - want).abs() < 1e-3 * want.abs().max(1.0),
                "logit {i}: rust {got} vs python {want}"
            );
        }
    }

    #[test]
    fn golden_sparsity_counters_match_python() {
        let Some(rt) = runtime() else { return };
        let l = rt.n_layers();
        let tau = vec![rt.meta.golden.tau_ref; l];
        let (imgs, _) = rt.data.batch(0, rt.meta.export_batch);
        let out = rt.infer(imgs, &tau, &tau).unwrap();
        for i in 0..l {
            let sw = out.s_w[i] as f64;
            let sa = out.s_a[i] as f64;
            let pd = out.pair_density[i] as f64;
            assert!((sw - rt.meta.golden.s_w_tau_ref[i]).abs() < 1e-5, "s_w[{i}]");
            assert!((sa - rt.meta.golden.s_a_tau_ref[i]).abs() < 1e-5, "s_a[{i}]");
            assert!((pd - rt.meta.golden.pair_density_tau_ref[i]).abs() < 1e-5, "pd[{i}]");
        }
    }

    #[test]
    fn thresholds_increase_sparsity_and_reduce_density() {
        let Some(rt) = runtime() else { return };
        let l = rt.n_layers();
        let lo = rt.evaluate(&vec![0.0; l], &vec![0.0; l], 1).unwrap();
        let hi = rt.evaluate(&vec![0.1; l], &vec![0.1; l], 1).unwrap();
        for i in 0..l {
            assert!(hi.s_w[i] >= lo.s_w[i] - 1e-9, "layer {i}");
            assert!(hi.pair_density[i] <= lo.pair_density[i] + 1e-9, "layer {i}");
        }
    }

    #[test]
    fn extreme_pruning_destroys_accuracy() {
        let Some(rt) = runtime() else { return };
        let l = rt.n_layers();
        let big = rt.evaluate(&vec![10.0; l], &vec![10.0; l], 1).unwrap();
        assert!(big.accuracy < 0.4, "pruning everything kept acc {}", big.accuracy);
        // everything below threshold: density collapses
        assert!(big.pair_density.iter().all(|&d| d < 0.05));
    }

    #[test]
    fn measured_transfer_curve_predicts_measured_sparsity() {
        // the meta.json quantile curves must agree with what the compiled
        // model actually measures — this ties the sparsity substrate to
        // the PJRT path
        let Some(rt) = runtime() else { return };
        let sp = rt.meta.measured_sparsity();
        let l = rt.n_layers();
        let tau = 0.05;
        let out = rt.evaluate(&vec![tau; l], &vec![0.0; l], 1).unwrap();
        for i in 0..l {
            let predicted = sp.layers[i].weight_curve.sparsity_at(tau);
            let measured = out.s_w[i];
            assert!(
                (predicted - measured).abs() < 0.06,
                "layer {i}: curve {predicted} vs measured {measured}"
            );
        }
    }
}
