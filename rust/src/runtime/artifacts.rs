//! Artifact loaders: `meta.json`, `weights.bin`, calibration data.
//!
//! These are the build-time outputs of `python/compile/aot.py`; the Rust
//! side never talks to Python — it reads these files and the HLO text.

use std::path::{Path, PathBuf};

use crate::sparsity::{LayerProfile, NetworkSparsity, TransferCurve};
use crate::util::json::Json;

/// One compute layer as described by the artifact metadata.
#[derive(Clone, Debug)]
pub struct LayerMeta {
    pub name: String,
    pub kind: String,
    pub kernel: usize,
    pub stride: usize,
    pub cin: usize,
    pub cout: usize,
    pub in_hw: usize,
    pub out_hw: usize,
    pub patch_k: usize,
    pub macs_per_image: u64,
    pub weight_shape: Vec<usize>,
    pub w_offset: usize,
    pub w_size: usize,
    pub b_offset: usize,
    pub b_size: usize,
}

/// Golden outputs recorded at export time (Rust↔Python integration tests).
#[derive(Clone, Debug)]
pub struct Golden {
    pub batch: usize,
    pub tau_ref: f64,
    pub logits_sum_tau0: f64,
    pub acc_tau0: f64,
    pub s_w_tau_ref: Vec<f64>,
    pub s_a_tau_ref: Vec<f64>,
    pub pair_density_tau_ref: Vec<f64>,
    pub pair_density_tau0: Vec<f64>,
    pub logits_first8_tau_ref: Vec<f64>,
}

/// Parsed `meta.json`.
#[derive(Clone, Debug)]
pub struct Meta {
    pub model: String,
    pub export_batch: usize,
    pub num_layers: usize,
    pub num_classes: usize,
    pub img_size: usize,
    pub img_channels: usize,
    pub fxp_scale: f64,
    pub dense_val_accuracy: f64,
    pub n_calib: usize,
    pub quantile_pts: Vec<f64>,
    pub weight_abs_quantiles: Vec<Vec<f64>>,
    pub act_abs_quantiles: Vec<Vec<f64>>,
    pub layers: Vec<LayerMeta>,
    pub golden: Golden,
}

fn f64s(j: &Json) -> Vec<f64> {
    j.as_f64_vec().expect("number array")
}

impl Meta {
    pub fn load(dir: &Path) -> Result<Meta, String> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .map_err(|e| format!("meta.json: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("meta.json: {e:?}"))?;
        let layers = j
            .req("layers")
            .as_arr()
            .expect("layers array")
            .iter()
            .map(|l| LayerMeta {
                name: l.req("name").as_str().unwrap().to_string(),
                kind: l.req("kind").as_str().unwrap().to_string(),
                kernel: l.req("kernel").as_usize().unwrap(),
                stride: l.req("stride").as_usize().unwrap(),
                cin: l.req("cin").as_usize().unwrap(),
                cout: l.req("cout").as_usize().unwrap(),
                in_hw: l.req("in_hw").as_usize().unwrap(),
                out_hw: l.req("out_hw").as_usize().unwrap(),
                patch_k: l.req("patch_k").as_usize().unwrap(),
                macs_per_image: l.req("macs_per_image").as_f64().unwrap() as u64,
                weight_shape: l
                    .req("weight_shape")
                    .as_f64_vec()
                    .unwrap()
                    .iter()
                    .map(|&v| v as usize)
                    .collect(),
                w_offset: l.req("w_offset").as_usize().unwrap(),
                w_size: l.req("w_size").as_usize().unwrap(),
                b_offset: l.req("b_offset").as_usize().unwrap(),
                b_size: l.req("b_size").as_usize().unwrap(),
            })
            .collect();
        let g = j.req("golden");
        let golden = Golden {
            batch: g.req("batch").as_usize().unwrap(),
            tau_ref: g.req("tau_ref").as_f64().unwrap(),
            logits_sum_tau0: g.req("logits_sum_tau0").as_f64().unwrap(),
            acc_tau0: g.req("acc_tau0").as_f64().unwrap(),
            s_w_tau_ref: f64s(g.req("s_w_tau_ref")),
            s_a_tau_ref: f64s(g.req("s_a_tau_ref")),
            pair_density_tau_ref: f64s(g.req("pair_density_tau_ref")),
            pair_density_tau0: f64s(g.req("pair_density_tau0")),
            logits_first8_tau_ref: f64s(g.req("logits_first8_tau_ref")),
        };
        Ok(Meta {
            model: j.req("model").as_str().unwrap().to_string(),
            export_batch: j.req("export_batch").as_usize().unwrap(),
            num_layers: j.req("num_layers").as_usize().unwrap(),
            num_classes: j.req("num_classes").as_usize().unwrap(),
            img_size: j.req("img_size").as_usize().unwrap(),
            img_channels: j.req("img_channels").as_usize().unwrap(),
            fxp_scale: j.req("fxp_scale").as_f64().unwrap(),
            dense_val_accuracy: j.req("dense_val_accuracy").as_f64().unwrap(),
            n_calib: j.req("n_calib").as_usize().unwrap(),
            quantile_pts: f64s(j.req("quantile_pts")),
            weight_abs_quantiles: j
                .req("weight_abs_quantiles")
                .as_arr()
                .unwrap()
                .iter()
                .map(f64s)
                .collect(),
            act_abs_quantiles: j
                .req("act_abs_quantiles")
                .as_arr()
                .unwrap()
                .iter()
                .map(f64s)
                .collect(),
            layers,
            golden,
        })
    }

    /// The *measured* sparsity model of the calibration network: transfer
    /// curves straight from the artifact's |w|/|a| quantile tables.
    pub fn measured_sparsity(&self) -> NetworkSparsity {
        let layers = self
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| LayerProfile {
                name: l.name.clone(),
                weight_curve: TransferCurve::from_quantiles(
                    &self.quantile_pts,
                    &self.weight_abs_quantiles[i],
                ),
                act_curve: TransferCurve::from_quantiles(
                    &self.quantile_pts,
                    &self.act_abs_quantiles[i],
                ),
                channel_imbalance: vec![1.0; l.cin.min(64)],
            })
            .collect();
        NetworkSparsity { network: self.model.clone(), layers }
    }
}

/// Raw f32 LE file reader.
pub fn read_f32s(path: &Path) -> Result<Vec<f32>, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
    if bytes.len() % 4 != 0 {
        return Err(format!("{}: not a multiple of 4 bytes", path.display()));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Raw i32 LE file reader.
pub fn read_i32s(path: &Path) -> Result<Vec<i32>, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
    if bytes.len() % 4 != 0 {
        return Err(format!("{}: not a multiple of 4 bytes", path.display()));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Network parameters sliced out of `weights.bin`.
#[derive(Clone, Debug)]
pub struct Weights {
    /// per-layer (weight tensor, bias vector) in artifact order
    pub params: Vec<(Vec<f32>, Vec<f32>)>,
}

impl Weights {
    pub fn load(dir: &Path, meta: &Meta) -> Result<Weights, String> {
        let flat = read_f32s(&dir.join("weights.bin"))?;
        let mut params = Vec::with_capacity(meta.layers.len());
        for l in &meta.layers {
            let w = flat
                .get(l.w_offset..l.w_offset + l.w_size)
                .ok_or_else(|| format!("weights.bin too short for {}", l.name))?
                .to_vec();
            let b = flat
                .get(l.b_offset..l.b_offset + l.b_size)
                .ok_or_else(|| format!("weights.bin too short for {} bias", l.name))?
                .to_vec();
            params.push((w, b));
        }
        Ok(Weights { params })
    }
}

/// Calibration/validation dataset (NHWC f32 images + i32 labels).
#[derive(Clone, Debug)]
pub struct CalibData {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub img_elems: usize,
}

impl CalibData {
    pub fn load(dir: &Path, meta: &Meta) -> Result<CalibData, String> {
        let images = read_f32s(&dir.join("calib_images.bin"))?;
        let labels = read_i32s(&dir.join("calib_labels.bin"))?;
        let img_elems = meta.img_size * meta.img_size * meta.img_channels;
        if images.len() != labels.len() * img_elems {
            return Err(format!(
                "calib data mismatch: {} pixels vs {} labels x {img_elems}",
                images.len(),
                labels.len()
            ));
        }
        Ok(CalibData { n: labels.len(), images, labels, img_elems })
    }

    /// Borrow batch `b` of size `batch` (images slice, labels slice).
    pub fn batch(&self, b: usize, batch: usize) -> (&[f32], &[i32]) {
        let lo = b * batch;
        let hi = ((b + 1) * batch).min(self.n);
        (&self.images[lo * self.img_elems..hi * self.img_elems], &self.labels[lo..hi])
    }

    pub fn n_batches(&self, batch: usize) -> usize {
        self.n / batch
    }
}

/// Default artifact directory: `$HASS_ARTIFACTS` or `artifacts/` relative
/// to the crate root (works from `cargo test`/`cargo bench`/examples).
pub fn default_dir() -> PathBuf {
    if let Ok(d) = std::env::var("HASS_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.join("artifacts")
}

/// True if all artifacts needed by the runtime are present.
pub fn available(dir: &Path) -> bool {
    ["model.hlo.txt", "meta.json", "weights.bin", "calib_images.bin", "calib_labels.bin"]
        .iter()
        .all(|f| dir.join(f).exists())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> PathBuf {
        default_dir()
    }

    #[test]
    fn meta_parses() {
        if !available(&dir()) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Meta::load(&dir()).unwrap();
        assert_eq!(m.num_layers, 10);
        assert_eq!(m.layers.len(), 10);
        assert_eq!(m.golden.batch, m.export_batch);
        assert_eq!(m.quantile_pts.len(), m.weight_abs_quantiles[0].len());
        assert!(m.dense_val_accuracy > 0.5, "training failed upstream?");
    }

    #[test]
    fn meta_layer_geometry_consistent() {
        if !available(&dir()) {
            return;
        }
        let m = Meta::load(&dir()).unwrap();
        for l in &m.layers {
            let wsize: usize = l.weight_shape.iter().product();
            assert_eq!(wsize, l.w_size, "{}", l.name);
            assert_eq!(l.b_size, l.cout, "{}", l.name);
            if l.kind == "conv" {
                assert_eq!(l.patch_k, l.kernel * l.kernel * l.cin, "{}", l.name);
            }
        }
    }

    #[test]
    fn weights_load_and_slice() {
        if !available(&dir()) {
            return;
        }
        let m = Meta::load(&dir()).unwrap();
        let w = Weights::load(&dir(), &m).unwrap();
        assert_eq!(w.params.len(), m.layers.len());
        for ((wv, bv), l) in w.params.iter().zip(&m.layers) {
            assert_eq!(wv.len(), l.w_size);
            assert_eq!(bv.len(), l.b_size);
            // quantized Q8.8 values are multiples of 1/256 within range
            for &v in wv.iter().take(50) {
                assert!((v * m.fxp_scale as f32).fract().abs() < 1e-3, "{v}");
            }
        }
    }

    #[test]
    fn calib_data_loads() {
        if !available(&dir()) {
            return;
        }
        let m = Meta::load(&dir()).unwrap();
        let d = CalibData::load(&dir(), &m).unwrap();
        assert_eq!(d.n, m.n_calib);
        assert!(d.labels.iter().all(|&l| (l as usize) < m.num_classes));
        let (imgs, labels) = d.batch(0, m.export_batch);
        assert_eq!(labels.len(), m.export_batch);
        assert_eq!(imgs.len(), m.export_batch * d.img_elems);
    }

    #[test]
    fn measured_sparsity_curves_are_monotone() {
        if !available(&dir()) {
            return;
        }
        let m = Meta::load(&dir()).unwrap();
        let sp = m.measured_sparsity();
        assert_eq!(sp.layers.len(), m.num_layers);
        for l in &sp.layers {
            for w in l.weight_curve.taus.windows(2) {
                assert!(w[1] >= w[0]);
            }
            // activations post-ReLU have natural zero mass except layer 0
            // (the raw image input); at least *some* layer must show it
        }
        let max_zero = sp
            .layers
            .iter()
            .map(|l| l.act_curve.frac_at_zero())
            .fold(0.0f64, f64::max);
        assert!(max_zero > 0.2, "no natural activation sparsity: {max_zero}");
    }
}
