//! PJRT runtime: load the AOT artifact and run it from the Rust hot path.
//!
//! Python runs once (`make artifacts`); afterwards this module is the only
//! thing touching the model: it compiles `model.hlo.txt` on the PJRT CPU
//! client (`xla` crate), keeps the weights resident as literals, and serves
//! batched `(accuracy, S_w, S_a, pair-density)` evaluations to the search
//! loop.  Thresholds are *runtime inputs* of the artifact, so every TPE
//! iteration reuses one compiled executable — no recompilation, no Python.
//!
//! ## Build features
//!
//! The PJRT executor needs the vendored `xla` + `anyhow` crates, which the
//! offline default build does not have.  The real implementation lives in
//! [`pjrt`] behind `--features pjrt`; without the feature, [`ModelRuntime`]
//! is a stub whose loaders return a clear [`RuntimeError`], so every
//! binary, example and bench still compiles and falls back to the
//! surrogate path at run time.  The artifact *loaders* ([`artifacts`]) are
//! plain `std` and always available.

pub mod artifacts;
pub mod train;

#[cfg(feature = "pjrt")]
pub(crate) mod pjrt;

pub use artifacts::{available, default_dir, CalibData, Meta, Weights};

#[cfg(feature = "pjrt")]
pub use pjrt::ModelRuntime;

/// Error of the dependency-free runtime surface (the `pjrt` build uses
/// `anyhow` internally instead).
#[derive(Clone, Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// What a build without the `pjrt` feature tells callers of the runtime.
#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str = "HASS was built without the `pjrt` feature: the measured \
evaluator needs the vendored `xla` + `anyhow` crates (see rust/Cargo.toml). \
Rebuild with `cargo build --features pjrt` in an environment that provides \
them, or use the surrogate evaluator";

/// Outputs of one forward pass.
#[derive(Clone, Debug)]
pub struct InferOutput {
    /// row-major [batch, num_classes]
    pub logits: Vec<f32>,
    /// measured per-layer weight sparsity at the given thresholds
    pub s_w: Vec<f32>,
    /// measured per-layer activation sparsity (batch average)
    pub s_a: Vec<f32>,
    /// measured per-layer non-zero *pair* density (the SPE counter value)
    pub pair_density: Vec<f32>,
}

/// Aggregated evaluation over calibration batches.
#[derive(Clone, Debug)]
pub struct EvalResult {
    /// top-1 accuracy over the evaluated batches
    pub accuracy: f64,
    pub s_w: Vec<f64>,
    pub s_a: Vec<f64>,
    pub pair_density: Vec<f64>,
    pub images: usize,
}

/// Top-1 accuracy of a row-major logits block against labels.
pub fn top1_accuracy(logits: &[f32], labels: &[i32], num_classes: usize) -> f64 {
    let mut hit = 0usize;
    for (row, &y) in logits.chunks_exact(num_classes).zip(labels) {
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap_or(-1);
        if pred == y {
            hit += 1;
        }
    }
    hit as f64 / labels.len().max(1) as f64
}

/// Stub runtime for builds without the `pjrt` feature: same shape as the
/// real [`pjrt::ModelRuntime`]-struct, but its loaders always fail with a
/// [`RuntimeError`] explaining how to enable the measured path.  No value
/// of this type can exist at run time.
#[cfg(not(feature = "pjrt"))]
pub struct ModelRuntime {
    pub meta: Meta,
    pub data: CalibData,
}

#[cfg(not(feature = "pjrt"))]
impl ModelRuntime {
    /// Always fails: the executor is not compiled in.
    pub fn load(_dir: &std::path::Path) -> Result<ModelRuntime, RuntimeError> {
        Err(RuntimeError(NO_PJRT.to_string()))
    }

    /// Always fails: the executor is not compiled in.
    pub fn load_default() -> Result<ModelRuntime, RuntimeError> {
        Self::load(&default_dir())
    }

    /// Number of compute layers (threshold vector length).
    pub fn n_layers(&self) -> usize {
        self.meta.num_layers
    }

    /// Unreachable in practice (no stub value can be constructed).
    pub fn infer(
        &self,
        _images: &[f32],
        _tau_w: &[f64],
        _tau_a: &[f64],
    ) -> Result<InferOutput, RuntimeError> {
        Err(RuntimeError(NO_PJRT.to_string()))
    }

    /// Top-1 accuracy of a logits block against labels.
    pub fn accuracy(&self, logits: &[f32], labels: &[i32]) -> f64 {
        top1_accuracy(logits, labels, self.meta.num_classes)
    }

    /// Unreachable in practice (no stub value can be constructed).
    pub fn evaluate(
        &self,
        _tau_w: &[f64],
        _tau_a: &[f64],
        _n_batches: usize,
    ) -> Result<EvalResult, RuntimeError> {
        Err(RuntimeError(NO_PJRT.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_accuracy_counts_argmax_hits() {
        // 3 classes, 3 rows: argmax = 2, 0, 1; labels hit 2 of 3
        let logits = [0.1f32, 0.2, 0.9, 1.0, 0.0, 0.5, 0.3, 0.8, 0.4];
        let labels = [2i32, 0, 2];
        let acc = top1_accuracy(&logits, &labels, 3);
        assert!((acc - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn top1_accuracy_empty_is_zero() {
        assert_eq!(top1_accuracy(&[], &[], 10), 0.0);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_loader_explains_missing_feature() {
        let err = ModelRuntime::load_default().err().expect("stub must not load");
        assert!(err.to_string().contains("pjrt"), "unhelpful error: {err}");
        // the alternate format used by the CLI error paths also works
        let msg = format!("{err:#}");
        assert!(msg.contains("surrogate"));
    }
}
