//! Masked fine-tuning runtime (the paper's §VII future-work extension).
//!
//! `train_step.hlo.txt` exports one SGD step of the folded CalibNet with
//! the clip thresholds inside the forward pass: pruned weights get zero
//! gradient (the keep-mask is d/dw of the clip), so running steps after
//! one-shot pruning is masked fine-tuning — accuracy recovery at fixed
//! sparsity, entirely from Rust through PJRT.
//!
//! Like [`super::ModelRuntime`], the executor needs the `pjrt` build
//! feature; without it [`TrainRuntime`] is a stub whose loader returns a
//! [`RuntimeError`](super::RuntimeError) so callers can fall back cleanly.

#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::{Context, Result};

#[cfg(feature = "pjrt")]
use super::artifacts::{CalibData, Meta, Weights};
#[cfg(feature = "pjrt")]
use super::pjrt::f32_literal;

/// Training-step executor holding mutable parameters.
#[cfg(feature = "pjrt")]
pub struct TrainRuntime {
    pub meta: Meta,
    pub data: CalibData,
    exe: xla::PjRtLoadedExecutable,
    /// current (w, b) per layer — updated by every step
    pub params: Vec<(Vec<f32>, Vec<f32>)>,
    batch: usize,
}

#[cfg(feature = "pjrt")]
impl TrainRuntime {
    pub fn load(dir: &Path) -> Result<TrainRuntime> {
        let meta = Meta::load(dir).map_err(anyhow::Error::msg)?;
        let weights = Weights::load(dir, &meta).map_err(anyhow::Error::msg)?;
        let data = CalibData::load(dir, &meta).map_err(anyhow::Error::msg)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            dir.join("train_step.hlo.txt").to_str().unwrap(),
        )
        .context("parse train_step.hlo.txt")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile train step")?;
        // the step graph was exported at TRAIN_BATCH (see python aot.py)
        let batch = meta_train_batch(dir)?;
        Ok(TrainRuntime { params: weights.params.clone(), meta, data, exe, batch })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Run one masked-SGD step on calibration batch `b`; returns the loss.
    pub fn step(&mut self, b: usize, tau_w: &[f64], tau_a: &[f64], lr: f32) -> Result<f32> {
        let m = &self.meta;
        let nb = self.data.n / self.batch;
        let b = b % nb.max(1);
        let lo = b * self.batch;
        let imgs = &self.data.images
            [lo * self.data.img_elems..(lo + self.batch) * self.data.img_elems];
        let labels = &self.data.labels[lo..lo + self.batch];

        let img_lit = f32_literal(
            &[self.batch, m.img_size, m.img_size, m.img_channels],
            imgs,
        )?;
        let lbl_bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(labels.as_ptr() as *const u8, labels.len() * 4)
        };
        let lbl_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &[self.batch],
            lbl_bytes,
        )?;
        let tw: Vec<f32> = tau_w.iter().map(|&v| v as f32).collect();
        let ta: Vec<f32> = tau_a.iter().map(|&v| v as f32).collect();
        let tw_lit = f32_literal(&[m.num_layers], &tw)?;
        let ta_lit = f32_literal(&[m.num_layers], &ta)?;
        let lr_lit = f32_literal(&[], &[lr])?;

        let mut param_lits = Vec::with_capacity(m.num_layers * 2);
        for (l, (w, bias)) in m.layers.iter().zip(&self.params) {
            param_lits.push(f32_literal(&l.weight_shape, w)?);
            param_lits.push(f32_literal(&[l.b_size], bias)?);
        }
        let mut args: Vec<&xla::Literal> = vec![&img_lit, &lbl_lit];
        for p in &param_lits {
            args.push(p);
        }
        args.push(&tw_lit);
        args.push(&ta_lit);
        args.push(&lr_lit);

        let result = self.exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == 2 * m.num_layers + 1,
            "train step returned {} outputs",
            parts.len()
        );
        for (i, part) in parts.iter().take(2 * m.num_layers).enumerate() {
            let v = part.to_vec::<f32>()?;
            let (w, b) = &mut self.params[i / 2];
            if i % 2 == 0 {
                *w = v;
            } else {
                *b = v;
            }
        }
        let loss = parts[2 * m.num_layers].to_vec::<f32>()?[0];
        Ok(loss)
    }
}

#[cfg(feature = "pjrt")]
fn meta_train_batch(dir: &Path) -> Result<usize> {
    let text = std::fs::read_to_string(dir.join("meta.json"))?;
    let j = crate::util::json::Json::parse(&text).map_err(|e| anyhow::anyhow!("{e:?}"))?;
    Ok(j.req("train_batch").as_usize().unwrap())
}

/// Stub training runtime for builds without the `pjrt` feature: the loader
/// always fails with a clear error, so no value of this type exists at run
/// time (see [`super::ModelRuntime`]'s stub for the pattern).
#[cfg(not(feature = "pjrt"))]
pub struct TrainRuntime {
    pub meta: super::Meta,
    pub data: super::CalibData,
    /// current (w, b) per layer — updated by every step
    pub params: Vec<(Vec<f32>, Vec<f32>)>,
}

#[cfg(not(feature = "pjrt"))]
impl TrainRuntime {
    /// Always fails: the executor is not compiled in.
    pub fn load(_dir: &std::path::Path) -> Result<TrainRuntime, super::RuntimeError> {
        Err(super::RuntimeError(
            "masked fine-tuning needs the `pjrt` build feature (vendored `xla` \
             + `anyhow`); rebuild with `cargo build --features pjrt`"
                .to_string(),
        ))
    }

    pub fn batch(&self) -> usize {
        0
    }

    /// Unreachable in practice (no stub value can be constructed).
    pub fn step(
        &mut self,
        _b: usize,
        _tau_w: &[f64],
        _tau_a: &[f64],
        _lr: f32,
    ) -> Result<f32, super::RuntimeError> {
        Err(super::RuntimeError("built without the `pjrt` feature".to_string()))
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::super::artifacts::{available, default_dir};
    use super::*;

    #[test]
    fn train_step_reduces_loss() {
        let dir = default_dir();
        if !available(&dir) || !dir.join("train_step.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut tr = TrainRuntime::load(&dir).unwrap();
        let l = tr.meta.num_layers;
        let tau = vec![0.0; l];
        let first = tr.step(0, &tau, &tau, 0.02).unwrap();
        let mut last = first;
        for s in 1..5 {
            last = tr.step(s % 3, &tau, &tau, 0.02).unwrap();
        }
        assert!(last.is_finite() && first.is_finite());
        // the model is already trained; loss must stay low and not blow up
        assert!(last < first + 0.5, "loss diverged: {first} -> {last}");
    }

    #[test]
    fn masked_step_preserves_pruned_weights() {
        let dir = default_dir();
        if !available(&dir) || !dir.join("train_step.hlo.txt").exists() {
            return;
        }
        let mut tr = TrainRuntime::load(&dir).unwrap();
        let l = tr.meta.num_layers;
        let tau = vec![0.05; l];
        // weights below tau before the step...
        let before: Vec<Vec<bool>> = tr
            .params
            .iter()
            .map(|(w, _)| w.iter().map(|&v| v.abs() < 0.05).collect())
            .collect();
        tr.step(0, &tau, &tau, 0.05).unwrap();
        // ...receive zero gradient through the clip, so they stay put
        for (li, (w, _)) in tr.params.iter().enumerate() {
            let mut moved = 0usize;
            for (i, &was_pruned) in before[li].iter().enumerate() {
                if was_pruned && (w[i].abs() >= 0.05) {
                    moved += 1;
                }
            }
            let frac = moved as f64 / w.len() as f64;
            assert!(frac < 0.01, "layer {li}: {frac} of pruned weights moved");
        }
    }
}
