//! Multi-device sharded search: one engine, N [`DeviceBudget`] shards.
//!
//! HASS's co-search argument (paper §V, Table II / Fig. 6) is that each
//! device geometry prices the same sparsity point differently — the U250
//! rewards wide parallelism the V7-690T cannot afford, the Stratix 10
//! trades BRAM for clock.  Cross-device comparisons therefore sweep one
//! sparsity frontier over several devices; running those sweeps serially
//! re-pays the whole evaluation cost per device.
//!
//! [`ShardedEngine`] runs the sweep as **one search**: every shard wraps
//! one device and owns a private TPE optimizer seeded exactly like a
//! standalone [`Engine::search`] on that device.  Generations advance in
//! lockstep; the union of `(shard, candidate)` work items is evaluated by
//! a single `std::thread::scope` pool writing into index-addressed slots
//! (flat index `shard * g + candidate`), then every shard reduces its
//! slice in candidate order (journal append + `observe_batch`).  Because
//! a shard's propose → evaluate → observe sequence is byte-identical to
//! the standalone loop and candidate evaluation is pure, **each device's
//! journal is bit-for-bit the journal of a standalone run** — the
//! determinism contract of [`crate::engine`] extended across devices.
//!
//! All shards share one multi-fingerprint [`DesignCache`]: keys carry the
//! device fingerprint, so shards can never read each other's pricings,
//! but the store, its lock striping and its single-compute guarantee are
//! common — and a cache handed in via
//! [`search_with_cache`](ShardedEngine::search_with_cache) keeps its
//! entries across searches, so a sparsity point priced for a device once
//! is never re-explored for that device in any later run on that cache.
//!
//! With [`SearchConfig::pipeline_depth`] `D > 0` the lockstep loop
//! becomes a bounded **lookahead pipeline**: generation *P* is proposed
//! the moment exactly `max(P − D, 0)` generations have been observed, so
//! up to `D + 1` generations measure concurrently on scoped tasks while
//! the reducer joins and observes them strictly in generation order.
//! The depth changes which observations TPE has seen when it proposes
//! (algorithmic — it enters the checkpoint fingerprint), but for a fixed
//! depth the schedule is a pure function of `(iterations, batch, D)`, so
//! journals stay invariant across thread counts, sync/async pipelines,
//! cache states and kill/resume.  `D = 0` runs the classic drained
//! propose → evaluate → observe loop inline, byte-identical to the
//! pre-pipeline engine.
//!
//! The cross-device [`ParetoPoint`] frontier (accuracy vs. computation
//! efficiency, the Fig. 1 axes) is aggregated over every record of every
//! shard, labelled with the device that produced it.

use std::collections::btree_map::Entry;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use crate::arch::Network;
use crate::dse::explore;
use crate::dse::frontier::shape_fingerprint;
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::ResourceModel;
use crate::metrics::{pareto_front, Point2, Table};
use crate::optim::tpe::TpeOptimizer;
use crate::pruning::PruningPlan;
use crate::sparsity::SparsityPoint;

use super::cache::{device_fingerprint, quantize_points, DesignCache, DeviceCacheHandle};
use super::ckpt::{search_fingerprint, Checkpoint, CheckpointSpec, DeviceCheckpoint};
use super::retry::{is_transient, RetryPolicy};
use super::{
    CandidateEvaluator, Engine, EngineStats, EvalCtx, EvalCompletion, EvalRequest,
    Measurement, SearchConfig, SearchRecord, SearchResult, ANCHORS,
};

/// One device's slice of a sharded search result.
#[derive(Clone, Debug)]
pub struct DeviceSearchResult {
    /// device name (from [`DeviceBudget::name`])
    pub device: String,
    /// journal + stats, bit-identical to a standalone run on this device
    pub result: SearchResult,
}

/// A point of the cross-device Pareto frontier (maximize accuracy and
/// computation efficiency), tagged with the device that reached it.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub device: String,
    pub iter: usize,
    pub accuracy: f64,
    pub avg_sparsity: f64,
    pub images_per_sec: f64,
    pub dsp: u64,
    pub efficiency: f64,
    pub objective: f64,
}

/// Aggregate execution counters of one sharded run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardedStats {
    /// device shards driven by the run
    pub devices: usize,
    /// worker threads of the shared evaluation pool
    pub threads: usize,
    /// lockstep generations (same for every shard)
    pub generations: usize,
    /// candidate evaluations summed over shards
    pub evaluations: usize,
    /// entries in the shared design cache after the run
    pub cache_entries: usize,
    /// design-cache hits summed over shards
    pub cache_hits: u64,
    /// design-cache misses summed over shards
    pub cache_misses: u64,
    /// layer frontiers held by the shared store after the run
    pub frontier_entries: usize,
    /// frontier-store hits summed over shards
    pub frontier_hits: u64,
    /// frontier-store misses summed over shards
    pub frontier_misses: u64,
    /// measurements skipped via cross-shard candidate dedup
    pub dedup_evals: u64,
    /// lockstep generations run through the async completion-queue
    /// pipeline (0 on the two-phase sync path)
    pub async_generations: usize,
    /// pricings started while the evaluator was still working through the
    /// generation's requests, summed over shards (timing-dependent stat;
    /// 0 on the sync path)
    pub overlap_pricings: u64,
    /// measurement completions that arrived out of submission order,
    /// summed over owning shards (timing-dependent stat)
    pub ooo_completions: u64,
    /// records re-scored by the cycle-level simulator (fidelity ladder),
    /// summed over shards
    pub sim_evals: usize,
    /// simulator-scored records that set a new running-best objective,
    /// summed over shards
    pub sim_promotions: usize,
    /// transient-failure retries consumed ([`SearchConfig::retry`]),
    /// summed over shards
    pub retried_evals: u64,
    /// measurements reclaimed as infeasible by the stall watchdog
    /// ([`SearchConfig::eval_timeout_ms`] / [`SearchConfig::deadline_ms`]),
    /// summed over shards
    pub reclaimed_stalls: u64,
    /// lockstep generations evaluated through the cross-generation
    /// lookahead pipeline ([`SearchConfig::pipeline_depth`] > 0),
    /// excluding generations replayed from a checkpoint (0 on the
    /// classic drained schedule)
    pub pipelined_generations: usize,
    /// proposals drawn while earlier generations were still unobserved
    /// (every candidate of generations `1..` under a depth ≥ 1
    /// schedule), summed over shards — a pure function of the schedule,
    /// identical across thread counts, sync/async and kill/resume
    pub lookahead_proposals: u64,
    /// nanoseconds the reducer spent blocked joining in-flight
    /// generation tasks (run-level, not per-shard-summed;
    /// timing-dependent like `overlap_pricings`; 0 on the depth-0
    /// inline path)
    pub barrier_wait_ns: u64,
}

/// Output of [`ShardedEngine::search`]: per-device results (standalone
/// bit-identical journals) plus the cross-device Pareto frontier.
#[derive(Clone, Debug)]
pub struct ShardedSearchResult {
    pub per_device: Vec<DeviceSearchResult>,
    /// non-dominated (accuracy, efficiency) records across all devices,
    /// accuracy-descending
    pub pareto: Vec<ParetoPoint>,
    pub stats: ShardedStats,
}

impl ShardedSearchResult {
    /// The result of one device, by name.
    pub fn by_device(&self, name: &str) -> Option<&SearchResult> {
        self.per_device.iter().find(|d| d.device == name).map(|d| &d.result)
    }

    /// One row per device: its best record + cache traffic.
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(&[
            "device", "best_iter", "accuracy", "avg_sparsity", "images_per_sec", "dsp",
            "images_per_cycle_per_dsp", "objective", "cache_hit_rate",
        ]);
        for d in &self.per_device {
            // a zero-iteration search has no best record — skip the row
            let Some(b) = d.result.try_best_record() else { continue };
            t.row(vec![
                d.device.clone(),
                b.iter.to_string(),
                format!("{:.3}", b.accuracy),
                format!("{:.4}", b.avg_sparsity),
                format!("{:.1}", b.images_per_sec),
                b.dsp.to_string(),
                format!("{:.4e}", b.efficiency),
                format!("{:.4}", b.objective),
                format!("{:.3}", d.result.stats.cache_hit_rate()),
            ]);
        }
        t
    }

    /// Write one journal CSV per device, deriving each path from `base`
    /// by inserting the device name before the extension
    /// (`results/j.csv` → `results/j.u250.csv`; plain `.device` suffix
    /// when `base` has no extension).  Parent directories are created.
    /// Returns the written paths, in device order.
    pub fn write_journals(&self, base: &str) -> std::io::Result<Vec<String>> {
        if let Some(dir) = std::path::Path::new(base).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut paths = Vec::with_capacity(self.per_device.len());
        for d in &self.per_device {
            let path = match base.rsplit_once('.') {
                Some((stem, ext)) if !stem.is_empty() && !ext.contains('/') => {
                    format!("{stem}.{}.{ext}", d.device)
                }
                _ => format!("{base}.{}", d.device),
            };
            std::fs::write(&path, d.result.to_table().to_csv())?;
            paths.push(path);
        }
        Ok(paths)
    }

    /// The cross-device frontier as a table (one row per Pareto point).
    pub fn pareto_table(&self) -> Table {
        let mut t = Table::new(&[
            "device", "iter", "accuracy", "avg_sparsity", "images_per_sec", "dsp",
            "images_per_cycle_per_dsp", "objective",
        ]);
        for p in &self.pareto {
            t.row(vec![
                p.device.clone(),
                p.iter.to_string(),
                format!("{:.3}", p.accuracy),
                format!("{:.4}", p.avg_sparsity),
                format!("{:.1}", p.images_per_sec),
                p.dsp.to_string(),
                format!("{:.4e}", p.efficiency),
                format!("{:.4}", p.objective),
            ]);
        }
        t
    }
}

/// Progress of one in-flight sharded search, reported to a
/// [`SearchControl`] observer after every lockstep generation.
#[derive(Clone, Copy, Debug)]
pub struct SearchProgress {
    /// lockstep generations completed so far (1-based at first call)
    pub generation: usize,
    /// per-shard iterations completed so far
    pub done: usize,
    /// per-shard iterations requested (`SearchConfig::iterations`)
    pub total: usize,
}

/// Observer + cancellation hook for a long-running search (the `hass
/// serve` daemon streams per-generation progress to its client through
/// this, and cancels the search when the client disconnects).
///
/// The observer is called between lockstep generations — a generation in
/// flight always completes, so cancellation never tears mid-evaluation
/// state and the shared caches stay coherent.  Returning `false` cancels:
/// [`ShardedEngine::search_with_cache_ctrl`] returns `None` and no
/// partial result escapes.
#[derive(Default)]
pub struct SearchControl<'c> {
    /// return `false` to cancel the search after the current generation
    pub observer: Option<&'c (dyn Fn(SearchProgress) -> bool + Sync)>,
    /// checkpoint to resume from ([`super::ckpt`]): its generations are
    /// *replayed* — proposals regenerated (consuming the optimizer RNG
    /// exactly as the original run did), evaluation skipped, records
    /// restored — so the continued journal is bit-identical to an
    /// uninterrupted run.  A checkpoint whose fingerprint or device set
    /// does not match this search is ignored (fresh start); the CLI
    /// validates loudly before handing one in.
    pub resume: Option<&'c Checkpoint>,
}

/// Immutable per-shard execution context: the single-device engine view,
/// its cache handle and the dense-throughput reference.  Shared (`&`) by
/// every in-flight generation task — which is what lets a depth-D
/// lookahead pipeline measure several generations concurrently while the
/// reducer exclusively owns the mutable [`ShardState`].
struct ShardExec<'e> {
    engine: Engine<'e>,
    handle: DeviceCacheHandle,
    dense_ips: f64,
}

/// Reducer-owned per-shard search state: the private optimizer, the
/// journal, and the run's counters.  Only the reducer (the generation
/// loop's caller thread) ever touches this, so proposing and observing
/// stay strictly ordered even when generations overlap in flight.
struct ShardState {
    /// hit/miss snapshots at shard start, so per-run stats stay correct
    /// on a warm shared cache
    hits0: u64,
    misses0: u64,
    /// frontier-store snapshots, taken *before* the dense-reference
    /// pricing so the run's stats cover it
    fhits0: u64,
    fmisses0: u64,
    /// measurements this shard skipped via cross-shard dedup
    dedup: u64,
    /// async-pipeline counters accumulated over this run's generations
    async_gens: usize,
    overlap: u64,
    ooo: u64,
    /// fault-tolerance counters accumulated over this run's generations
    retried: u64,
    reclaimed: u64,
    /// proposals drawn before this shard had observed every earlier
    /// generation (the lookahead pipeline's schedule counter)
    lookahead: u64,
    tpe: TpeOptimizer,
    records: Vec<SearchRecord>,
}

/// One generation's proposals, `[shard][candidate][2 * n_layers]`.
type Proposals = Vec<Vec<Vec<f64>>>;

/// The sharded search engine: one evaluator + target geometry, fanned out
/// over several device budgets (or partitions of one device).
///
/// Duplicate devices in `devices` — *identical budgets*, i.e. the same
/// device fingerprint — are collapsed to **one shard per distinct
/// device** at search time: duplicates share one cache fingerprint (and
/// therefore one hit/miss counter pair), so extra shards could only
/// repeat work and double-count its cache traffic (their journals
/// coincide by determinism anyway).  Same-*name* devices with different
/// resource budgets are different devices and all run.  `per_device`
/// holds one entry per distinct device, first-seen order.
pub struct ShardedEngine<'a> {
    pub evaluator: &'a dyn CandidateEvaluator,
    pub target: &'a Network,
    pub rm: &'a ResourceModel,
    pub devices: &'a [DeviceBudget],
}

impl<'a> ShardedEngine<'a> {
    pub fn new(
        evaluator: &'a dyn CandidateEvaluator,
        target: &'a Network,
        rm: &'a ResourceModel,
        devices: &'a [DeviceBudget],
    ) -> Self {
        ShardedEngine { evaluator, target, rm, devices }
    }

    /// Run the sharded HASS search with a private design cache.
    pub fn search(&self, cfg: &SearchConfig) -> ShardedSearchResult {
        self.search_with_cache(cfg, &DesignCache::new())
    }

    /// Run the sharded HASS search against a caller-owned (possibly warm)
    /// shared design cache.  The cache never changes results — it only
    /// shifts the per-device hit/miss split in the returned stats.
    pub fn search_with_cache(
        &self,
        cfg: &SearchConfig,
        cache: &DesignCache,
    ) -> ShardedSearchResult {
        // the default SearchControl has no observer, so cancellation is
        // impossible by construction — this expect is unreachable
        // lint: allow(panic-safety)
        self.search_with_cache_ctrl(cfg, cache, &SearchControl::default())
            .expect("a search without an observer cannot be cancelled")
    }

    /// [`search_with_cache`](Self::search_with_cache) with a
    /// [`SearchControl`]: the observer sees progress after every lockstep
    /// generation and may cancel by returning `false`, in which case the
    /// search stops before the next generation and `None` is returned
    /// (the shared cache keeps everything priced so far — cancellation
    /// never poisons or truncates it).
    pub fn search_with_cache_ctrl(
        &self,
        cfg: &SearchConfig,
        cache: &DesignCache,
        ctrl: &SearchControl<'_>,
    ) -> Option<ShardedSearchResult> {
        // collapse identical budgets (same device fingerprint — the key
        // prefix of every cache entry) to one shard each: duplicates
        // would share one fingerprint, so extra shards could only repeat
        // work and double-count its cache traffic.  Same-name devices
        // with *different* budgets fingerprint apart and all run.
        let mut seen: BTreeSet<u64> = BTreeSet::new();
        let devices: Vec<&'a DeviceBudget> =
            self.devices.iter().filter(|d| seen.insert(device_fingerprint(d))).collect();
        assert!(!devices.is_empty(), "sharded search needs at least one device");
        let n = self.evaluator.sparsity_model().layers.len();
        assert_eq!(
            n,
            self.target.compute_layers().len(),
            "evaluator and target geometry disagree on layer count"
        );
        let batch = cfg.engine.batch.max(1);
        let n_dev = devices.len();
        let threads = cfg.engine.resolved_threads_for(n_dev * batch);
        let base_acc = self.evaluator.base_accuracy().max(1e-9);
        // per-layer shape fingerprints for the frontier store, shared by
        // every shard (shapes are device-independent)
        let shapes: Vec<u64> =
            self.target.compute_layers().iter().map(|l| shape_fingerprint(l)).collect();
        // dense reference design per device, for throughput normalization
        let dense_points =
            quantize_points(&vec![SparsityPoint::DENSE; n], cfg.engine.quant_bits);

        let handles: Vec<DeviceCacheHandle> = devices
            .iter()
            .map(|&dev| cache.register(dev, self.target, self.rm, &cfg.dse))
            .collect();
        // frontier snapshots *before* the dense pricing: the run's stats
        // cover the frontiers it builds/reuses for the dense reference
        let f0: Vec<(u64, u64)> =
            handles.iter().map(|h| (h.frontier_hits(), h.frontier_misses())).collect();

        // Price each device's dense reference — served counter-free from
        // a warm cache, computed (and remembered) otherwise.  The
        // pricings are independent and each as expensive as a candidate
        // evaluation, so a cold start fans them out over the same kind of
        // scoped pool the generations use.
        let mut denses: Vec<Option<crate::dse::NetworkDesign>> = Vec::new();
        denses.resize_with(n_dev, || None);
        {
            let dense_for = |i: usize| {
                let dev = devices[i];
                let cached = if cfg.engine.cache {
                    cache.get(&handles[i], &dense_points)
                } else {
                    None
                };
                cached.unwrap_or_else(|| {
                    let d = if cfg.engine.cache {
                        cache.explore_via_frontiers(
                            &handles[i],
                            self.target,
                            &dense_points,
                            &shapes,
                            self.rm,
                            dev,
                            &cfg.dse,
                        )
                    } else {
                        explore(self.target, &dense_points, self.rm, dev, &cfg.dse)
                    };
                    if cfg.engine.cache {
                        cache.insert(&handles[i], &dense_points, d.clone());
                    }
                    d
                })
            };
            if threads.min(n_dev) <= 1 {
                for (i, slot) in denses.iter_mut().enumerate() {
                    *slot = Some(dense_for(i));
                }
            } else {
                // one thread per device — n_dev is small
                std::thread::scope(|sc| {
                    for (i, slot) in denses.iter_mut().enumerate() {
                        let dense_for = &dense_for;
                        sc.spawn(move || *slot = Some(dense_for(i)));
                    }
                });
            }
        }

        let mut execs: Vec<ShardExec<'a>> = Vec::with_capacity(n_dev);
        let mut states: Vec<ShardState> = Vec::with_capacity(n_dev);
        for ((dev, handle), (dense, (fhits0, fmisses0))) in
            devices.into_iter().zip(handles).zip(denses.into_iter().zip(f0))
        {
            // slot-filled invariant: the scoped spawn above wrote every slot
            // lint: allow(panic-safety)
            let dense = dense.expect("dense slot filled");
            let dense_ips = dense.images_per_sec(dev).max(1e-9);
            states.push(ShardState {
                hits0: handle.hits(),
                misses0: handle.misses(),
                fhits0,
                fmisses0,
                dedup: 0,
                async_gens: 0,
                overlap: 0,
                ooo: 0,
                retried: 0,
                reclaimed: 0,
                lookahead: 0,
                // every shard is seeded exactly like a standalone run,
                // which is what makes its journal standalone-identical
                tpe: TpeOptimizer::new(2 * n, cfg.seed, cfg.tpe.clone()),
                records: Vec::with_capacity(cfg.iterations),
            });
            execs.push(ShardExec {
                engine: Engine::new(self.evaluator, self.target, self.rm, dev),
                handle,
                dense_ips,
            });
        }

        // checkpoint/resume: fingerprint the result-relevant configuration;
        // a matching checkpoint's generations are replayed below, anything
        // else is silently a fresh start (the CLI validates loudly first)
        let device_fps: Vec<u64> =
            execs.iter().map(|s| device_fingerprint(s.engine.dev)).collect();
        let fp = search_fingerprint(cfg, &shapes, &device_fps);
        let resume_done = match ctrl.resume {
            Some(ck)
                if ck.fingerprint == fp
                    && ck.done <= cfg.iterations
                    && ck.devices.len() == execs.len()
                    && ck
                        .devices
                        .iter()
                        .zip(&execs)
                        .all(|(d, s)| d.device == s.engine.dev.name) =>
            {
                ck.done
            }
            _ => 0,
        };

        // one EvalCtx per shard, built once: pure borrowed data shared by
        // every (possibly concurrent) generation task
        let ctxs: Vec<EvalCtx<'_>> = execs
            .iter()
            .map(|ex| EvalCtx {
                cache: if cfg.engine.cache { Some((cache, &ex.handle)) } else { None },
                quant_bits: cfg.engine.quant_bits,
                dense_ips: ex.dense_ips,
                dev_fp: device_fingerprint(ex.engine.dev),
                base_acc,
                mode: cfg.mode,
                lambda: cfg.lambda,
                dse: &cfg.dse,
                shapes: &shapes,
            })
            .collect();

        // --- the generation loop: a depth-D lookahead pipeline ----------
        //
        // Generation *P* is proposed the moment exactly `max(P − D, 0)`
        // generations have been reduced (and every earlier generation has
        // been proposed), so proposals are always drawn in ascending
        // generation order on each shard's single optimizer RNG stream —
        // the whole schedule is a pure function of (iterations, batch, D)
        // and never of thread timing.  At D = 0 this degenerates to the
        // classic propose → evaluate → observe drained loop, evaluated
        // inline on this thread (no task, no join): journals and stats
        // are byte-identical to the pre-pipeline engine.  At D > 0, up to
        // D + 1 generations are in flight on scoped tasks (each fanning
        // its candidates over the shared pool width — a slow generation
        // tail no longer idles the machine) while this thread reduces
        // them strictly in generation order.
        let depth = cfg.pipeline_depth;
        let n_gens = cfg.iterations.div_ceil(batch);
        let evaluator = self.evaluator;
        let mut generations = 0usize;
        let mut done = 0usize;
        let mut pipelined = 0usize;
        let mut barrier_wait_ns = 0u64;
        let cancelled = std::thread::scope(|sc| {
            // an in-flight generation: its proposals travel with the task
            // and come back with the records, so the reducer observes
            // them without cloning
            enum Pending<'s> {
                /// replayed from a checkpoint, or evaluated inline (D = 0)
                Ready(Proposals, GenerationOutput),
                /// measuring on a scoped task (D > 0)
                Running(std::thread::ScopedJoinHandle<'s, (Proposals, GenerationOutput)>),
            }
            let mut inflight: std::collections::VecDeque<(usize, bool, Pending<'_>)> =
                std::collections::VecDeque::new();
            let mut next_propose = 0usize;
            while generations < n_gens {
                // --- launch every generation whose observation prefix is
                //     in: gen P needs exactly max(P − D, 0) reduced ------
                while next_propose < n_gens && next_propose - generations <= depth {
                    let start = next_propose * batch;
                    let g = batch.min(cfg.iterations - start);
                    // propose per shard: anchors first, then a frozen-
                    // model TPE batch (identical schedule to the drained
                    // serial loop)
                    let n_anchor = if cfg.warm_start {
                        3usize.saturating_sub(start).min(g)
                    } else {
                        0
                    };
                    let xs_all: Proposals = states
                        .iter_mut()
                        .map(|s| {
                            let mut xs: Vec<Vec<f64>> = Vec::with_capacity(g);
                            for j in 0..n_anchor {
                                xs.push(vec![ANCHORS[start + j]; 2 * n]);
                            }
                            xs.extend(s.tpe.suggest_batch(g - n_anchor));
                            xs
                        })
                        .collect();
                    if depth > 0 && next_propose > 0 {
                        // drawn while earlier generations were still
                        // unobserved — a pure function of the schedule,
                        // replay included, so kill/resume can't move it
                        for s in states.iter_mut() {
                            s.lookahead += g as u64;
                        }
                    }
                    let replayed = start < resume_done;
                    let pending = if replayed {
                        // resume replay: records come from the checkpoint,
                        // so the generation's entire evaluation cost is
                        // skipped.  The proposals above consumed the
                        // optimizer RNG exactly as the original run did;
                        // feeding them back below with the checkpointed
                        // objectives reproduces the TPE model state bit
                        // for bit.  (`start` boundaries align because
                        // checkpoints are only written between generations
                        // of a fingerprint-identical schedule.)
                        // resume_done > 0 is only ever set from a
                        // present ctrl.resume: lint: allow(panic-safety)
                        let ck =
                            ctrl.resume.expect("resume_done > 0 implies a checkpoint");
                        let mut records = Vec::with_capacity(execs.len() * g);
                        for d in &ck.devices {
                            records.extend(d.records[start..start + g].iter().cloned());
                        }
                        let zeros = vec![0u64; execs.len()];
                        let out = GenerationOutput {
                            records,
                            dedup: zeros.clone(),
                            overlap: zeros.clone(),
                            ooo: zeros.clone(),
                            retries: zeros.clone(),
                            reclaimed: zeros,
                        };
                        Pending::Ready(xs_all, out)
                    } else if depth == 0 {
                        // drained schedule: evaluate inline, no join — the
                        // classic loop, byte for byte
                        let out = if cfg.engine.async_eval {
                            run_generation_async(
                                evaluator, &execs, &ctxs, &xs_all, start, g, threads, cfg,
                            )
                        } else {
                            run_generation(
                                &execs, &ctxs, &xs_all, start, g, threads, &cfg.retry,
                            )
                        };
                        Pending::Ready(xs_all, out)
                    } else {
                        let (execs, ctxs) = (&execs, &ctxs);
                        Pending::Running(sc.spawn(move || {
                            let out = if cfg.engine.async_eval {
                                run_generation_async(
                                    evaluator, execs, ctxs, &xs_all, start, g, threads,
                                    cfg,
                                )
                            } else {
                                run_generation(
                                    execs, ctxs, &xs_all, start, g, threads, &cfg.retry,
                                )
                            };
                            (xs_all, out)
                        }))
                    };
                    inflight.push_back((g, replayed, pending));
                    next_propose += 1;
                }
                // --- reduce the oldest in-flight generation, in candidate
                //     order per shard --------------------------------------
                // the propose loop above always pushes before this pop
                // (depth ≥ 0), so: lint: allow(panic-safety)
                let (g, replayed, pending) =
                    inflight.pop_front().expect("a launched generation");
                let (xs_all, evaluated) = match pending {
                    Pending::Ready(xs, out) => (xs, out),
                    Pending::Running(h) => {
                        // barrier_wait_ns is a wall-clock *stat*, never
                        // in the journal: lint: allow(determinism)
                        let t0 = Instant::now();
                        // lint: allow(panic-safety) — join propagates a
                        // worker panic; swallowing it would corrupt state
                        let r = h.join().expect("generation task panicked");
                        barrier_wait_ns += t0.elapsed().as_nanos() as u64;
                        r
                    }
                };
                if depth > 0 && !replayed {
                    pipelined += 1;
                }
                let mut flat = evaluated.records.into_iter();
                for (si, (s, xs)) in states.iter_mut().zip(xs_all).enumerate() {
                    let recs: Vec<SearchRecord> = flat.by_ref().take(g).collect();
                    let mut observed = Vec::with_capacity(g);
                    for (x, rec) in xs.into_iter().zip(&recs) {
                        observed.push((x, rec.objective));
                    }
                    s.records.extend(recs);
                    s.tpe.observe_batch(observed);
                    s.dedup += evaluated.dedup[si];
                    s.overlap += evaluated.overlap[si];
                    s.ooo += evaluated.ooo[si];
                    s.retried += evaluated.retries[si];
                    s.reclaimed += evaluated.reclaimed[si];
                    if cfg.engine.async_eval && !replayed {
                        s.async_gens += 1;
                    }
                }
                generations += 1;
                done += g;
                // crash safety: persist the journal prefix at the
                // configured cadence (not during replay — that checkpoint
                // already exists, and not at completion — the result is
                // about to be returned).  Checkpoints land only at reduced
                // generation boundaries, so a mid-pipeline snapshot is
                // always a fully-observed prefix the replay above can
                // regenerate from.
                if let Some(spec) = &cfg.checkpoint {
                    if done > resume_done
                        && done < cfg.iterations
                        && generations % spec.every.max(1) == 0
                    {
                        write_checkpoint(spec, fp, done, &execs, &states);
                    }
                }
                if let Some(obs) = ctrl.observer {
                    let go = obs(SearchProgress {
                        generation: generations,
                        done,
                        total: cfg.iterations,
                    });
                    if !go && done < cfg.iterations {
                        // cancelled (client disconnect / daemon shutdown):
                        // leave a checkpoint behind so the run can resume.
                        // Generations still in flight are joined by the
                        // scope on the way out and their results dropped —
                        // the checkpoint covers exactly the reduced prefix.
                        if let Some(spec) = &cfg.checkpoint {
                            write_checkpoint(spec, fp, done, &execs, &states);
                        }
                        return true;
                    }
                }
            }
            false
        });
        if cancelled {
            return None;
        }

        // --- finalize: per-device results + cross-device frontier -------
        let cache_entries = cache.len();
        let frontier_entries = cache.frontier_store().len();
        let mut per_device: Vec<DeviceSearchResult> = Vec::with_capacity(n_dev);
        let (mut total_hits, mut total_misses) = (0u64, 0u64);
        let (mut total_fhits, mut total_fmisses) = (0u64, 0u64);
        let mut total_dedup = 0u64;
        let (mut total_overlap, mut total_ooo) = (0u64, 0u64);
        let (mut total_sim_evals, mut total_sim_promotions) = (0usize, 0usize);
        let (mut total_retried, mut total_reclaimed) = (0u64, 0u64);
        let mut total_lookahead = 0u64;
        let async_generations = if cfg.engine.async_eval { generations } else { 0 };
        for (ex, s) in execs.into_iter().zip(states) {
            let best = s
                .records
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.objective.total_cmp(&b.1.objective))
                .map(|(i, _)| i)
                .unwrap_or(0);
            let hits = ex.handle.hits() - s.hits0;
            let misses = ex.handle.misses() - s.misses0;
            let fhits = ex.handle.frontier_hits() - s.fhits0;
            let fmisses = ex.handle.frontier_misses() - s.fmisses0;
            total_hits += hits;
            total_misses += misses;
            total_fhits += fhits;
            total_fmisses += fmisses;
            total_dedup += s.dedup;
            total_overlap += s.overlap;
            total_ooo += s.ooo;
            total_retried += s.retried;
            total_reclaimed += s.reclaimed;
            total_lookahead += s.lookahead;
            // fidelity-ladder accounting, derived from the journal itself
            // in candidate order — thread-count invariant by construction
            let mut sim_evals = 0usize;
            let mut sim_promotions = 0usize;
            let mut dis_sum = 0.0f64;
            let mut run_best = f64::NEG_INFINITY;
            for r in &s.records {
                if r.simulated {
                    sim_evals += 1;
                    if r.objective > run_best {
                        sim_promotions += 1;
                    }
                    if r.analytic_images_per_sec > 0.0 {
                        dis_sum += (r.images_per_sec - r.analytic_images_per_sec).abs()
                            / r.analytic_images_per_sec;
                    }
                }
                run_best = run_best.max(r.objective);
            }
            let sim_disagreement =
                if sim_evals > 0 { dis_sum / sim_evals as f64 } else { 0.0 };
            total_sim_evals += sim_evals;
            total_sim_promotions += sim_promotions;
            per_device.push(DeviceSearchResult {
                device: ex.engine.dev.name.clone(),
                result: SearchResult {
                    best,
                    dense_images_per_sec: ex.dense_ips,
                    stats: EngineStats {
                        evaluations: s.records.len(),
                        generations,
                        threads,
                        batch,
                        cache_hits: hits,
                        cache_misses: misses,
                        frontier_hits: fhits,
                        frontier_misses: fmisses,
                        dedup_evals: s.dedup,
                        async_generations: s.async_gens,
                        overlap_pricings: s.overlap,
                        ooo_completions: s.ooo,
                        sim_evals,
                        sim_promotions,
                        sim_disagreement,
                        retried_evals: s.retried,
                        reclaimed_stalls: s.reclaimed,
                        pipelined_generations: pipelined,
                        lookahead_proposals: s.lookahead,
                        barrier_wait_ns,
                    },
                    records: s.records,
                },
            });
        }
        let pareto = cross_device_pareto(&per_device);
        Some(ShardedSearchResult {
            stats: ShardedStats {
                devices: n_dev,
                threads,
                generations,
                evaluations: per_device.iter().map(|d| d.result.records.len()).sum(),
                cache_entries,
                cache_hits: total_hits,
                cache_misses: total_misses,
                frontier_entries,
                frontier_hits: total_fhits,
                frontier_misses: total_fmisses,
                dedup_evals: total_dedup,
                async_generations,
                overlap_pricings: total_overlap,
                ooo_completions: total_ooo,
                sim_evals: total_sim_evals,
                sim_promotions: total_sim_promotions,
                retried_evals: total_retried,
                reclaimed_stalls: total_reclaimed,
                pipelined_generations: pipelined,
                lookahead_proposals: total_lookahead,
                barrier_wait_ns,
            },
            pareto,
            per_device,
        })
    }
}

/// Everything one lockstep generation hands back to the reducer: records
/// in flat `shard * g + candidate` order plus per-shard execution
/// counters (all-zero overlap/ooo on the sync two-phase path, all-zero
/// reclaimed everywhere but the async watchdog).
struct GenerationOutput {
    records: Vec<SearchRecord>,
    dedup: Vec<u64>,
    overlap: Vec<u64>,
    ooo: Vec<u64>,
    retries: Vec<u64>,
    reclaimed: Vec<u64>,
}

/// Best-effort checkpoint write between generations: a failed save must
/// never kill a healthy search, so IO errors are reported and swallowed
/// (the previous checkpoint, if any, survives intact — saves are atomic).
fn write_checkpoint(
    spec: &CheckpointSpec,
    fingerprint: u64,
    done: usize,
    execs: &[ShardExec<'_>],
    states: &[ShardState],
) {
    let ck = Checkpoint {
        fingerprint,
        done,
        devices: execs
            .iter()
            .zip(states)
            .map(|(ex, s)| DeviceCheckpoint {
                device: ex.engine.dev.name.clone(),
                records: s.records.clone(),
            })
            .collect(),
    };
    if let Err(e) = ck.save(&spec.path) {
        eprintln!("warning: checkpoint write to '{}' failed: {e}", spec.path);
    }
}

/// Cross-shard dedup of one generation's proposals: every `(shard,
/// candidate)` work item is mapped onto its *distinct* proposal (first
/// occurrence in flat order owns it).  Identical proposals across shards
/// are guaranteed during TPE random startup and for warm-start anchors,
/// where every shard's seed-identical optimizer emits the same
/// candidates; measurement is device-independent, so sharing it cannot
/// change any journal — evaluations are pure by the
/// [`CandidateEvaluator`] contract.
struct ProposalDedup {
    /// distinct-proposal slot of each flat work item
    meas_idx: Vec<usize>,
    /// first `(shard, candidate)` occurrence of each distinct proposal
    owners: Vec<(usize, usize)>,
    /// flat work items referencing each distinct proposal (disjoint sets)
    users: Vec<Vec<usize>>,
    /// per shard: measurements skipped because another shard owns them
    dedup: Vec<u64>,
}

fn dedup_proposals(xs_all: &[Vec<Vec<f64>>], n_shards: usize, g: usize) -> ProposalDedup {
    let total = n_shards * g;
    let mut meas_idx: Vec<usize> = Vec::with_capacity(total);
    let mut owners: Vec<(usize, usize)> = Vec::new();
    let mut users: Vec<Vec<usize>> = Vec::new();
    // BTreeMap, not HashMap: dedup bookkeeping sits on the journaled
    // path, and ordered maps keep every iteration deterministic by
    // construction (the determinism lint bans hashed iteration here)
    let mut seen: BTreeMap<Vec<u64>, usize> = BTreeMap::new();
    let mut dedup = vec![0u64; n_shards];
    for k in 0..total {
        let (si, j) = (k / g, k % g);
        let key: Vec<u64> = xs_all[si][j].iter().map(|v| v.to_bits()).collect();
        match seen.entry(key) {
            Entry::Occupied(e) => {
                meas_idx.push(*e.get());
                users[*e.get()].push(k);
                dedup[si] += 1;
            }
            Entry::Vacant(e) => {
                e.insert(owners.len());
                meas_idx.push(owners.len());
                users.push(vec![k]);
                owners.push((si, j));
            }
        }
    }
    ProposalDedup { meas_idx, owners, users, dedup }
}

/// Evaluate one lockstep generation in two index-addressed parallel
/// passes (the sync path):
///
/// 1. **Measure** — each *distinct* proposal ([`dedup_proposals`]) is
///    measured once, by its first `(shard, candidate)` occurrence in flat
///    order.
/// 2. **Score** — every `(shard, candidate)` work item prices its shard's
///    device (design cache + frontier store) and scores Eq. 6, flat index
///    `shard * g + candidate`, each worker writing into its own slot.
///
/// The barrier between the passes is what [`run_generation_async`]
/// removes.
fn run_generation(
    shards: &[ShardExec<'_>],
    ctxs: &[EvalCtx<'_>],
    xs_all: &[Vec<Vec<f64>>],
    base_iter: usize,
    g: usize,
    threads: usize,
    retry: &RetryPolicy,
) -> GenerationOutput {
    let total = shards.len() * g;
    let dd = dedup_proposals(xs_all, shards.len(), g);
    // --- pass 1: measure each distinct proposal exactly once ------------
    let mut meas: Vec<Option<Measurement>> = Vec::new();
    meas.resize_with(dd.owners.len(), || None);
    run_slots(&mut meas, threads, |slot, mi| {
        let (si, j) = dd.owners[mi];
        *slot = Some(shards[si].engine.measure_candidate(&xs_all[si][j], retry));
    });
    // lint: allow(panic-safety) — run_slots filled every slot by contract
    let meas: Vec<Measurement> =
        meas.into_iter().map(|o| o.expect("measurement slot filled")).collect();
    // retry accounting follows measurement ownership (flat-order first
    // occurrence), like the dedup counter
    let mut retries = vec![0u64; shards.len()];
    for (mi, m) in meas.iter().enumerate() {
        retries[dd.owners[mi].0] += m.retries as u64;
    }
    // --- pass 2: price + score every (shard, candidate) work item -------
    let mut out: Vec<Option<SearchRecord>> = Vec::new();
    out.resize_with(total, || None);
    run_slots(&mut out, threads, |slot, k| {
        let (si, j) = (k / g, k % g);
        *slot = Some(shards[si].engine.score_candidate(
            base_iter + j,
            &meas[dd.meas_idx[k]],
            &ctxs[si],
        ));
    });
    // lint: allow(panic-safety) — run_slots filled every slot by contract
    let records = out.into_iter().map(|o| o.expect("generation slot filled")).collect();
    GenerationOutput {
        records,
        dedup: dd.dedup,
        overlap: vec![0; shards.len()],
        ooo: vec![0; shards.len()],
        retries,
        reclaimed: vec![0; shards.len()],
    }
}

/// Evaluate one lockstep generation through the **async completion
/// queue** — the tentpole pipeline replacing the measure-all-then-
/// price-all barrier of [`run_generation`]:
///
/// * one submitter thread hands the whole generation's distinct
///   proposals ([`dedup_proposals`]) to
///   [`CandidateEvaluator::eval_async`], which streams
///   [`EvalCompletion`]s back over an `mpsc` channel in *any* order;
/// * `threads` pricing workers pop completions as they arrive (pops are
///   serialized, pricing is parallel) and immediately price + score every
///   `(shard, candidate)` work item referencing that proposal — while
///   later measurements are still in flight;
/// * each scored record is routed back with its flat index and placed
///   into its index-addressed slot by the collector, so scheduling,
///   completion order and thread count can never move a result.
///
/// The journal reduction downstream is unchanged (candidate order per
/// shard), which makes the whole pipeline an execution knob: bit-for-bit
/// identical to the sync path for any evaluator honoring the purity
/// contract, including ones that complete out of submission order.
///
/// # Stall watchdog
///
/// With [`SearchConfig::eval_timeout_ms`] (silence between completions)
/// or [`SearchConfig::deadline_ms`] (whole-generation budget) non-zero,
/// a pop that would otherwise block forever times out and **reclaims
/// every still-outstanding measurement** as a failed one — each gets an
/// infeasible-scored record ("measurement stalled; reclaimed by the
/// watchdog", deliberately not transient so it is never retried), and
/// the generation completes.  An evaluator that returned without sending
/// every completion is reclaimed immediately (those completions can
/// never arrive).  Late completions that do arrive after reclamation are
/// ignored.  Both knobs default to 0 = the wait-forever semantics, where
/// a short completion count is still a contract violation.  Caveat: the
/// watchdog reclaims *completions*; an `eval_async` implementation that
/// itself never returns still blocks the generation's scope join.
fn run_generation_async(
    evaluator: &dyn CandidateEvaluator,
    shards: &[ShardExec<'_>],
    ctxs: &[EvalCtx<'_>],
    xs_all: &[Vec<Vec<f64>>],
    base_iter: usize,
    g: usize,
    threads: usize,
    cfg: &SearchConfig,
) -> GenerationOutput {
    let retry = cfg.retry;
    let (eval_timeout, deadline) = (cfg.eval_timeout_ms, cfg.deadline_ms);
    let n_shards = shards.len();
    let total = n_shards * g;
    let dd = dedup_proposals(xs_all, n_shards, g);
    let n_meas = dd.owners.len();
    let n_points = evaluator.sparsity_model().layers.len();
    // decode once per distinct proposal: the plan travels with the
    // request, and is also what the scored records carry
    let plans: Vec<PruningPlan> = dd
        .owners
        .iter()
        .map(|&(si, j)| {
            PruningPlan::from_unit_point(&xs_all[si][j], evaluator.sparsity_model())
        })
        .collect();
    let requests: Vec<EvalRequest> = plans
        .iter()
        .enumerate()
        .map(|(slot, plan)| EvalRequest { slot, plan: plan.clone() })
        .collect();

    // completion-pop state shared by the pricing workers: pops are
    // serialized (recv under the lock), which is also what makes the
    // out-of-order accounting race-free
    struct PopState {
        rx: mpsc::Receiver<EvalCompletion>,
        received: usize,
        max_slot: Option<usize>,
        done: Vec<bool>,
        /// last completion arrival (or generation start): what
        /// `eval_timeout_ms` measures silence against
        // lint: allow(determinism) — watchdog clock: opt-in fault
        // tolerance, reclaims stalls; never enters journal records
        last_progress: Instant,
    }
    // lint: allow(determinism) — watchdog clock (see PopState above)
    let gen_start = Instant::now();
    let (meas_tx, meas_rx) = mpsc::channel::<EvalCompletion>();
    let pop = Mutex::new(PopState {
        rx: meas_rx,
        received: 0,
        max_slot: None,
        done: vec![false; n_meas],
        last_progress: gen_start,
    });
    let (rec_tx, rec_rx) = mpsc::channel::<(usize, SearchRecord)>();
    let overlap: Vec<AtomicU64> = (0..n_shards).map(|_| AtomicU64::new(0)).collect();
    let ooo: Vec<AtomicU64> = (0..n_shards).map(|_| AtomicU64::new(0)).collect();
    let retried: Vec<AtomicU64> = (0..n_shards).map(|_| AtomicU64::new(0)).collect();
    let reclaimed: Vec<AtomicU64> = (0..n_shards).map(|_| AtomicU64::new(0)).collect();
    // true while the evaluator is still working through the generation's
    // request batch: pricings started in that window genuinely overlap
    // measurement work (a queue backlog drained *after* the evaluator
    // finished is throughput, not overlap, and is not counted)
    let measuring = AtomicBool::new(true);

    let mut out: Vec<Option<SearchRecord>> = Vec::new();
    out.resize_with(total, || None);
    std::thread::scope(|sc| {
        // submitter: the evaluator owns its scheduling; when it returns,
        // the moved sender drops and the workers' recv unblocks
        {
            let measuring = &measuring;
            sc.spawn(move || {
                evaluator.eval_async(requests, meas_tx);
                measuring.store(false, Ordering::Release);
            });
        }
        for _ in 0..threads.max(1) {
            let rec_tx = rec_tx.clone();
            let (pop, plans, dd) = (&pop, &plans, &dd);
            let (overlap, ooo, measuring) = (&overlap, &ooo, &measuring);
            let (retried, reclaimed) = (&retried, &reclaimed);
            sc.spawn(move || loop {
                // one popped completion — or the watchdog's harvest of
                // every slot that will never complete
                enum Popped {
                    One(EvalCompletion, bool),
                    Stalled(Vec<usize>),
                }
                // pop one completion (serialized); price its users
                // (parallel across workers) after releasing the lock
                let popped = {
                    // poison recovery: PopState's fields are advanced one
                    // completion at a time under the lock; a panicking
                    // popper leaves them consistent for the next worker
                    let mut st = crate::util::lock_clean(&pop);
                    if st.received == n_meas {
                        return;
                    }
                    let recv = if eval_timeout == 0 && deadline == 0 {
                        // wait-forever semantics: a closed channel with
                        // outstanding slots is a contract violation the
                        // collector will report
                        st.rx.recv().map_err(|_| false)
                    } else {
                        // watchdog: bound the wait by the nearer of the
                        // per-completion timeout and the generation
                        // deadline.  A disconnect with outstanding slots
                        // means those completions can never arrive —
                        // reclaim immediately rather than waiting out the
                        // timer.
                        // lint: allow(determinism) — watchdog clock only
                        let now = Instant::now();
                        let mut wait = Duration::from_secs(86_400);
                        if eval_timeout > 0 {
                            let t = st.last_progress + Duration::from_millis(eval_timeout);
                            wait = wait.min(t.saturating_duration_since(now));
                        }
                        if deadline > 0 {
                            let t = gen_start + Duration::from_millis(deadline);
                            wait = wait.min(t.saturating_duration_since(now));
                        }
                        st.rx.recv_timeout(wait).map_err(|_| true)
                    };
                    match recv {
                        Ok(c) => {
                            // lint: allow(determinism) — watchdog clock
                            st.last_progress = Instant::now();
                            assert!(
                                c.slot < n_meas
                                    && !std::mem::replace(&mut st.done[c.slot], true),
                                "evaluator violated the eval_async contract on slot {}",
                                c.slot
                            );
                            st.received += 1;
                            let out_of_order = st.max_slot.is_some_and(|m| c.slot < m);
                            st.max_slot =
                                Some(st.max_slot.map_or(c.slot, |m| m.max(c.slot)));
                            Popped::One(c, out_of_order)
                        }
                        Err(false) => return,
                        Err(true) => {
                            // watchdog fired: mark every outstanding slot
                            // done so no other worker waits again, and
                            // reclaim them all below
                            let stalled: Vec<usize> = st
                                .done
                                .iter()
                                .enumerate()
                                .filter(|&(_, &d)| !d)
                                .map(|(s, _)| s)
                                .collect();
                            for &s in &stalled {
                                st.done[s] = true;
                            }
                            st.received = n_meas;
                            Popped::Stalled(stalled)
                        }
                    }
                };
                let (c, out_of_order) = match popped {
                    Popped::One(c, out_of_order) => (c, out_of_order),
                    Popped::Stalled(stalled) => {
                        // score reclaimed slots as failed measurements:
                        // infeasible records keep the journal and the TPE
                        // feedback shape-complete, and the search moves on
                        for slot in stalled {
                            // relaxed: stats counter, read via into_inner
                            // after the scope joins every worker
                            reclaimed[dd.owners[slot].0].fetch_add(1, Ordering::Relaxed);
                            let meas = Measurement::from_result(
                                shards[0].engine.target,
                                plans[slot].clone(),
                                Err("measurement stalled; reclaimed by the watchdog"
                                    .to_string()),
                                n_points,
                            );
                            for &k in &dd.users[slot] {
                                let (si, j) = (k / g, k % g);
                                let rec = shards[si].engine.score_candidate(
                                    base_iter + j,
                                    &meas,
                                    &ctxs[si],
                                );
                                if rec_tx.send((k, rec)).is_err() {
                                    return; // collector bailed out
                                }
                            }
                        }
                        continue; // next pop sees received == n_meas
                    }
                };
                if out_of_order {
                    // relaxed: stats counter, read after the scope join
                    ooo[dd.owners[c.slot].0].fetch_add(1, Ordering::Relaxed);
                }
                let overlapping = measuring.load(Ordering::Acquire);
                // a transient completion failure is re-driven on this
                // worker, synchronously, under the same retry schedule as
                // the sync path — so both pipelines see the same final
                // outcome for the same plan
                let (result, tries) = match c.result {
                    Err(e) if is_transient(&e) => {
                        let mut first = Some(Err(e));
                        retry.run(|| match first.take() {
                            Some(r) => r,
                            None => evaluator.try_eval(&plans[c.slot]),
                        })
                    }
                    r => (r, 0),
                };
                if tries > 0 {
                    // relaxed: stats counter, read after the scope join
                    retried[dd.owners[c.slot].0].fetch_add(tries as u64, Ordering::Relaxed);
                }
                let meas = Measurement::from_result(
                    shards[0].engine.target,
                    plans[c.slot].clone(),
                    result,
                    n_points,
                );
                for &k in &dd.users[c.slot] {
                    let (si, j) = (k / g, k % g);
                    if overlapping {
                        // relaxed: stats counter, read after the scope join
                        overlap[si].fetch_add(1, Ordering::Relaxed);
                    }
                    let rec =
                        shards[si].engine.score_candidate(base_iter + j, &meas, &ctxs[si]);
                    if rec_tx.send((k, rec)).is_err() {
                        return; // collector bailed out
                    }
                }
            });
        }
        drop(rec_tx);
        // collector: place each scored record into its flat slot.  Runs on
        // the generation's own thread, concurrently with the workers.
        for _ in 0..total {
            let (k, rec) = rec_rx
                .recv()
                // lint: allow(panic-safety) — an eval_async contract
                // violation must abort loudly, not journal silently
                .expect("evaluator completed fewer requests than were submitted");
            out[k] = Some(rec);
        }
    });
    // lint: allow(panic-safety) — the collector above filled every slot
    let records = out.into_iter().map(|o| o.expect("generation slot filled")).collect();
    GenerationOutput {
        records,
        dedup: dd.dedup,
        overlap: overlap.into_iter().map(|a| a.into_inner()).collect(),
        ooo: ooo.into_iter().map(|a| a.into_inner()).collect(),
        retries: retried.into_iter().map(|a| a.into_inner()).collect(),
        reclaimed: reclaimed.into_iter().map(|a| a.into_inner()).collect(),
    }
}

/// Fill every slot via `fill(slot, index)` on up to `threads` scoped
/// workers, each owning a contiguous index-addressed chunk — scheduling
/// can never affect where a result lands.  (Also the worker pool of the
/// fidelity ladder's pricing/simulation rungs, see `evaluator`.)
pub(super) fn run_slots<T: Send>(
    slots: &mut [Option<T>],
    threads: usize,
    fill: impl Fn(&mut Option<T>, usize) + Sync,
) {
    let total = slots.len();
    if total == 0 {
        return;
    }
    let threads = threads.clamp(1, total);
    if threads <= 1 {
        for (k, slot) in slots.iter_mut().enumerate() {
            fill(slot, k);
        }
    } else {
        let chunk = total.div_ceil(threads);
        std::thread::scope(|sc| {
            for (ci, oc) in slots.chunks_mut(chunk).enumerate() {
                let fill = &fill;
                sc.spawn(move || {
                    for (off, slot) in oc.iter_mut().enumerate() {
                        fill(slot, ci * chunk + off);
                    }
                });
            }
        });
    }
}

/// Non-dominated (accuracy ↑, efficiency ↑) records across every shard.
fn cross_device_pareto(per_device: &[DeviceSearchResult]) -> Vec<ParetoPoint> {
    let mut pts: Vec<Point2> = Vec::new();
    let mut src: Vec<(&str, &SearchRecord)> = Vec::new();
    for d in per_device {
        for r in &d.result.records {
            // pareto_front only reads x/y; provenance lives in `src`
            pts.push(Point2 { label: String::new(), x: r.accuracy, y: r.efficiency });
            src.push((d.device.as_str(), r));
        }
    }
    pareto_front(&pts)
        .into_iter()
        .map(|i| {
            let (device, r) = src[i];
            ParetoPoint {
                device: device.to_string(),
                iter: r.iter,
                accuracy: r.accuracy,
                avg_sparsity: r.avg_sparsity,
                images_per_sec: r.images_per_sec,
                dsp: r.dsp,
                efficiency: r.efficiency,
                objective: r.objective,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::coordinator::SurrogateEvaluator;
    use crate::dse::DseConfig;
    use crate::engine::EngineConfig;
    use crate::sparsity::synthesize;

    fn surrogate(seed: u64) -> SurrogateEvaluator {
        let net = networks::calibnet();
        let sparsity = synthesize(&net, seed);
        SurrogateEvaluator { net, sparsity, base_acc: 85.0 }
    }

    fn cfg(iters: usize, seed: u64, engine: EngineConfig) -> SearchConfig {
        SearchConfig {
            iterations: iters,
            seed,
            dse: DseConfig { max_iters: 1_500, ..Default::default() },
            engine,
            ..Default::default()
        }
    }

    fn objective_bits(r: &SearchResult) -> Vec<u64> {
        r.records.iter().map(|x| x.objective.to_bits()).collect()
    }

    /// The tentpole contract: every device's journal from a sharded run is
    /// bit-identical to a standalone single-device run with the same seed.
    #[test]
    fn sharded_journals_match_standalone_per_device() {
        let ev = surrogate(31);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
        let c = cfg(
            12,
            7,
            EngineConfig { batch: 3, threads: 0, cache: true, quant_bits: 12, async_eval: false },
        );
        let sharded = ShardedEngine::new(&ev, &net, &rm, &devices).search(&c);
        assert_eq!(sharded.per_device.len(), 2);
        for dev in &devices {
            let standalone = Engine::new(&ev, &net, &rm, dev).search(&c);
            let shard = sharded.by_device(&dev.name).expect("device present");
            assert_eq!(
                objective_bits(&standalone),
                objective_bits(shard),
                "{} diverged from its standalone run",
                dev.name
            );
            assert_eq!(standalone.best, shard.best);
            assert_eq!(standalone.best_record().plan, shard.best_record().plan);
        }
    }

    #[test]
    fn single_device_shard_is_engine_search() {
        let ev = surrogate(32);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let devices = [DeviceBudget::u250()];
        let c = cfg(
            8,
            3,
            EngineConfig { batch: 2, threads: 2, cache: true, quant_bits: 0, async_eval: false },
        );
        let sharded = ShardedEngine::new(&ev, &net, &rm, &devices).search(&c);
        let single = Engine::new(&ev, &net, &rm, &devices[0]).search(&c);
        assert_eq!(
            objective_bits(&single),
            objective_bits(&sharded.per_device[0].result)
        );
        assert_eq!(sharded.stats.devices, 1);
        assert_eq!(sharded.stats.evaluations, 8);
    }

    /// Duplicate budgets collapse to one shard per distinct device — a
    /// duplicate shares its twin's cache fingerprint, so running it would
    /// only repeat work and double-count the same counters.
    #[test]
    fn duplicate_devices_collapse_to_one_shard_each() {
        let ev = surrogate(39);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let dup = [
            DeviceBudget::u250(),
            DeviceBudget::u250(),
            DeviceBudget::v7_690t(),
            DeviceBudget::u250(),
        ];
        let c = cfg(
            6,
            5,
            EngineConfig { batch: 3, threads: 0, cache: true, quant_bits: 12, async_eval: false },
        );
        let r = ShardedEngine::new(&ev, &net, &rm, &dup).search(&c);
        assert_eq!(r.stats.devices, 2, "one shard per distinct device");
        assert_eq!(r.per_device.len(), 2);
        assert_eq!(r.per_device[0].device, "u250", "first-seen order");
        assert_eq!(r.per_device[1].device, "7v690t");
        assert_eq!(r.stats.evaluations, 2 * 6);
        // and the deduped run matches the already-distinct one exactly
        let distinct = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
        let r2 = ShardedEngine::new(&ev, &net, &rm, &distinct).search(&c);
        for (a, b) in r.per_device.iter().zip(&r2.per_device) {
            assert_eq!(a.device, b.device);
            assert_eq!(objective_bits(&a.result), objective_bits(&b.result));
        }
        // a same-NAME device with a different budget is a different
        // device (distinct fingerprint): both shards must run
        let mixed = [DeviceBudget { dsp: 2_048, ..DeviceBudget::u250() }, DeviceBudget::u250()];
        let r3 = ShardedEngine::new(&ev, &net, &rm, &mixed).search(&c);
        assert_eq!(r3.stats.devices, 2, "same-name different-budget devices must both run");
        assert_eq!(r3.stats.evaluations, 2 * 6);
    }

    /// Async sharded generations reduce to the same per-device journals
    /// as the sync barrier — and dedup accounting is pipeline-invariant.
    #[test]
    fn async_sharded_matches_sync_per_device() {
        let ev = surrogate(40);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
        let sync_c = cfg(
            9,
            13,
            EngineConfig { batch: 3, threads: 0, cache: true, quant_bits: 12, async_eval: false },
        );
        let async_c = cfg(
            9,
            13,
            EngineConfig { batch: 3, threads: 0, cache: true, quant_bits: 12, async_eval: true },
        );
        let eng = ShardedEngine::new(&ev, &net, &rm, &devices);
        let sync = eng.search(&sync_c);
        let asynced = eng.search(&async_c);
        for (a, b) in sync.per_device.iter().zip(&asynced.per_device) {
            assert_eq!(a.device, b.device);
            assert_eq!(
                objective_bits(&a.result),
                objective_bits(&b.result),
                "{}: async sharded journal diverged",
                a.device
            );
            assert_eq!(
                a.result.stats.dedup_evals, b.result.stats.dedup_evals,
                "{}: dedup must be pipeline-invariant",
                a.device
            );
        }
        assert_eq!(asynced.stats.async_generations, asynced.stats.generations);
        assert_eq!(sync.stats.async_generations, 0);
    }

    /// For a fixed lookahead depth, the pipeline is an execution knob:
    /// thread count and sync/async evaluation never move a journal bit,
    /// and the schedule counters are pure functions of the schedule.
    #[test]
    fn pipelined_search_is_execution_invariant_for_fixed_depth() {
        let ev = surrogate(41);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
        let mk = |threads: usize, async_eval: bool| SearchConfig {
            pipeline_depth: 2,
            ..cfg(
                10,
                9,
                EngineConfig { batch: 3, threads, cache: true, quant_bits: 12, async_eval },
            )
        };
        let eng = ShardedEngine::new(&ev, &net, &rm, &devices);
        let a = eng.search(&mk(0, false));
        for r in [eng.search(&mk(2, false)), eng.search(&mk(0, true))] {
            for (x, y) in a.per_device.iter().zip(&r.per_device) {
                assert_eq!(
                    objective_bits(&x.result),
                    objective_bits(&y.result),
                    "{}: depth-2 journal moved under an execution knob",
                    x.device
                );
            }
        }
        // 10 iters at batch 3 = 4 generations (3+3+3+1); every generation
        // after the first is proposed ahead of its observations
        assert_eq!(a.stats.pipelined_generations, 4);
        assert_eq!(a.stats.lookahead_proposals, 2 * (3 + 3 + 1));
        // a depth-0 run of the same search keeps every pipeline counter
        // at its drained-schedule zero
        let drained = eng.search(&cfg(
            10,
            9,
            EngineConfig { batch: 3, threads: 0, cache: true, quant_bits: 12, async_eval: false },
        ));
        assert_eq!(drained.stats.pipelined_generations, 0);
        assert_eq!(drained.stats.lookahead_proposals, 0);
        assert_eq!(drained.stats.barrier_wait_ns, 0);
    }

    #[test]
    fn pareto_front_is_nondominated_and_sourced_from_journals() {
        let ev = surrogate(33);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
        let c = cfg(
            10,
            5,
            EngineConfig { batch: 5, threads: 0, cache: true, quant_bits: 12, async_eval: false },
        );
        let r = ShardedEngine::new(&ev, &net, &rm, &devices).search(&c);
        assert!(!r.pareto.is_empty());
        for p in &r.pareto {
            // every frontier point must exist in its device's journal...
            let journal = r.by_device(&p.device).expect("device of pareto point");
            let rec = &journal.records[p.iter];
            assert_eq!(rec.accuracy.to_bits(), p.accuracy.to_bits());
            assert_eq!(rec.efficiency.to_bits(), p.efficiency.to_bits());
            // ...and no record anywhere may strictly dominate it
            for d in &r.per_device {
                for other in &d.result.records {
                    assert!(
                        !(other.accuracy > p.accuracy && other.efficiency > p.efficiency),
                        "{}#{} dominated by {}#{}",
                        p.device,
                        p.iter,
                        d.device,
                        other.iter
                    );
                }
            }
        }
    }

    /// A warm shared cache serves every repeated pricing: re-running the
    /// same sharded search against the same cache must miss zero times.
    #[test]
    fn shared_cache_persists_across_sharded_runs() {
        let ev = surrogate(34);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
        let c = cfg(
            6,
            11,
            EngineConfig { batch: 2, threads: 0, cache: true, quant_bits: 12, async_eval: false },
        );
        let cache = DesignCache::new();
        let eng = ShardedEngine::new(&ev, &net, &rm, &devices);
        let cold = eng.search_with_cache(&c, &cache);
        assert!(cold.stats.cache_misses > 0);
        let warm = eng.search_with_cache(&c, &cache);
        assert_eq!(
            warm.stats.cache_misses, 0,
            "warm cache must serve every pricing of a repeated run"
        );
        assert_eq!(warm.stats.cache_hits, 2 * 6);
        for (a, b) in cold.per_device.iter().zip(&warm.per_device) {
            assert_eq!(a.device, b.device);
            assert_eq!(objective_bits(&a.result), objective_bits(&b.result));
        }
    }

    #[test]
    fn per_device_stats_cover_every_evaluation() {
        let ev = surrogate(35);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let devices =
            [DeviceBudget::u250(), DeviceBudget::v7_690t(), DeviceBudget::stratix10()];
        let c = cfg(
            7,
            13,
            EngineConfig { batch: 3, threads: 0, cache: true, quant_bits: 12, async_eval: false },
        );
        let r = ShardedEngine::new(&ev, &net, &rm, &devices).search(&c);
        assert_eq!(r.stats.devices, 3);
        assert_eq!(r.stats.evaluations, 21);
        assert_eq!(r.stats.generations, 3); // 3 + 3 + 1
        assert!(r.stats.cache_entries > 0);
        for d in &r.per_device {
            let s = &d.result.stats;
            assert_eq!(
                s.cache_hits + s.cache_misses,
                7,
                "{}: every pricing must be accounted",
                d.device
            );
            assert_eq!(s.evaluations, 7);
            assert_eq!(s.generations, 3);
        }
        assert_eq!(r.stats.cache_hits + r.stats.cache_misses, 21);
    }

    /// Cross-shard dedup: with `iterations ≤ n_startup` every shard's
    /// optimizer is in its model-free random phase and — being seeded
    /// identically — proposes the *same* candidates (anchors included),
    /// so every shard after the first measures nothing itself.
    #[test]
    fn startup_candidates_are_deduped_across_shards() {
        let ev = surrogate(38);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let devices =
            [DeviceBudget::u250(), DeviceBudget::v7_690t(), DeviceBudget::stratix10()];
        let iters = 9; // < TpeConfig::default().n_startup
        let c = cfg(
            iters,
            17,
            EngineConfig { batch: 3, threads: 0, cache: true, quant_bits: 12, async_eval: false },
        );
        let r = ShardedEngine::new(&ev, &net, &rm, &devices).search(&c);
        // first shard (flat order) owns every measurement; the other two
        // dedup all of theirs
        assert_eq!(r.per_device[0].result.stats.dedup_evals, 0);
        for d in &r.per_device[1..] {
            assert_eq!(
                d.result.stats.dedup_evals, iters as u64,
                "{}: startup proposals must be fully deduped",
                d.device
            );
        }
        assert_eq!(r.stats.dedup_evals, 2 * iters as u64);
        // pricing is NOT deduped — every shard still prices its device
        for d in &r.per_device {
            let s = &d.result.stats;
            assert_eq!(s.cache_hits + s.cache_misses, iters as u64, "{}", d.device);
        }
    }

    /// The frontier store gives structural reuse on design-cache misses:
    /// ResNet-18 repeats its block shapes, so even a cold search hits the
    /// store — and a warm design cache skips it entirely.
    #[test]
    fn frontier_store_reuse_shows_in_stats() {
        let net = networks::resnet18();
        let ev = SurrogateEvaluator {
            net: net.clone(),
            sparsity: synthesize(&net, 2),
            base_acc: 69.75,
        };
        let rm = ResourceModel::default();
        let devices = [DeviceBudget::u250()];
        let c = cfg(
            4,
            3,
            EngineConfig { batch: 2, threads: 0, cache: true, quant_bits: 12, async_eval: false },
        );
        let cache = DesignCache::new();
        let eng = ShardedEngine::new(&ev, &net, &rm, &devices);
        let cold = eng.search_with_cache(&c, &cache);
        let s = &cold.per_device[0].result.stats;
        assert!(s.frontier_misses > 0, "cold run must build frontiers");
        assert!(
            s.frontier_hits > 0,
            "repeated ResNet shapes must hit the frontier store"
        );
        assert!(cold.stats.frontier_entries > 0);
        assert_eq!(cold.stats.frontier_hits, s.frontier_hits);
        // warm rerun: every pricing is a design-cache hit, so the
        // frontier store sees no traffic at all
        let warm = eng.search_with_cache(&c, &cache);
        assert_eq!(warm.stats.cache_misses, 0);
        assert_eq!(warm.stats.frontier_hits + warm.stats.frontier_misses, 0);
        // and the journals are unaffected by any of the reuse machinery
        for (a, b) in cold.per_device.iter().zip(&warm.per_device) {
            assert_eq!(objective_bits(&a.result), objective_bits(&b.result));
        }
    }

    #[test]
    fn summary_and_pareto_tables_have_one_row_per_entry() {
        let ev = surrogate(36);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
        let c = cfg(5, 1, EngineConfig {
            batch: 5,
            threads: 0,
            cache: true,
            quant_bits: 12,
            async_eval: false,
        });
        let r = ShardedEngine::new(&ev, &net, &rm, &devices).search(&c);
        assert_eq!(r.summary_table().rows.len(), 2);
        assert_eq!(r.pareto_table().rows.len(), r.pareto.len());
        assert!(r.by_device("u250").is_some());
        assert!(r.by_device("no-such-device").is_none());
    }

    #[test]
    fn write_journals_one_csv_per_device() {
        let ev = surrogate(37);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
        let c = cfg(4, 2, EngineConfig {
            batch: 4,
            threads: 0,
            cache: true,
            quant_bits: 12,
            async_eval: false,
        });
        let r = ShardedEngine::new(&ev, &net, &rm, &devices).search(&c);
        let base = std::env::temp_dir().join("hass_shard_journal_test.csv");
        let paths = r.write_journals(base.to_str().unwrap()).unwrap();
        assert_eq!(paths.len(), 2);
        for (path, d) in paths.iter().zip(&r.per_device) {
            assert!(path.contains(&d.device), "path {path} misses device name");
            assert!(path.ends_with(".csv"), "extension must be preserved: {path}");
            let csv = std::fs::read_to_string(path).unwrap();
            assert_eq!(csv.lines().count(), 1 + d.result.records.len());
            std::fs::remove_file(path).ok();
        }
    }
}
