//! Memoization of DSE pricings.
//!
//! `dse::explore` dominates the cost of a search iteration on the
//! surrogate path (and is the entire hardware-pricing cost on the measured
//! path).  It is a pure function of (network, sparsity points, resource
//! model, device), and within one search the network / resource model /
//! device are fixed — so a [`DesignCache`] keyed by the sparsity points
//! plus a device fingerprint makes repeated pricings O(1).
//!
//! Exact f64 keys alone would almost never collide between TPE proposals;
//! the engine therefore *snaps* operating points to a dyadic grid with
//! [`quantize_points`] before pricing.  Snapping is applied whether or not
//! the cache is enabled, so turning the cache on or off never changes
//! results — a cache hit returns bit-for-bit what recomputation would.
//! `quant_bits = 0` disables snapping (exact keys), which is the engine
//! default so the serial path reproduces the pre-engine seed behavior.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::dse::NetworkDesign;
use crate::hardware::device::DeviceBudget;
use crate::sparsity::SparsityPoint;

/// Snap each operating point to multiples of `2^-bits` (0 = identity).
///
/// At the engine's batched default of 12 bits the grid step is ~2.4e-4
/// sparsity — far below anything the hardware model resolves — while
/// nearby proposals from a converging optimizer collapse onto shared keys.
pub fn quantize_points(points: &[SparsityPoint], bits: u32) -> Vec<SparsityPoint> {
    if bits == 0 {
        return points.to_vec();
    }
    let grid = (1u64 << bits.min(52)) as f64;
    points
        .iter()
        .map(|p| SparsityPoint {
            s_w: (p.s_w * grid).round() / grid,
            s_a: (p.s_a * grid).round() / grid,
        })
        .collect()
}

/// Cache key: device fingerprint + the exact bit patterns of the (already
/// snapped) per-layer operating points.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct Key {
    device: u64,
    points: Vec<(u64, u64)>,
}

fn point_bits(points: &[SparsityPoint]) -> Vec<(u64, u64)> {
    points.iter().map(|p| (p.s_w.to_bits(), p.s_a.to_bits())).collect()
}

/// FNV-1a fingerprint of the device budget (name + resource counts).
fn device_fingerprint(dev: &DeviceBudget) -> u64 {
    fn mix(mut h: u64, v: u64) -> u64 {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
    let mut h: u64 = 0xcbf29ce484222325;
    for b in dev.name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h = mix(h, dev.dsp);
    h = mix(h, dev.lut);
    h = mix(h, dev.bram18k);
    h = mix(h, dev.uram);
    h = mix(h, dev.freq_mhz.to_bits());
    h
}

/// Thread-safe memo table for [`crate::dse::explore`] results.
///
/// Shared by reference across a generation's evaluation threads; lookups
/// and inserts take a short-lived lock, the pricing itself runs unlocked
/// (two threads racing on the same key both compute the same deterministic
/// design, so the duplicate work is benign and rare).
pub struct DesignCache {
    device: u64,
    map: Mutex<HashMap<Key, NetworkDesign>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl DesignCache {
    pub fn new(dev: &DeviceBudget) -> Self {
        DesignCache {
            device: device_fingerprint(dev),
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn key(&self, points: &[SparsityPoint]) -> Key {
        Key { device: self.device, points: point_bits(points) }
    }

    /// Return the cached design for `points`, or price via `compute` and
    /// remember the result.  `points` should already be snapped (see
    /// [`quantize_points`]); the key is their exact bit pattern.
    pub fn get_or_compute<F>(&self, points: &[SparsityPoint], compute: F) -> NetworkDesign
    where
        F: FnOnce() -> NetworkDesign,
    {
        let key = self.key(points);
        if let Some(d) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return d.clone();
        }
        let d = compute();
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.map.lock().unwrap().insert(key, d.clone());
        d
    }

    /// Pre-seed an entry (e.g. the dense reference design) without
    /// touching the hit/miss counters.
    pub fn insert(&self, points: &[SparsityPoint], design: NetworkDesign) {
        let key = self.key(points);
        self.map.lock().unwrap().insert(key, design);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::resources::Resources;

    fn design(dsp: u64) -> NetworkDesign {
        NetworkDesign {
            designs: vec![],
            throughput: 1e-5,
            resources: Resources { dsp, lut: 0, bram18k: 0, uram: 0 },
        }
    }

    fn pts(vals: &[(f64, f64)]) -> Vec<SparsityPoint> {
        vals.iter().map(|&(s_w, s_a)| SparsityPoint { s_w, s_a }).collect()
    }

    #[test]
    fn miss_then_hit_counts_and_returns_cached_value() {
        let cache = DesignCache::new(&DeviceBudget::u250());
        let p = pts(&[(0.5, 0.25), (0.125, 0.0)]);
        let mut computes = 0;
        let a = cache.get_or_compute(&p, || {
            computes += 1;
            design(42)
        });
        let b = cache.get_or_compute(&p, || {
            computes += 1;
            design(999) // must not be called
        });
        assert_eq!(computes, 1);
        assert_eq!(a.resources.dsp, 42);
        assert_eq!(b.resources.dsp, 42);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_points_are_distinct_entries() {
        let cache = DesignCache::new(&DeviceBudget::u250());
        cache.get_or_compute(&pts(&[(0.5, 0.5)]), || design(1));
        cache.get_or_compute(&pts(&[(0.5, 0.5000001)]), || design(2));
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 0);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn quantization_collapses_nearby_points() {
        // at 8 bits the grid step is 1/256 ≈ 3.9e-3: points 1e-4 apart snap
        // to the same representative, points far apart stay distinct
        let a = quantize_points(&pts(&[(0.5000, 0.3000)]), 8);
        let b = quantize_points(&pts(&[(0.5001, 0.2999)]), 8);
        let c = quantize_points(&pts(&[(0.6000, 0.3000)]), 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // snapped values are exact multiples of the grid
        assert_eq!(a[0].s_w, 128.0 / 256.0);
    }

    #[test]
    fn zero_bits_is_identity() {
        let p = pts(&[(0.123456789, 0.987654321)]);
        let q = quantize_points(&p, 0);
        assert_eq!(p[0].s_w.to_bits(), q[0].s_w.to_bits());
        assert_eq!(p[0].s_a.to_bits(), q[0].s_a.to_bits());
    }

    #[test]
    fn quantization_error_is_bounded_by_grid() {
        let p = pts(&[(0.777, 0.333)]);
        for bits in [8u32, 12, 16] {
            let q = quantize_points(&p, bits);
            let step = 1.0 / (1u64 << bits) as f64;
            assert!((q[0].s_w - 0.777).abs() <= step / 2.0 + 1e-15);
            assert!((q[0].s_a - 0.333).abs() <= step / 2.0 + 1e-15);
        }
    }

    #[test]
    fn preseeded_entry_hits_without_miss() {
        let cache = DesignCache::new(&DeviceBudget::u250());
        let p = pts(&[(0.0, 0.0)]);
        cache.insert(&p, design(7));
        let d = cache.get_or_compute(&p, || design(1000));
        assert_eq!(d.resources.dsp, 7);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 0);
    }

    #[test]
    fn different_devices_never_share_entries() {
        let u250 = DesignCache::new(&DeviceBudget::u250());
        let small = DeviceBudget {
            name: "small".into(),
            dsp: 64,
            lut: 200_000,
            bram18k: 600,
            uram: 64,
            freq_mhz: 250.0,
        };
        assert_ne!(u250.device, DesignCache::new(&small).device);
    }

    #[test]
    fn concurrent_lookups_are_consistent() {
        let cache = DesignCache::new(&DeviceBudget::u250());
        let p = pts(&[(0.25, 0.75)]);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        let d = cache.get_or_compute(&p, || design(5));
                        assert_eq!(d.resources.dsp, 5);
                    }
                });
            }
        });
        assert_eq!(cache.len(), 1);
        // every lookup either hit or missed; at least the first missed
        assert_eq!(cache.hits() + cache.misses(), 200);
        assert!(cache.misses() >= 1);
    }
}
