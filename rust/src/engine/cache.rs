//! Memoization of DSE pricings, shared across devices, search shards —
//! and, via on-disk snapshots, across whole processes.
//!
//! `dse::explore` dominates the cost of a search iteration on the
//! surrogate path (and is the entire hardware-pricing cost on the measured
//! path).  It is a pure function of (network, sparsity points, resource
//! model, DSE config, device) — so a [`DesignCache`] keyed by
//! `(pricing-context fingerprint, sparsity points)` makes repeated
//! pricings O(1), where the context fingerprint covers *all* of those
//! inputs except the points themselves (see [`pricing_fingerprint`]).
//!
//! Since the multi-device sharding work the cache is a **multi-fingerprint
//! store**: one `DesignCache` serves any number of [`DeviceBudget`]s (and
//! pricing configurations) at once.  Each device is
//! [`register`](DesignCache::register)ed under its context, yielding a
//! [`DeviceCacheHandle`] that carries the FNV-1a fingerprint and its
//! private hit/miss counters; entries of different devices — or the same
//! device under different configs — can never collide because the
//! fingerprint is part of every key.
//!
//! # Structural reuse: the frontier store
//!
//! Exact-point memoization only pays off on repeats; every *new* quantized
//! point vector still used to pay a full `dse::explore`.  The cache also
//! owns a [`FrontierStore`]: a second memo holding the per-layer
//! [`LayerFrontier`]s (`dse::frontier`) keyed by
//! `(device + resource model, layer shape, layer point)` — deliberately
//! *narrower* than the design keys, because a frontier does not depend on
//! the network or the DSE config.  The engine's miss path
//! ([`DesignCache::explore_via_frontiers`]) prices through it, so a brand
//! new candidate re-enumerates a layer's design space only if that
//! (shape, point) pair has never been priced before — across candidates,
//! generations, shards, and searches over *different* networks or DSE
//! configs that repeat layer shapes.  Frontier traffic is counted
//! separately ([`DeviceCacheHandle::frontier_hits`] /
//! [`frontier_misses`](DeviceCacheHandle::frontier_misses)).
//!
//! # Concurrency core
//!
//! Both stores are thin typed layers over one generic primitive,
//! [`StripedMemo`] (`util::memo`): keys are spread over independent mutex
//! stripes, a miss installs an empty `OnceLock` cell under the stripe
//! lock and fills it *outside* the lock, and racing threads block on the
//! in-flight cell instead of re-pricing — `compute` runs **at most once
//! per key**, even under contention.  The memo reports which caller
//! installed the cell, which is all this module adds on top: per-device
//! hit/miss accounting.
//!
//! Exact f64 keys alone would almost never collide between TPE proposals;
//! the engine therefore *snaps* operating points to a dyadic grid with
//! [`quantize_points`] before pricing.  Snapping is applied whether or not
//! the cache is enabled, so turning the cache on or off never changes
//! results — a cache hit returns bit-for-bit what recomputation would.
//! `quant_bits = 0` disables snapping (exact keys), which is the engine
//! default so the serial path reproduces the pre-engine seed behavior.
//!
//! # On-disk snapshots
//!
//! [`DesignCache::save`] / [`DesignCache::load`] persist both stores as a
//! versioned JSON document (`util::json`, no external deps), so Fig. 5 /
//! Table II sweeps and ablations start warm:
//!
//! ```text
//! { "format":  "hass-design-cache",
//!   "version": 1,
//!   "designs": [ { "fp":  <pricing-context fingerprint, hex>,
//!                  "pts": [<s_w bits, hex>, <s_a bits, hex>, ...],
//!                  "thr": <throughput bits, hex>,
//!                  "res": [dsp, lut, bram18k, uram],
//!                  "ds":  [[i_par, o_par, n_mac], ...],
//!                  "check": <entry checksum, hex> }, ... ],
//!   "frontiers": [ { "ctx": <frontier-context fingerprint, hex>,
//!                    "shape": <layer-shape fingerprint, hex>,
//!                    "pt":  [<s_w bits, hex>, <s_a bits, hex>],
//!                    "es":  [[rate bits, cycles, cost bits, i_par, o_par,
//!                             n_mac, dsp, lut, bram18k, uram], ...],
//!                    "check": <entry checksum, hex> }, ... ] }
//! ```
//!
//! Every u64 fingerprint and every f64 travels as its 16-hex-digit bit
//! pattern ([`crate::util::json::u64_to_hex`]): JSON numbers are f64,
//! which cannot carry 64-bit hashes exactly and cannot carry ±inf at all
//! (frontier costs on URAM-less devices are `+inf`), while bit patterns
//! make the roundtrip exact — a warm-from-disk cache returns
//! **bit-identical** pricings, so a repeated search misses zero times and
//! journals bit-for-bit what the cold run journaled.  Each entry carries
//! a `check` fingerprint (FNV-1a folded over its fields' canonical
//! serializations, sorted key order, `check` itself excluded);
//! entries whose recorded fingerprint does not match the recomputed one —
//! a truncated write, a hand-edited file — are *skipped* on load
//! ([`SnapshotStats::skipped`]) rather than poisoning the cache.  Context
//! mismatches need no load-time handling at all: the pricing-context
//! fingerprint is part of every key, so entries saved under another
//! network / resource model / DSE config simply never hit.
//!
//! # Compaction and cross-process sharing
//!
//! Long-lived cache files only grow, so both stores track *usage*: each
//! [`get_or_compute`](DesignCache::get_or_compute) /
//! [`get_or_build`](FrontierStore::get_or_build) bumps a per-entry use
//! count and last-touched tick (counter-free [`get`](DesignCache::get) /
//! [`insert`](DesignCache::insert) deliberately do not, so pre-seeded
//! reference designs and snapshot rebuilds stay invisible to the
//! accounting).  Usage rides along in the snapshot as optional `uses` /
//! `tick` entry fields — *excluded* from the `check` checksum, so the
//! format version stays 1 and old snapshots load unchanged — and
//! survives a save/load round trip.
//! [`save_compacted`](DesignCache::save_compacted) with a nonzero cap
//! evicts least-recently-used entries (oldest tick first, then fewest
//! uses) past the cap, per store.
//!
//! Saves are also safe against *concurrent* savers sharing one
//! `--cache-file`: the writer takes a best-effort advisory lock (an
//! atomically created `<path>.lock` sibling, with bounded backoff and
//! stale-lock stealing), merges entries already on disk that it does not
//! hold in memory (the in-memory version of an entry always wins), and
//! renames the temp file into place — so two processes warming one
//! snapshot union their work instead of the last writer discarding the
//! first's.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::arch::{LayerDesc, Network};
use crate::dse::frontier::{build_frontier, entries_are_ordered, FrontierEntry, LayerFrontier};
use crate::dse::{explore_frontiers_checked, minimal_checked, DseConfig, NetworkDesign};
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::{ResourceModel, Resources};
use crate::hardware::LayerDesign;
use crate::sparsity::SparsityPoint;
use crate::util::{fault, lock_clean};
use crate::util::json::{u64_from_hex, u64_to_hex, Json};
use crate::util::memo::StripedMemo;

/// Number of independent map shards (locks) inside each store of a
/// [`DesignCache`].
pub const STRIPES: usize = 16;

/// Snap each operating point to multiples of `2^-bits` (0 = identity).
///
/// At the engine's batched default of 12 bits the grid step is ~2.4e-4
/// sparsity — far below anything the hardware model resolves — while
/// nearby proposals from a converging optimizer collapse onto shared keys.
pub fn quantize_points(points: &[SparsityPoint], bits: u32) -> Vec<SparsityPoint> {
    if bits == 0 {
        return points.to_vec();
    }
    let grid = (1u64 << bits.min(52)) as f64;
    points
        .iter()
        .map(|p| SparsityPoint {
            s_w: (p.s_w * grid).round() / grid,
            s_a: (p.s_a * grid).round() / grid,
        })
        .collect()
}

/// Cache key: device fingerprint + the exact bit patterns of the (already
/// snapped) per-layer operating points.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
struct Key {
    device: u64,
    points: Vec<(u64, u64)>,
}

fn point_bits(points: &[SparsityPoint]) -> Vec<(u64, u64)> {
    points.iter().map(|p| (p.s_w.to_bits(), p.s_a.to_bits())).collect()
}

/// FNV-1a fingerprint of a device budget (name + resource counts).
pub(crate) fn device_fingerprint(dev: &DeviceBudget) -> u64 {
    fn mix(mut h: u64, v: u64) -> u64 {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
    let mut h: u64 = 0xcbf29ce484222325;
    for b in dev.name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h = mix(h, dev.dsp);
    h = mix(h, dev.lut);
    h = mix(h, dev.bram18k);
    h = mix(h, dev.uram);
    h = mix(h, dev.freq_mhz.to_bits());
    h
}

/// Fold a string into an FNV-1a hash state.
fn fnv_extend(mut h: u64, s: &str) -> u64 {
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a fingerprint of the **full pricing context**: the device budget
/// plus the Debug forms of (network, resource model, DSE config) —
/// everything besides the operating points that `dse::explore` output
/// depends on.  Folding the whole context into the key is what makes
/// cross-search cache reuse safe: a warm cache queried under a different
/// network / resource model / DSE config *misses* (and re-prices) instead
/// of silently serving designs explored under the old configuration.
pub(crate) fn pricing_fingerprint(
    dev: &DeviceBudget,
    net: &Network,
    rm: &ResourceModel,
    dse: &DseConfig,
) -> u64 {
    let mut h = device_fingerprint(dev);
    // Debug formatting recursively covers every field (f64s print with
    // shortest-roundtrip precision, so distinct values stay distinct)
    for s in [format!("{net:?}"), format!("{rm:?}"), format!("{dse:?}")] {
        h = fnv_extend(h, &s);
    }
    h
}

/// FNV-1a fingerprint of the **frontier context**: device budget +
/// resource model only.  A [`LayerFrontier`] is a pure function of (layer
/// shape, point, resource model, device) — it does not depend on the
/// network (the shape key covers the layer) or on `DseConfig` — so keying
/// the frontier store more narrowly than the design cache lets warm
/// caches share frontiers across searches over different networks or DSE
/// configs that repeat layer shapes.
pub(crate) fn frontier_fingerprint(dev: &DeviceBudget, rm: &ResourceModel) -> u64 {
    fnv_extend(device_fingerprint(dev), &format!("{rm:?}"))
}

/// Per-device cache traffic counters (shared with the owning cache).
#[derive(Debug, Default)]
struct DevStats {
    hits: AtomicU64,
    misses: AtomicU64,
    /// layer-frontier store traffic (see [`FrontierStore`]) — counted
    /// separately from whole-design hits/misses because a single design
    /// miss issues one frontier lookup per compute layer
    frontier_hits: AtomicU64,
    frontier_misses: AtomicU64,
}

/// A device's view into a shared [`DesignCache`]: its pricing-context
/// fingerprint plus its private hit/miss counters.  Obtained from
/// [`DesignCache::register`]; cloning yields a handle to the *same*
/// counters, and re-registering the same device under the same context
/// returns the same counters too, so stats survive across searches that
/// share one cache.
#[derive(Clone, Debug)]
pub struct DeviceCacheHandle {
    fingerprint: u64,
    /// narrower context for the frontier store (device + resource model
    /// only — see [`frontier_fingerprint`])
    frontier_fp: u64,
    stats: Arc<DevStats>,
}

impl DeviceCacheHandle {
    /// [`pricing_fingerprint`] baked into every key of this device.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Lookups served from the cache (including waits on in-flight
    /// computations) since this device was first registered.
    pub fn hits(&self) -> u64 {
        // relaxed: stats counter read for reporting only
        self.stats.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to price from scratch.
    pub fn misses(&self) -> u64 {
        // relaxed: stats counter read for reporting only
        self.stats.misses.load(Ordering::Relaxed)
    }

    /// Layer-frontier lookups served from the shared [`FrontierStore`]
    /// (structural reuse on whole-design cache misses).
    pub fn frontier_hits(&self) -> u64 {
        // relaxed: stats counter read for reporting only
        self.stats.frontier_hits.load(Ordering::Relaxed)
    }

    /// Layer-frontier lookups that had to enumerate the design space.
    pub fn frontier_misses(&self) -> u64 {
        // relaxed: stats counter read for reporting only
        self.stats.frontier_misses.load(Ordering::Relaxed)
    }
}

/// Key of one layer frontier: frontier-context fingerprint (device +
/// resource model, see [`frontier_fingerprint`]) + layer *shape*
/// fingerprint + the exact bit pattern of the (snapped) operating point.
/// Keying by shape — not layer index or network — lets the repeated
/// blocks of a ResNet share one frontier within a candidate, across
/// candidates, and across searches over different networks.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
struct FrontierKey {
    context: u64,
    shape: u64,
    point: (u64, u64),
}

/// Per-device store of [`LayerFrontier`]s — the structural half of the
/// pricing cache, a typed layer over [`StripedMemo`].  [`DesignCache`]
/// memoizes *whole-network* designs on exact (quantized) point vectors;
/// every miss there still pays a full `explore`.  This store memoizes the
/// expensive part of that miss — the per-layer design-space enumeration —
/// keyed by `(device + resource model, layer shape, layer point)`, so a
/// new candidate whose per-layer operating points (or layer shapes) were
/// ever seen before rebuilds nothing and only re-runs the cheap bisection
/// lookups.  Shared across candidates, generations, shards and searches
/// (even over different networks / DSE configs — frontiers don't depend
/// on either); the memo's single-compute contract applies per frontier.
pub struct FrontierStore {
    memo: StripedMemo<FrontierKey, Arc<LayerFrontier>>,
    /// per-entry (use count, last-touched tick) for LRU compaction; one
    /// short-lived lock per lookup is noise next to a frontier build
    usage: Mutex<BTreeMap<FrontierKey, (u64, u64)>>,
    clock: AtomicU64,
}

impl FrontierStore {
    fn new() -> Self {
        FrontierStore {
            memo: StripedMemo::new(STRIPES),
            usage: Mutex::new(BTreeMap::new()),
            clock: AtomicU64::new(0),
        }
    }

    /// Total frontiers across all stripes (including in-flight cells).
    pub fn len(&self) -> usize {
        self.memo.len()
    }

    pub fn is_empty(&self) -> bool {
        self.memo.is_empty()
    }

    /// Fetch (or build exactly once) the frontier of `layer` at `point`
    /// under the handle's pricing context.  `shape` is
    /// `dse::frontier::shape_fingerprint(layer)`, precomputed by callers
    /// that price many candidates over the same geometry.
    pub(crate) fn get_or_build(
        &self,
        handle: &DeviceCacheHandle,
        shape: u64,
        layer: &LayerDesc,
        point: SparsityPoint,
        rm: &ResourceModel,
        dev: &DeviceBudget,
    ) -> Arc<LayerFrontier> {
        let key = FrontierKey {
            context: handle.frontier_fp,
            shape,
            point: (point.s_w.to_bits(), point.s_a.to_bits()),
        };
        let (frontier, fresh) = self
            .memo
            .get_or_compute(key.clone(), || Arc::new(build_frontier(layer, point, rm, dev)));
        if fresh {
            // relaxed: stats counters, hit/miss accounting only
            handle.stats.frontier_misses.fetch_add(1, Ordering::Relaxed);
        } else {
            // relaxed: stats counters, hit/miss accounting only
            handle.stats.frontier_hits.fetch_add(1, Ordering::Relaxed);
        }
        touch(&self.usage, &self.clock, key);
        frontier
    }
}

/// Bump an entry's (uses, last tick) in a store's usage map.  The maps
/// hold no cross-entry invariant, so a poisoned lock is recovered like
/// everywhere else in the cache.
fn touch<K: Ord>(
    usage: &Mutex<BTreeMap<K, (u64, u64)>>,
    clock: &AtomicU64,
    key: K,
) {
    // relaxed: tick allocator — uniqueness comes from the atomic RMW;
    // ticks only steer LRU eviction on save, never search results
    let tick = clock.fetch_add(1, Ordering::Relaxed) + 1;
    let mut map = lock_clean(usage);
    let e = map.entry(key).or_insert((0, 0));
    e.0 += 1;
    e.1 = tick;
}

/// Thread-safe, multi-device memo table for [`crate::dse::explore`]
/// results, plus the [`FrontierStore`] that makes its misses cheap.
///
/// Shared by reference across every shard's evaluation threads; both
/// stores sit on [`StripedMemo`], so lookups take one short-lived stripe
/// lock and the pricing itself runs unlocked behind a per-key cell,
/// computed exactly once (see the module docs).
pub struct DesignCache {
    designs: StripedMemo<Key, NetworkDesign>,
    devices: Mutex<BTreeMap<u64, Arc<DevStats>>>,
    frontiers: FrontierStore,
    /// per-entry (use count, last-touched tick) for LRU compaction
    usage: Mutex<BTreeMap<Key, (u64, u64)>>,
    clock: AtomicU64,
}

impl Default for DesignCache {
    fn default() -> Self {
        Self::new()
    }
}

impl DesignCache {
    /// An empty store, ready to serve any number of devices.
    pub fn new() -> Self {
        DesignCache {
            designs: StripedMemo::new(STRIPES),
            devices: Mutex::new(BTreeMap::new()),
            frontiers: FrontierStore::new(),
            usage: Mutex::new(BTreeMap::new()),
            clock: AtomicU64::new(0),
        }
    }

    /// The per-layer frontier store shared by this cache's devices.
    pub fn frontier_store(&self) -> &FrontierStore {
        &self.frontiers
    }

    /// Price `points` through the frontier store: fetch or build each
    /// layer's frontier (keyed by the handle's context + layer shape +
    /// layer point), then run the bisection on lookups.  Bit-identical to
    /// [`crate::dse::explore`]; `shapes[i]` must be
    /// `dse::frontier::shape_fingerprint` of compute layer `i`.
    ///
    /// This is the design-cache *miss* path of the engine — the design
    /// memo makes repeats O(1), this makes the non-repeats cheap.
    #[allow(clippy::too_many_arguments)]
    pub fn explore_via_frontiers(
        &self,
        handle: &DeviceCacheHandle,
        net: &Network,
        points: &[SparsityPoint],
        shapes: &[u64],
        rm: &ResourceModel,
        dev: &DeviceBudget,
        dse: &DseConfig,
    ) -> NetworkDesign {
        let compute = net.compute_layers();
        assert_eq!(compute.len(), points.len());
        assert_eq!(compute.len(), shapes.len());
        // infeasibility early-out before any frontier work — the same
        // check (same code) `dse::explore` starts with, so URAM-less
        // devices never touch the store
        let (minimal, min_res) = match minimal_checked(net, points, rm, dev) {
            Ok(min) => min,
            Err(unfit) => return unfit,
        };
        let frontiers: Vec<Arc<LayerFrontier>> = compute
            .iter()
            .zip(points.iter().zip(shapes))
            .map(|(l, (p, &s))| self.frontiers.get_or_build(handle, s, l, *p, rm, dev))
            .collect();
        explore_frontiers_checked(net, points, rm, dev, dse, &frontiers, minimal, min_res)
    }

    /// Register a device under a pricing context (network, resource
    /// model, DSE config), returning its handle.  Idempotent: the same
    /// budget under the same context returns a handle to the same
    /// counters; *any* context change re-keys the device so stale designs
    /// can never cross configurations.
    pub fn register(
        &self,
        dev: &DeviceBudget,
        net: &Network,
        rm: &ResourceModel,
        dse: &DseConfig,
    ) -> DeviceCacheHandle {
        let fp = pricing_fingerprint(dev, net, rm, dse);
        // poison-tolerant like the striped stores: the map holds no
        // invariant a panicking holder could corrupt, and a resident
        // server must keep registering devices after a worker panic
        let stats = lock_clean(&self.devices)
            .entry(fp)
            .or_insert_with(|| Arc::new(DevStats::default()))
            .clone();
        DeviceCacheHandle { fingerprint: fp, frontier_fp: frontier_fingerprint(dev, rm), stats }
    }

    /// Number of distinct (device, pricing context) registrations so far.
    pub fn device_count(&self) -> usize {
        lock_clean(&self.devices).len()
    }

    fn key(handle: &DeviceCacheHandle, points: &[SparsityPoint]) -> Key {
        Key { device: handle.fingerprint, points: point_bits(points) }
    }

    /// Return the cached design of `points` on the handle's device, or
    /// price via `compute` and remember the result.  `points` should
    /// already be snapped (see [`quantize_points`]); the key is their
    /// exact bit pattern.  `compute` runs at most once per key across all
    /// threads; late arrivals block on the in-flight cell.
    pub fn get_or_compute<F>(
        &self,
        handle: &DeviceCacheHandle,
        points: &[SparsityPoint],
        compute: F,
    ) -> NetworkDesign
    where
        F: FnOnce() -> NetworkDesign,
    {
        let key = Self::key(handle, points);
        let (design, fresh) = self.designs.get_or_compute(key.clone(), compute);
        if fresh {
            // relaxed: stats counters, hit/miss accounting only
            handle.stats.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            // relaxed: stats counters, hit/miss accounting only
            handle.stats.hits.fetch_add(1, Ordering::Relaxed);
        }
        touch(&self.usage, &self.clock, key);
        design
    }

    /// Counter-free lookup, the read half of [`insert`](Self::insert):
    /// used for reference designs (e.g. the dense pricing a warm cache
    /// already holds) that must not skew hit/miss accounting.  An entry
    /// still being computed by another thread reads as absent — callers
    /// recompute, which is benign because pricing is deterministic.
    pub fn get(
        &self,
        handle: &DeviceCacheHandle,
        points: &[SparsityPoint],
    ) -> Option<NetworkDesign> {
        self.designs.get(&Self::key(handle, points))
    }

    /// Pre-seed an entry (e.g. the dense reference design) without
    /// touching the hit/miss counters.
    pub fn insert(
        &self,
        handle: &DeviceCacheHandle,
        points: &[SparsityPoint],
        design: NetworkDesign,
    ) {
        self.designs.insert(Self::key(handle, points), design);
    }

    /// Total entries across all stripes and devices (including in-flight
    /// cells).
    pub fn len(&self) -> usize {
        self.designs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.designs.is_empty()
    }

    // ---- on-disk snapshots (see the module docs for the format) -------

    /// Serialize every **completed** entry of both stores (in-flight
    /// cells are skipped) into the versioned snapshot document.  Entry
    /// order is canonical (sorted by serialization), so the same cache
    /// contents always produce the same file.
    pub fn to_snapshot(&self) -> Json {
        let (designs, frontiers) = self.entry_lists();
        Self::snapshot_doc(designs, frontiers)
    }

    /// Every completed entry of both stores as `(tick, uses, entry)` —
    /// the working set [`Self::to_snapshot`] and
    /// [`Self::save_compacted`] order, merge and evict over.
    fn entry_lists(&self) -> (Vec<SnapshotEntry>, Vec<SnapshotEntry>) {
        let mut designs: Vec<SnapshotEntry> = Vec::new();
        {
            let usage = lock_clean(&self.usage);
            self.designs.for_each_complete(|k, v| {
                let (uses, tick) = usage.get(k).copied().unwrap_or((0, 0));
                designs.push((tick, uses, design_to_json(k, v, uses, tick)));
            });
        }
        let mut frontiers: Vec<SnapshotEntry> = Vec::new();
        {
            let usage = lock_clean(&self.frontiers.usage);
            self.frontiers.memo.for_each_complete(|k, f| {
                let (uses, tick) = usage.get(k).copied().unwrap_or((0, 0));
                frontiers.push((tick, uses, frontier_to_json(k, f, uses, tick)));
            });
        }
        (designs, frontiers)
    }

    /// Assemble the versioned document in canonical (sorted) entry order.
    fn snapshot_doc(designs: Vec<SnapshotEntry>, frontiers: Vec<SnapshotEntry>) -> Json {
        let mut dj: Vec<Json> = designs.into_iter().map(|(_, _, j)| j).collect();
        dj.sort_by_cached_key(|j| j.to_string());
        let mut fj: Vec<Json> = frontiers.into_iter().map(|(_, _, j)| j).collect();
        fj.sort_by_cached_key(|j| j.to_string());
        Json::obj(vec![
            ("format", Json::Str(SNAPSHOT_FORMAT.into())),
            ("version", Json::Num(SNAPSHOT_VERSION)),
            ("designs", Json::Arr(dj)),
            ("frontiers", Json::Arr(fj)),
        ])
    }

    /// Rebuild a cache from a snapshot document.  Unknown format or
    /// version is an error (nothing is loaded); individual entries that
    /// fail their integrity check or are malformed are *skipped* and
    /// counted, never loaded half-way.  Loaded entries are bit-identical
    /// to what [`Self::to_snapshot`] saw.
    pub fn from_snapshot(snapshot: &Json) -> Result<(DesignCache, SnapshotStats), String> {
        if snapshot.get("format").and_then(|f| f.as_str()) != Some(SNAPSHOT_FORMAT) {
            return Err("not a design-cache snapshot (bad or missing 'format')".into());
        }
        let version = snapshot.get("version").and_then(|v| v.as_f64());
        if version != Some(SNAPSHOT_VERSION) {
            return Err(format!(
                "unsupported design-cache snapshot version {version:?} \
                 (this build reads version {SNAPSHOT_VERSION})"
            ));
        }
        let cache = DesignCache::new();
        let mut stats = SnapshotStats::default();
        let designs = snapshot
            .get("designs")
            .and_then(|d| d.as_arr())
            .ok_or_else(|| "snapshot missing 'designs' array".to_string())?;
        let mut max_tick = 0u64;
        for entry in designs {
            match design_from_json(entry) {
                Some((key, design)) => {
                    let (uses, tick) = usage_of(entry);
                    if uses > 0 {
                        max_tick = max_tick.max(tick);
                        lock_clean(&cache.usage).insert(key.clone(), (uses, tick));
                    }
                    cache.designs.insert(key, design);
                    stats.designs += 1;
                }
                None => stats.skipped += 1,
            }
        }
        // relaxed: the cache is still private to this thread here
        cache.clock.store(max_tick, Ordering::Relaxed);
        let frontiers = snapshot
            .get("frontiers")
            .and_then(|d| d.as_arr())
            .ok_or_else(|| "snapshot missing 'frontiers' array".to_string())?;
        let mut max_tick = 0u64;
        for entry in frontiers {
            match frontier_from_json(entry) {
                Some((key, frontier)) => {
                    let (uses, tick) = usage_of(entry);
                    if uses > 0 {
                        max_tick = max_tick.max(tick);
                        lock_clean(&cache.frontiers.usage)
                            .insert(key.clone(), (uses, tick));
                    }
                    cache.frontiers.memo.insert(key, frontier);
                    stats.frontiers += 1;
                }
                None => stats.skipped += 1,
            }
        }
        // relaxed: the cache is still private to this thread here
        cache.frontiers.clock.store(max_tick, Ordering::Relaxed);
        Ok((cache, stats))
    }

    /// Write the snapshot to `path` (parent directories are created),
    /// returning how many entries were persisted.  The write goes to a
    /// sibling temp file first and renames over `path`, so an
    /// interrupted save (Ctrl-C, OOM mid-sweep) leaves the previous good
    /// snapshot intact instead of a truncated file.
    pub fn save<P: AsRef<std::path::Path>>(&self, path: P) -> std::io::Result<SnapshotStats> {
        self.save_compacted(path, 0)
    }

    /// [`save`](Self::save) with optional LRU compaction: a nonzero
    /// `max_entries` keeps at most that many design and frontier entries
    /// each, evicting least-recently-used entries first (oldest tick,
    /// then fewest uses — see the module docs).  Every save, capped or
    /// not, also *merges* with whatever another process persisted to
    /// `path` concurrently: under a best-effort advisory `<path>.lock`
    /// the on-disk entries this cache does not hold are adopted before
    /// the (atomic tmp+rename) write, so sharers union their work
    /// instead of the last writer discarding the first's.
    pub fn save_compacted<P: AsRef<std::path::Path>>(
        &self,
        path: P,
        max_entries: usize,
    ) -> std::io::Result<SnapshotStats> {
        let path = path.as_ref();
        if let Some(e) = fault::io_error("cache.save") {
            return Err(e);
        }
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let _lock = SnapshotLock::acquire(path);
        let (mut designs, mut frontiers) = self.entry_lists();
        // merge-on-save: a corrupt or foreign file merges nothing and is
        // simply overwritten (per-entry checksums keep corruption out)
        if let Ok(text) = std::fs::read_to_string(path) {
            if let Ok(disk) = Json::parse(&text) {
                if disk.get("format").and_then(|f| f.as_str()) == Some(SNAPSHOT_FORMAT)
                    && disk.get("version").and_then(|v| v.as_f64()) == Some(SNAPSHOT_VERSION)
                {
                    if let Some(d) = disk.get("designs").and_then(|d| d.as_arr()) {
                        merge_disk_entries(&mut designs, d);
                    }
                    if let Some(f) = disk.get("frontiers").and_then(|f| f.as_arr()) {
                        merge_disk_entries(&mut frontiers, f);
                    }
                }
            }
        }
        let evicted =
            evict_lru(&mut designs, max_entries) + evict_lru(&mut frontiers, max_entries);
        let stats = SnapshotStats {
            designs: designs.len(),
            frontiers: frontiers.len(),
            skipped: 0,
            evicted,
        };
        let snapshot = Self::snapshot_doc(designs, frontiers);
        // per-process tmp name: concurrent savers to one path each write
        // their own sibling and the renames are last-writer-wins with a
        // *valid* file either way
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(format!(".{}.tmp", std::process::id()));
        let tmp = std::path::PathBuf::from(tmp);
        std::fs::write(&tmp, snapshot.to_string())?;
        std::fs::rename(&tmp, path).inspect_err(|_| {
            let _ = std::fs::remove_file(&tmp);
        })?;
        Ok(stats)
    }

    /// Read a snapshot file written by [`Self::save`].  IO and parse
    /// problems are errors; per-entry integrity failures are counted in
    /// the returned stats instead (see [`Self::from_snapshot`]).
    pub fn load<P: AsRef<std::path::Path>>(
        path: P,
    ) -> Result<(DesignCache, SnapshotStats), String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let json =
            Json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))?;
        Self::from_snapshot(&json)
    }
}

/// Entry counts of one [`DesignCache::save`] / [`DesignCache::load`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SnapshotStats {
    /// whole-network design entries written / loaded
    pub designs: usize,
    /// layer-frontier entries written / loaded
    pub frontiers: usize,
    /// entries rejected on load (integrity-check or shape mismatch)
    pub skipped: usize,
    /// least-recently-used entries dropped by a capped save
    /// ([`DesignCache::save_compacted`]); always 0 on load
    pub evicted: usize,
}

/// `(last-touched tick, use count, serialized entry)` — the snapshot
/// working set.
type SnapshotEntry = (u64, u64, Json);

/// An entry's recorded usage (`uses`, `tick` fields; 0 when absent).
fn usage_of(entry: &Json) -> (u64, u64) {
    let uses = entry.get("uses").and_then(u64_field).unwrap_or(0);
    let tick = entry.get("tick").and_then(u64_field).unwrap_or(0);
    (uses, tick)
}

/// The key fields identifying an entry within its section (usage and
/// value payload excluded): design entries are `(fp, pts)`, frontier
/// entries `(ctx, shape, pt)`.
fn entry_identity(e: &Json) -> Option<String> {
    if let Some(fp) = e.get("fp") {
        return Some(format!("{}|{}", fp.to_string(), e.get("pts")?.to_string()));
    }
    Some(format!(
        "{}|{}|{}",
        e.get("ctx")?.to_string(),
        e.get("shape")?.to_string(),
        e.get("pt")?.to_string()
    ))
}

/// Fold a snapshot section already on disk into `mine`: entries we do
/// not hold in memory are adopted along with their recorded usage;
/// entries we do hold keep the in-memory version (it is at least as
/// fresh).  Entries failing their integrity check merge nothing.
fn merge_disk_entries(mine: &mut Vec<SnapshotEntry>, disk: &[Json]) {
    let have: BTreeSet<String> =
        mine.iter().filter_map(|(_, _, j)| entry_identity(j)).collect();
    for e in disk {
        if !check_matches(e) {
            continue;
        }
        let Some(id) = entry_identity(e) else { continue };
        if have.contains(&id) {
            continue;
        }
        let (uses, tick) = usage_of(e);
        mine.push((tick, uses, e.clone()));
    }
}

/// Drop least-recently-used entries past `cap` (0 = unlimited): oldest
/// tick first, fewest uses breaking ties, the serialization as the
/// final deterministic tiebreak.  Returns how many were evicted.
fn evict_lru(entries: &mut Vec<SnapshotEntry>, cap: usize) -> usize {
    if cap == 0 || entries.len() <= cap {
        return 0;
    }
    entries.sort_by_cached_key(|(tick, uses, j)| (*tick, *uses, j.to_string()));
    let evict = entries.len() - cap;
    entries.drain(..evict);
    evict
}

/// Best-effort advisory lock for snapshot saves: an atomically created
/// `<path>.lock` sibling.  Contended acquisition backs off a bounded
/// number of times; a lock left behind by a crashed holder is stolen by
/// age.  If the lock still cannot be taken the save proceeds unlocked —
/// the tmp+rename write stays atomic either way, the lock only makes
/// the concurrent read-merge-write cycles serialize.
struct SnapshotLock {
    path: std::path::PathBuf,
    held: bool,
}

impl SnapshotLock {
    const STALE: std::time::Duration = std::time::Duration::from_secs(10);

    fn acquire(target: &std::path::Path) -> SnapshotLock {
        let mut lock = target.as_os_str().to_owned();
        lock.push(".lock");
        let path = std::path::PathBuf::from(lock);
        for attempt in 0u32..10 {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    use std::io::Write;
                    let _ = write!(f, "{}", std::process::id());
                    return SnapshotLock { path, held: true };
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = std::fs::metadata(&path)
                        .and_then(|m| m.modified())
                        .ok()
                        .and_then(|t| t.elapsed().ok())
                        .is_some_and(|age| age > Self::STALE);
                    if stale {
                        let _ = std::fs::remove_file(&path);
                        continue;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(
                        5u64.checked_shl(attempt).unwrap_or(u64::MAX).min(80),
                    ));
                }
                // unwritable directory, permission trouble: the write
                // itself will surface the real error — proceed unlocked
                Err(_) => break,
            }
        }
        SnapshotLock { path, held: false }
    }
}

impl Drop for SnapshotLock {
    fn drop(&mut self) {
        if self.held {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// `--cache-file <path>` support shared by the bench sweep drivers
/// (`ablations`, `fig5_search_traj`, `table2_comparison`): scan argv for
/// the flag, load a warm cache (cold start on a missing file; cold start
/// with a stderr note on a corrupt one — a sweep must never hard-fail on
/// its own cache), and hand back the path for [`save_cache_file`].
/// `tag` prefixes the notes (e.g. `"[fig5]"`).
pub fn cache_file_from_args(tag: &str) -> (DesignCache, Option<String>) {
    let mut args = std::env::args();
    let mut path: Option<String> = None;
    while let Some(a) = args.next() {
        if a == "--cache-file" {
            match args.next() {
                // a following flag (e.g. `--cache-file --quick`) is not a
                // path — don't swallow it and write a file named "--quick"
                Some(p) if !p.starts_with("--") => path = Some(p),
                _ => eprintln!("{tag} --cache-file needs a path; ignoring the flag"),
            }
        }
    }
    let cache = match &path {
        Some(p) if std::path::Path::new(p).exists() => match DesignCache::load(p) {
            Ok((cache, st)) => {
                eprintln!(
                    "{tag} cache <- {p}: {} designs, {} frontiers",
                    st.designs, st.frontiers
                );
                cache
            }
            Err(e) => {
                eprintln!("{tag} warning: starting cold: {e}");
                DesignCache::new()
            }
        },
        _ => DesignCache::new(),
    };
    (cache, path)
}

/// Save a sweep driver's cache back to its `--cache-file` path (no-op
/// without one); failures are reported, not fatal.
pub fn save_cache_file(cache: &DesignCache, path: &Option<String>, tag: &str) {
    if let Some(p) = path {
        match cache.save(p) {
            Ok(st) => eprintln!(
                "{tag} cache -> {p}: {} designs, {} frontiers",
                st.designs, st.frontiers
            ),
            Err(e) => eprintln!("{tag} failed to save cache '{p}': {e}"),
        }
    }
}

const SNAPSHOT_FORMAT: &str = "hass-design-cache";
const SNAPSHOT_VERSION: f64 = 1.0;

/// FNV-1a over an entry's fields, the `check` field excluded: each key
/// and its value's canonical serialization are folded in, in `BTreeMap`
/// (sorted) key order.  Values serialize deterministically, so the
/// checksum is representation-stable — and hashing field by field means
/// verification needs neither a deep clone of the entry nor a
/// re-serialization of the whole object.  The usage fields (`uses`,
/// `tick`) are excluded too: they are bookkeeping, not payload, and
/// excluding them keeps the snapshot format at version 1 (old files
/// load unchanged, old builds skip nothing).
fn entry_checksum(fields: &BTreeMap<String, Json>) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for (k, v) in fields {
        if k == "check" || k == "uses" || k == "tick" {
            continue;
        }
        h = fnv_extend(h, k);
        h = fnv_extend(h, &v.to_string());
    }
    h
}

/// Stamp an entry object with its `check` fingerprint.
fn with_check(entry: Json) -> Json {
    match entry {
        Json::Obj(mut m) => {
            let check = entry_checksum(&m);
            m.insert("check".into(), Json::Str(u64_to_hex(check)));
            Json::Obj(m)
        }
        other => other,
    }
}

/// Does the entry's recorded `check` match its payload?
fn check_matches(entry: &Json) -> bool {
    let Json::Obj(m) = entry else { return false };
    let Some(stored) = m.get("check").and_then(|c| c.as_str()).and_then(u64_from_hex) else {
        return false;
    };
    entry_checksum(m) == stored
}

fn hex_field(j: &Json) -> Option<u64> {
    u64_from_hex(j.as_str()?)
}

/// Integer-valued JSON number → usize (rejects negatives, fractions and
/// anything outside f64's exact-integer range).
fn usize_field(j: &Json) -> Option<usize> {
    let f = j.as_f64()?;
    if !(0.0..=9.0e15).contains(&f) || f.fract() != 0.0 {
        return None;
    }
    Some(f as usize)
}

fn u64_field(j: &Json) -> Option<u64> {
    usize_field(j).map(|v| v as u64)
}

fn resources_to_json(r: &Resources) -> Json {
    Json::Arr(vec![
        Json::Num(r.dsp as f64),
        Json::Num(r.lut as f64),
        Json::Num(r.bram18k as f64),
        Json::Num(r.uram as f64),
    ])
}

fn resources_from_json(j: &Json) -> Option<Resources> {
    let a = j.as_arr()?;
    if a.len() != 4 {
        return None;
    }
    Some(Resources {
        dsp: u64_field(&a[0])?,
        lut: u64_field(&a[1])?,
        bram18k: u64_field(&a[2])?,
        uram: u64_field(&a[3])?,
    })
}

fn layer_design_from_json(j: &Json) -> Option<LayerDesign> {
    let a = j.as_arr()?;
    if a.len() != 3 {
        return None;
    }
    let (i_par, o_par, n_mac) = (usize_field(&a[0])?, usize_field(&a[1])?, usize_field(&a[2])?);
    if i_par == 0 || o_par == 0 || n_mac == 0 {
        return None;
    }
    Some(LayerDesign { i_par, o_par, n_mac })
}

fn design_to_json(key: &Key, design: &NetworkDesign, uses: u64, tick: u64) -> Json {
    let mut pts = Vec::with_capacity(key.points.len() * 2);
    for &(w, a) in &key.points {
        pts.push(Json::Str(u64_to_hex(w)));
        pts.push(Json::Str(u64_to_hex(a)));
    }
    let ds: Vec<Json> = design
        .designs
        .iter()
        .map(|d| {
            Json::Arr(vec![
                Json::Num(d.i_par as f64),
                Json::Num(d.o_par as f64),
                Json::Num(d.n_mac as f64),
            ])
        })
        .collect();
    let mut fields = vec![
        ("fp", Json::Str(u64_to_hex(key.device))),
        ("pts", Json::Arr(pts)),
        ("thr", Json::Str(u64_to_hex(design.throughput.to_bits()))),
        ("res", resources_to_json(&design.resources)),
        ("ds", Json::Arr(ds)),
    ];
    if uses > 0 {
        fields.push(("uses", Json::Num(uses as f64)));
        fields.push(("tick", Json::Num(tick as f64)));
    }
    with_check(Json::obj(fields))
}

fn design_from_json(entry: &Json) -> Option<(Key, NetworkDesign)> {
    if !check_matches(entry) {
        return None;
    }
    let device = hex_field(entry.get("fp")?)?;
    let pts = entry.get("pts")?.as_arr()?;
    // zero-layer keys never arise from real pricings — reject them like
    // any other malformed shape
    if pts.is_empty() || pts.len() % 2 != 0 {
        return None;
    }
    let mut points = Vec::with_capacity(pts.len() / 2);
    for pair in pts.chunks(2) {
        points.push((hex_field(&pair[0])?, hex_field(&pair[1])?));
    }
    let throughput = f64::from_bits(hex_field(entry.get("thr")?)?);
    let resources = resources_from_json(entry.get("res")?)?;
    let designs = entry
        .get("ds")?
        .as_arr()?
        .iter()
        .map(layer_design_from_json)
        .collect::<Option<Vec<_>>>()?;
    Some((Key { device, points }, NetworkDesign { designs, throughput, resources }))
}

fn frontier_to_json(key: &FrontierKey, frontier: &LayerFrontier, uses: u64, tick: u64) -> Json {
    let es: Vec<Json> = frontier
        .entries()
        .iter()
        .map(|e| {
            Json::Arr(vec![
                Json::Str(u64_to_hex(e.rate.to_bits())),
                Json::Str(u64_to_hex(e.cycles)),
                Json::Str(u64_to_hex(e.cost.to_bits())),
                Json::Num(e.design.i_par as f64),
                Json::Num(e.design.o_par as f64),
                Json::Num(e.design.n_mac as f64),
                Json::Num(e.resources.dsp as f64),
                Json::Num(e.resources.lut as f64),
                Json::Num(e.resources.bram18k as f64),
                Json::Num(e.resources.uram as f64),
            ])
        })
        .collect();
    let pt = vec![Json::Str(u64_to_hex(key.point.0)), Json::Str(u64_to_hex(key.point.1))];
    let mut fields = vec![
        ("ctx", Json::Str(u64_to_hex(key.context))),
        ("shape", Json::Str(u64_to_hex(key.shape))),
        ("pt", Json::Arr(pt)),
        ("es", Json::Arr(es)),
    ];
    if uses > 0 {
        fields.push(("uses", Json::Num(uses as f64)));
        fields.push(("tick", Json::Num(tick as f64)));
    }
    with_check(Json::obj(fields))
}

fn frontier_from_json(entry: &Json) -> Option<(FrontierKey, Arc<LayerFrontier>)> {
    if !check_matches(entry) {
        return None;
    }
    let context = hex_field(entry.get("ctx")?)?;
    let shape = hex_field(entry.get("shape")?)?;
    let pt = entry.get("pt")?.as_arr()?;
    if pt.len() != 2 {
        return None;
    }
    let point = (hex_field(&pt[0])?, hex_field(&pt[1])?);
    let mut entries = Vec::new();
    for row in entry.get("es")?.as_arr()? {
        let row = row.as_arr()?;
        if row.len() != 10 {
            return None;
        }
        let design = LayerDesign {
            i_par: usize_field(&row[3])?,
            o_par: usize_field(&row[4])?,
            n_mac: usize_field(&row[5])?,
        };
        if design.i_par == 0 || design.o_par == 0 || design.n_mac == 0 {
            return None;
        }
        entries.push(FrontierEntry {
            rate: f64::from_bits(hex_field(&row[0])?),
            cycles: hex_field(&row[1])?,
            cost: f64::from_bits(hex_field(&row[2])?),
            design,
            resources: Resources {
                dsp: u64_field(&row[6])?,
                lut: u64_field(&row[7])?,
                bram18k: u64_field(&row[8])?,
                uram: u64_field(&row[9])?,
            },
        });
    }
    // `build_frontier` never yields an empty frontier; an empty entry
    // would make the warm run price the layer as infeasible (queries
    // return None), silently diverging from the cold run — reject it
    if entries.is_empty() || !entries_are_ordered(&entries) {
        return None;
    }
    let key = FrontierKey { context, shape, point };
    Some((key, Arc::new(LayerFrontier::from_entries(entries))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::resources::Resources;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Barrier;

    fn design(dsp: u64) -> NetworkDesign {
        NetworkDesign {
            designs: vec![],
            throughput: 1e-5,
            resources: Resources { dsp, lut: 0, bram18k: 0, uram: 0 },
        }
    }

    fn pts(vals: &[(f64, f64)]) -> Vec<SparsityPoint> {
        vals.iter().map(|&(s_w, s_a)| SparsityPoint { s_w, s_a }).collect()
    }

    /// Register under a fixed test pricing context (calibnet + defaults).
    fn reg(cache: &DesignCache, dev: &DeviceBudget) -> DeviceCacheHandle {
        cache.register(
            dev,
            &crate::arch::networks::calibnet(),
            &ResourceModel::default(),
            &DseConfig::default(),
        )
    }

    fn u250_cache() -> (DesignCache, DeviceCacheHandle) {
        let cache = DesignCache::new();
        let h = reg(&cache, &DeviceBudget::u250());
        (cache, h)
    }

    #[test]
    fn miss_then_hit_counts_and_returns_cached_value() {
        let (cache, h) = u250_cache();
        let p = pts(&[(0.5, 0.25), (0.125, 0.0)]);
        let mut computes = 0;
        let a = cache.get_or_compute(&h, &p, || {
            computes += 1;
            design(42)
        });
        let b = cache.get_or_compute(&h, &p, || {
            computes += 1;
            design(999) // must not be called
        });
        assert_eq!(computes, 1);
        assert_eq!(a.resources.dsp, 42);
        assert_eq!(b.resources.dsp, 42);
        assert_eq!(h.hits(), 1);
        assert_eq!(h.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_points_are_distinct_entries() {
        let (cache, h) = u250_cache();
        cache.get_or_compute(&h, &pts(&[(0.5, 0.5)]), || design(1));
        cache.get_or_compute(&h, &pts(&[(0.5, 0.5000001)]), || design(2));
        assert_eq!(h.misses(), 2);
        assert_eq!(h.hits(), 0);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn quantization_collapses_nearby_points() {
        // at 8 bits the grid step is 1/256 ≈ 3.9e-3: points 1e-4 apart snap
        // to the same representative, points far apart stay distinct
        let a = quantize_points(&pts(&[(0.5000, 0.3000)]), 8);
        let b = quantize_points(&pts(&[(0.5001, 0.2999)]), 8);
        let c = quantize_points(&pts(&[(0.6000, 0.3000)]), 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // snapped values are exact multiples of the grid
        assert_eq!(a[0].s_w, 128.0 / 256.0);
    }

    #[test]
    fn zero_bits_is_identity() {
        let p = pts(&[(0.123456789, 0.987654321)]);
        let q = quantize_points(&p, 0);
        assert_eq!(p[0].s_w.to_bits(), q[0].s_w.to_bits());
        assert_eq!(p[0].s_a.to_bits(), q[0].s_a.to_bits());
    }

    #[test]
    fn quantization_error_is_bounded_by_grid() {
        let p = pts(&[(0.777, 0.333)]);
        for bits in [8u32, 12, 16] {
            let q = quantize_points(&p, bits);
            let step = 1.0 / (1u64 << bits) as f64;
            assert!((q[0].s_w - 0.777).abs() <= step / 2.0 + 1e-15);
            assert!((q[0].s_a - 0.333).abs() <= step / 2.0 + 1e-15);
        }
    }

    // ---- property tests (util::prop) --------------------------------

    #[test]
    fn prop_quantize_is_idempotent() {
        // snapped points are exact grid multiples, so snapping again is a
        // bitwise no-op (round(int) == int; the grid is a power of two)
        forall(200, 0xA1, |rng| {
            let bits = [4u32, 8, 12, 16, 24][rng.below(5)];
            let p: Vec<SparsityPoint> = (0..rng.below(6) + 1)
                .map(|_| SparsityPoint { s_w: rng.f64(), s_a: rng.f64() })
                .collect();
            let q1 = quantize_points(&p, bits);
            let q2 = quantize_points(&q1, bits);
            for (a, b) in q1.iter().zip(&q2) {
                assert_eq!(a.s_w.to_bits(), b.s_w.to_bits(), "s_w not idempotent");
                assert_eq!(a.s_a.to_bits(), b.s_a.to_bits(), "s_a not idempotent");
            }
        });
    }

    #[test]
    fn prop_quantize_is_monotone() {
        forall(200, 0xA2, |rng| {
            let bits = [4u32, 8, 12, 16][rng.below(4)];
            let (a, b) = (rng.f64(), rng.f64());
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let q = quantize_points(
                &pts(&[(lo, lo), (hi, hi)]),
                bits,
            );
            assert!(q[0].s_w <= q[1].s_w, "rounding must preserve order");
            assert!(q[0].s_a <= q[1].s_a, "rounding must preserve order");
        });
    }

    #[test]
    fn prop_quantize_error_within_half_grid_step_and_unit_range() {
        forall(200, 0xA3, |rng| {
            let bits = 1 + rng.below(32) as u32;
            let step = 1.0 / (1u64 << bits.min(52)) as f64;
            let p = SparsityPoint { s_w: rng.f64(), s_a: rng.f64() };
            let q = &quantize_points(&[p], bits)[0];
            assert!((q.s_w - p.s_w).abs() <= step / 2.0 + 1e-12);
            assert!((q.s_a - p.s_a).abs() <= step / 2.0 + 1e-12);
            assert!((0.0..=1.0).contains(&q.s_w));
            assert!((0.0..=1.0).contains(&q.s_a));
        });
    }

    /// Random perturbations of a device budget must change the fingerprint
    /// — fingerprints are what keep per-device cache keys disjoint.
    #[test]
    fn prop_distinct_device_budgets_never_share_a_fingerprint() {
        forall(200, 0xA4, |rng| {
            let base = DeviceBudget {
                name: "dev".into(),
                dsp: 1 + rng.below(20_000) as u64,
                lut: 1 + rng.below(2_000_000) as u64,
                bram18k: 1 + rng.below(10_000) as u64,
                uram: rng.below(2_000) as u64,
                freq_mhz: 50.0 + rng.f64() * 500.0,
            };
            let mut other = base.clone();
            match rng.below(6) {
                0 => other.name.push('x'),
                1 => other.dsp += 1,
                2 => other.lut += 1,
                3 => other.bram18k += 1,
                4 => other.uram += 1,
                _ => other.freq_mhz += 0.125,
            }
            assert_ne!(base, other, "perturbation must change the budget");
            assert_ne!(
                device_fingerprint(&base),
                device_fingerprint(&other),
                "distinct budgets collided: {base:?} vs {other:?}"
            );
        });
    }

    #[test]
    fn registered_devices_get_disjoint_key_spaces() {
        let cache = DesignCache::new();
        let h_u250 = reg(&cache, &DeviceBudget::u250());
        let h_v7 = reg(&cache, &DeviceBudget::v7_690t());
        assert_ne!(h_u250.fingerprint(), h_v7.fingerprint());
        // identical points on two devices: two entries, zero cross-hits
        let p = pts(&[(0.5, 0.5)]);
        cache.get_or_compute(&h_u250, &p, || design(1));
        cache.get_or_compute(&h_v7, &p, || design(2));
        assert_eq!(cache.len(), 2);
        assert_eq!(h_u250.misses(), 1);
        assert_eq!(h_v7.misses(), 1);
        assert_eq!(h_u250.hits() + h_v7.hits(), 0);
        // and each device still sees its own design
        assert_eq!(cache.get_or_compute(&h_u250, &p, || design(9)).resources.dsp, 1);
        assert_eq!(cache.get_or_compute(&h_v7, &p, || design(9)).resources.dsp, 2);
        assert_eq!(cache.device_count(), 2);
    }

    #[test]
    fn reregistering_a_device_shares_its_counters() {
        let cache = DesignCache::new();
        let h1 = reg(&cache, &DeviceBudget::u250());
        cache.get_or_compute(&h1, &pts(&[(0.1, 0.2)]), || design(3));
        let h2 = reg(&cache, &DeviceBudget::u250());
        assert_eq!(h2.misses(), 1, "stats must survive re-registration");
        cache.get_or_compute(&h2, &pts(&[(0.1, 0.2)]), || design(4));
        assert_eq!(h1.hits(), 1);
        assert_eq!(cache.device_count(), 1);
    }

    /// A warm cache queried under a different pricing context (here: a
    /// different DSE config / network) must miss, never serve the old
    /// configuration's designs.
    #[test]
    fn different_pricing_contexts_never_share_entries() {
        let cache = DesignCache::new();
        let dev = DeviceBudget::u250();
        let net = crate::arch::networks::calibnet();
        let rm = ResourceModel::default();
        let h1 = cache.register(&dev, &net, &rm, &DseConfig::default());
        let p = pts(&[(0.5, 0.5)]);
        cache.get_or_compute(&h1, &p, || design(1));
        // same device, different DSE config: new key space
        let dse2 = DseConfig { max_iters: 1_500, ..DseConfig::default() };
        let h2 = cache.register(&dev, &net, &rm, &dse2);
        assert_ne!(h1.fingerprint(), h2.fingerprint());
        assert!(cache.get(&h2, &p).is_none(), "stale design crossed configs");
        // same device, different network: new key space too
        let net2 = crate::arch::networks::resnet18();
        let h3 = cache.register(&dev, &net2, &rm, &DseConfig::default());
        assert_ne!(h1.fingerprint(), h3.fingerprint());
        assert!(cache.get(&h3, &p).is_none());
        assert_eq!(cache.device_count(), 3);
    }

    #[test]
    fn get_is_counter_free_and_sees_only_completed_entries() {
        let (cache, h) = u250_cache();
        let p = pts(&[(0.5, 0.5)]);
        assert!(cache.get(&h, &p).is_none());
        cache.insert(&h, &p, design(11));
        assert_eq!(cache.get(&h, &p).unwrap().resources.dsp, 11);
        // neither the miss-shaped nor the hit-shaped lookup counted
        assert_eq!(h.hits() + h.misses(), 0);
        // and a computed entry is visible to `get` too
        let q = pts(&[(0.25, 0.125)]);
        cache.get_or_compute(&h, &q, || design(12));
        assert_eq!(cache.get(&h, &q).unwrap().resources.dsp, 12);
    }

    #[test]
    fn preseeded_entry_hits_without_miss() {
        let (cache, h) = u250_cache();
        let p = pts(&[(0.0, 0.0)]);
        cache.insert(&h, &p, design(7));
        let d = cache.get_or_compute(&h, &p, || design(1000));
        assert_eq!(d.resources.dsp, 7);
        assert_eq!(h.hits(), 1);
        assert_eq!(h.misses(), 0);
    }

    /// Stats-level companion of the double-compute regression test (the
    /// single-compute core itself is tested in `util::memo`): many
    /// threads missing the same key must account one miss and
    /// THREADS − 1 hits on the device's counters.
    #[test]
    fn contended_miss_computes_exactly_once() {
        const THREADS: usize = 8;
        let (cache, h) = u250_cache();
        let p = pts(&[(0.25, 0.75)]);
        let computes = AtomicUsize::new(0);
        let gate = Barrier::new(THREADS);
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    gate.wait(); // maximize overlap on the first lookup
                    let d = cache.get_or_compute(&h, &p, || {
                        computes.fetch_add(1, Ordering::SeqCst);
                        // widen the race window: late arrivals must block
                        // on the in-flight cell, not recompute
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        design(5)
                    });
                    assert_eq!(d.resources.dsp, 5);
                });
            }
        });
        assert_eq!(computes.load(Ordering::SeqCst), 1, "duplicate compute");
        assert_eq!(cache.len(), 1);
        assert_eq!(h.misses(), 1, "exactly one thread may count the miss");
        assert_eq!(h.hits(), (THREADS - 1) as u64);
    }

    #[test]
    fn concurrent_lookups_are_consistent() {
        let (cache, h) = u250_cache();
        let p = pts(&[(0.25, 0.75)]);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        let d = cache.get_or_compute(&h, &p, || design(5));
                        assert_eq!(d.resources.dsp, 5);
                    }
                });
            }
        });
        assert_eq!(cache.len(), 1);
        // every lookup either hit or missed; exactly the first missed
        assert_eq!(h.hits() + h.misses(), 200);
        assert_eq!(h.misses(), 1);
    }

    // ---- frontier store ----------------------------------------------

    #[test]
    fn frontier_store_counts_hits_and_misses_per_device() {
        let cache = DesignCache::new();
        let net = crate::arch::networks::calibnet();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let h = cache.register(&dev, &net, &rm, &DseConfig::default());
        let layer = net.compute_layers()[0];
        let shape = crate::dse::frontier::shape_fingerprint(layer);
        let p = SparsityPoint { s_w: 0.5, s_a: 0.25 };
        let a = cache.frontier_store().get_or_build(&h, shape, layer, p, &rm, &dev);
        let b = cache.frontier_store().get_or_build(&h, shape, layer, p, &rm, &dev);
        assert!(Arc::ptr_eq(&a, &b), "repeat lookup must share the frontier");
        assert_eq!(h.frontier_misses(), 1);
        assert_eq!(h.frontier_hits(), 1);
        assert_eq!(cache.frontier_store().len(), 1);
        // a different point is a different frontier
        let q = SparsityPoint { s_w: 0.5, s_a: 0.5 };
        cache.frontier_store().get_or_build(&h, shape, layer, q, &rm, &dev);
        assert_eq!(h.frontier_misses(), 2);
        assert_eq!(cache.frontier_store().len(), 2);
        // ...and so is the same point under another device's context
        let h2 = cache.register(&DeviceBudget::v7_690t(), &net, &rm, &DseConfig::default());
        cache.frontier_store().get_or_build(&h2, shape, layer, p, &rm, &DeviceBudget::v7_690t());
        assert_eq!(h2.frontier_misses(), 1);
        assert_eq!(h2.frontier_hits(), 0);
        assert_eq!(cache.frontier_store().len(), 3);
        // frontier traffic never touches the whole-design counters
        assert_eq!(h.hits() + h.misses() + h2.hits() + h2.misses(), 0);
    }

    /// The frontier store is keyed by (device, resource model, shape,
    /// point) — narrower than the design cache — so contexts differing
    /// only in network or DSE config share frontiers.
    #[test]
    fn frontiers_shared_across_pricing_contexts_with_same_device_and_rm() {
        let cache = DesignCache::new();
        let dev = DeviceBudget::u250();
        let rm = ResourceModel::default();
        let calib = crate::arch::networks::calibnet();
        let net18 = crate::arch::networks::resnet18();
        let h1 = cache.register(&dev, &calib, &rm, &DseConfig::default());
        let dse2 = DseConfig { max_iters: 32, ..DseConfig::default() };
        let h2 = cache.register(&dev, &net18, &rm, &dse2);
        assert_ne!(h1.fingerprint(), h2.fingerprint(), "design contexts must differ");
        let layer = calib.compute_layers()[0];
        let shape = crate::dse::frontier::shape_fingerprint(layer);
        let p = SparsityPoint { s_w: 0.25, s_a: 0.25 };
        let a = cache.frontier_store().get_or_build(&h1, shape, layer, p, &rm, &dev);
        let b = cache.frontier_store().get_or_build(&h2, shape, layer, p, &rm, &dev);
        assert!(Arc::ptr_eq(&a, &b), "same (device, rm, shape, point) must share");
        assert_eq!(h1.frontier_misses(), 1);
        assert_eq!(h2.frontier_hits(), 1);
        assert_eq!(cache.frontier_store().len(), 1);
        // a different resource model is a different frontier context
        let rm2 = ResourceModel { lut_per_mac: 39.0, ..ResourceModel::default() };
        let h3 = cache.register(&dev, &calib, &rm2, &DseConfig::default());
        cache.frontier_store().get_or_build(&h3, shape, layer, p, &rm2, &dev);
        assert_eq!(h3.frontier_misses(), 1);
        assert_eq!(cache.frontier_store().len(), 2);
    }

    #[test]
    fn explore_via_frontiers_is_bit_identical_to_explore() {
        let cache = DesignCache::new();
        let net = crate::arch::networks::calibnet();
        let n = net.compute_layers().len();
        let rm = ResourceModel::default();
        let dse = DseConfig::default();
        let shapes: Vec<u64> = net
            .compute_layers()
            .iter()
            .map(|l| crate::dse::frontier::shape_fingerprint(l))
            .collect();
        for dev in [DeviceBudget::u250(), DeviceBudget::v7_690t()] {
            let h = cache.register(&dev, &net, &rm, &dse);
            for s in [0.0, 0.4] {
                let points = vec![SparsityPoint { s_w: s, s_a: s }; n];
                let via = cache.explore_via_frontiers(&h, &net, &points, &shapes, &rm, &dev, &dse);
                let plain = crate::dse::explore(&net, &points, &rm, &dev, &dse);
                assert_eq!(via.designs, plain.designs, "{}/s={s}", dev.name);
                assert_eq!(via.throughput.to_bits(), plain.throughput.to_bits());
                assert_eq!(via.resources, plain.resources);
            }
        }
        // the URAM-less device early-outs before the store: only the U250
        // populated frontiers
        let h250 = cache.register(&DeviceBudget::u250(), &net, &rm, &dse);
        assert!(h250.frontier_misses() > 0);
        let h7 = cache.register(&DeviceBudget::v7_690t(), &net, &rm, &dse);
        assert_eq!(h7.frontier_misses() + h7.frontier_hits(), 0);
    }

    #[test]
    fn stripes_spread_entries() {
        let (cache, h) = u250_cache();
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            let p = pts(&[(rng.f64(), rng.f64()), (rng.f64(), rng.f64())]);
            cache.get_or_compute(&h, &p, || design(1));
        }
        assert_eq!(cache.len(), 200);
        // with 200 random keys over 16 stripes, no stripe should hold more
        // than half of everything (a loose check that striping is active)
        let max_stripe = cache.designs.stripe_lens().into_iter().max().unwrap();
        assert!(max_stripe < 100, "stripe imbalance: {max_stripe}/200");
    }

    // ---- on-disk snapshots -------------------------------------------

    #[test]
    fn snapshot_roundtrips_the_design_memo_bit_for_bit() {
        let (cache, h) = u250_cache();
        let p1 = pts(&[(0.5, 0.25), (0.125, 0.0)]);
        let p2 = pts(&[(0.3, 0.7)]);
        cache.get_or_compute(&h, &p1, || NetworkDesign {
            designs: vec![LayerDesign { i_par: 2, o_par: 4, n_mac: 9 }],
            throughput: 0.1 + 0.2, // not exactly representable: bit test
            resources: Resources { dsp: 42, lut: 1_000_000, bram18k: 77, uram: 3 },
        });
        cache.insert(&h, &p2, design(7));
        let snap = cache.to_snapshot();
        let (loaded, st) = DesignCache::from_snapshot(&snap).unwrap();
        assert_eq!(st, SnapshotStats { designs: 2, frontiers: 0, skipped: 0, evicted: 0 });
        let h2 = reg(&loaded, &DeviceBudget::u250());
        let back = loaded.get(&h2, &p1).expect("loaded entry");
        assert_eq!(back.throughput.to_bits(), (0.1f64 + 0.2).to_bits());
        assert_eq!(back.resources, Resources { dsp: 42, lut: 1_000_000, bram18k: 77, uram: 3 });
        assert_eq!(back.designs, vec![LayerDesign { i_par: 2, o_par: 4, n_mac: 9 }]);
        assert_eq!(loaded.get(&h2, &p2).unwrap().resources.dsp, 7);
        // a loaded entry serves get_or_compute as a plain hit
        let d = loaded.get_or_compute(&h2, &p1, || design(999));
        assert_eq!(d.resources.dsp, 42);
        assert_eq!(h2.hits(), 1);
        assert_eq!(h2.misses(), 0);
    }

    #[test]
    fn snapshot_roundtrips_frontiers_including_infinite_costs() {
        let cache = DesignCache::new();
        let net = crate::arch::networks::calibnet();
        let rm = ResourceModel::default();
        let p = SparsityPoint { s_w: 0.5, s_a: 0.25 };
        let layer = net.compute_layers()[0];
        let shape = crate::dse::frontier::shape_fingerprint(layer);
        // v7_690t has no URAM: every frontier cost is +inf — the encoding
        // torture test (JSON numbers cannot carry inf)
        let devs = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
        for dev in &devs {
            let h = cache.register(dev, &net, &rm, &DseConfig::default());
            cache.frontier_store().get_or_build(&h, shape, layer, p, &rm, dev);
        }
        let (loaded, st) = DesignCache::from_snapshot(&cache.to_snapshot()).unwrap();
        assert_eq!(st, SnapshotStats { designs: 0, frontiers: 2, skipped: 0, evicted: 0 });
        assert_eq!(loaded.frontier_store().len(), 2);
        for dev in &devs {
            let h = loaded.register(dev, &net, &rm, &DseConfig::default());
            let f = loaded.frontier_store().get_or_build(&h, shape, layer, p, &rm, dev);
            assert_eq!(h.frontier_misses(), 0, "{}: loaded frontier must hit", dev.name);
            assert_eq!(h.frontier_hits(), 1);
            let fresh = build_frontier(layer, p, &rm, dev);
            assert_eq!(f.entries().len(), fresh.entries().len());
            for (a, b) in f.entries().iter().zip(fresh.entries()) {
                assert_eq!(a.rate.to_bits(), b.rate.to_bits());
                assert_eq!(a.cycles, b.cycles);
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
                assert_eq!(a.design, b.design);
                assert_eq!(a.resources, b.resources);
            }
            if dev.uram == 0 {
                assert!(f.entries().iter().all(|e| e.cost.is_infinite()));
            }
        }
    }

    #[test]
    fn snapshot_format_and_version_are_enforced() {
        let cache = DesignCache::new();
        let snap = cache.to_snapshot();
        assert!(DesignCache::from_snapshot(&snap).is_ok());
        assert!(DesignCache::from_snapshot(&Json::parse("{}").unwrap()).is_err());
        let Json::Obj(mut m) = snap else { unreachable!() };
        m.insert("version".into(), Json::Num(2.0));
        assert!(DesignCache::from_snapshot(&Json::Obj(m)).is_err());
    }

    #[test]
    fn save_and_load_roundtrip_via_file() {
        let (cache, h) = u250_cache();
        cache.get_or_compute(&h, &pts(&[(0.5, 0.5)]), || design(3));
        let path = std::env::temp_dir().join("hass_cache_save_load_test.json");
        let saved = cache.save(&path).unwrap();
        assert_eq!(saved, SnapshotStats { designs: 1, frontiers: 0, skipped: 0, evicted: 0 });
        let (loaded, st) = DesignCache::load(&path).unwrap();
        assert_eq!(st.designs, 1);
        let h2 = reg(&loaded, &DeviceBudget::u250());
        assert_eq!(loaded.get(&h2, &pts(&[(0.5, 0.5)])).unwrap().resources.dsp, 3);
        std::fs::remove_file(&path).ok();
        assert!(DesignCache::load(&path).is_err(), "missing file must error");
    }

    #[test]
    fn snapshot_files_are_canonical() {
        // same contents, two caches filled in different orders -> same file
        let (a, ha) = u250_cache();
        let (b, hb) = u250_cache();
        let p1 = pts(&[(0.5, 0.5)]);
        let p2 = pts(&[(0.25, 0.75)]);
        a.insert(&ha, &p1, design(1));
        a.insert(&ha, &p2, design(2));
        b.insert(&hb, &p2, design(2));
        b.insert(&hb, &p1, design(1));
        assert_eq!(a.to_snapshot().to_string(), b.to_snapshot().to_string());
    }

    #[test]
    fn prop_snapshot_roundtrips_arbitrary_quantized_points() {
        forall(40, 0xA5, |rng| {
            let cache = DesignCache::new();
            let h = reg(&cache, &DeviceBudget::u250());
            let bits = [4u32, 8, 12][rng.below(3)];
            let mut keys: Vec<Vec<SparsityPoint>> = Vec::new();
            for _ in 0..1 + rng.below(4) {
                let p: Vec<SparsityPoint> = (0..1 + rng.below(5))
                    .map(|_| SparsityPoint { s_w: rng.f64(), s_a: rng.f64() })
                    .collect();
                let q = quantize_points(&p, bits);
                let d = NetworkDesign {
                    designs: vec![
                        LayerDesign {
                            i_par: 1 + rng.below(8),
                            o_par: 1 + rng.below(8),
                            n_mac: 1 + rng.below(64),
                        };
                        q.len()
                    ],
                    throughput: rng.f64() * 1e-3,
                    resources: Resources {
                        dsp: rng.below(10_000) as u64,
                        lut: rng.below(2_000_000) as u64,
                        bram18k: rng.below(5_000) as u64,
                        uram: rng.below(1_000) as u64,
                    },
                };
                cache.insert(&h, &q, d);
                keys.push(q);
            }
            let (loaded, st) = DesignCache::from_snapshot(&cache.to_snapshot()).unwrap();
            assert_eq!(st.skipped, 0);
            assert_eq!(st.designs, cache.len());
            let h2 = reg(&loaded, &DeviceBudget::u250());
            for q in &keys {
                let orig = cache.get(&h, q).unwrap();
                let back = loaded.get(&h2, q).expect("loaded entry");
                assert_eq!(orig.throughput.to_bits(), back.throughput.to_bits());
                assert_eq!(orig.resources, back.resources);
                assert_eq!(orig.designs, back.designs);
            }
        });
    }

    #[test]
    fn prop_frontier_snapshot_roundtrips_infinite_and_finite_costs() {
        let net = crate::arch::networks::calibnet();
        let rm = ResourceModel::default();
        forall(12, 0xA6, |rng| {
            let dev = DeviceBudget {
                name: "rand".into(),
                dsp: 16 + rng.below(20_000) as u64,
                lut: 10_000 + rng.below(2_000_000) as u64,
                bram18k: 100 + rng.below(10_000) as u64,
                // uram == 0 exercises the +inf cost encodings
                uram: if rng.bool(0.5) { 0 } else { 16 + rng.below(2_000) as u64 },
                freq_mhz: 250.0,
            };
            let cache = DesignCache::new();
            let h = cache.register(&dev, &net, &rm, &DseConfig::default());
            let layer = net.compute_layers()[rng.below(net.compute_layers().len())];
            let shape = crate::dse::frontier::shape_fingerprint(layer);
            let p = SparsityPoint { s_w: rng.f64(), s_a: rng.f64() };
            let orig = cache.frontier_store().get_or_build(&h, shape, layer, p, &rm, &dev);
            let (loaded, st) = DesignCache::from_snapshot(&cache.to_snapshot()).unwrap();
            assert_eq!((st.frontiers, st.skipped), (1, 0));
            let h2 = loaded.register(&dev, &net, &rm, &DseConfig::default());
            let back = loaded.frontier_store().get_or_build(&h2, shape, layer, p, &rm, &dev);
            assert_eq!(h2.frontier_misses(), 0, "loaded frontier must serve as a hit");
            assert_eq!(orig.entries().len(), back.entries().len());
            for (a, b) in orig.entries().iter().zip(back.entries()) {
                assert_eq!(a.rate.to_bits(), b.rate.to_bits());
                assert_eq!(a.cycles, b.cycles);
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
                assert_eq!(a.design, b.design);
                assert_eq!(a.resources, b.resources);
            }
            if dev.uram == 0 {
                assert!(back.entries().iter().all(|e| e.cost.is_infinite()));
            }
        });
    }

    /// Any single-field tamper — payload or the recorded check itself —
    /// must reject the entry on load, never half-load it.
    #[test]
    fn prop_snapshot_rejects_fingerprint_mismatched_entries() {
        forall(30, 0xA7, |rng| {
            let (cache, h) = u250_cache();
            let q = quantize_points(
                &[SparsityPoint { s_w: rng.f64(), s_a: rng.f64() }],
                12,
            );
            cache.insert(&h, &q, design((1 + rng.below(100)) as u64));
            let Json::Obj(mut top) = cache.to_snapshot() else { unreachable!() };
            let Some(Json::Arr(mut designs)) = top.remove("designs") else { unreachable!() };
            let Json::Obj(entry) = &mut designs[0] else { unreachable!() };
            match rng.below(3) {
                0 => entry.insert("thr".into(), Json::Str(u64_to_hex(rng.next_u64()))),
                1 => entry.insert("fp".into(), Json::Str(u64_to_hex(rng.next_u64()))),
                _ => entry.insert("check".into(), Json::Str(u64_to_hex(rng.next_u64()))),
            };
            top.insert("designs".into(), Json::Arr(designs));
            let (loaded, st) = DesignCache::from_snapshot(&Json::Obj(top)).unwrap();
            assert_eq!(st.skipped, 1, "tampered entry must be skipped");
            assert_eq!(st.designs, 0);
            assert!(loaded.is_empty());
        });
    }

    #[test]
    fn disordered_frontier_entries_are_rejected_on_load() {
        let cache = DesignCache::new();
        let net = crate::arch::networks::calibnet();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let h = cache.register(&dev, &net, &rm, &DseConfig::default());
        let layer = net.compute_layers()[0];
        let shape = crate::dse::frontier::shape_fingerprint(layer);
        let p = SparsityPoint { s_w: 0.4, s_a: 0.4 };
        cache.frontier_store().get_or_build(&h, shape, layer, p, &rm, &dev);
        // reverse the entry rows and re-stamp a *valid* check: the order
        // validation itself must reject the entry
        let Json::Obj(mut top) = cache.to_snapshot() else { unreachable!() };
        let Some(Json::Arr(mut frontiers)) = top.remove("frontiers") else { unreachable!() };
        let fixed = {
            let Json::Obj(fe) = &mut frontiers[0] else { unreachable!() };
            let Some(Json::Arr(mut rows)) = fe.remove("es") else { unreachable!() };
            rows.reverse();
            fe.insert("es".into(), Json::Arr(rows));
            fe.remove("check");
            with_check(Json::Obj(fe.clone()))
        };
        frontiers[0] = fixed;
        top.insert("frontiers".into(), Json::Arr(frontiers));
        let (loaded, st) = DesignCache::from_snapshot(&Json::Obj(top)).unwrap();
        assert_eq!(st.skipped, 1);
        assert_eq!(st.frontiers, 0);
        assert!(loaded.frontier_store().is_empty());
    }

    // ---- compaction + cross-process sharing ---------------------------

    #[test]
    fn usage_survives_a_snapshot_round_trip() {
        let (cache, h) = u250_cache();
        let hot = pts(&[(0.5, 0.5)]);
        let cold = pts(&[(0.25, 0.25)]);
        cache.get_or_compute(&h, &hot, || design(1));
        cache.get_or_compute(&h, &hot, || design(1));
        cache.get_or_compute(&h, &cold, || design(2));
        let snap = cache.to_snapshot();
        assert!(snap.to_string().contains("\"uses\""), "usage must be persisted");
        let (loaded, st) = DesignCache::from_snapshot(&snap).unwrap();
        assert_eq!(st.designs, 2);
        assert_eq!(st.skipped, 0, "usage fields must not break the checksum");
        // hit counts and recency round-trip: re-snapshotting the loaded
        // cache reproduces the original file byte for byte
        assert_eq!(loaded.to_snapshot().to_string(), snap.to_string());
    }

    #[test]
    fn capped_save_evicts_least_recently_used_entries() {
        let _x = crate::util::fault::exclusive();
        let (cache, h) = u250_cache();
        let old = pts(&[(0.125, 0.125)]);
        let hot = pts(&[(0.5, 0.5)]);
        cache.get_or_compute(&h, &old, || design(1));
        cache.get_or_compute(&h, &hot, || design(2));
        cache.get_or_compute(&h, &hot, || design(2)); // newer AND more used
        let path = std::env::temp_dir().join("hass_cache_compaction_test.json");
        std::fs::remove_file(&path).ok();
        let st = cache.save_compacted(&path, 1).unwrap();
        assert_eq!((st.designs, st.evicted), (1, 1));
        let (loaded, _) = DesignCache::load(&path).unwrap();
        let h2 = reg(&loaded, &DeviceBudget::u250());
        assert!(loaded.get(&h2, &hot).is_some(), "most-recently-used must survive");
        assert!(loaded.get(&h2, &old).is_none(), "LRU entry must be evicted");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn concurrent_savers_merge_instead_of_clobbering() {
        let _x = crate::util::fault::exclusive();
        let path = std::env::temp_dir().join("hass_cache_merge_test.json");
        std::fs::remove_file(&path).ok();
        let (a, ha) = u250_cache();
        a.get_or_compute(&ha, &pts(&[(0.5, 0.5)]), || design(1));
        a.save(&path).unwrap();
        // a second cache (another process, conceptually) that never saw
        // the first one's entry must union with it on save
        let (b, hb) = u250_cache();
        b.get_or_compute(&hb, &pts(&[(0.25, 0.25)]), || design(2));
        let st = b.save(&path).unwrap();
        assert_eq!(st.designs, 2, "save must adopt the on-disk entry");
        let (merged, _) = DesignCache::load(&path).unwrap();
        let h = reg(&merged, &DeviceBudget::u250());
        assert_eq!(merged.get(&h, &pts(&[(0.5, 0.5)])).unwrap().resources.dsp, 1);
        assert_eq!(merged.get(&h, &pts(&[(0.25, 0.25)])).unwrap().resources.dsp, 2);
        // ...and for a key held by both, the in-memory version wins
        let (c, hc) = u250_cache();
        c.insert(&hc, &pts(&[(0.5, 0.5)]), design(9));
        c.save(&path).unwrap();
        let (merged, _) = DesignCache::load(&path).unwrap();
        let h = reg(&merged, &DeviceBudget::u250());
        assert_eq!(merged.get(&h, &pts(&[(0.5, 0.5)])).unwrap().resources.dsp, 9);
        assert!(!path.with_extension("json.lock").exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn a_held_foreign_lock_delays_but_never_blocks_a_save() {
        let _x = crate::util::fault::exclusive();
        let path = std::env::temp_dir().join("hass_cache_lockwait_test.json");
        let mut l = path.clone().into_os_string();
        l.push(".lock");
        let lock = std::path::PathBuf::from(l);
        std::fs::remove_file(&path).ok();
        std::fs::write(&lock, "held").unwrap();
        let (cache, h) = u250_cache();
        cache.get_or_compute(&h, &pts(&[(0.5, 0.5)]), || design(1));
        // the lock is fresh (not stale): acquisition backs off, gives up,
        // and the save proceeds unlocked instead of deadlocking
        let st = cache.save(&path).unwrap();
        assert_eq!(st.designs, 1);
        assert!(lock.exists(), "a fresh foreign lock must not be deleted");
        std::fs::remove_file(&lock).ok();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn an_armed_save_fault_surfaces_as_an_io_error() {
        let _x = crate::util::fault::exclusive();
        let path = std::env::temp_dir().join("hass_cache_fault_test.json");
        std::fs::remove_file(&path).ok();
        let (cache, h) = u250_cache();
        cache.get_or_compute(&h, &pts(&[(0.5, 0.5)]), || design(1));
        {
            let _g = crate::util::fault::armed("cache.save", 1);
            let err = cache.save(&path).unwrap_err();
            assert!(err.to_string().contains("injected fault"));
            assert!(!path.exists(), "a failed save must write nothing");
        }
        // disarmed again: the same save succeeds
        assert!(cache.save(&path).is_ok());
        std::fs::remove_file(&path).ok();
    }
}
