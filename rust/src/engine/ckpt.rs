//! Crash-safe search checkpoints (`hass search --checkpoint/--resume`).
//!
//! A long sweep killed mid-run used to restart cold.  The engine now
//! periodically snapshots everything a resumed process needs to
//! *replay* the interrupted search: the per-device journal prefix (every
//! [`SearchRecord`] scored so far) plus the generation cursor, tagged
//! with a [`search_fingerprint`] of every result-relevant configuration
//! field.
//!
//! # Replay-based resume
//!
//! TPE has no state-export API, and serializing the Parzen model would
//! create a second source of truth that could drift from the live
//! implementation.  Resume instead *re-runs the generation loop*:
//! proposals are regenerated exactly (the optimizer consumes its RNG
//! stream identically because seed, batch schedule and warm-start
//! anchors are fingerprint-protected), but **evaluation is skipped** for
//! every replayed generation — records come from the checkpoint and are
//! fed straight back to `observe_batch` with the regenerated proposal
//! coordinates.  Because evaluation is the entire cost of a search,
//! replay is effectively free, and the resumed run's journal is
//! **bit-identical** to the uninterrupted run's by the engine's
//! determinism contract (enforced in `tests/chaos.rs` and the
//! chaos-smoke CI job).
//!
//! Checkpoints are only ever written at generation boundaries, so
//! `done` is always a prefix of the generation schedule and replay
//! granularity is exact.
//!
//! # Format
//!
//! One JSON document, written atomically (tmp + rename, the same
//! machinery as the cache snapshots):
//!
//! ```text
//! {"format": "hass-checkpoint", "version": 1,
//!  "fingerprint": "<16-hex search fingerprint>",
//!  "done": <iterations completed per shard>,
//!  "devices": [{"device": "<name>", "records": [<record>, ...]}, ...]}
//! ```
//!
//! Every `f64` is encoded as its 16-hex-digit IEEE-754 bit pattern
//! (`util::json::u64_to_hex`), so a round trip is exact down to the last
//! bit — a resumed journal can be `cmp`-equal to the original.
//!
//! The fingerprint covers exactly the fields the determinism contract
//! names as result-relevant — iterations, seed, mode, λ, warm start,
//! TPE and DSE configuration, `engine.batch`, `engine.quant_bits`, the
//! target's layer shapes and the device budgets — and deliberately
//! excludes the execution knobs (`threads`, `cache`, `async_eval`) plus
//! the fault-tolerance knobs, so a checkpoint taken on 1 thread resumes
//! on 16.  A mismatched checkpoint is refused loudly by the CLI and
//! ignored (fresh start) by the engine.

use std::collections::BTreeSet;

use crate::arch::Network;
use crate::dse::frontier::shape_fingerprint;
use crate::hardware::device::DeviceBudget;
use crate::pruning::PruningPlan;
use crate::util::fault;
use crate::util::json::{u64_from_hex, u64_to_hex, Json};

use super::cache::device_fingerprint;
use super::{SearchConfig, SearchRecord};

/// Where and how often the engine writes checkpoints.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointSpec {
    /// checkpoint file path (rewritten atomically on every save)
    pub path: String,
    /// write every `every` completed generations (minimum 1)
    pub every: usize,
}

/// One device's journal prefix inside a checkpoint.
#[derive(Clone, Debug)]
pub struct DeviceCheckpoint {
    pub device: String,
    pub records: Vec<SearchRecord>,
}

/// Everything a resumed search replays from.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// [`search_fingerprint`] of the run that wrote this checkpoint
    pub fingerprint: u64,
    /// per-shard iterations completed (always a generation boundary)
    pub done: usize,
    pub devices: Vec<DeviceCheckpoint>,
}

fn mix(h: &mut u64, v: u64) {
    *h ^= v;
    *h = h.wrapping_mul(0x100000001b3);
}

fn mix_bytes(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        mix(h, b as u64);
    }
}

/// FNV-1a over every *result-relevant* field of a search: the
/// checkpoint-compatibility key.  Execution knobs (`threads`, `cache`,
/// `async_eval`) and the fault-tolerance knobs (retry, timeouts,
/// checkpoint cadence) are excluded — they never change results, so
/// they must never invalidate a checkpoint.
pub fn search_fingerprint(cfg: &SearchConfig, shapes: &[u64], device_fps: &[u64]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    mix(&mut h, cfg.iterations as u64);
    mix(&mut h, cfg.seed);
    mix_bytes(&mut h, format!("{:?}", cfg.mode).as_bytes());
    for l in cfg.lambda {
        mix(&mut h, l.to_bits());
    }
    mix(&mut h, cfg.warm_start as u64);
    mix_bytes(&mut h, format!("{:?}", cfg.tpe).as_bytes());
    mix_bytes(&mut h, format!("{:?}", cfg.dse).as_bytes());
    mix(&mut h, cfg.engine.batch.max(1) as u64);
    mix(&mut h, cfg.engine.quant_bits as u64);
    // pipeline depth is algorithmic (a depth-D schedule observes lagged
    // prefixes), so it must invalidate cross-depth resumes — but mixing
    // it only when non-zero keeps every depth-0 fingerprint (and every
    // pre-pipeline checkpoint on disk) byte-compatible
    if cfg.pipeline_depth > 0 {
        mix(&mut h, cfg.pipeline_depth as u64);
    }
    for &s in shapes {
        mix(&mut h, s);
    }
    for &d in device_fps {
        mix(&mut h, d);
    }
    h
}

/// [`search_fingerprint`] computed from a target geometry and a raw
/// device list, collapsing duplicate budgets exactly like the sharded
/// engine does — the CLI-side validator for `--resume`.
pub fn resume_fingerprint(
    cfg: &SearchConfig,
    target: &Network,
    devices: &[DeviceBudget],
) -> u64 {
    let shapes: Vec<u64> =
        target.compute_layers().iter().map(|l| shape_fingerprint(l)).collect();
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    let fps: Vec<u64> = devices
        .iter()
        .map(device_fingerprint)
        .filter(|fp| seen.insert(*fp))
        .collect();
    search_fingerprint(cfg, &shapes, &fps)
}

fn f64_json(v: f64) -> Json {
    Json::Str(u64_to_hex(v.to_bits()))
}

fn json_f64(j: &Json) -> Option<f64> {
    j.as_str().and_then(u64_from_hex).map(f64::from_bits)
}

fn record_to_json(r: &SearchRecord) -> Json {
    let hexes = |v: &[f64]| {
        Json::Arr(v.iter().map(|t| Json::Str(u64_to_hex(t.to_bits()))).collect())
    };
    Json::obj(vec![
        ("iter", Json::Num(r.iter as f64)),
        ("acc", f64_json(r.accuracy)),
        ("spa", f64_json(r.avg_sparsity)),
        ("den", f64_json(r.op_density)),
        ("ips", f64_json(r.images_per_sec)),
        ("aips", f64_json(r.analytic_images_per_sec)),
        ("dsp", Json::Num(r.dsp as f64)),
        ("eff", f64_json(r.efficiency)),
        ("obj", f64_json(r.objective)),
        ("sim", Json::Bool(r.simulated)),
        ("tw", hexes(&r.plan.tau_w)),
        ("ta", hexes(&r.plan.tau_a)),
    ])
}

fn record_from_json(j: &Json) -> Result<SearchRecord, String> {
    let f = |k: &str| {
        j.get(k)
            .and_then(json_f64)
            .ok_or_else(|| format!("checkpoint record: bad field '{k}'"))
    };
    let taus = |k: &str| -> Result<Vec<f64>, String> {
        j.get(k)
            .and_then(|a| a.as_arr())
            .ok_or_else(|| format!("checkpoint record: bad field '{k}'"))?
            .iter()
            .map(|t| {
                t.as_str()
                    .and_then(u64_from_hex)
                    .map(f64::from_bits)
                    .ok_or_else(|| format!("checkpoint record: bad threshold in '{k}'"))
            })
            .collect()
    };
    let tau_w = taus("tw")?;
    let tau_a = taus("ta")?;
    if tau_w.len() != tau_a.len() || tau_w.is_empty() {
        return Err("checkpoint record: threshold arrays disagree".to_string());
    }
    Ok(SearchRecord {
        iter: j
            .get("iter")
            .and_then(|v| v.as_usize())
            .ok_or("checkpoint record: bad field 'iter'")?,
        accuracy: f("acc")?,
        avg_sparsity: f("spa")?,
        op_density: f("den")?,
        images_per_sec: f("ips")?,
        analytic_images_per_sec: f("aips")?,
        dsp: j
            .get("dsp")
            .and_then(|v| v.as_usize())
            .ok_or("checkpoint record: bad field 'dsp'")? as u64,
        efficiency: f("eff")?,
        objective: f("obj")?,
        simulated: j
            .get("sim")
            .and_then(|v| v.as_bool())
            .ok_or("checkpoint record: bad field 'sim'")?,
        plan: PruningPlan { tau_w, tau_a },
    })
}

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::Str("hass-checkpoint".to_string())),
            ("version", Json::Num(1.0)),
            ("fingerprint", Json::Str(u64_to_hex(self.fingerprint))),
            ("done", Json::Num(self.done as f64)),
            (
                "devices",
                Json::Arr(
                    self.devices
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("device", Json::Str(d.device.clone())),
                                (
                                    "records",
                                    Json::Arr(d.records.iter().map(record_to_json).collect()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Checkpoint, String> {
        match v.get("format").and_then(|f| f.as_str()) {
            Some("hass-checkpoint") => {}
            other => return Err(format!("not a hass checkpoint (format {other:?})")),
        }
        match v.get("version").and_then(|x| x.as_f64()) {
            Some(ver) if ver == 1.0 => {}
            other => return Err(format!("unsupported checkpoint version {other:?}")),
        }
        let fingerprint = v
            .get("fingerprint")
            .and_then(|f| f.as_str())
            .and_then(u64_from_hex)
            .ok_or("checkpoint: bad fingerprint")?;
        let done =
            v.get("done").and_then(|d| d.as_usize()).ok_or("checkpoint: bad 'done'")?;
        let mut devices = Vec::new();
        for d in v
            .get("devices")
            .and_then(|d| d.as_arr())
            .ok_or("checkpoint: missing 'devices'")?
        {
            let device = d
                .get("device")
                .and_then(|n| n.as_str())
                .ok_or("checkpoint: device entry without a name")?
                .to_string();
            let records: Vec<SearchRecord> = d
                .get("records")
                .and_then(|r| r.as_arr())
                .ok_or("checkpoint: device entry without records")?
                .iter()
                .map(record_from_json)
                .collect::<Result<_, _>>()?;
            if records.len() != done {
                return Err(format!(
                    "checkpoint: device '{device}' carries {} records for done = {done}",
                    records.len()
                ));
            }
            devices.push(DeviceCheckpoint { device, records });
        }
        if devices.is_empty() {
            return Err("checkpoint: no devices".to_string());
        }
        Ok(Checkpoint { fingerprint, done, devices })
    }

    /// Atomically write the checkpoint: serialize to `<path>.<pid>.tmp`
    /// in the target directory, then rename over `path` — a reader (or a
    /// crash) can never observe a torn file.  Honors the `"ckpt.save"`
    /// fault-injection site.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        if let Some(e) = fault::io_error("ckpt.save") {
            return Err(e);
        }
        let p = std::path::Path::new(path);
        if let Some(dir) = p.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let tmp = format!("{path}.{}.tmp", std::process::id());
        std::fs::write(&tmp, self.to_json().to_string())?;
        std::fs::rename(&tmp, path).inspect_err(|_| {
            std::fs::remove_file(&tmp).ok();
        })
    }

    /// Load and validate a checkpoint file.
    pub fn load(path: &str) -> Result<Checkpoint, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("failed to read checkpoint '{path}': {e}"))?;
        let v = Json::parse(&text)
            .map_err(|e| format!("failed to parse checkpoint '{path}': {e}"))?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::engine::EngineConfig;

    fn record(iter: usize, obj: f64) -> SearchRecord {
        SearchRecord {
            iter,
            accuracy: 84.25 + obj,
            avg_sparsity: 0.3125,
            op_density: 0.64,
            images_per_sec: 1234.5678,
            analytic_images_per_sec: 1200.0,
            dsp: 4321,
            efficiency: 3.25e-7,
            objective: obj,
            simulated: iter % 2 == 0,
            plan: PruningPlan {
                tau_w: vec![0.01 * iter as f64, 0.2],
                tau_a: vec![0.0, 0.15 + obj],
            },
        }
    }

    fn ckpt() -> Checkpoint {
        Checkpoint {
            fingerprint: 0xdead_beef_cafe_f00d,
            done: 3,
            devices: vec![DeviceCheckpoint {
                device: "u250".to_string(),
                records: vec![record(0, 1.0625), record(1, -0.5), record(2, f64::MIN)],
            }],
        }
    }

    #[test]
    fn checkpoint_roundtrips_bit_exactly() {
        let c = ckpt();
        let back = Checkpoint::from_json(&c.to_json()).expect("roundtrip");
        assert_eq!(back.fingerprint, c.fingerprint);
        assert_eq!(back.done, c.done);
        assert_eq!(back.devices.len(), 1);
        assert_eq!(back.devices[0].device, "u250");
        for (a, b) in back.devices[0].records.iter().zip(&c.devices[0].records) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
            assert_eq!(a.objective.to_bits(), b.objective.to_bits());
            assert_eq!(a.efficiency.to_bits(), b.efficiency.to_bits());
            assert_eq!(a.dsp, b.dsp);
            assert_eq!(a.simulated, b.simulated);
            assert_eq!(a.plan, b.plan);
        }
    }

    #[test]
    fn save_load_roundtrip_via_file() {
        let path = std::env::temp_dir().join("hass_ckpt_roundtrip.json");
        let path = path.to_str().unwrap();
        let c = ckpt();
        c.save(path).expect("save");
        let back = Checkpoint::load(path).expect("load");
        assert_eq!(back.done, c.done);
        assert_eq!(
            back.devices[0].records[2].objective.to_bits(),
            f64::MIN.to_bits(),
            "infeasible scores must survive the file exactly"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn malformed_checkpoints_are_rejected_not_panicked() {
        assert!(Checkpoint::load("/nonexistent/ckpt.json").is_err());
        let bad = [
            r#"{"format": "something-else", "version": 1}"#,
            r#"{"format": "hass-checkpoint", "version": 2, "fingerprint": "00", "done": 0, "devices": []}"#,
            r#"{"format": "hass-checkpoint", "version": 1, "fingerprint": "zz", "done": 0, "devices": []}"#,
            r#"{"format": "hass-checkpoint", "version": 1, "fingerprint": "0000000000000001", "done": 0, "devices": []}"#,
        ];
        for text in bad {
            let v = Json::parse(text).expect("test JSON parses");
            assert!(Checkpoint::from_json(&v).is_err(), "accepted: {text}");
        }
        // done/record-count disagreement is refused
        let mut c = ckpt();
        c.done = 5;
        assert!(Checkpoint::from_json(&c.to_json()).is_err());
    }

    #[test]
    fn fingerprint_tracks_result_relevant_fields_only() {
        let net = networks::calibnet();
        let devices = [crate::hardware::device::DeviceBudget::u250()];
        let base = SearchConfig { iterations: 8, seed: 3, ..Default::default() };
        let fp = resume_fingerprint(&base, &net, &devices);
        assert_eq!(fp, resume_fingerprint(&base, &net, &devices), "stable");

        // result-relevant changes move the fingerprint
        let seed = SearchConfig { seed: 4, ..base.clone() };
        assert_ne!(fp, resume_fingerprint(&seed, &net, &devices));
        let iters = SearchConfig { iterations: 9, ..base.clone() };
        assert_ne!(fp, resume_fingerprint(&iters, &net, &devices));
        let batch = SearchConfig {
            engine: EngineConfig { batch: 4, ..base.engine },
            ..base.clone()
        };
        assert_ne!(fp, resume_fingerprint(&batch, &net, &devices));
        // pipeline depth is algorithmic too — but depth 0 (the classic
        // drained schedule) must keep pre-pipeline fingerprints intact
        let depth0 = SearchConfig { pipeline_depth: 0, ..base.clone() };
        assert_eq!(fp, resume_fingerprint(&depth0, &net, &devices));
        let depth2 = SearchConfig { pipeline_depth: 2, ..base.clone() };
        assert_ne!(fp, resume_fingerprint(&depth2, &net, &devices));
        let depth1 = SearchConfig { pipeline_depth: 1, ..base.clone() };
        assert_ne!(
            resume_fingerprint(&depth1, &net, &devices),
            resume_fingerprint(&depth2, &net, &devices)
        );

        // execution knobs must NOT move it (a 1-thread checkpoint resumes
        // on 16 threads, with or without the cache, sync or async)
        let knobs = SearchConfig {
            engine: EngineConfig {
                threads: 16,
                cache: false,
                async_eval: true,
                ..base.engine
            },
            ..base.clone()
        };
        assert_eq!(fp, resume_fingerprint(&knobs, &net, &devices));

        // duplicate devices collapse exactly like the sharded engine
        let dup = [
            crate::hardware::device::DeviceBudget::u250(),
            crate::hardware::device::DeviceBudget::u250(),
        ];
        assert_eq!(fp, resume_fingerprint(&base, &net, &dup));
    }
}
