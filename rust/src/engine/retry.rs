//! Transient-failure classification and bounded exponential backoff.
//!
//! A measurement backend can fail two ways: *permanently* (the plan is
//! genuinely unevaluable — a rejected configuration, a model error) or
//! *transiently* (a flaky device, a dropped connection, an injected
//! chaos fault).  Permanent failures are data: the engine scores the
//! candidate infeasible and moves on, exactly as before.  Transient
//! failures deserve another try before the candidate is written off.
//!
//! Classification is by error-string convention: an [`EvalError`]
//! starting with [`TRANSIENT_PREFIX`] is transient, anything else is
//! permanent.  Every pre-existing backend error ("measurement backend
//! rejected plan…", "PJRT evaluation failed…") lacks the prefix, so the
//! default policy changes nothing for them — retry behavior is strictly
//! opt-in for backends that tag their errors.
//!
//! Determinism: a backend whose *final* outcome after retries is a pure
//! function of the plan (true of [`crate::util::fault::FaultyEvaluator`]
//! by construction — its attempt counter is keyed by plan, not by time)
//! keeps journals bit-identical across thread counts and pipelines.
//! Backoff sleeps affect wall clock only, never results.

use super::evaluator::EvalError;

/// Error-string prefix marking an [`EvalError`] as transient (retryable).
pub const TRANSIENT_PREFIX: &str = "transient:";

/// Is this failure worth retrying?
pub fn is_transient(e: &EvalError) -> bool {
    e.starts_with(TRANSIENT_PREFIX)
}

/// Bounded-retry policy for transient evaluation failures.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// retries after the first attempt (0 = never retry)
    pub max_retries: u32,
    /// backoff before the first retry, milliseconds
    pub base_backoff_ms: u64,
    /// backoff ceiling, milliseconds
    pub max_backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 3, base_backoff_ms: 1, max_backoff_ms: 50 }
    }
}

impl RetryPolicy {
    /// A policy that never retries (PR-7 behavior: first failure scores
    /// the candidate infeasible).
    pub fn never() -> Self {
        RetryPolicy { max_retries: 0, base_backoff_ms: 0, max_backoff_ms: 0 }
    }

    /// Exponential backoff for retry number `attempt` (0-based), capped.
    pub fn backoff_ms(&self, attempt: u32) -> u64 {
        self.base_backoff_ms
            .checked_shl(attempt)
            .unwrap_or(u64::MAX)
            .min(self.max_backoff_ms)
    }

    /// Run `f`, retrying transient failures with backoff until it
    /// succeeds, fails permanently, or the retry budget is spent.
    /// Returns the final result plus the number of retries consumed
    /// (for the engine's `retried_evals` stat).
    pub fn run<T>(
        &self,
        mut f: impl FnMut() -> Result<T, EvalError>,
    ) -> (Result<T, EvalError>, u32) {
        let mut attempt = 0;
        loop {
            match f() {
                Err(e) if is_transient(&e) && attempt < self.max_retries => {
                    let ms = self.backoff_ms(attempt);
                    if ms > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(ms));
                    }
                    attempt += 1;
                }
                r => return (r, attempt),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_is_by_prefix_only() {
        assert!(is_transient(&format!("{TRANSIENT_PREFIX} device hiccup")));
        assert!(!is_transient(&"measurement backend rejected plan (s = 1.9)".to_string()));
        assert!(!is_transient(&"PJRT evaluation failed".to_string()));
        assert!(!is_transient(&String::new()));
    }

    #[test]
    fn backoff_grows_exponentially_and_caps() {
        let p = RetryPolicy { max_retries: 10, base_backoff_ms: 2, max_backoff_ms: 9 };
        assert_eq!(p.backoff_ms(0), 2);
        assert_eq!(p.backoff_ms(1), 4);
        assert_eq!(p.backoff_ms(2), 8);
        assert_eq!(p.backoff_ms(3), 9, "capped");
        assert_eq!(p.backoff_ms(200), 9, "shift overflow saturates to the cap");
    }

    #[test]
    fn transients_retry_until_success() {
        let p = RetryPolicy { max_retries: 5, base_backoff_ms: 0, max_backoff_ms: 0 };
        let mut calls = 0;
        let (r, retries) = p.run(|| {
            calls += 1;
            if calls <= 3 {
                Err(format!("{TRANSIENT_PREFIX} flaky"))
            } else {
                Ok(calls)
            }
        });
        assert_eq!(r, Ok(4));
        assert_eq!(retries, 3);
    }

    #[test]
    fn budget_exhaustion_returns_the_last_transient_error() {
        let p = RetryPolicy { max_retries: 2, base_backoff_ms: 0, max_backoff_ms: 0 };
        let mut calls = 0;
        let (r, retries) = p.run(|| -> Result<(), EvalError> {
            calls += 1;
            Err(format!("{TRANSIENT_PREFIX} always down"))
        });
        assert!(r.is_err());
        assert_eq!(calls, 3, "one attempt + two retries");
        assert_eq!(retries, 2);
    }

    #[test]
    fn permanent_errors_never_retry() {
        let p = RetryPolicy::default();
        let mut calls = 0;
        let (r, retries) = p.run(|| -> Result<(), EvalError> {
            calls += 1;
            Err("rejected plan".to_string())
        });
        assert!(r.is_err());
        assert_eq!(calls, 1);
        assert_eq!(retries, 0);
    }

    #[test]
    fn never_policy_is_first_failure_wins() {
        let p = RetryPolicy::never();
        let mut calls = 0;
        let (r, _) = p.run(|| -> Result<(), EvalError> {
            calls += 1;
            Err(format!("{TRANSIENT_PREFIX} flaky"))
        });
        assert!(r.is_err());
        assert_eq!(calls, 1);
    }
}
