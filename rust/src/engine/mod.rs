//! Batched, parallel, cache-backed candidate-evaluation engine — the
//! execution substrate of the HASS search loop (paper §V-B).
//!
//! The search couples TPE sparsity proposals with DSE hardware pricing
//! (Eq. 6); its throughput is dominated by per-candidate evaluation cost.
//! This module restructures the loop around that insight:
//!
//! * **Pluggable evaluation** — [`CandidateEvaluator`] (see [`evaluator`])
//!   abstracts the measurement backend, so the measured PJRT path, the
//!   surrogate path, and test doubles all drive the same engine.
//! * **Batched proposals** — each generation asks the optimizer for
//!   `batch` candidates at once ([`TpeOptimizer::suggest_batch`]), with
//!   the Parzen model frozen at generation start (synchronous batch
//!   Bayesian optimization), and feeds all results back in candidate
//!   order ([`TpeOptimizer::observe_batch`]).
//! * **Parallel evaluation** — a generation's candidates are evaluated
//!   concurrently with `std::thread::scope`; every worker writes into its
//!   own index-addressed slot, and records / optimizer updates are reduced
//!   in candidate order, so results are **bit-for-bit independent of the
//!   thread count**.
//! * **Memoized pricing** — [`DesignCache`] (see [`cache`]) memoizes
//!   `dse::explore` keyed by (device fingerprint, quantized operating
//!   points).  Quantization is applied whether or not the cache is on, so
//!   the cache can **never** change results either.
//! * **Cheap misses** — a design-cache miss no longer pays a full design
//!   -space rescan: the cache's [`FrontierStore`] keeps per-layer
//!   `dse::frontier::LayerFrontier`s keyed by (pricing context, layer
//!   *shape*, layer point), so new candidates re-enumerate a layer's
//!   design space only when that (shape, point) pair has never been
//!   priced — across candidates, generations, shards and searches.
//!   Frontier pricing is bit-identical to the scan (differential-tested),
//!   so this can never change results either.
//! * **Cross-process persistence** — both pricing stores serialize to a
//!   versioned JSON snapshot ([`DesignCache::save`] / [`DesignCache::load`],
//!   format documented in [`cache`]), so Fig. 5 / Table II sweeps and
//!   ablations start warm: a repeated search against a warm-from-disk
//!   cache misses zero times and journals bit-for-bit what the cold run
//!   journaled (encodings are exact down to the f64 bit pattern).
//! * **Async completion-queue pipeline** — with
//!   [`EngineConfig::async_eval`] a generation's measurement requests are
//!   handed to [`CandidateEvaluator::eval_async`] as a batch; completions
//!   stream back over an `mpsc` queue **in any order**, and pricing
//!   workers score every already-completed candidate while later
//!   measurements are still in flight — replacing the two-phase
//!   measure-all-then-price-all barrier.  Slots stay index-addressed and
//!   the journal is still reduced in candidate order, so the pipeline is
//!   an execution knob like `threads`: it can never change results
//!   ([`EngineStats::overlap_pricings`] / [`EngineStats::ooo_completions`]
//!   count the overlap it actually bought).
//! * **Fidelity ladder** — [`SimulatedEvaluator`] (see [`evaluator`])
//!   wraps any backend: the swarm is priced analytically, and each
//!   generation's analytic top-k per device is re-scored with the
//!   event-driven cycle-level simulator ([`crate::simulator`]).  A
//!   matching non-deadlocked [`SimScore`] replaces the analytic
//!   throughput/efficiency in [`Engine::score_candidate`], so Eq. 6 sees
//!   simulator fidelity exactly on the frontier the optimizer exploits
//!   ([`EngineStats::sim_evals`] / [`EngineStats::sim_promotions`] /
//!   [`EngineStats::sim_disagreement`] account for it).  Requires the
//!   async pipeline — the ladder ranks within a generation.
//! * **Cross-shard measurement dedup** — each generation measures every
//!   *distinct* proposal once and shares the result across shards.
//!   During TPE random startup (and for warm-start anchors) the
//!   seed-identical shard optimizers propose the same candidates, which a
//!   naive sharded loop re-measured per shard; evaluations are pure by
//!   the [`CandidateEvaluator`] contract, so sharing them is invisible in
//!   the journals ([`EngineStats::dedup_evals`] counts the savings).
//!
//! # Multi-device sharding (`shard`)
//!
//! HASS's central claim is that each device geometry prices the same
//! sparsity point differently — Table II / Fig. 6 comparisons sweep one
//! sparsity frontier across several devices.  [`ShardedEngine`] (see
//! [`shard`]) runs that sweep as **one search over N device shards**:
//! every generation, each shard proposes its own TPE batch (seeded
//! identically to a standalone run), the union of `(device, candidate)`
//! work items is evaluated by one scoped thread pool into index-addressed
//! slots, and each shard reduces its slice in candidate order.  All shards
//! share one multi-fingerprint [`DesignCache`], so pricings persist across
//! shards and across repeated searches on the same cache, with per-device
//! hit/miss accounting.  [`Engine::search`] is now the single-shard
//! special case of this machinery — which is exactly what makes the
//! sharded/standalone determinism contract structural rather than
//! incidental.
//!
//! # Fault tolerance
//!
//! PR 7 made evaluation failures non-panicking; this layer makes them
//! *survivable*:
//!
//! * **Transient retry** — [`RetryPolicy`] (see [`retry`]) classifies an
//!   [`EvalError`] by the [`TRANSIENT_PREFIX`] convention and re-drives
//!   transient failures with bounded exponential backoff before the
//!   candidate is scored infeasible ([`EngineStats::retried_evals`]).
//!   Every pre-existing backend error is permanent, so the default
//!   policy changes nothing for them.
//! * **Stall watchdog** — on the async pipeline,
//!   [`SearchConfig::eval_timeout_ms`] bounds the silence between
//!   completions and [`SearchConfig::deadline_ms`] bounds a whole
//!   generation; when either fires, every still-outstanding measurement
//!   is reclaimed as an infeasible-scored record
//!   ([`EngineStats::reclaimed_stalls`]) and the search keeps moving.
//!   Both default to off (0), preserving wait-forever semantics.
//! * **Checkpoint/resume** — [`SearchConfig::checkpoint`] periodically
//!   writes an atomic, fingerprint-tagged journal snapshot
//!   ([`ckpt`]); [`SearchControl::resume`] replays it so a killed run
//!   continues where it stopped with a bit-identical journal.
//! * **Deterministic chaos** — [`crate::util::fault`] injects all of the
//!   above failure modes as pure functions of `(fault seed, plan)`, so
//!   `tests/chaos.rs` and the chaos-smoke CI job reproduce every
//!   recovery path exactly, across thread counts and pipelines.
//!
//! # Cross-generation pipelining (`SearchConfig::pipeline_depth`)
//!
//! The classic loop drains every generation at a barrier before TPE may
//! propose the next one, so the slowest candidate of generation *g*
//! idles the whole pool.  With `pipeline_depth = D > 0` the engine runs
//! a **deterministic lookahead pipeline** instead: generation *P*'s
//! proposals are drawn the moment exactly `max(P − D, 0)` generations
//! have been observed, so up to `D + 1` generations are measured
//! concurrently while the reducer joins and observes them strictly in
//! generation order.  Proposals are always drawn in ascending generation
//! order on the single per-shard optimizer RNG stream and
//! `observe_batch` still fires in candidate order per generation, so a
//! pipelined run is bit-identical across thread counts, sync/async
//! pipelines, cache states and kill/resume — the depth itself *is*
//! algorithmic (generation *P* sees `max(P − D, 0)` observed
//! generations instead of *P*), which is why `pipeline_depth > 0`
//! enters the checkpoint fingerprint while `D = 0` reproduces the
//! classic drained schedule (and its fingerprint) exactly.
//! [`EngineStats::pipelined_generations`],
//! [`EngineStats::lookahead_proposals`] and
//! [`EngineStats::barrier_wait_ns`] make the overlap measurable.
//!
//! # Determinism contract
//!
//! A search result is a pure function of `(evaluator, target, device,
//! SearchConfig{seed, iterations, pipeline_depth, …},
//! EngineConfig{batch, quant_bits})`.
//! `EngineConfig::threads`, `EngineConfig::cache` and
//! `EngineConfig::async_eval` are execution knobs only: any thread count,
//! either cache setting and either generation pipeline (two-phase barrier
//! or async completion queue — even with an evaluator that completes out
//! of submission order) reproduce the same journal bit-for-bit.  `batch`
//! *is* algorithmic (a frozen-model
//! generation of k proposals is not the same sequence as k serial
//! ask/tell rounds — the standard batched-BO trade-off), except during
//! TPE's random-startup phase, where proposals are model-free and the
//! candidate stream is identical for every batch size.
//! `SearchConfig::pipeline_depth` is algorithmic for the same reason —
//! a depth-D schedule observes lagged prefixes — but for a *fixed*
//! depth the journal is again invariant under every execution knob
//! above.  Sharding extends
//! the contract across devices: for a fixed seed, each device's journal
//! from a [`ShardedEngine`] run is bit-identical to a standalone
//! [`Engine::search`] on that device alone, whatever the shard count,
//! thread count, or cache sharing.
//!
//! `EngineConfig::default()` (batch 1, exact keys) reproduces the
//! pre-engine serial loop exactly; [`crate::coordinator::search`] is now a
//! thin wrapper over [`Engine::search`].
//!
//! [`TpeOptimizer::suggest_batch`]: crate::optim::tpe::TpeOptimizer::suggest_batch
//! [`TpeOptimizer::observe_batch`]: crate::optim::tpe::TpeOptimizer::observe_batch

pub mod cache;
pub mod ckpt;
pub mod evaluator;
pub mod retry;
pub mod shard;

pub use cache::{
    cache_file_from_args, quantize_points, save_cache_file, DesignCache, DeviceCacheHandle,
    FrontierStore, SnapshotStats,
};
pub use ckpt::{
    resume_fingerprint, search_fingerprint, Checkpoint, CheckpointSpec, DeviceCheckpoint,
};
pub use evaluator::{
    CandidateEvaluator, EvalCompletion, EvalError, EvalPoint, EvalRequest, SimScore,
    SimulatedEvaluator,
};
pub use retry::{is_transient, RetryPolicy, TRANSIENT_PREFIX};
pub use shard::{
    DeviceSearchResult, ParetoPoint, SearchControl, SearchProgress, ShardedEngine,
    ShardedSearchResult, ShardedStats,
};

use crate::arch::Network;
use crate::dse::{explore, DseConfig};
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::ResourceModel;
use crate::metrics::Table;
use crate::optim::tpe::TpeConfig;
use crate::pruning::{self, PruningPlan};

/// Which metrics the objective sees (Fig. 5's two curves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchMode {
    /// Eq. 6: accuracy + sparsity + throughput − DSPs (HASS)
    HardwareAware,
    /// accuracy + sparsity only (the traditional flow of Fig. 2a)
    SoftwareOnly,
}

/// Execution shape of the engine: generation size, worker threads, and
/// pricing memoization.  See the module docs for the determinism contract.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// candidates proposed and evaluated per TPE generation (1 = the
    /// seed-serial ask/tell loop)
    pub batch: usize,
    /// evaluation worker threads; 0 = min(work items per generation,
    /// available parallelism), where a sharded search has
    /// `shards x batch` work items per generation
    pub threads: usize,
    /// memoize `dse::explore` results across candidates
    pub cache: bool,
    /// snap operating points to a 2^-bits grid before pricing (0 = exact;
    /// >0 makes nearby candidates share cache entries)
    pub quant_bits: u32,
    /// run generations through the async completion-queue pipeline
    /// ([`CandidateEvaluator::eval_async`]): pricing overlaps in-flight
    /// measurements instead of waiting behind the measure-all barrier.
    /// Execution knob only — results are bit-identical either way.
    pub async_eval: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { batch: 1, threads: 0, cache: true, quant_bits: 0, async_eval: false }
    }
}

impl EngineConfig {
    /// A sensible parallel configuration: k-candidate generations, auto
    /// threads, cache with a 2^-12 (~2.4e-4 sparsity) pricing grid, and
    /// the async completion-queue pipeline.
    pub fn batched(k: usize) -> Self {
        EngineConfig {
            batch: k.max(1),
            threads: 0,
            cache: true,
            quant_bits: 12,
            async_eval: true,
        }
    }

    /// Worker threads for a generation of `work` items (0 = auto).
    pub(super) fn resolved_threads_for(&self, work: usize) -> usize {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let t = if self.threads == 0 { hw } else { self.threads };
        t.clamp(1, work.max(1))
    }
}

/// Search hyper-parameters.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub iterations: usize,
    pub mode: SearchMode,
    pub seed: u64,
    /// λ1 (sparsity), λ2 (throughput), λ3 (DSP) of Eq. 6
    pub lambda: [f64; 3],
    /// anchor the optimizer with the dense and two mild uniform plans
    /// before random startup — one-shot pruning response surfaces are
    /// cliff-heavy, and without an anchor a short search may never sample
    /// the high-accuracy region at all
    pub warm_start: bool,
    pub tpe: TpeConfig,
    pub dse: DseConfig,
    pub engine: EngineConfig,
    /// retry schedule for transient ([`TRANSIENT_PREFIX`]-tagged)
    /// measurement failures; the default retries nothing that existed
    /// before the convention, so it is behavior-preserving
    pub retry: RetryPolicy,
    /// async pipeline only: reclaim every outstanding measurement of a
    /// generation if no completion arrives for this many milliseconds
    /// (0 = wait forever).  Reclaimed slots score infeasible, like any
    /// other failed measurement.  Wall-clock-dependent by nature: only
    /// genuinely stuck measurements are reclaimed deterministically.
    pub eval_timeout_ms: u64,
    /// async pipeline only: reclaim every outstanding measurement once a
    /// generation has run for this many milliseconds (0 = no deadline)
    pub deadline_ms: u64,
    /// write crash-safe checkpoints ([`ckpt`]) at this path/cadence
    pub checkpoint: Option<CheckpointSpec>,
    /// cross-generation lookahead depth: generation *P*'s proposals are
    /// drawn once `max(P − D, 0)` generations are observed, so up to
    /// `D + 1` generations measure concurrently.  0 (default) keeps the
    /// classic drained schedule — journals and fingerprints unchanged.
    /// Depth is **algorithmic** (see the module docs): a fixed depth is
    /// bit-deterministic across every execution knob, but different
    /// depths are different searches, so `D > 0` enters the checkpoint
    /// fingerprint.
    pub pipeline_depth: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            iterations: 96, // the paper's Fig. 5 budget
            mode: SearchMode::HardwareAware,
            seed: 0,
            // normalization heuristics (paper §V-B): keep accuracy the
            // dominant term so the search tolerates <1-point drops only,
            // with hardware terms strong enough to steer among equals
            lambda: [0.10, 0.15, 0.10],
            warm_start: true,
            tpe: TpeConfig::default(),
            dse: DseConfig::default(),
            engine: EngineConfig::default(),
            retry: RetryPolicy::default(),
            eval_timeout_ms: 0,
            deadline_ms: 0,
            checkpoint: None,
            pipeline_depth: 0,
        }
    }
}

/// One journal line of the search.
#[derive(Clone, Debug)]
pub struct SearchRecord {
    pub iter: usize,
    pub accuracy: f64,
    pub avg_sparsity: f64,
    pub op_density: f64,
    /// throughput the objective saw — analytic, or the cycle-level
    /// simulator's when the fidelity ladder re-scored this record
    pub images_per_sec: f64,
    /// the analytic (DSE-model) throughput; equals `images_per_sec`
    /// unless `simulated`
    pub analytic_images_per_sec: f64,
    pub dsp: u64,
    /// images / cycle / DSP (the paper's efficiency metric)
    pub efficiency: f64,
    pub objective: f64,
    /// this record's throughput/efficiency come from the cycle-level
    /// simulator (fidelity ladder), not the analytic model
    pub simulated: bool,
    pub plan: PruningPlan,
}

/// Execution counters of one engine run.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// candidates evaluated (== iterations)
    pub evaluations: usize,
    /// TPE generations (== ceil(iterations / batch))
    pub generations: usize,
    /// worker threads of the evaluation pool (shared across shards in a
    /// sharded search)
    pub threads: usize,
    pub batch: usize,
    /// this device's design-cache hits during this run
    pub cache_hits: u64,
    /// this device's design-cache misses during this run
    pub cache_misses: u64,
    /// layer-frontier store hits during this run (structural reuse on
    /// design-cache misses; includes the dense-reference pricing)
    pub frontier_hits: u64,
    /// layer-frontier store misses (design-space enumerations actually
    /// paid) during this run
    pub frontier_misses: u64,
    /// candidate *measurements* this shard skipped because an identical
    /// proposal was measured once for the whole generation (cross-shard
    /// dedup — TPE startup and warm-start anchors propose identical
    /// candidates on every shard)
    pub dedup_evals: u64,
    /// generations this shard ran through the async completion-queue
    /// pipeline (`EngineConfig::async_eval`)
    pub async_generations: usize,
    /// candidate pricings of this shard that started while the evaluator
    /// was still working through the generation's request batch — the
    /// overlap the async pipeline bought over the two-phase barrier.
    /// (Backlog drained after the evaluator finished is not counted.)
    /// Timing-dependent (a stat, not a result); always 0 on the sync
    /// path.
    pub overlap_pricings: u64,
    /// measurement completions owned by this shard that arrived after a
    /// later-submitted request had already completed (the evaluator
    /// finished work out of submission order).  Timing-dependent.
    pub ooo_completions: u64,
    /// records of this shard re-scored by the cycle-level simulator
    /// (fidelity ladder; 0 for plain evaluators)
    pub sim_evals: usize,
    /// simulator-scored records that set a new running-best objective
    /// when they landed — promotions the ladder's fidelity actually won
    pub sim_promotions: usize,
    /// mean relative |simulated − analytic| images/second deviation over
    /// this shard's simulator-scored records (0.0 when none) — the
    /// analytic-model drift signal the ladder measures as it runs
    pub sim_disagreement: f64,
    /// transient-failure retries this shard's measurements consumed
    /// ([`SearchConfig::retry`]); 0 under the default policy unless the
    /// backend tags errors transient
    pub retried_evals: u64,
    /// measurements of this shard reclaimed as infeasible by the stall
    /// watchdog ([`SearchConfig::eval_timeout_ms`] /
    /// [`SearchConfig::deadline_ms`])
    pub reclaimed_stalls: u64,
    /// generations this shard ran through the cross-generation lookahead
    /// pipeline ([`SearchConfig::pipeline_depth`] > 0); replayed
    /// (resumed-from-checkpoint) generations are not counted
    pub pipelined_generations: usize,
    /// proposals this shard drew while observations lagged behind the
    /// proposal front (lookahead draws) — deterministic for a fixed
    /// depth: every candidate of generation P > 0 when depth ≥ 1
    pub lookahead_proposals: u64,
    /// nanoseconds the reducer spent blocked joining in-flight
    /// generation tasks (the residual barrier a deeper pipeline
    /// shrinks).  Timing-dependent (a stat, not a result); 0 on the
    /// depth-0 inline path.
    pub barrier_wait_ns: u64,
}

impl EngineStats {
    /// Fraction of pricings served from the design cache (0.0 when the
    /// cache saw no traffic at all, e.g. when it was disabled).
    pub fn cache_hit_rate(&self) -> f64 {
        let t = (self.cache_hits + self.cache_misses) as f64;
        if t == 0.0 {
            0.0
        } else {
            self.cache_hits as f64 / t
        }
    }
}

/// Search output: full journal + index of the best Eq.6 iteration.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub records: Vec<SearchRecord>,
    pub best: usize,
    /// dense reference used for throughput normalization
    pub dense_images_per_sec: f64,
    pub stats: EngineStats,
}

impl SearchResult {
    /// # Panics
    /// On a zero-iteration search (no records).  Callers that accept
    /// `--iters 0` must use [`try_best_record`](Self::try_best_record).
    pub fn best_record(&self) -> &SearchRecord {
        &self.records[self.best]
    }

    /// Best record, or `None` for a zero-iteration search.
    pub fn try_best_record(&self) -> Option<&SearchRecord> {
        self.records.get(self.best)
    }

    /// Write the journal CSV to `path`, creating parent directories.
    pub fn write_journal(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_table().to_csv())
    }

    /// Fig. 5's y-axis: the computation efficiency of the *incumbent* —
    /// the best design so far **by the search's own objective**.  (A
    /// running max of efficiency would credit the software-only search
    /// for efficient points it visits but would never select.)
    pub fn efficiency_trajectory(&self) -> Vec<f64> {
        let mut best_obj = f64::NEG_INFINITY;
        let mut best_eff = 0.0f64;
        self.records
            .iter()
            .map(|r| {
                if r.objective > best_obj {
                    best_obj = r.objective;
                    best_eff = r.efficiency;
                }
                best_eff
            })
            .collect()
    }

    /// Journal as a table (one row per iteration).
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&[
            "iter", "accuracy", "avg_sparsity", "op_density", "images_per_sec", "dsp",
            "images_per_cycle_per_dsp", "objective",
        ]);
        for r in &self.records {
            t.row(vec![
                r.iter.to_string(),
                format!("{:.3}", r.accuracy),
                format!("{:.4}", r.avg_sparsity),
                format!("{:.4}", r.op_density),
                format!("{:.1}", r.images_per_sec),
                r.dsp.to_string(),
                format!("{:.4e}", r.efficiency),
                format!("{:.4}", r.objective),
            ]);
        }
        t
    }
}

/// Per-shard evaluation context shared (immutably) by the workers.
pub(super) struct EvalCtx<'a> {
    pub(super) cache: Option<(&'a DesignCache, &'a DeviceCacheHandle)>,
    pub(super) quant_bits: u32,
    pub(super) dense_ips: f64,
    /// `engine::cache` fingerprint of this shard's device, matched
    /// against [`SimScore::device_fp`] when a laddered evaluator attached
    /// cycle-level re-scores
    pub(super) dev_fp: u64,
    pub(super) base_acc: f64,
    pub(super) mode: SearchMode,
    pub(super) lambda: [f64; 3],
    pub(super) dse: &'a DseConfig,
    /// per-compute-layer `dse::frontier::shape_fingerprint`s of the
    /// target, precomputed once per search for the frontier store
    pub(super) shapes: &'a [u64],
}

/// The device-independent half of a candidate evaluation: decoded plan,
/// measured accuracy/operating points, sparsity metrics.  Computed once
/// per *distinct* proposal of a generation and shared across shards.
///
/// A failed measurement (`error` set) carries placeholder dense points so
/// downstream shapes stay valid, and [`Engine::score_candidate`] scores it
/// [`INFEASIBLE_OBJECTIVE`] without touching the pricing caches.
pub(super) struct Measurement {
    pub(super) plan: PruningPlan,
    pub(super) ev: EvalPoint,
    pub(super) metrics: pruning::SparsityMetrics,
    pub(super) error: Option<EvalError>,
    /// transient-failure retries this measurement consumed
    pub(super) retries: u32,
}

impl Measurement {
    /// Fold an evaluator outcome into a `Measurement`.  An `Err` becomes a
    /// zero-accuracy dense placeholder — the search keeps running and TPE
    /// simply learns this region is bad, instead of the whole process
    /// aborting (fatal for a resident daemon, where a worker panic would
    /// also poison the shared caches).
    pub(super) fn from_result(
        target: &Network,
        plan: PruningPlan,
        result: Result<EvalPoint, EvalError>,
        n_points: usize,
    ) -> Measurement {
        match result {
            Ok(ev) => {
                let metrics = pruning::metrics(target, &ev.points);
                Measurement { plan, ev, metrics, error: None, retries: 0 }
            }
            Err(e) => {
                let ev = EvalPoint {
                    accuracy: 0.0,
                    points: vec![crate::sparsity::SparsityPoint::DENSE; n_points],
                    sim: Vec::new(),
                };
                let metrics = pruning::metrics(target, &ev.points);
                Measurement { plan, ev, metrics, error: Some(e), retries: 0 }
            }
        }
    }
}

/// The batched search engine: an evaluator plus the fixed hardware-side
/// context (target geometry, resource model, device budget).
pub struct Engine<'a> {
    pub evaluator: &'a dyn CandidateEvaluator,
    pub target: &'a Network,
    pub rm: &'a ResourceModel,
    pub dev: &'a DeviceBudget,
}

/// Warm-start anchor plans: dense, mild, moderate uniform sparsity.
pub(super) const ANCHORS: [f64; 3] = [0.0, 0.15, 0.35];

/// Objective assigned to a candidate whose measurement failed.  `f64::MIN`
/// (not `NEG_INFINITY`: TPE asserts finite observations) ranks below every
/// real Eq. 6 score, so a failed candidate never becomes the incumbent and
/// the optimizer learns to avoid the region.
pub const INFEASIBLE_OBJECTIVE: f64 = f64::MIN;

impl<'a> Engine<'a> {
    pub fn new(
        evaluator: &'a dyn CandidateEvaluator,
        target: &'a Network,
        rm: &'a ResourceModel,
        dev: &'a DeviceBudget,
    ) -> Self {
        Engine { evaluator, target, rm, dev }
    }

    /// Run the HASS search (Eq. 6 objective, or software-only).
    ///
    /// This is the single-shard special case of [`ShardedEngine::search`]
    /// — one device, a private design cache.
    pub fn search(&self, cfg: &SearchConfig) -> SearchResult {
        self.search_with_cache(cfg, &DesignCache::new())
    }

    /// [`search`](Self::search) against a caller-owned (possibly shared,
    /// possibly warm) design cache.  The cache never changes results; a
    /// warm cache only changes the hit/miss split in the returned stats.
    pub fn search_with_cache(&self, cfg: &SearchConfig, cache: &DesignCache) -> SearchResult {
        self.search_with_cache_ctrl(cfg, cache, &SearchControl::default())
            .expect("a search without an observer cannot be cancelled")
    }

    /// [`search_with_cache`](Self::search_with_cache) with a
    /// [`SearchControl`] (progress observer / cancellation / checkpoint
    /// resume) — the single-shard face of
    /// [`ShardedEngine::search_with_cache_ctrl`].
    pub fn search_with_cache_ctrl(
        &self,
        cfg: &SearchConfig,
        cache: &DesignCache,
        ctrl: &SearchControl<'_>,
    ) -> Option<SearchResult> {
        let sharded = ShardedEngine::new(
            self.evaluator,
            self.target,
            self.rm,
            std::slice::from_ref(self.dev),
        );
        let mut r = sharded.search_with_cache_ctrl(cfg, cache, ctrl)?;
        Some(r.per_device.remove(0).result)
    }

    /// Device-independent half of a candidate evaluation: decode the
    /// proposal, run the (possibly expensive) measurement backend, derive
    /// sparsity metrics.  Touches neither the device budget nor the
    /// resource model — a sharded generation measures each distinct
    /// proposal once and shares the result across shards.  Transient
    /// backend failures are re-driven under `retry` before the candidate
    /// is written off.
    pub(super) fn measure_candidate(&self, x: &[f64], retry: &RetryPolicy) -> Measurement {
        let model = self.evaluator.sparsity_model();
        let n_points = model.layers.len();
        let plan = PruningPlan::from_unit_point(x, model);
        let (result, retries) = retry.run(|| self.evaluator.try_eval(&plan));
        let mut m = Measurement::from_result(self.target, plan, result, n_points);
        m.retries = retries;
        m
    }

    /// Device-dependent half: price the measured operating points on this
    /// engine's device (design cache + frontier store on the miss path)
    /// and score the Eq. 6 objective.
    pub(super) fn score_candidate(
        &self,
        iter: usize,
        meas: &Measurement,
        ctx: &EvalCtx<'_>,
    ) -> SearchRecord {
        if meas.error.is_some() {
            // failed measurement: nothing to price (the caches never see
            // it) — record a minimal-objective placeholder so TPE steers
            // away from the region while the search keeps running
            return SearchRecord {
                iter,
                accuracy: 0.0,
                avg_sparsity: 0.0,
                op_density: 1.0,
                images_per_sec: 0.0,
                analytic_images_per_sec: 0.0,
                dsp: 0,
                efficiency: 0.0,
                objective: INFEASIBLE_OBJECTIVE,
                simulated: false,
                plan: meas.plan.clone(),
            };
        }
        let pts = quantize_points(&meas.ev.points, ctx.quant_bits);
        let design = match ctx.cache {
            Some((c, h)) => c.get_or_compute(h, &pts, || {
                c.explore_via_frontiers(
                    h, self.target, &pts, ctx.shapes, self.rm, self.dev, ctx.dse,
                )
            }),
            None => explore(self.target, &pts, self.rm, self.dev, ctx.dse),
        };
        let analytic_ips = design.images_per_sec(self.dev);
        // fidelity ladder: a laddered evaluator may have attached a
        // cycle-level re-score for this shard's device; a deadlocked
        // simulation keeps the analytic number
        let sim = meas
            .ev
            .sim
            .iter()
            .find(|s| s.device_fp == ctx.dev_fp && !s.deadlocked);
        let (ips, efficiency, simulated) = match sim {
            Some(s) => {
                let dsp = design.resources.dsp.max(1) as f64;
                // the simulated images/cycle/DSP counterpart of
                // `design.efficiency()`
                (s.images_per_sec, s.images_per_sec / (self.dev.freq_hz() * dsp), true)
            }
            None => (analytic_ips, design.efficiency(), false),
        };

        let f_acc = meas.ev.accuracy / ctx.base_acc; // ∈ [0, 1]
        let f_spa = meas.metrics.avg_sparsity; // ∈ [0, 1)
        // saturating throughput gain: ∈ (0, 2), =1 at the dense reference.
        // An unbounded ratio would swamp the accuracy term on networks
        // where sparsity buys 10-20x (the λ "normalization" of Eq. 6).
        let raw = ips / ctx.dense_ips;
        let f_thr = 2.0 * raw / (1.0 + raw);
        let f_dsp = design.resources.dsp as f64 / self.dev.dsp.max(1) as f64;
        let objective = match ctx.mode {
            SearchMode::HardwareAware => {
                f_acc + ctx.lambda[0] * f_spa + ctx.lambda[1] * f_thr
                    - ctx.lambda[2] * f_dsp
            }
            SearchMode::SoftwareOnly => f_acc + ctx.lambda[0] * f_spa,
        };
        SearchRecord {
            iter,
            accuracy: meas.ev.accuracy,
            avg_sparsity: meas.metrics.avg_sparsity,
            op_density: meas.metrics.op_density,
            images_per_sec: ips,
            analytic_images_per_sec: analytic_ips,
            dsp: design.resources.dsp,
            efficiency,
            objective,
            simulated,
            plan: meas.plan.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::coordinator::SurrogateEvaluator;
    use crate::sparsity::synthesize;

    fn surrogate(seed: u64) -> SurrogateEvaluator {
        let net = networks::calibnet();
        let sparsity = synthesize(&net, seed);
        SurrogateEvaluator { net, sparsity, base_acc: 85.0 }
    }

    fn cfg(iters: usize, seed: u64, engine: EngineConfig) -> SearchConfig {
        SearchConfig {
            iterations: iters,
            seed,
            dse: DseConfig { max_iters: 1_500, ..Default::default() },
            engine,
            ..Default::default()
        }
    }

    fn run(ev: &SurrogateEvaluator, c: &SearchConfig) -> SearchResult {
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        Engine::new(ev, &net, &rm, &dev).search(c)
    }

    fn objective_bits(r: &SearchResult) -> Vec<u64> {
        r.records.iter().map(|x| x.objective.to_bits()).collect()
    }

    /// The satellite determinism contract: a k=4 generation evaluated on 4
    /// worker threads with the design cache on reproduces — bit for bit —
    /// the same schedule evaluated serially (1 thread) with every pricing
    /// recomputed from scratch.
    #[test]
    fn parallel_k4_with_cache_matches_serial_k1_threads() {
        let ev = surrogate(11);
        let serial = run(
            &ev,
            &cfg(
                20,
                7,
                EngineConfig {
                    batch: 4,
                    threads: 1,
                    cache: false,
                    quant_bits: 0,
                    async_eval: false,
                },
            ),
        );
        let parallel = run(
            &ev,
            &cfg(
                20,
                7,
                EngineConfig {
                    batch: 4,
                    threads: 4,
                    cache: true,
                    quant_bits: 0,
                    async_eval: false,
                },
            ),
        );
        assert_eq!(objective_bits(&serial), objective_bits(&parallel));
        assert_eq!(serial.best, parallel.best);
        assert_eq!(serial.best_record().plan, parallel.best_record().plan);
        assert_eq!(
            serial.best_record().objective.to_bits(),
            parallel.best_record().objective.to_bits()
        );
        assert_eq!(serial.efficiency_trajectory(), parallel.efficiency_trajectory());
    }

    #[test]
    fn odd_thread_counts_also_match() {
        let ev = surrogate(12);
        let a = run(
            &ev,
            &cfg(
                13, // not divisible by the batch: exercises the short tail
                3,
                EngineConfig {
                    batch: 5,
                    threads: 1,
                    cache: true,
                    quant_bits: 12,
                    async_eval: false,
                },
            ),
        );
        let b = run(
            &ev,
            &cfg(
                13,
                3,
                EngineConfig {
                    batch: 5,
                    threads: 3,
                    cache: true,
                    quant_bits: 12,
                    async_eval: false,
                },
            ),
        );
        assert_eq!(objective_bits(&a), objective_bits(&b));
        assert_eq!(a.records.len(), 13);
    }

    /// During TPE random startup the model is frozen at None for every
    /// batch size, so the candidate stream — and the journal — is
    /// identical whether the engine runs generations of 1, 2 or 4.
    #[test]
    fn startup_prefix_identical_across_batch_sizes() {
        let ev = surrogate(13);
        let n_startup = TpeConfig::default().n_startup; // 10
        let base = run(&ev, &cfg(n_startup, 5, EngineConfig::default()));
        for k in [2usize, 4] {
            let batched = run(
                &ev,
                &cfg(
                    n_startup,
                    5,
                    EngineConfig {
                        batch: k,
                        threads: 2,
                        cache: true,
                        quant_bits: 0,
                        async_eval: false,
                    },
                ),
            );
            assert_eq!(
                objective_bits(&base),
                objective_bits(&batched),
                "batch {k} diverged during random startup"
            );
        }
    }

    /// Quantized pricing is applied with the cache on *and* off, so the
    /// cache cannot change results even on the approximate grid.
    #[test]
    fn cache_on_off_identical_with_quantized_pricing() {
        let ev = surrogate(14);
        let on = run(
            &ev,
            &cfg(
                16,
                9,
                EngineConfig {
                    batch: 4,
                    threads: 2,
                    cache: true,
                    quant_bits: 12,
                    async_eval: false,
                },
            ),
        );
        let off = run(
            &ev,
            &cfg(
                16,
                9,
                EngineConfig {
                    batch: 4,
                    threads: 2,
                    cache: false,
                    quant_bits: 12,
                    async_eval: false,
                },
            ),
        );
        assert_eq!(objective_bits(&on), objective_bits(&off));
        assert_eq!(on.best, off.best);
        // the disabled cache reports no traffic
        assert_eq!(off.stats.cache_hits + off.stats.cache_misses, 0);
        // the enabled cache saw every pricing
        assert_eq!(on.stats.cache_hits + on.stats.cache_misses, 16);
    }

    #[test]
    fn stats_count_generations_and_evaluations() {
        let ev = surrogate(15);
        let r = run(
            &ev,
            &cfg(
                10,
                2,
                EngineConfig {
                    batch: 4,
                    threads: 2,
                    cache: true,
                    quant_bits: 0,
                    async_eval: false,
                },
            ),
        );
        assert_eq!(r.stats.evaluations, 10);
        assert_eq!(r.stats.generations, 3); // 4 + 4 + 2
        assert_eq!(r.stats.batch, 4);
        assert!(r.stats.threads >= 1);
        assert_eq!(r.records.len(), 10);
        for (i, rec) in r.records.iter().enumerate() {
            assert_eq!(rec.iter, i, "journal order must follow candidate order");
        }
    }

    #[test]
    fn batch_larger_than_budget_is_clamped() {
        let ev = surrogate(16);
        let r = run(
            &ev,
            &cfg(
                3,
                1,
                EngineConfig {
                    batch: 8,
                    threads: 0,
                    cache: true,
                    quant_bits: 0,
                    async_eval: false,
                },
            ),
        );
        assert_eq!(r.records.len(), 3);
        assert_eq!(r.stats.generations, 1);
        assert!(r.best < 3);
    }

    /// The async completion-queue pipeline is an execution knob: with the
    /// default (serial, in-order) `eval_async` it reproduces the sync
    /// two-phase journal bit for bit, at any thread count.
    #[test]
    fn async_pipeline_matches_sync_bit_for_bit() {
        let ev = surrogate(18);
        let sync = run(
            &ev,
            &cfg(
                14,
                23,
                EngineConfig {
                    batch: 4,
                    threads: 2,
                    cache: true,
                    quant_bits: 12,
                    async_eval: false,
                },
            ),
        );
        for threads in [1usize, 3] {
            let asynced = run(
                &ev,
                &cfg(
                    14,
                    23,
                    EngineConfig {
                        batch: 4,
                        threads,
                        cache: true,
                        quant_bits: 12,
                        async_eval: true,
                    },
                ),
            );
            assert_eq!(
                objective_bits(&sync),
                objective_bits(&asynced),
                "async pipeline diverged at {threads} pricing threads"
            );
            assert_eq!(sync.best, asynced.best);
            assert_eq!(sync.best_record().plan, asynced.best_record().plan);
            // every generation went through the queue...
            assert_eq!(asynced.stats.async_generations, asynced.stats.generations);
        }
        // ...and the sync run reports no async activity at all
        assert_eq!(sync.stats.async_generations, 0);
        assert_eq!(sync.stats.overlap_pricings, 0);
        assert_eq!(sync.stats.ooo_completions, 0);
    }

    #[test]
    fn batched_config_enables_async_pipeline() {
        let c = EngineConfig::batched(4);
        assert!(c.async_eval);
        assert_eq!(c.batch, 4);
        assert!(!EngineConfig::default().async_eval, "default stays the seed-serial loop");
    }

    #[test]
    fn cache_hit_rate_handles_zero_traffic() {
        let s = EngineStats::default();
        assert_eq!(s.cache_hit_rate(), 0.0, "0/0 must not be NaN");
        let s = EngineStats { cache_hits: 3, cache_misses: 1, ..Default::default() };
        assert!((s.cache_hit_rate() - 0.75).abs() < 1e-12);
        let s = EngineStats { cache_hits: 0, cache_misses: 5, ..Default::default() };
        assert_eq!(s.cache_hit_rate(), 0.0);
    }

    /// A warm shared cache changes the hit/miss split but not the journal.
    #[test]
    fn warm_cache_rerun_is_all_hits_and_bit_identical() {
        let ev = surrogate(17);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let c = cfg(
            8,
            21,
            EngineConfig { batch: 2, threads: 2, cache: true, quant_bits: 12, async_eval: false },
        );
        let cache = DesignCache::new();
        let eng = Engine::new(&ev, &net, &rm, &dev);
        let cold = eng.search_with_cache(&c, &cache);
        let warm = eng.search_with_cache(&c, &cache);
        assert_eq!(objective_bits(&cold), objective_bits(&warm));
        assert!(cold.stats.cache_misses > 0);
        assert_eq!(
            warm.stats.cache_misses, 0,
            "every pricing of a repeated run must be served from the cache"
        );
        assert_eq!(warm.stats.cache_hits, 8);
    }
}
