//! The candidate-evaluation seam of the search engine.
//!
//! [`CandidateEvaluator`] is the pluggable measurement backend: given a
//! pruning plan it returns accuracy plus the reached per-layer sparsity
//! operating points.  The two production backends live in
//! [`crate::coordinator`] ([`MeasuredEvaluator`](crate::coordinator::MeasuredEvaluator)
//! over the PJRT artifact, [`SurrogateEvaluator`](crate::coordinator::SurrogateEvaluator)
//! for target geometries we cannot execute); tests and tools can supply
//! their own.
//!
//! The trait requires `Sync` because the engine evaluates one generation's
//! candidates concurrently with scoped threads, sharing the evaluator by
//! reference.  Implementations whose backing executor is not thread-safe
//! (e.g. a PJRT client) must serialize internally — correctness of the
//! search does not depend on intra-generation evaluation order.
//!
//! # Asynchronous evaluation ([`CandidateEvaluator::eval_async`])
//!
//! Measured backends can be orders of magnitude slower than DSE pricing,
//! and they serialize internally — under the two-phase
//! measure-all-then-price-all generation loop the pricing threads sit
//! idle behind the evaluator lock.  [`eval_async`] is the completion-queue
//! seam that lets the engine overlap the two: the engine hands the backend
//! a whole generation of [`EvalRequest`]s plus an `mpsc` [`Sender`]; the
//! backend pushes one [`EvalCompletion`] per request **as soon as that
//! request finishes**, in *any* order, on *any* thread.  The engine prices
//! completed candidates while later ones are still in flight
//! (`EngineConfig::async_eval`); because each completion carries its
//! request's `slot` and evaluations are pure, completion order can never
//! change results — see the determinism contract in [`crate::engine`].
//!
//! The default implementation evaluates serially through [`eval`] and
//! sends each completion immediately, which already buys the overlap for
//! every existing backend (including `MeasuredEvaluator`, whose internal
//! mutex serializes measurements anyway).  Backends with real concurrency
//! (a device pool, a remote service) override it and complete out of
//! order; the engine does not care.
//!
//! # The fidelity ladder ([`SimulatedEvaluator`])
//!
//! The analytic DSE model (Eq. 1–3) prices a candidate in microseconds
//! but abstracts away dynamics — FIFO backpressure, pipeline fill — that
//! the cycle-level simulator ([`crate::simulator`]) captures exactly.
//! [`SimulatedEvaluator`] wraps any backend and climbs that ladder per
//! generation: every candidate is measured and priced analytically, then
//! the analytic top-k per device is re-scored with the event-driven
//! simulator, attaching one [`SimScore`] per device to the promoted
//! candidates' [`EvalPoint`]s.  The scoring side
//! (`Engine::score_candidate`) applies a matching non-deadlocked score in
//! place of the analytic throughput, so the search objective sees
//! simulator fidelity exactly where it matters: on the frontier the
//! optimizer is about to exploit.
//!
//! [`eval`]: CandidateEvaluator::eval
//! [`eval_async`]: CandidateEvaluator::eval_async
//! [`Sender`]: std::sync::mpsc::Sender

use std::sync::mpsc::{self, Sender};

use crate::arch::Network;
use crate::dse::{explore, DseConfig, NetworkDesign};
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::ResourceModel;
use crate::pruning::PruningPlan;
use crate::simulator::{simulate_par, stages_from_design, SparsityDynamics};
use crate::sparsity::{NetworkSparsity, SparsityPoint};

use super::cache::device_fingerprint;
use super::shard::run_slots;

/// Cycle-level re-score of one candidate on one device, attached by the
/// fidelity ladder ([`SimulatedEvaluator`]) to a promoted candidate's
/// [`EvalPoint`].
#[derive(Clone, Copy, Debug)]
pub struct SimScore {
    /// design-cache fingerprint of the simulated device (see
    /// `engine::cache`); the scoring side applies a score only on the
    /// shard whose device matches
    pub device_fp: u64,
    /// simulated throughput on that device, images/second
    pub images_per_sec: f64,
    /// the simulated pipeline wedged — the score is meaningless and the
    /// scoring side keeps the analytic number
    pub deadlocked: bool,
}

/// Accuracy + reached operating points for one pruning plan.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub accuracy: f64,
    pub points: Vec<SparsityPoint>,
    /// cycle-level re-scores attached by a laddered evaluator (one per
    /// simulated device); empty for plain backends
    pub sim: Vec<SimScore>,
}

/// One measurement request of an asynchronous generation: a decoded plan
/// plus the index-addressed slot its completion must carry back.
#[derive(Clone, Debug)]
pub struct EvalRequest {
    /// index of this request within its generation's distinct-proposal
    /// list; the matching [`EvalCompletion::slot`] routes the result
    pub slot: usize,
    pub plan: PruningPlan,
}

/// Why a measurement backend could not evaluate a plan (a PJRT execution
/// error, a lost device, a failed remote call).  Carried through
/// [`EvalCompletion::result`] so the engine scores the candidate
/// infeasible and keeps running instead of panicking — a worker panic
/// would poison the shared striped caches and, in a resident
/// `hass serve` process, kill every subsequent request.
pub type EvalError = String;

/// One finished measurement, tagged with its request's slot.  `Err`
/// means the backend could not evaluate the plan; the engine records the
/// candidate as infeasible (see `Engine::score_candidate`) — a failure is
/// data, not a panic.
#[derive(Clone, Debug)]
pub struct EvalCompletion {
    /// [`EvalRequest::slot`] of the request this result answers
    pub slot: usize,
    pub result: Result<EvalPoint, EvalError>,
}

/// Measurement backend of the search loop.
///
/// Evaluations must be *pure* with respect to the plan: the engine may
/// evaluate candidates of one generation in any order, on any thread, and
/// relies on `eval(plan)` returning the same value either way.  The same
/// contract extends to [`try_eval`](Self::try_eval) and
/// [`eval_async`](Self::eval_async): however a backend schedules or
/// reorders a batch, each completion must be exactly what a lone
/// evaluation of that plan would have returned — including which plans
/// *fail* (an error must be a deterministic function of the plan for the
/// journals to stay reproducible).
pub trait CandidateEvaluator: Sync {
    /// Sparsity model used to decode optimizer coordinates into thresholds.
    fn sparsity_model(&self) -> &NetworkSparsity;
    /// Evaluate a pruning plan: accuracy + per-layer operating points.
    fn eval(&self, plan: &PruningPlan) -> EvalPoint;
    /// Reference (unpruned) accuracy, for reporting drops.
    fn base_accuracy(&self) -> f64;

    /// Fallible evaluation — what the engine actually calls.  Backends
    /// whose measurements can fail (PJRT, remote services) override this
    /// and return `Err` instead of panicking; the engine scores the
    /// candidate infeasible and keeps running.  The default wraps the
    /// infallible [`eval`](Self::eval).
    fn try_eval(&self, plan: &PruningPlan) -> Result<EvalPoint, EvalError> {
        Ok(self.eval(plan))
    }

    /// Evaluate a generation's worth of requests, pushing one completion
    /// per request onto `completions` **as soon as it finishes** — in any
    /// order, from any thread.  The engine's async pipeline
    /// (`EngineConfig::async_eval`) prices completed candidates while the
    /// rest are still in flight.  A failed measurement completes with
    /// `Err` — every submitted slot must complete exactly once, failed or
    /// not.
    ///
    /// The default implementation evaluates serially via
    /// [`try_eval`](Self::try_eval) and completes in submission order.  A
    /// closed receiver (the engine bailing out) is not an error: stop
    /// evaluating and return.
    fn eval_async(&self, requests: Vec<EvalRequest>, completions: Sender<EvalCompletion>) {
        for req in requests {
            let result = self.try_eval(&req.plan);
            if completions.send(EvalCompletion { slot: req.slot, result }).is_err() {
                return; // receiver gone: nobody is waiting for the rest
            }
        }
    }
}

/// Fidelity-laddered evaluator: analytic pricing for the swarm, the
/// cycle-level simulator for the frontier.
///
/// Wraps any [`CandidateEvaluator`] (`inner` measures accuracy and
/// operating points as usual).  Per generation, [`eval_async`] climbs the
/// ladder:
///
/// 1. **measure** every candidate through `inner`;
/// 2. **rank** every `(candidate, device)` pair with the analytic DSE
///    model (`dse::explore`, no cache — the evaluator stays pure and
///    self-contained);
/// 3. **promote** the union over devices of the analytic top-`top_k`
///    candidates by images/second, and re-score each promoted
///    `(candidate, device)` pair with the event-driven simulator
///    ([`crate::simulator::simulate_par`], `Deterministic` dynamics,
///    `sim_images` images), attaching one [`SimScore`] per device.
///    Cores left idle by a small promotion set go *inside* each
///    simulation as per-layer scan workers (bit-identical to the serial
///    core), so a single promoted candidate still fills the machine.
///
/// Unpromoted candidates are released the moment ranking finishes, so
/// the engine prices them while the promoted simulations are still
/// running.  Everything on the ladder is deterministic (pure pricing, a
/// deterministic simulator, slot-tiebroken ranking), so results are
/// bit-identical for any thread count — the engine's determinism
/// contract holds.
///
/// The ladder ranks *within a generation*, which a lone
/// [`eval`](CandidateEvaluator::eval) cannot see: `eval` is plain
/// delegation to `inner`, and the engine must run this evaluator through
/// the async pipeline (`EngineConfig::async_eval`; the `hass search
/// --evaluator sim` CLI enforces it) for the ladder to engage.
pub struct SimulatedEvaluator {
    /// measurement backend producing accuracy + operating points
    pub inner: Box<dyn CandidateEvaluator>,
    /// target geometry the ladder prices and simulates
    pub target: Network,
    pub rm: ResourceModel,
    /// devices to rank on; every promoted candidate gets one [`SimScore`]
    /// per device
    pub devices: Vec<DeviceBudget>,
    /// DSE budget of the ladder's analytic ranking rung
    pub dse: DseConfig,
    /// candidates promoted to the simulator per generation, per device
    pub top_k: usize,
    /// images each promoted simulation runs (amortizes pipeline fill)
    pub sim_images: usize,
}

/// Machine parallelism (1 if unknown).
fn hw_parallelism() -> usize {
    std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
}

/// Worker threads for the ladder's internal pools — the evaluator runs
/// on the engine's submitter thread and owns its own scheduling.  Hard
/// cap: [`hw_parallelism`], never the amount of work — a generation with
/// hundreds of (candidate, device) pairs must not spawn hundreds of
/// threads on top of the engine's own workers.
fn ladder_threads(work: usize) -> usize {
    hw_parallelism().clamp(1, work.max(1))
}

impl CandidateEvaluator for SimulatedEvaluator {
    fn sparsity_model(&self) -> &NetworkSparsity {
        self.inner.sparsity_model()
    }

    /// Plain delegation: a lone evaluation has no generation to rank
    /// within, so the sync path degrades to the inner backend.
    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        self.inner.eval(plan)
    }

    fn base_accuracy(&self) -> f64 {
        self.inner.base_accuracy()
    }

    fn eval_async(&self, requests: Vec<EvalRequest>, completions: Sender<EvalCompletion>) {
        let n = requests.len();
        if n == 0 {
            return;
        }
        // rung 0: measure the whole generation through the inner backend
        let (tx, rx) = mpsc::channel();
        self.inner.eval_async(requests, tx);
        let mut measured: Vec<Option<Result<EvalPoint, EvalError>>> = Vec::new();
        measured.resize_with(n, || None);
        for c in rx {
            assert!(
                c.slot < n && measured[c.slot].is_none(),
                "inner evaluator violated the eval_async contract on slot {}",
                c.slot
            );
            measured[c.slot] = Some(c.result);
        }
        assert!(
            measured.iter().all(|r| r.is_some()),
            "inner evaluator completed fewer requests than were submitted"
        );
        // a failed measurement has no operating points to price or
        // simulate: pass the error straight through (the engine scores it
        // infeasible) and climb the ladder with the healthy slots only.
        // `slots[i]` maps ladder index i back to the original slot.
        let mut slots: Vec<usize> = Vec::with_capacity(n);
        let mut results: Vec<Option<EvalPoint>> = Vec::with_capacity(n);
        for (slot, r) in measured.into_iter().enumerate() {
            match r.expect("checked above") {
                Ok(point) => {
                    slots.push(slot);
                    results.push(Some(point));
                }
                Err(e) => {
                    if completions.send(EvalCompletion { slot, result: Err(e) }).is_err() {
                        return;
                    }
                }
            }
        }
        let m = slots.len();
        let n_dev = self.devices.len();
        if n_dev == 0 || m == 0 {
            for (i, r) in results.into_iter().enumerate() {
                let result = Ok(r.expect("healthy slot present"));
                if completions.send(EvalCompletion { slot: slots[i], result }).is_err() {
                    return;
                }
            }
            return;
        }

        // rung 1: price every healthy (candidate, device) pair analytically
        let mut designs: Vec<Option<NetworkDesign>> = Vec::new();
        designs.resize_with(m * n_dev, || None);
        run_slots(&mut designs, ladder_threads(m * n_dev), |slot, k| {
            let (i, d) = (k / n_dev, k % n_dev);
            let points = &results[i].as_ref().expect("healthy slot present").points;
            *slot =
                Some(explore(&self.target, points, &self.rm, &self.devices[d], &self.dse));
        });
        let designs: Vec<NetworkDesign> =
            designs.into_iter().map(|o| o.expect("pricing slot filled")).collect();

        // promote the union over devices of the analytic top-k
        let k_top = self.top_k.max(1).min(m);
        let mut promoted = vec![false; m];
        for d in 0..n_dev {
            let mut order: Vec<usize> = (0..m).collect();
            order.sort_by(|&a, &b| {
                let ia = designs[a * n_dev + d].images_per_sec(&self.devices[d]);
                let ib = designs[b * n_dev + d].images_per_sec(&self.devices[d]);
                ib.total_cmp(&ia).then(a.cmp(&b)) // ties: earlier slot wins
            });
            for &i in order.iter().take(k_top) {
                promoted[i] = true;
            }
        }

        // release the analytic-only candidates now — the engine prices
        // them while the promoted simulations run
        for i in 0..m {
            if !promoted[i] {
                let result = Ok(results[i].take().expect("healthy slot present"));
                if completions.send(EvalCompletion { slot: slots[i], result }).is_err() {
                    return;
                }
            }
        }

        // rung 2: cycle-level simulation of every promoted (candidate,
        // device) pair, concurrently.  When fewer simulations than cores
        // are in flight, the leftover parallelism goes *inside* each
        // simulation (`simulate_par`'s per-layer chunked scans), so a
        // lone promoted candidate still uses the whole machine instead of
        // one core — pool × per_sim never exceeds hw_parallelism.
        let idx: Vec<usize> = (0..m).filter(|&i| promoted[i]).collect();
        let mut scores: Vec<Option<SimScore>> = Vec::new();
        scores.resize_with(idx.len() * n_dev, || None);
        let pool = ladder_threads(idx.len() * n_dev);
        let per_sim = (hw_parallelism() / pool.max(1)).max(1);
        run_slots(&mut scores, pool, |slot, k| {
            let (i, d) = (idx[k / n_dev], k % n_dev);
            let dev = &self.devices[d];
            let points = &results[i].as_ref().expect("promoted result present").points;
            let cfgs = stages_from_design(
                &self.target,
                &designs[i * n_dev + d].designs,
                points,
                self.rm.fifo_depth,
            );
            let rep = simulate_par(
                &self.target,
                &cfgs,
                self.sim_images.max(1),
                SparsityDynamics::Deterministic,
                per_sim,
            );
            *slot = Some(SimScore {
                device_fp: device_fingerprint(dev),
                images_per_sec: rep.throughput * dev.freq_hz(),
                deadlocked: rep.deadlocked,
            });
        });
        for (pi, &i) in idx.iter().enumerate() {
            let mut result = results[i].take().expect("promoted result present");
            result.sim = (0..n_dev)
                .map(|d| scores[pi * n_dev + d].expect("sim slot filled"))
                .collect();
            if completions.send(EvalCompletion { slot: slots[i], result: Ok(result) }).is_err()
            {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::sparsity::synthesize;
    use std::sync::mpsc;

    /// Minimal evaluator relying entirely on the default `eval_async`.
    struct Plain {
        sparsity: NetworkSparsity,
    }

    impl CandidateEvaluator for Plain {
        fn sparsity_model(&self) -> &NetworkSparsity {
            &self.sparsity
        }

        fn eval(&self, plan: &PruningPlan) -> EvalPoint {
            let points = plan.points(&self.sparsity);
            let s: f64 = points.iter().map(|p| p.s_w).sum();
            EvalPoint { accuracy: 90.0 - s, points, sim: Vec::new() }
        }

        fn base_accuracy(&self) -> f64 {
            90.0
        }
    }

    #[test]
    fn ladder_thread_pool_is_capped_at_available_parallelism() {
        let hw = hw_parallelism();
        // never more threads than cores, no matter how many
        // (candidate, device) slots a generation carries
        assert_eq!(ladder_threads(usize::MAX), hw);
        assert_eq!(ladder_threads(10_000 * 64), hw.min(10_000 * 64));
        // and never more threads than work (or zero)
        assert_eq!(ladder_threads(0), 1);
        assert_eq!(ladder_threads(1), 1);
    }

    #[test]
    fn default_eval_async_completes_every_request_with_eval_results() {
        let net = networks::calibnet();
        let ev = Plain { sparsity: synthesize(&net, 7) };
        let n = ev.sparsity_model().layers.len();
        let plans: Vec<PruningPlan> = [0.0, 0.25, 0.6]
            .iter()
            .map(|&s| PruningPlan::from_unit_point(&vec![s; 2 * n], &ev.sparsity))
            .collect();
        let requests: Vec<EvalRequest> = plans
            .iter()
            .enumerate()
            .map(|(slot, plan)| EvalRequest { slot, plan: plan.clone() })
            .collect();
        let (tx, rx) = mpsc::channel();
        ev.eval_async(requests, tx);
        let mut got: Vec<EvalCompletion> = rx.iter().collect();
        assert_eq!(got.len(), plans.len());
        got.sort_by_key(|c| c.slot);
        for (c, plan) in got.iter().zip(&plans) {
            let direct = ev.eval(plan);
            let got = c.result.as_ref().expect("healthy evaluator never errors");
            assert_eq!(got.accuracy.to_bits(), direct.accuracy.to_bits());
            assert_eq!(got.points.len(), direct.points.len());
            for (a, b) in got.points.iter().zip(&direct.points) {
                assert_eq!(a.s_w.to_bits(), b.s_w.to_bits());
                assert_eq!(a.s_a.to_bits(), b.s_a.to_bits());
            }
        }
    }

    #[test]
    fn default_eval_async_stops_on_closed_receiver() {
        let net = networks::calibnet();
        let ev = Plain { sparsity: synthesize(&net, 8) };
        let n = ev.sparsity_model().layers.len();
        let requests: Vec<EvalRequest> = (0..4)
            .map(|slot| EvalRequest {
                slot,
                plan: PruningPlan::from_unit_point(&vec![0.3; 2 * n], &ev.sparsity),
            })
            .collect();
        let (tx, rx) = mpsc::channel();
        drop(rx);
        // must return quietly instead of panicking on the send error
        ev.eval_async(requests, tx);
    }

    fn laddered(seed: u64, top_k: usize) -> SimulatedEvaluator {
        let net = networks::calibnet();
        SimulatedEvaluator {
            inner: Box::new(Plain { sparsity: synthesize(&net, seed) }),
            target: net,
            rm: ResourceModel::default(),
            devices: vec![DeviceBudget::u250()],
            dse: DseConfig { max_iters: 1_500, ..Default::default() },
            top_k,
            sim_images: 2,
        }
    }

    fn ladder_requests(ev: &SimulatedEvaluator, sparsities: &[f64]) -> Vec<EvalRequest> {
        let n = ev.sparsity_model().layers.len();
        sparsities
            .iter()
            .enumerate()
            .map(|(slot, &s)| EvalRequest {
                slot,
                plan: PruningPlan::from_unit_point(&vec![s; 2 * n], ev.sparsity_model()),
            })
            .collect()
    }

    fn run_ladder(ev: &SimulatedEvaluator, sparsities: &[f64]) -> Vec<EvalPoint> {
        let reqs = ladder_requests(ev, sparsities);
        let n = reqs.len();
        let (tx, rx) = mpsc::channel();
        ev.eval_async(reqs, tx);
        let mut out: Vec<Option<EvalPoint>> = Vec::new();
        out.resize_with(n, || None);
        for c in rx {
            out[c.slot] = Some(c.result.expect("healthy evaluator never errors"));
        }
        out.into_iter().map(|o| o.expect("every slot completed")).collect()
    }

    #[test]
    fn ladder_promotes_exactly_top_k_and_keeps_inner_results() {
        let ev = laddered(21, 2);
        let sparsities = [0.0, 0.2, 0.45, 0.7];
        let results = run_ladder(&ev, &sparsities);
        let fp = device_fingerprint(&ev.devices[0]);
        let promoted = results.iter().filter(|r| !r.sim.is_empty()).count();
        assert_eq!(promoted, 2, "top-2 of one device must be simulated");
        for r in &results {
            for s in &r.sim {
                assert_eq!(s.device_fp, fp);
                assert!(s.deadlocked || s.images_per_sec > 0.0);
            }
        }
        // the measurement itself is untouched: bit-identical to the inner
        // backend's lone eval
        let reqs = ladder_requests(&ev, &sparsities);
        for (r, req) in results.iter().zip(&reqs) {
            let direct = ev.inner.eval(&req.plan);
            assert_eq!(r.accuracy.to_bits(), direct.accuracy.to_bits());
            assert_eq!(r.points.len(), direct.points.len());
        }
    }

    #[test]
    fn ladder_is_deterministic() {
        let ev = laddered(22, 2);
        let sparsities = [0.1, 0.3, 0.55];
        let a = run_ladder(&ev, &sparsities);
        let b = run_ladder(&ev, &sparsities);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sim.len(), y.sim.len());
            for (sx, sy) in x.sim.iter().zip(&y.sim) {
                assert_eq!(sx.device_fp, sy.device_fp);
                assert_eq!(sx.images_per_sec.to_bits(), sy.images_per_sec.to_bits());
                assert_eq!(sx.deadlocked, sy.deadlocked);
            }
        }
    }

    /// Inner evaluator that fails as a *pure function of the plan* (any
    /// impure failure predicate would make journals nondeterministic).
    struct Failing {
        sparsity: NetworkSparsity,
        fail_above: f64,
    }

    impl CandidateEvaluator for Failing {
        fn sparsity_model(&self) -> &NetworkSparsity {
            &self.sparsity
        }

        fn eval(&self, plan: &PruningPlan) -> EvalPoint {
            self.try_eval(plan).expect("caller must use try_eval for failing plans")
        }

        fn try_eval(&self, plan: &PruningPlan) -> Result<EvalPoint, EvalError> {
            let points = plan.points(&self.sparsity);
            let s: f64 = points.iter().map(|p| p.s_w).sum();
            if s > self.fail_above {
                return Err(format!("measurement backend rejected plan (s = {s:.3})"));
            }
            Ok(EvalPoint { accuracy: 90.0 - s, points, sim: Vec::new() })
        }

        fn base_accuracy(&self) -> f64 {
            90.0
        }
    }

    #[test]
    fn ladder_passes_inner_errors_through_and_prices_the_rest() {
        let net = networks::calibnet();
        let sparsity = synthesize(&net, 31);
        let n = sparsity.layers.len();
        // fail_above = 0 fails every plan with any weight sparsity; the
        // dense plan (s = 0) survives
        let ev = SimulatedEvaluator {
            inner: Box::new(Failing { sparsity: sparsity.clone(), fail_above: 0.0 }),
            target: net,
            rm: ResourceModel::default(),
            devices: vec![DeviceBudget::u250()],
            dse: DseConfig { max_iters: 1_500, ..Default::default() },
            top_k: 2,
            sim_images: 2,
        };
        let reqs: Vec<EvalRequest> = [0.0, 0.4, 0.7]
            .iter()
            .enumerate()
            .map(|(slot, &s)| EvalRequest {
                slot,
                plan: PruningPlan::from_unit_point(&vec![s; 2 * n], &sparsity),
            })
            .collect();
        let (tx, rx) = mpsc::channel();
        ev.eval_async(reqs, tx);
        let mut out: Vec<Option<Result<EvalPoint, EvalError>>> = vec![None, None, None];
        for c in rx {
            assert!(out[c.slot].is_none(), "duplicate completion for slot {}", c.slot);
            out[c.slot] = Some(c.result);
        }
        let out: Vec<Result<EvalPoint, EvalError>> =
            out.into_iter().map(|o| o.expect("every slot completed")).collect();
        // the dense slot survives the ladder and, as the only healthy
        // candidate, is promoted to simulation
        let healthy = out[0].as_ref().expect("dense plan must succeed");
        assert!(!healthy.sim.is_empty(), "sole healthy candidate must be simulated");
        // failed slots pass through untouched, carrying the inner error
        for slot in [1, 2] {
            let err = out[slot].as_ref().expect_err("sparse plans must fail");
            assert!(err.contains("rejected plan"), "error lost in the ladder: {err}");
        }
    }

    #[test]
    fn ladder_promotes_the_analytically_fastest_candidates() {
        // sparser candidates price faster on the analytic model, so with
        // top_k = 1 the single promoted candidate must be the sparsest
        let ev = laddered(23, 1);
        let results = run_ladder(&ev, &[0.0, 0.35, 0.65]);
        assert_eq!(
            results.iter().filter(|r| !r.sim.is_empty()).count(),
            1,
            "exactly one candidate promoted at top_k = 1"
        );
        assert!(
            !results[2].sim.is_empty(),
            "the sparsest (analytically fastest) candidate must be the promoted one"
        );
    }
}
