//! The candidate-evaluation seam of the search engine.
//!
//! [`CandidateEvaluator`] is the pluggable measurement backend: given a
//! pruning plan it returns accuracy plus the reached per-layer sparsity
//! operating points.  The two production backends live in
//! [`crate::coordinator`] ([`MeasuredEvaluator`](crate::coordinator::MeasuredEvaluator)
//! over the PJRT artifact, [`SurrogateEvaluator`](crate::coordinator::SurrogateEvaluator)
//! for target geometries we cannot execute); tests and tools can supply
//! their own.
//!
//! The trait requires `Sync` because the engine evaluates one generation's
//! candidates concurrently with scoped threads, sharing the evaluator by
//! reference.  Implementations whose backing executor is not thread-safe
//! (e.g. a PJRT client) must serialize internally — correctness of the
//! search does not depend on intra-generation evaluation order.
//!
//! # Asynchronous evaluation ([`CandidateEvaluator::eval_async`])
//!
//! Measured backends can be orders of magnitude slower than DSE pricing,
//! and they serialize internally — under the two-phase
//! measure-all-then-price-all generation loop the pricing threads sit
//! idle behind the evaluator lock.  [`eval_async`] is the completion-queue
//! seam that lets the engine overlap the two: the engine hands the backend
//! a whole generation of [`EvalRequest`]s plus an `mpsc` [`Sender`]; the
//! backend pushes one [`EvalCompletion`] per request **as soon as that
//! request finishes**, in *any* order, on *any* thread.  The engine prices
//! completed candidates while later ones are still in flight
//! (`EngineConfig::async_eval`); because each completion carries its
//! request's `slot` and evaluations are pure, completion order can never
//! change results — see the determinism contract in [`crate::engine`].
//!
//! The default implementation evaluates serially through [`eval`] and
//! sends each completion immediately, which already buys the overlap for
//! every existing backend (including `MeasuredEvaluator`, whose internal
//! mutex serializes measurements anyway).  Backends with real concurrency
//! (a device pool, a remote service) override it and complete out of
//! order; the engine does not care.
//!
//! [`eval`]: CandidateEvaluator::eval
//! [`eval_async`]: CandidateEvaluator::eval_async
//! [`Sender`]: std::sync::mpsc::Sender

use std::sync::mpsc::Sender;

use crate::pruning::PruningPlan;
use crate::sparsity::{NetworkSparsity, SparsityPoint};

/// Accuracy + reached operating points for one pruning plan.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub accuracy: f64,
    pub points: Vec<SparsityPoint>,
}

/// One measurement request of an asynchronous generation: a decoded plan
/// plus the index-addressed slot its completion must carry back.
#[derive(Clone, Debug)]
pub struct EvalRequest {
    /// index of this request within its generation's distinct-proposal
    /// list; the matching [`EvalCompletion::slot`] routes the result
    pub slot: usize,
    pub plan: PruningPlan,
}

/// One finished measurement, tagged with its request's slot.
#[derive(Clone, Debug)]
pub struct EvalCompletion {
    /// [`EvalRequest::slot`] of the request this result answers
    pub slot: usize,
    pub result: EvalPoint,
}

/// Measurement backend of the search loop.
///
/// Evaluations must be *pure* with respect to the plan: the engine may
/// evaluate candidates of one generation in any order, on any thread, and
/// relies on `eval(plan)` returning the same value either way.  The same
/// contract extends to [`eval_async`](Self::eval_async): however a backend
/// schedules or reorders a batch, each completion must be exactly what a
/// lone `eval` of that plan would have returned.
pub trait CandidateEvaluator: Sync {
    /// Sparsity model used to decode optimizer coordinates into thresholds.
    fn sparsity_model(&self) -> &NetworkSparsity;
    /// Evaluate a pruning plan: accuracy + per-layer operating points.
    fn eval(&self, plan: &PruningPlan) -> EvalPoint;
    /// Reference (unpruned) accuracy, for reporting drops.
    fn base_accuracy(&self) -> f64;

    /// Evaluate a generation's worth of requests, pushing one completion
    /// per request onto `completions` **as soon as it finishes** — in any
    /// order, from any thread.  The engine's async pipeline
    /// (`EngineConfig::async_eval`) prices completed candidates while the
    /// rest are still in flight.
    ///
    /// The default implementation evaluates serially via
    /// [`eval`](Self::eval) and completes in submission order.  A closed
    /// receiver (the engine bailing out) is not an error: stop evaluating
    /// and return.
    fn eval_async(&self, requests: Vec<EvalRequest>, completions: Sender<EvalCompletion>) {
        for req in requests {
            let result = self.eval(&req.plan);
            if completions.send(EvalCompletion { slot: req.slot, result }).is_err() {
                return; // receiver gone: nobody is waiting for the rest
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::sparsity::synthesize;
    use std::sync::mpsc;

    /// Minimal evaluator relying entirely on the default `eval_async`.
    struct Plain {
        sparsity: NetworkSparsity,
    }

    impl CandidateEvaluator for Plain {
        fn sparsity_model(&self) -> &NetworkSparsity {
            &self.sparsity
        }

        fn eval(&self, plan: &PruningPlan) -> EvalPoint {
            let points = plan.points(&self.sparsity);
            let s: f64 = points.iter().map(|p| p.s_w).sum();
            EvalPoint { accuracy: 90.0 - s, points }
        }

        fn base_accuracy(&self) -> f64 {
            90.0
        }
    }

    #[test]
    fn default_eval_async_completes_every_request_with_eval_results() {
        let net = networks::calibnet();
        let ev = Plain { sparsity: synthesize(&net, 7) };
        let n = ev.sparsity_model().layers.len();
        let plans: Vec<PruningPlan> = [0.0, 0.25, 0.6]
            .iter()
            .map(|&s| PruningPlan::from_unit_point(&vec![s; 2 * n], &ev.sparsity))
            .collect();
        let requests: Vec<EvalRequest> = plans
            .iter()
            .enumerate()
            .map(|(slot, plan)| EvalRequest { slot, plan: plan.clone() })
            .collect();
        let (tx, rx) = mpsc::channel();
        ev.eval_async(requests, tx);
        let mut got: Vec<EvalCompletion> = rx.iter().collect();
        assert_eq!(got.len(), plans.len());
        got.sort_by_key(|c| c.slot);
        for (c, plan) in got.iter().zip(&plans) {
            let direct = ev.eval(plan);
            assert_eq!(c.result.accuracy.to_bits(), direct.accuracy.to_bits());
            assert_eq!(c.result.points.len(), direct.points.len());
            for (a, b) in c.result.points.iter().zip(&direct.points) {
                assert_eq!(a.s_w.to_bits(), b.s_w.to_bits());
                assert_eq!(a.s_a.to_bits(), b.s_a.to_bits());
            }
        }
    }

    #[test]
    fn default_eval_async_stops_on_closed_receiver() {
        let net = networks::calibnet();
        let ev = Plain { sparsity: synthesize(&net, 8) };
        let n = ev.sparsity_model().layers.len();
        let requests: Vec<EvalRequest> = (0..4)
            .map(|slot| EvalRequest {
                slot,
                plan: PruningPlan::from_unit_point(&vec![0.3; 2 * n], &ev.sparsity),
            })
            .collect();
        let (tx, rx) = mpsc::channel();
        drop(rx);
        // must return quietly instead of panicking on the send error
        ev.eval_async(requests, tx);
    }
}
