//! The candidate-evaluation seam of the search engine.
//!
//! [`CandidateEvaluator`] is the pluggable measurement backend: given a
//! pruning plan it returns accuracy plus the reached per-layer sparsity
//! operating points.  The two production backends live in
//! [`crate::coordinator`] ([`MeasuredEvaluator`](crate::coordinator::MeasuredEvaluator)
//! over the PJRT artifact, [`SurrogateEvaluator`](crate::coordinator::SurrogateEvaluator)
//! for target geometries we cannot execute); tests and tools can supply
//! their own.
//!
//! The trait requires `Sync` because the engine evaluates one generation's
//! candidates concurrently with scoped threads, sharing the evaluator by
//! reference.  Implementations whose backing executor is not thread-safe
//! (e.g. a PJRT client) must serialize internally — correctness of the
//! search does not depend on intra-generation evaluation order.

use crate::pruning::PruningPlan;
use crate::sparsity::{NetworkSparsity, SparsityPoint};

/// Accuracy + reached operating points for one pruning plan.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub accuracy: f64,
    pub points: Vec<SparsityPoint>,
}

/// Measurement backend of the search loop.
///
/// Evaluations must be *pure* with respect to the plan: the engine may
/// evaluate candidates of one generation in any order, on any thread, and
/// relies on `eval(plan)` returning the same value either way.
pub trait CandidateEvaluator: Sync {
    /// Sparsity model used to decode optimizer coordinates into thresholds.
    fn sparsity_model(&self) -> &NetworkSparsity;
    /// Evaluate a pruning plan: accuracy + per-layer operating points.
    fn eval(&self, plan: &PruningPlan) -> EvalPoint;
    /// Reference (unpruned) accuracy, for reporting drops.
    fn base_accuracy(&self) -> f64;
}
