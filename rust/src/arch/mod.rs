//! Dataflow-graph IR for DNN workloads.
//!
//! A [`Network`] is the left-hand side of the paper's Fig. 3: a sequence of
//! dataflow nodes, each a hardware component.  Compute nodes (convolutions
//! and linear layers — the paper's "blue nodes") are the resource-intensive
//! ones the sparse engines accelerate; the rest (pooling, elementwise add,
//! activations) are cheap streaming components assumed rate-matched.
//!
//! The five evaluation geometries of the paper (ResNet-18/50, MobileNetV2,
//! MobileNetV3-S/L, exact torchvision shapes at 224x224) plus the really
//! executed CalibNet are built in [`networks`].

pub mod networks;

/// Operator of a dataflow node.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// 2-D convolution (grouped; `groups == cin == cout` is depthwise).
    Conv {
        kernel: usize,
        stride: usize,
        pad: usize,
        cin: usize,
        cout: usize,
        groups: usize,
    },
    /// Fully connected.
    Linear { cin: usize, cout: usize },
    /// Max/avg pooling window.
    Pool { kernel: usize, stride: usize, channels: usize },
    /// Global average pool to 1x1.
    GlobalPool { channels: usize },
    /// Elementwise residual add.
    Add { channels: usize },
    /// Elementwise activation (ReLU / hard-swish / sigmoid-mul for SE).
    Act { channels: usize },
}

/// One dataflow node plus its input spatial size.
#[derive(Clone, Debug)]
pub struct LayerDesc {
    pub name: String,
    pub op: Op,
    /// spatial edge length of the input feature map (1 for vector input)
    pub in_hw: usize,
    /// true for nodes on a side branch (projection shortcuts, SE blocks):
    /// they tap the main pipeline rather than extending it, so the linear
    /// chain validation skips them when propagating shapes.
    pub branch: bool,
}

impl LayerDesc {
    /// Is this a compute ("blue") node mapped onto sparse engines?
    pub fn is_compute(&self) -> bool {
        matches!(self.op, Op::Conv { .. } | Op::Linear { .. })
    }

    /// Output spatial edge length.
    pub fn out_hw(&self) -> usize {
        match &self.op {
            Op::Conv { stride, .. } | Op::Pool { stride, .. } => {
                self.in_hw.div_ceil(*stride)
            }
            Op::GlobalPool { .. } | Op::Linear { .. } => 1,
            Op::Add { .. } | Op::Act { .. } => self.in_hw,
        }
    }

    /// Output channels.
    pub fn out_channels(&self) -> usize {
        match &self.op {
            Op::Conv { cout, .. } => *cout,
            Op::Linear { cout, .. } => *cout,
            Op::Pool { channels, .. }
            | Op::GlobalPool { channels }
            | Op::Add { channels }
            | Op::Act { channels } => *channels,
        }
    }

    /// Dot-product length K of one output (the paper's full vector length
    /// before input-parallel splitting): k*k*cin/groups for conv.
    pub fn patch_k(&self) -> usize {
        match &self.op {
            Op::Conv { kernel, cin, groups, .. } => kernel * kernel * cin / groups,
            Op::Linear { cin, .. } => *cin,
            _ => 0,
        }
    }

    /// Number of output elements per image.
    pub fn outputs_per_image(&self) -> usize {
        match &self.op {
            Op::Conv { cout, .. } => self.out_hw() * self.out_hw() * cout,
            Op::Linear { cout, .. } => *cout,
            _ => 0,
        }
    }

    /// Dense MAC count per image, C_l (including zero operands).
    pub fn macs_per_image(&self) -> u64 {
        (self.outputs_per_image() as u64) * (self.patch_k() as u64)
    }

    /// Weight parameter count.
    pub fn weight_count(&self) -> u64 {
        match &self.op {
            Op::Conv { kernel, cin, cout, groups, .. } => {
                (kernel * kernel * cin / groups * cout) as u64
            }
            Op::Linear { cin, cout } => (cin * cout) as u64,
            _ => 0,
        }
    }

    /// Input-channel extent available for i-parallelism (paper's I).
    pub fn i_extent(&self) -> usize {
        match &self.op {
            Op::Conv { cin, groups, .. } => cin / groups,
            Op::Linear { cin, .. } => *cin,
            _ => 1,
        }
    }

    /// Output-filter extent available for o-parallelism (paper's O).
    pub fn o_extent(&self) -> usize {
        match &self.op {
            Op::Conv { cout, .. } => *cout,
            Op::Linear { cout, .. } => *cout,
            _ => 1,
        }
    }
}

/// A whole workload: dataflow graph in topological (pipeline) order.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub input_hw: usize,
    pub input_channels: usize,
    pub layers: Vec<LayerDesc>,
}

impl Network {
    /// Indices of compute layers (the DSE design variables).
    pub fn compute_indices(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.is_compute())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn compute_layers(&self) -> Vec<&LayerDesc> {
        self.layers.iter().filter(|l| l.is_compute()).collect()
    }

    /// Total dense MACs per image.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs_per_image()).sum()
    }

    /// Total weight parameters.
    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_count()).sum()
    }

    /// Structural sanity: spatial sizes must chain, channel counts match.
    pub fn validate(&self) -> Result<(), String> {
        let mut hw = self.input_hw;
        let mut ch = self.input_channels;
        for (i, l) in self.layers.iter().enumerate() {
            if l.branch {
                // side branches only need internally consistent geometry
                if let Op::Conv { kernel, stride, .. } = &l.op {
                    if *stride == 0 || *kernel == 0 {
                        return Err(format!("{}: branch layer {i} bad geometry", self.name));
                    }
                }
                continue;
            }
            if l.in_hw != hw {
                return Err(format!(
                    "{}: layer {i} ({}) expects in_hw {} but pipeline provides {hw}",
                    self.name, l.name, l.in_hw
                ));
            }
            let expect_cin = match &l.op {
                Op::Conv { cin, .. } => Some(*cin),
                Op::Linear { cin, .. } => Some(*cin),
                Op::Pool { channels, .. }
                | Op::GlobalPool { channels }
                | Op::Add { channels }
                | Op::Act { channels } => Some(*channels),
            };
            if let Some(c) = expect_cin {
                if c != ch {
                    return Err(format!(
                        "{}: layer {i} ({}) expects {c} channels, pipeline provides {ch}",
                        self.name, l.name
                    ));
                }
            }
            if let Op::Conv { kernel, pad, stride, .. } = &l.op {
                // same-padding family used throughout torchvision models
                if *pad > *kernel || *stride == 0 {
                    return Err(format!("{}: layer {i} bad geometry", self.name));
                }
            }
            hw = l.out_hw();
            ch = l.out_channels();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(name: &str, k: usize, s: usize, cin: usize, cout: usize, hw: usize) -> LayerDesc {
        LayerDesc {
            name: name.into(),
            op: Op::Conv { kernel: k, stride: s, pad: (k - 1) / 2, cin, cout, groups: 1 },
            in_hw: hw,
            branch: false,
        }
    }

    #[test]
    fn conv_geometry() {
        let l = conv("c", 3, 1, 3, 16, 32);
        assert_eq!(l.out_hw(), 32);
        assert_eq!(l.patch_k(), 27);
        assert_eq!(l.outputs_per_image(), 32 * 32 * 16);
        assert_eq!(l.macs_per_image(), 32 * 32 * 16 * 27);
        assert_eq!(l.weight_count(), 27 * 16);
    }

    #[test]
    fn strided_conv_halves_spatial() {
        let l = conv("c", 3, 2, 16, 32, 32);
        assert_eq!(l.out_hw(), 16);
    }

    #[test]
    fn depthwise_conv() {
        let l = LayerDesc {
            name: "dw".into(),
            op: Op::Conv { kernel: 3, stride: 1, pad: 1, cin: 32, cout: 32, groups: 32 },
            in_hw: 16,
            branch: false,
        };
        assert_eq!(l.patch_k(), 9);
        assert_eq!(l.macs_per_image(), 16 * 16 * 32 * 9);
        assert_eq!(l.weight_count(), 9 * 32);
        assert_eq!(l.i_extent(), 1);
    }

    #[test]
    fn linear_layer() {
        let l = LayerDesc {
            name: "fc".into(),
            op: Op::Linear { cin: 512, cout: 1000 },
            in_hw: 1,
            branch: false,
        };
        assert_eq!(l.macs_per_image(), 512_000);
        assert_eq!(l.out_hw(), 1);
        assert!(l.is_compute());
    }

    #[test]
    fn pool_is_not_compute() {
        let l = LayerDesc {
            name: "p".into(),
            op: Op::Pool { kernel: 2, stride: 2, channels: 64 },
            in_hw: 8,
            branch: false,
        };
        assert!(!l.is_compute());
        assert_eq!(l.macs_per_image(), 0);
        assert_eq!(l.out_hw(), 4);
    }

    #[test]
    fn validate_catches_spatial_mismatch() {
        let net = Network {
            name: "bad".into(),
            input_hw: 32,
            input_channels: 3,
            layers: vec![conv("a", 3, 2, 3, 8, 32), conv("b", 3, 1, 8, 8, 32)],
        };
        assert!(net.validate().is_err());
    }

    #[test]
    fn validate_catches_channel_mismatch() {
        let net = Network {
            name: "bad".into(),
            input_hw: 32,
            input_channels: 3,
            layers: vec![conv("a", 3, 1, 3, 8, 32), conv("b", 3, 1, 16, 8, 32)],
        };
        assert!(net.validate().is_err());
    }

    #[test]
    fn validate_accepts_chain() {
        let net = Network {
            name: "ok".into(),
            input_hw: 32,
            input_channels: 3,
            layers: vec![conv("a", 3, 2, 3, 8, 32), conv("b", 3, 1, 8, 8, 16)],
        };
        assert!(net.validate().is_ok());
    }
}
