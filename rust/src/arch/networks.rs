//! Network zoo: the paper's five evaluation geometries (exact torchvision
//! shapes, 224x224 ImageNet input) plus the really-executed CalibNet.
//!
//! Every builder tracks spatial sizes exactly and is `validate()`d in
//! tests; total MAC/parameter counts are pinned against the published
//! torchvision numbers (ResNet-18 ≈ 1.81 GMACs / 11.7 M params, ...).

use super::{LayerDesc, Network, Op};

struct B {
    layers: Vec<LayerDesc>,
    hw: usize,
    ch: usize,
}

impl B {
    fn new(hw: usize, ch: usize) -> Self {
        B { layers: Vec::new(), hw, ch }
    }

    fn conv(&mut self, name: &str, k: usize, s: usize, cout: usize) -> &mut Self {
        self.conv_g(name, k, s, cout, 1)
    }

    fn conv_g(&mut self, name: &str, k: usize, s: usize, cout: usize, groups: usize) -> &mut Self {
        self.layers.push(LayerDesc {
            name: name.into(),
            op: Op::Conv {
                kernel: k,
                stride: s,
                pad: (k - 1) / 2,
                cin: self.ch,
                cout,
                groups,
            },
            in_hw: self.hw,
            branch: false,
        });
        self.hw = self.hw.div_ceil(s);
        self.ch = cout;
        self
    }

    fn dw(&mut self, name: &str, k: usize, s: usize) -> &mut Self {
        let c = self.ch;
        self.conv_g(name, k, s, c, c)
    }

    /// Side-branch conv (projection shortcut): consumes `(hw, cin)` from an
    /// earlier tap point, does not advance the main chain.
    fn branch_conv(&mut self, name: &str, k: usize, s: usize, cin: usize, cout: usize, hw: usize) {
        self.layers.push(LayerDesc {
            name: name.into(),
            op: Op::Conv { kernel: k, stride: s, pad: (k - 1) / 2, cin, cout, groups: 1 },
            in_hw: hw,
            branch: true,
        });
    }

    /// Side-branch linear (SE block FC), spatial 1.
    fn branch_linear(&mut self, name: &str, cin: usize, cout: usize) {
        self.layers.push(LayerDesc {
            name: name.into(),
            op: Op::Linear { cin, cout },
            in_hw: 1,
            branch: true,
        });
    }

    fn act(&mut self, name: &str) -> &mut Self {
        self.layers.push(LayerDesc {
            name: name.into(),
            op: Op::Act { channels: self.ch },
            in_hw: self.hw,
            branch: false,
        });
        self
    }

    fn add(&mut self, name: &str) -> &mut Self {
        self.layers.push(LayerDesc {
            name: name.into(),
            op: Op::Add { channels: self.ch },
            in_hw: self.hw,
            branch: false,
        });
        self
    }

    fn pool(&mut self, name: &str, k: usize, s: usize) -> &mut Self {
        self.layers.push(LayerDesc {
            name: name.into(),
            op: Op::Pool { kernel: k, stride: s, channels: self.ch },
            in_hw: self.hw,
            branch: false,
        });
        self.hw = self.hw.div_ceil(s);
        self
    }

    fn gap(&mut self, name: &str) -> &mut Self {
        self.layers.push(LayerDesc {
            name: name.into(),
            op: Op::GlobalPool { channels: self.ch },
            in_hw: self.hw,
            branch: false,
        });
        self.hw = 1;
        self
    }

    fn linear(&mut self, name: &str, cout: usize) -> &mut Self {
        self.layers.push(LayerDesc {
            name: name.into(),
            op: Op::Linear { cin: self.ch, cout },
            in_hw: 1,
            branch: false,
        });
        self.ch = cout;
        self
    }

    fn finish(self, name: &str, input_hw: usize, input_channels: usize) -> Network {
        let net = Network {
            name: name.into(),
            input_hw,
            input_channels,
            layers: self.layers,
        };
        debug_assert_eq!(net.validate(), Ok(()));
        net
    }
}

// ------------------------------------------------------------- CalibNet

/// The really-executed calibration network (matches python/compile/common.py).
pub fn calibnet() -> Network {
    let mut b = B::new(32, 3);
    b.conv("stem", 3, 1, 16).act("stem.relu");
    // block 1: identity shortcut
    b.conv("b1.conv1", 3, 1, 16).act("b1.relu1");
    b.conv("b1.conv2", 3, 1, 16).add("b1.add").act("b1.relu2");
    // block 2: projection shortcut, stride 2
    b.conv("b2.conv1", 3, 2, 32).act("b2.relu1");
    b.conv("b2.conv2", 3, 1, 32);
    b.branch_conv("b2.down", 1, 2, 16, 32, 32);
    b.add("b2.add").act("b2.relu2");
    // block 3
    b.conv("b3.conv1", 3, 2, 64).act("b3.relu1");
    b.conv("b3.conv2", 3, 1, 64);
    b.branch_conv("b3.down", 1, 2, 32, 64, 16);
    b.add("b3.add").act("b3.relu2");
    b.gap("gap").linear("fc", 10);
    b.finish("calibnet", 32, 3)
}

/// Order in which CalibNet's compute layers appear in the AOT artifact
/// (python side: stem, b1.conv1, b1.conv2, b2.conv1, b2.conv2, b2.down,
/// b3.conv1, b3.conv2, b3.down, fc).
pub fn calibnet_artifact_order() -> Vec<&'static str> {
    vec![
        "stem", "b1.conv1", "b1.conv2", "b2.conv1", "b2.conv2", "b2.down",
        "b3.conv1", "b3.conv2", "b3.down", "fc",
    ]
}

// ------------------------------------------------------------ ResNet-18

fn basic_block(b: &mut B, name: &str, cout: usize, stride: usize) {
    let cin = b.ch;
    let hw_in = b.hw;
    b.conv(&format!("{name}.conv1"), 3, stride, cout).act(&format!("{name}.relu1"));
    b.conv(&format!("{name}.conv2"), 3, 1, cout);
    if stride != 1 || cin != cout {
        b.branch_conv(&format!("{name}.down"), 1, stride, cin, cout, hw_in);
    }
    b.add(&format!("{name}.add")).act(&format!("{name}.relu2"));
}

pub fn resnet18() -> Network {
    let mut b = B::new(224, 3);
    b.conv("conv1", 7, 2, 64).act("relu1").pool("maxpool", 3, 2);
    for (stage, (c, s)) in [(64, 1), (128, 2), (256, 2), (512, 2)].iter().enumerate() {
        for blk in 0..2 {
            let stride = if blk == 0 { *s } else { 1 };
            basic_block(&mut b, &format!("layer{}.{}", stage + 1, blk), *c, stride);
        }
    }
    b.gap("avgpool").linear("fc", 1000);
    b.finish("resnet18", 224, 3)
}

// ------------------------------------------------------------ ResNet-50

fn bottleneck(b: &mut B, name: &str, mid: usize, cout: usize, stride: usize) {
    let cin = b.ch;
    let hw_in = b.hw;
    b.conv(&format!("{name}.conv1"), 1, 1, mid).act(&format!("{name}.relu1"));
    b.conv(&format!("{name}.conv2"), 3, stride, mid).act(&format!("{name}.relu2"));
    b.conv(&format!("{name}.conv3"), 1, 1, cout);
    if stride != 1 || cin != cout {
        b.branch_conv(&format!("{name}.down"), 1, stride, cin, cout, hw_in);
    }
    b.add(&format!("{name}.add")).act(&format!("{name}.relu3"));
}

pub fn resnet50() -> Network {
    let mut b = B::new(224, 3);
    b.conv("conv1", 7, 2, 64).act("relu1").pool("maxpool", 3, 2);
    let stages: [(usize, usize, usize, usize); 4] = [
        (64, 256, 3, 1),
        (128, 512, 4, 2),
        (256, 1024, 6, 2),
        (512, 2048, 3, 2),
    ];
    for (si, (mid, cout, blocks, s)) in stages.iter().enumerate() {
        for blk in 0..*blocks {
            let stride = if blk == 0 { *s } else { 1 };
            bottleneck(&mut b, &format!("layer{}.{}", si + 1, blk), *mid, *cout, stride);
        }
    }
    b.gap("avgpool").linear("fc", 1000);
    b.finish("resnet50", 224, 3)
}

// ---------------------------------------------------------- MobileNetV2

fn inverted_residual(b: &mut B, name: &str, expand: usize, cout: usize, stride: usize) {
    let cin = b.ch;
    let hidden = cin * expand;
    if expand != 1 {
        b.conv(&format!("{name}.expand"), 1, 1, hidden).act(&format!("{name}.act1"));
    }
    b.dw(&format!("{name}.dw"), 3, stride).act(&format!("{name}.act2"));
    b.conv(&format!("{name}.project"), 1, 1, cout);
    if stride == 1 && cin == cout {
        b.add(&format!("{name}.add"));
    }
}

pub fn mobilenet_v2() -> Network {
    let mut b = B::new(224, 3);
    b.conv("stem", 3, 2, 32).act("stem.act");
    // (expand t, channels c, repeats n, stride s) — torchvision table
    let cfg: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut idx = 0;
    for (t, c, n, s) in cfg {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            inverted_residual(&mut b, &format!("ir{idx}"), t, c, stride);
            idx += 1;
        }
    }
    b.conv("head", 1, 1, 1280).act("head.act");
    b.gap("gap").linear("fc", 1000);
    b.finish("mobilenet_v2", 224, 3)
}

// ---------------------------------------------------------- MobileNetV3

#[allow(clippy::too_many_arguments)]
fn mbv3_block(b: &mut B, name: &str, k: usize, exp: usize, cout: usize, se: bool, stride: usize) {
    let cin = b.ch;
    if exp != cin {
        b.conv(&format!("{name}.expand"), 1, 1, exp).act(&format!("{name}.act1"));
    }
    b.dw(&format!("{name}.dw"), k, stride).act(&format!("{name}.act2"));
    if se {
        // squeeze-excitation: GAP -> fc1 -> relu -> fc2 -> hsigmoid-mul.
        // torchvision uses squeeze = make_divisible(exp / 4, 8).
        let sq = make_divisible(exp / 4, 8);
        b.branch_linear(&format!("{name}.se.fc1"), exp, sq);
        b.branch_linear(&format!("{name}.se.fc2"), sq, exp);
    }
    b.conv(&format!("{name}.project"), 1, 1, cout);
    if stride == 1 && cin == cout {
        b.add(&format!("{name}.add"));
    }
}

fn make_divisible(v: usize, d: usize) -> usize {
    let new = std::cmp::max(d, (v + d / 2) / d * d);
    if (new as f64) < 0.9 * v as f64 {
        new + d
    } else {
        new
    }
}

pub fn mobilenet_v3_large() -> Network {
    let mut b = B::new(224, 3);
    b.conv("stem", 3, 2, 16).act("stem.hs");
    // (k, exp, out, SE, stride) — torchvision mobilenet_v3_large
    let cfg: [(usize, usize, usize, bool, usize); 15] = [
        (3, 16, 16, false, 1),
        (3, 64, 24, false, 2),
        (3, 72, 24, false, 1),
        (5, 72, 40, true, 2),
        (5, 120, 40, true, 1),
        (5, 120, 40, true, 1),
        (3, 240, 80, false, 2),
        (3, 200, 80, false, 1),
        (3, 184, 80, false, 1),
        (3, 184, 80, false, 1),
        (3, 480, 112, true, 1),
        (3, 672, 112, true, 1),
        (5, 672, 160, true, 2),
        (5, 960, 160, true, 1),
        (5, 960, 160, true, 1),
    ];
    for (i, (k, e, c, se, s)) in cfg.iter().enumerate() {
        mbv3_block(&mut b, &format!("blk{i}"), *k, *e, *c, *se, *s);
    }
    b.conv("head", 1, 1, 960).act("head.hs");
    b.gap("gap").linear("fc1", 1280).act("fc1.hs").linear("fc2", 1000);
    b.finish("mobilenet_v3_large", 224, 3)
}

pub fn mobilenet_v3_small() -> Network {
    let mut b = B::new(224, 3);
    b.conv("stem", 3, 2, 16).act("stem.hs");
    let cfg: [(usize, usize, usize, bool, usize); 11] = [
        (3, 16, 16, true, 2),
        (3, 72, 24, false, 2),
        (3, 88, 24, false, 1),
        (5, 96, 40, true, 2),
        (5, 240, 40, true, 1),
        (5, 240, 40, true, 1),
        (5, 120, 48, true, 1),
        (5, 144, 48, true, 1),
        (5, 288, 96, true, 2),
        (5, 576, 96, true, 1),
        (5, 576, 96, true, 1),
    ];
    for (i, (k, e, c, se, s)) in cfg.iter().enumerate() {
        mbv3_block(&mut b, &format!("blk{i}"), *k, *e, *c, *se, *s);
    }
    b.conv("head", 1, 1, 576).act("head.hs");
    b.gap("gap").linear("fc1", 1024).act("fc1.hs").linear("fc2", 1000);
    b.finish("mobilenet_v3_small", 224, 3)
}

/// Look a network up by CLI name.
pub fn by_name(name: &str) -> Option<Network> {
    match name {
        "calibnet" => Some(calibnet()),
        "resnet18" => Some(resnet18()),
        "resnet50" => Some(resnet50()),
        "mobilenet_v2" | "mbv2" => Some(mobilenet_v2()),
        "mobilenet_v3_small" | "mbv3s" => Some(mobilenet_v3_small()),
        "mobilenet_v3_large" | "mbv3l" => Some(mobilenet_v3_large()),
        _ => None,
    }
}

pub const ALL_NETWORKS: [&str; 6] = [
    "calibnet",
    "resnet18",
    "resnet50",
    "mobilenet_v2",
    "mobilenet_v3_small",
    "mobilenet_v3_large",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_networks_validate() {
        for name in ALL_NETWORKS {
            let net = by_name(name).unwrap();
            net.validate().unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn resnet18_macs_and_params_match_torchvision() {
        let net = resnet18();
        // torchvision: 1.814 GMACs, 11.69 M params
        let gmacs = net.total_macs() as f64 / 1e9;
        assert!((1.75..1.90).contains(&gmacs), "resnet18 gmacs {gmacs}");
        let params = net.total_weights() as f64 / 1e6;
        assert!((11.0..12.0).contains(&params), "resnet18 params {params}M");
    }

    #[test]
    fn resnet50_macs_and_params_match_torchvision() {
        let net = resnet50();
        // torchvision: 4.09 GMACs, 25.6 M params (conv+fc weights ≈ 25.5 M)
        let gmacs = net.total_macs() as f64 / 1e9;
        assert!((3.95..4.25).contains(&gmacs), "resnet50 gmacs {gmacs}");
        let params = net.total_weights() as f64 / 1e6;
        assert!((25.0..26.0).contains(&params), "resnet50 params {params}M");
    }

    #[test]
    fn mobilenet_v2_macs_match_torchvision() {
        let net = mobilenet_v2();
        // torchvision: 0.30 GMACs, 3.4 M params
        let gmacs = net.total_macs() as f64 / 1e9;
        assert!((0.28..0.33).contains(&gmacs), "mbv2 gmacs {gmacs}");
        let params = net.total_weights() as f64 / 1e6;
        assert!((3.1..3.6).contains(&params), "mbv2 params {params}M");
    }

    #[test]
    fn mobilenet_v3_large_macs_match_torchvision() {
        let net = mobilenet_v3_large();
        // torchvision: 0.217 GMACs, 5.5 M params
        let gmacs = net.total_macs() as f64 / 1e9;
        assert!((0.20..0.24).contains(&gmacs), "mbv3l gmacs {gmacs}");
        let params = net.total_weights() as f64 / 1e6;
        assert!((5.0..6.0).contains(&params), "mbv3l params {params}M");
    }

    #[test]
    fn mobilenet_v3_small_macs_match_torchvision() {
        let net = mobilenet_v3_small();
        // torchvision: 0.057 GMACs, 2.5 M params
        let gmacs = net.total_macs() as f64 / 1e9;
        assert!((0.050..0.065).contains(&gmacs), "mbv3s gmacs {gmacs}");
        let params = net.total_weights() as f64 / 1e6;
        assert!((2.0..3.0).contains(&params), "mbv3s params {params}M");
    }

    #[test]
    fn calibnet_matches_python_side() {
        let net = calibnet();
        // python common.total_params() ∈ (70k, 90k) — weights only here
        let params = net.total_weights();
        assert!((70_000..90_000).contains(&params), "calibnet params {params}");
        assert_eq!(net.compute_layers().len(), 10);
    }

    #[test]
    fn calibnet_artifact_order_covers_all_compute_layers() {
        let net = calibnet();
        let names: Vec<_> = net.compute_layers().iter().map(|l| l.name.clone()).collect();
        let mut order = calibnet_artifact_order();
        order.sort_unstable();
        let mut got: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        got.sort_unstable();
        assert_eq!(order, got);
    }

    #[test]
    fn resnet18_has_16_3x3_convs_for_fig4() {
        // The paper's Fig. 4 speaks of 16 3x3 conv layers in ResNet-18
        let net = resnet18();
        let n3x3 = net
            .layers
            .iter()
            .filter(|l| matches!(l.op, Op::Conv { kernel: 3, .. }))
            .count();
        assert_eq!(n3x3, 16);
    }

    #[test]
    fn resnet18_spatial_chain() {
        let net = resnet18();
        // last compute layer before fc must see 7x7 maps
        let last_conv = net
            .layers
            .iter()
            .filter(|l| matches!(l.op, Op::Conv { .. }) && !l.branch)
            .next_back()
            .unwrap();
        assert_eq!(last_conv.in_hw, 7);
    }

    #[test]
    fn mbv2_depthwise_identified() {
        let net = mobilenet_v2();
        let dw = net
            .layers
            .iter()
            .find(|l| l.name == "ir1.dw")
            .unwrap();
        match dw.op {
            Op::Conv { groups, cin, cout, .. } => {
                assert_eq!(groups, cin);
                assert_eq!(cin, cout);
                assert_eq!(dw.patch_k(), 9);
            }
            _ => panic!("not a conv"),
        }
    }

    #[test]
    fn make_divisible_matches_torchvision_rule() {
        assert_eq!(make_divisible(16, 8), 16);
        // 18 rounds to 16, but 16 < 0.9*18 so the rule bumps up a step
        assert_eq!(make_divisible(18, 8), 24);
        assert_eq!(make_divisible(30, 8), 32);
        assert_eq!(make_divisible(4, 8), 8);
    }

    #[test]
    fn by_name_aliases() {
        assert!(by_name("mbv2").is_some());
        assert!(by_name("nope").is_none());
    }
}
