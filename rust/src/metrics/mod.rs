//! Result emitters: CSV / markdown tables and Pareto-front extraction —
//! everything the bench harness uses to regenerate the paper's tables and
//! figures into `results/`.

use std::io::Write;
use std::path::Path;

/// A rectangular results table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.headers.join(","));
        s.push('\n');
        for r in &self.rows {
            let esc: Vec<String> = r
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            s.push_str(&esc.join(","));
            s.push('\n');
        }
        s
    }

    pub fn to_markdown(&self) -> String {
        let n = self.headers.len();
        // column widths for alignment
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut s = String::new();
        let line = |cells: &[String], w: &[usize]| {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect();
            format!("| {} |\n", padded.join(" | "))
        };
        s.push_str(&line(&self.headers, &w));
        let sep: Vec<String> = (0..n).map(|i| "-".repeat(w[i])).collect();
        s.push_str(&line(&sep, &w));
        for r in &self.rows {
            s.push_str(&line(r, &w));
        }
        s
    }

    /// Write both `.csv` and `.md` forms next to each other.
    pub fn write_files(&self, dir: &Path, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{stem}.csv")))?;
        f.write_all(self.to_csv().as_bytes())?;
        let mut f = std::fs::File::create(dir.join(format!("{stem}.md")))?;
        f.write_all(self.to_markdown().as_bytes())?;
        Ok(())
    }
}

/// A labelled 2-D point for Pareto analysis (both axes maximized; negate
/// a coordinate to minimize it).
#[derive(Clone, Debug, PartialEq)]
pub struct Point2 {
    pub label: String,
    pub x: f64,
    pub y: f64,
}

/// Indices of the non-dominated points (maximize x and y). Stable order:
/// sorted by x descending within the front.
pub fn pareto_front(points: &[Point2]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[b]
            .x
            .total_cmp(&points[a].x)
            .then(points[b].y.total_cmp(&points[a].y))
    });
    let mut front = Vec::new();
    let mut best_y = f64::NEG_INFINITY;
    for &i in &idx {
        if points[i].y > best_y {
            front.push(i);
            best_y = points[i].y;
        }
    }
    front
}

/// Format a float compactly for tables (3 significant decimals).
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else if v.abs() >= 0.01 {
        format!("{v:.3}")
    } else {
        format!("{v:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(label: &str, x: f64, y: f64) -> Point2 {
        Point2 { label: label.into(), x, y }
    }

    #[test]
    fn csv_round_shape() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1,\"x,y\"\n");
    }

    #[test]
    fn markdown_has_separator() {
        let mut t = Table::new(&["col"]);
        t.row(vec!["v".into()]);
        let md = t.to_markdown();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[1].contains("---"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn pareto_extracts_non_dominated() {
        let pts = vec![
            p("dominated", 1.0, 1.0),
            p("front-a", 3.0, 2.0),
            p("front-b", 2.0, 5.0),
            p("dominated2", 2.0, 2.0),
            p("front-c", 1.5, 6.0),
        ];
        let f = pareto_front(&pts);
        let labels: Vec<&str> = f.iter().map(|&i| pts[i].label.as_str()).collect();
        assert_eq!(labels, vec!["front-a", "front-b", "front-c"]);
    }

    #[test]
    fn pareto_single_point() {
        let pts = vec![p("solo", 1.0, 1.0)];
        assert_eq!(pareto_front(&pts), vec![0]);
    }

    #[test]
    fn pareto_all_on_front_when_tradeoff() {
        let pts: Vec<Point2> =
            (0..5).map(|i| p(&format!("p{i}"), i as f64, -(i as f64))).collect();
        assert_eq!(pareto_front(&pts).len(), 5);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(4895.0), "4895");
        assert_eq!(fmt(69.75), "69.8");
        assert_eq!(fmt(0.92), "0.920");
        assert!(fmt(3.42e-9).contains('e'));
    }

    #[test]
    fn write_files_creates_artifacts() {
        let dir = std::env::temp_dir().join("hass_metrics_test");
        let mut t = Table::new(&["a"]);
        t.row(vec!["1".into()]);
        t.write_files(&dir, "t").unwrap();
        assert!(dir.join("t.csv").exists());
        assert!(dir.join("t.md").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
