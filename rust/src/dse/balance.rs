//! Intra-layer balancing strategy (paper §IV "Balancing Strategy").
//!
//! Under unstructured pruning, per-input-channel / per-output-filter
//! densities differ, so the i×o SPEs of a layer would run at imbalanced
//! rates and stall the pipeline.  At compile time the paper assigns the
//! I input channels and O output filters to the i×o engines with
//! simulated annealing, minimizing the spread of engine processing rates.
//!
//! An engine's work is the sum of pair densities of the (channel, filter)
//! slice it owns; the slowest engine sets the layer's group time, so the
//! objective is the **maximum** engine load (normalized by the mean —
//! 1.0 is a perfect balance).

use crate::optim::anneal::{anneal, AnnealSchedule};
use crate::util::rng::Rng;

/// Assignment of channels/filters to engine groups.
#[derive(Clone, Debug)]
pub struct Assignment {
    /// channel index -> input-engine group in [0, i_par)
    pub chan_group: Vec<usize>,
    /// filter index -> output-engine group in [0, o_par)
    pub filt_group: Vec<usize>,
}

/// Result of the balancing SA.
#[derive(Clone, Debug)]
pub struct BalanceResult {
    pub assignment: Assignment,
    /// max/mean engine load before SA (contiguous assignment)
    pub imbalance_before: f64,
    /// max/mean engine load after SA
    pub imbalance_after: f64,
}

/// Max-over-mean engine load of an assignment.
///
/// `chan_density[c]` and `filt_density[f]` are relative density
/// multipliers; engine (gi, go) load = Σ_{c∈gi} d_c · Σ_{f∈go} d_f
/// (separable because every (c, f) pair in the slice is processed).
pub fn imbalance(
    chan_density: &[f64],
    filt_density: &[f64],
    asg: &Assignment,
    i_par: usize,
    o_par: usize,
) -> f64 {
    let mut chan_load = vec![0.0; i_par];
    for (c, &g) in asg.chan_group.iter().enumerate() {
        chan_load[g] += chan_density[c];
    }
    let mut filt_load = vec![0.0; o_par];
    for (f, &g) in asg.filt_group.iter().enumerate() {
        filt_load[g] += filt_density[f];
    }
    let mut max_load = 0.0f64;
    let mut sum = 0.0;
    for &cl in &chan_load {
        for &fl in &filt_load {
            let l = cl * fl;
            max_load = max_load.max(l);
            sum += l;
        }
    }
    let mean = sum / (i_par * o_par) as f64;
    if mean <= 0.0 {
        return 1.0;
    }
    max_load / mean
}

/// Contiguous round-robin starting point (what naive folding would do).
pub fn contiguous_assignment(
    n_chan: usize,
    n_filt: usize,
    i_par: usize,
    o_par: usize,
) -> Assignment {
    Assignment {
        chan_group: (0..n_chan).map(|c| c * i_par / n_chan).collect(),
        filt_group: (0..n_filt).map(|f| f * o_par / n_filt).collect(),
    }
}

/// Solve the allocation problem with SA (paper's Balancing Strategy).
pub fn balance(
    chan_density: &[f64],
    filt_density: &[f64],
    i_par: usize,
    o_par: usize,
    schedule: &AnnealSchedule,
    rng: &mut Rng,
) -> BalanceResult {
    assert!(i_par >= 1 && o_par >= 1);
    assert!(chan_density.len() >= i_par, "need >= one channel per group");
    assert!(filt_density.len() >= o_par, "need >= one filter per group");
    let init = contiguous_assignment(chan_density.len(), filt_density.len(), i_par, o_par);
    let before = imbalance(chan_density, filt_density, &init, i_par, o_par);
    if i_par == 1 && o_par == 1 {
        return BalanceResult {
            assignment: init,
            imbalance_before: before,
            imbalance_after: before,
        };
    }
    let energy =
        |a: &Assignment| imbalance(chan_density, filt_density, a, i_par, o_par);
    let neighbor = move |a: &Assignment, r: &mut Rng| {
        let mut b = a.clone();
        // swap two items within one side (preserves group sizes) or move
        // one item to another group (changes sizes) with equal odds
        let side_chan = r.bool(0.5) && i_par > 1;
        if side_chan || o_par == 1 {
            if r.bool(0.5) {
                let x = r.below(b.chan_group.len());
                let y = r.below(b.chan_group.len());
                b.chan_group.swap(x, y);
            } else {
                let x = r.below(b.chan_group.len());
                b.chan_group[x] = r.below(i_par);
            }
        } else if r.bool(0.5) {
            let x = r.below(b.filt_group.len());
            let y = r.below(b.filt_group.len());
            b.filt_group.swap(x, y);
        } else {
            let x = r.below(b.filt_group.len());
            b.filt_group[x] = r.below(o_par);
        }
        b
    };
    let (best, after) = anneal(init, energy, neighbor, schedule, rng);
    BalanceResult { assignment: best, imbalance_before: before, imbalance_after: after }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn skewed(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0.4 * rng.gauss()).exp()).collect()
    }

    #[test]
    fn uniform_density_is_already_balanced() {
        let cd = vec![1.0; 16];
        let fd = vec![1.0; 16];
        let asg = contiguous_assignment(16, 16, 4, 4);
        assert!((imbalance(&cd, &fd, &asg, 4, 4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sa_reduces_imbalance_on_skewed_densities() {
        let cd = skewed(32, 1);
        let fd = skewed(64, 2);
        let mut rng = Rng::new(3);
        let r = balance(&cd, &fd, 4, 8, &AnnealSchedule::default(), &mut rng);
        assert!(
            r.imbalance_after <= r.imbalance_before,
            "{} -> {}",
            r.imbalance_before,
            r.imbalance_after
        );
        assert!(r.imbalance_after < 1.25, "still imbalanced: {}", r.imbalance_after);
    }

    #[test]
    fn single_engine_needs_no_balancing() {
        let cd = skewed(8, 4);
        let fd = skewed(8, 5);
        let mut rng = Rng::new(6);
        let r = balance(&cd, &fd, 1, 1, &AnnealSchedule::default(), &mut rng);
        assert!((r.imbalance_after - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_at_least_one() {
        forall(30, 0x1B, |rng| {
            let n = 4 + rng.below(30);
            let m = 4 + rng.below(30);
            let cd: Vec<f64> = (0..n).map(|_| rng.range(0.1, 2.0)).collect();
            let fd: Vec<f64> = (0..m).map(|_| rng.range(0.1, 2.0)).collect();
            let asg = contiguous_assignment(n, m, 2, 2);
            assert!(imbalance(&cd, &fd, &asg, 2, 2) >= 1.0 - 1e-12);
        });
    }

    #[test]
    fn assignment_groups_stay_in_range() {
        let cd = skewed(20, 7);
        let fd = skewed(24, 8);
        let mut rng = Rng::new(9);
        let schedule = AnnealSchedule { iters: 500, ..Default::default() };
        let r = balance(&cd, &fd, 4, 6, &schedule, &mut rng);
        assert!(r.assignment.chan_group.iter().all(|&g| g < 4));
        assert!(r.assignment.filt_group.iter().all(|&g| g < 6));
        assert_eq!(r.assignment.chan_group.len(), 20);
        assert_eq!(r.assignment.filt_group.len(), 24);
    }

    #[test]
    fn deterministic_for_seed() {
        let cd = skewed(16, 10);
        let fd = skewed(16, 11);
        let run = |seed| {
            let mut rng = Rng::new(seed);
            balance(&cd, &fd, 4, 4, &AnnealSchedule::default(), &mut rng).imbalance_after
        };
        assert_eq!(run(12).to_bits(), run(12).to_bits());
    }

    #[test]
    fn adversarial_bimodal_distribution() {
        // half the channels are 10x denser: contiguous grouping is terrible
        let mut cd = vec![0.2; 16];
        cd.extend(vec![2.0; 16]);
        let fd = vec![1.0; 8];
        let mut rng = Rng::new(13);
        let r = balance(&cd, &fd, 4, 2, &AnnealSchedule::default(), &mut rng);
        assert!(r.imbalance_before > 1.5, "setup not adversarial: {}", r.imbalance_before);
        assert!(r.imbalance_after < 1.1, "SA failed: {}", r.imbalance_after);
    }
}
