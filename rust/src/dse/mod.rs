//! Design Space Exploration of the sparse dataflow accelerator (paper §V-A).
//!
//! The DSE takes a network, its per-layer sparsity operating points, a
//! resource model and a device budget, and produces one [`LayerDesign`]
//! per compute layer:
//!
//! 1. **Performance model** (Eq. 2–3) — layer throughput from the SPE
//!    cycle model; network throughput is the pipeline minimum.
//! 2. **Rate balancing** (Eq. 4–5) — every non-bottleneck layer is
//!    re-fitted to the *cheapest* design that still meets the pipeline
//!    rate, releasing resources ([`balance_rates`]).
//! 3. **Resource-constrained incrementing** (§V-A.3) — from the
//!    resource-minimal design, repeatedly raise the parallelism of the
//!    slowest layer one step, re-balance, and stop when the budget is
//!    exhausted ([`explore`]).
//! 4. **Partitioning & reconfiguration** (§V-A.4) — [`partition`].
//!
//! # The frontier pricing kernel ([`frontier`])
//!
//! Steps 2–3 used to rescan the whole divisor×n_mac design space of every
//! layer on every query.  [`explore`], [`balance_rates`] and the
//! partitioning annealer now price through per-layer
//! [`LayerFrontier`]s instead: the design space is enumerated **once** per
//! (layer shape, sparsity point, resource model, device budget) and
//! reduced to a rate-sorted Pareto frontier, so every subsequent
//! "cheapest design achieving rate λ" query is a binary search.  Results
//! are bit-identical to the seed scan ([`cheapest_design_achieving`] /
//! [`explore_scan`], both kept as the reference implementation for
//! differential tests and benches).
//!
//! Frontiers are rebuilt only when one of their four inputs changes:
//! [`explore`] builds them per call (deduplicated by layer shape via
//! [`build_frontiers`]), [`partition`] builds them once per network and
//! re-uses them across every annealing step and slice, and the engine's
//! `DesignCache` keeps a lock-striped per-device store so candidates,
//! generations, shards and whole searches share them.

pub mod balance;
pub mod frontier;
pub mod partition;

pub use frontier::{build_frontier, build_frontiers, FrontierEntry, LayerFrontier};

use std::sync::Arc;

use crate::arch::Network;
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::{ResourceModel, Resources};
use crate::hardware::{divisors, LayerDesign};
use crate::sparsity::SparsityPoint;
use crate::util::{ceil_div, clampf};

/// A complete accelerator design for one network on one device.
#[derive(Clone, Debug)]
pub struct NetworkDesign {
    /// one design per compute layer, in `compute_indices` order
    pub designs: Vec<LayerDesign>,
    /// pipeline throughput, images per cycle (Eq. 3)
    pub throughput: f64,
    pub resources: Resources,
}

impl NetworkDesign {
    /// Images per second at the device clock.
    pub fn images_per_sec(&self, dev: &DeviceBudget) -> f64 {
        self.throughput * dev.freq_hz()
    }

    /// The paper's headline efficiency metric: images / cycle / DSP.
    pub fn efficiency(&self) -> f64 {
        self.throughput / self.resources.dsp.max(1) as f64
    }
}

/// Pipeline throughput of a candidate design — Eq. 3 (min over layers).
pub fn network_throughput(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
) -> f64 {
    let compute = net.compute_layers();
    assert_eq!(compute.len(), designs.len());
    assert_eq!(compute.len(), points.len());
    compute
        .iter()
        .zip(designs.iter().zip(points))
        .map(|(l, (d, p))| d.throughput(l, *p))
        .fold(f64::INFINITY, f64::min)
}

/// Index of the slowest compute layer (the pipeline bottleneck).
pub fn bottleneck(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
) -> usize {
    let compute = net.compute_layers();
    let mut worst = 0;
    let mut worst_th = f64::INFINITY;
    for (i, (l, (d, p))) in compute.iter().zip(designs.iter().zip(points)).enumerate() {
        let th = d.throughput(l, *p);
        if th < worst_th {
            worst_th = th;
            worst = i;
        }
    }
    worst
}

/// Candidate `n_mac` values worth considering for a layer: for every
/// achievable initiation interval `t` there is a unique minimal N, so the
/// whole [1, M] range collapses to ~2·√M distinct useful points.
///
/// Degenerate inputs are guarded: a zero-length pair stream (`m_len == 0`)
/// or a fully-pruned layer (`density == 0.0`, or NaN) still returns `[1]`
/// — a single-MAC SPE is always a valid (if idle) design, and callers
/// iterate over this list assuming it is non-empty.
pub fn useful_n_macs(m_len: usize, density: f64) -> Vec<usize> {
    if m_len == 0 {
        return vec![1];
    }
    let density = clampf(density, 0.0, 1.0); // NaN collapses to 0.0
    let useful = (density * m_len as f64).max(1.0);
    let t_max = useful.ceil() as u64;
    let mut out: Vec<usize> = Vec::new();
    let mut t = 1u64;
    while t <= t_max {
        let n = ((useful / t as f64).ceil() as usize).clamp(1, m_len);
        if out.last() != Some(&n) {
            out.push(n);
        }
        // skip t values that map to the same n
        let t_next = (useful / (n.saturating_sub(1)).max(1) as f64).ceil() as u64;
        t = t.max(t_next).max(t + 1);
        if n == 1 {
            break;
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Budget-normalized scalar cost of a resource bundle: each dimension is
/// divided by the device budget, so "cheapest" tracks whichever resource
/// actually binds on this device (LUTs on a U250 ResNet-18, DSPs on a
/// DSP-starved part, ...).
pub fn norm_cost(r: &Resources, dev: &DeviceBudget) -> f64 {
    let mut c = r.dsp as f64 / dev.dsp.max(1) as f64
        + r.lut as f64 / dev.lut.max(1) as f64
        + r.bram18k as f64 / dev.bram18k.max(1) as f64;
    if dev.uram > 0 {
        c += r.uram as f64 / dev.uram as f64;
    } else if r.uram > 0 {
        c += f64::INFINITY; // no URAM on this device
    }
    c
}

/// Cheapest design (by [`norm_cost`]) for layer `li` of `net` achieving
/// throughput ≥ `min_thr` under sparsity `point` — Eq. 4's inner
/// minimization.  Returns `None` if even full parallelism misses.
pub fn cheapest_design_achieving(
    net: &Network,
    li: usize,
    point: SparsityPoint,
    rm: &ResourceModel,
    dev: &DeviceBudget,
    min_thr: f64,
) -> Option<LayerDesign> {
    let layer = net.compute_layers()[li];
    if min_thr <= 0.0 {
        return Some(LayerDesign::MINIMAL);
    }
    let budget_cycles = (1.0 / min_thr).floor().max(1.0) as u64;
    let mut best: Option<(LayerDesign, f64)> = None;
    for &o in &divisors(layer.o_extent()) {
        let groups = ceil_div(layer.outputs_per_image() as u64, o as u64);
        // SPE must finish one output group within budget_cycles/groups
        let t_budget = budget_cycles / groups;
        if t_budget == 0 {
            continue; // even t=1 per group is too slow at this o
        }
        for &i in &divisors(layer.i_extent()) {
            let probe = LayerDesign { i_par: i, o_par: o, n_mac: 1 };
            let m = probe.m_len(layer);
            let useful = (point.pair_density() * m as f64).max(0.0);
            // minimal N with ceil(useful/N) <= t_budget
            let n = if useful <= t_budget as f64 {
                1
            } else {
                (useful / t_budget as f64).ceil() as usize
            };
            if n > m {
                continue;
            }
            let d = LayerDesign { i_par: i, o_par: o, n_mac: n.max(1) };
            if !d.feasible(layer) || d.throughput(layer, point) < min_thr {
                continue;
            }
            let r = rm.layer(layer, &d);
            let c = norm_cost(&r, dev);
            if best.as_ref().is_none_or(|(_, bc)| c < *bc) {
                best = Some((d, c));
            }
        }
    }
    best.map(|(d, _)| d)
}

/// Total resources of the non-compute streaming nodes (constant per net).
fn aux_total(net: &Network, rm: &ResourceModel) -> Resources {
    net.layers
        .iter()
        .filter(|l| !l.is_compute())
        .map(|l| rm.aux_node(l))
        .sum()
}

/// Rate balancing — Eq. 4–5.  Refit every layer to the cheapest design
/// that still sustains the current pipeline throughput.  The bottleneck
/// layer itself is also refitted (its own rate is the target), which can
/// only shed resources, never lower the pipeline minimum.
///
/// Prices through freshly built per-layer frontiers, so a one-shot call
/// pays an enumeration per distinct layer shape to answer one query per
/// layer — slower than a single scan, but on the same pricing kernel as
/// everything else (one implementation to trust).  Callers that balance
/// the same layers repeatedly should build frontiers once with
/// [`build_frontiers`] and call [`balance_rates_with`], where the build
/// amortizes.
pub fn balance_rates(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
) -> Vec<LayerDesign> {
    let frontiers = build_frontiers(net, points, rm, dev);
    balance_rates_with(net, designs, points, &frontiers)
}

/// [`balance_rates`] against prebuilt frontiers (one per compute layer,
/// in order) — bit-identical to the seed scan, O(layers · log |frontier|).
pub fn balance_rates_with(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
    frontiers: &[Arc<LayerFrontier>],
) -> Vec<LayerDesign> {
    assert_eq!(designs.len(), frontiers.len());
    let thr = network_throughput(net, designs, points);
    designs
        .iter()
        .zip(frontiers)
        .map(|(d, f)| f.cheapest_design_achieving(thr).unwrap_or(*d))
        .collect()
}

/// Configuration of the incrementing loop.
#[derive(Clone, Debug)]
pub struct DseConfig {
    /// hard cap on incrementing iterations (safety)
    pub max_iters: usize,
    /// re-run rate balancing every this many accepted increments
    pub balance_every: usize,
}

impl Default for DseConfig {
    fn default() -> Self {
        DseConfig { max_iters: 100_000, balance_every: 64 }
    }
}

/// Resource-constrained exploration (§V-A.3).  The paper grows the
/// slowest layer step by step and rate-balances after every step; the
/// fixed point of that loop is "the largest pipeline rate λ whose
/// cheapest rate-λ design (Eq. 4 per layer) fits the budget".  Per-layer
/// minimal cost is monotone in λ, so we find that fixed point directly by
/// bisection over λ — same result, deterministic, and orders of magnitude
/// fewer model evaluations than replaying every increment.
///
/// Prices through per-layer [`LayerFrontier`]s built once per call
/// (deduplicated by layer shape): each bisection probe is
/// O(layers · log |frontier|).  Bit-identical to [`explore_scan`], the
/// seed implementation that rescans the design space on every probe.
pub fn explore(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
) -> NetworkDesign {
    // infeasibility early-out *before* paying for frontier builds
    // (URAM-less devices skip all pricing work)
    let (minimal, min_res) = match minimal_checked(net, points, rm, dev) {
        Ok(min) => min,
        Err(unfit) => return unfit,
    };
    let frontiers = build_frontiers(net, points, rm, dev);
    explore_frontiers_checked(net, points, rm, dev, cfg, &frontiers, minimal, min_res)
}

/// [`explore`] against prebuilt per-layer frontiers (one per compute
/// layer, in order) — the hot entry point for callers that price the same
/// layers repeatedly (the engine's design cache, the partition annealer).
pub fn explore_with_frontiers(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
    frontiers: &[Arc<LayerFrontier>],
) -> NetworkDesign {
    let (minimal, min_res) = match minimal_checked(net, points, rm, dev) {
        Ok(min) => min,
        Err(unfit) => return unfit,
    };
    explore_frontiers_checked(net, points, rm, dev, cfg, frontiers, minimal, min_res)
}

/// The frontier-pricer bisection with the minimal design's fit already
/// verified ([`minimal_checked`]) — lets `explore`, `explore_with_frontiers`
/// and the engine cache's store-backed path all pay the O(layers) minimal
/// pricing exactly once.
#[allow(clippy::too_many_arguments)]
pub(crate) fn explore_frontiers_checked(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
    frontiers: &[Arc<LayerFrontier>],
    minimal: Vec<LayerDesign>,
    min_res: Resources,
) -> NetworkDesign {
    let compute = net.compute_layers();
    assert_eq!(frontiers.len(), compute.len());
    explore_impl(net, points, rm, dev, cfg, minimal, min_res, |i, lam| {
        if lam <= 0.0 {
            let d = LayerDesign::MINIMAL;
            return Some((d, rm.layer(compute[i], &d)));
        }
        frontiers[i].cheapest_achieving(lam).map(|e| (e.design, e.resources))
    })
}

/// The seed scan-per-probe implementation, kept verbatim as the reference
/// for differential tests and the `hotpath` bench's before/after split.
pub fn explore_scan(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
) -> NetworkDesign {
    let (minimal, min_res) = match minimal_checked(net, points, rm, dev) {
        Ok(min) => min,
        Err(unfit) => return unfit,
    };
    let compute = net.compute_layers();
    explore_impl(net, points, rm, dev, cfg, minimal, min_res, |i, lam| {
        cheapest_design_achieving(net, i, points[i], rm, dev, lam)
            .map(|d| (d, rm.layer(compute[i], &d)))
    })
}

/// The minimal design and its whole-network resources, or the shared
/// over-budget early return: a network whose resource-minimal design does
/// not fit cannot map at all — `Err` carries that design, which every
/// explore entry point (including the engine cache's frontier-store path)
/// returns as-is (callers check `dev.fits`).
pub(crate) fn minimal_checked(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
) -> Result<(Vec<LayerDesign>, Resources), NetworkDesign> {
    let compute = net.compute_layers();
    assert_eq!(compute.len(), points.len());
    let minimal = vec![LayerDesign::MINIMAL; compute.len()];
    let min_res = rm.network(net, &minimal);
    if dev.fits(&min_res) {
        Ok((minimal, min_res))
    } else {
        let throughput = network_throughput(net, &minimal, points);
        Err(NetworkDesign { designs: minimal, throughput, resources: min_res })
    }
}

/// The bisection core, generic over the per-layer pricer: `price_layer(i,
/// λ)` returns the cheapest design of compute layer `i` achieving rate λ
/// plus its resources, or `None` if unreachable.  Both pricers (frontier
/// lookup and seed scan) produce bit-identical designs, so the whole
/// bisection trajectory — and the returned `NetworkDesign` — is too.
/// `minimal`/`min_res` come from [`minimal_checked`]; the caller has
/// already returned early if they exceed the budget.
#[allow(clippy::too_many_arguments)]
fn explore_impl(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
    minimal: Vec<LayerDesign>,
    min_res: Resources,
    price_layer: impl Fn(usize, f64) -> Option<(LayerDesign, Resources)>,
) -> NetworkDesign {
    let compute = net.compute_layers();
    assert_eq!(compute.len(), points.len());
    let aux = aux_total(net, rm);

    // cheapest whole-network design at pipeline rate lam (None: infeasible)
    let design_at = |lam: f64| -> Option<(Vec<LayerDesign>, Resources)> {
        let mut designs = Vec::with_capacity(compute.len());
        let mut total = aux;
        for i in 0..compute.len() {
            let (d, r) = price_layer(i, lam)?;
            total = total + r;
            designs.push(d);
        }
        if dev.fits(&total) {
            Some((designs, total))
        } else {
            None
        }
    };

    // feasible lower bound: the minimal design's rate
    let mut lo = network_throughput(net, &minimal, points);
    // structural upper bound: full output parallelism, one cycle per group
    let hi_struct = compute
        .iter()
        .map(|l| 1.0 / ceil_div(l.outputs_per_image() as u64, l.o_extent() as u64) as f64)
        .fold(f64::INFINITY, f64::min);
    let mut best = design_at(lo).unwrap_or((minimal.clone(), min_res));
    if let Some(b) = design_at(hi_struct) {
        // the whole structural ceiling fits (device much larger than net)
        let throughput = network_throughput(net, &b.0, points);
        return NetworkDesign { designs: b.0, throughput, resources: b.1 };
    }
    let mut hi = hi_struct;
    // log-space bisection: stop when the bracket is tight or iters are
    // out.  `max_iters` is honored even below the 64-probe convergence
    // default — a caller asking for a coarser (cheaper) exploration gets
    // one (the seed silently clamped small values up to 16).
    let iters = cfg.max_iters.min(64);
    for _ in 0..iters {
        if hi / lo < 1.0 + 1e-9 {
            break;
        }
        let mid = (lo * hi).sqrt();
        match design_at(mid) {
            Some(b) => {
                lo = mid;
                best = b;
            }
            None => hi = mid,
        }
    }
    let (designs, resources) = best;
    let throughput = network_throughput(net, &designs, points);
    NetworkDesign { designs, throughput, resources }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::sparsity::SparsityPoint;
    use crate::util::prop::forall;

    fn setup(name: &str, s: f64) -> (Network, Vec<SparsityPoint>, ResourceModel) {
        let net = networks::by_name(name).unwrap();
        let n = net.compute_layers().len();
        let points = vec![SparsityPoint { s_w: s, s_a: s }; n];
        (net, points, ResourceModel::default())
    }

    #[test]
    fn minimal_design_throughput_is_pipeline_min() {
        let (net, points, _) = setup("calibnet", 0.0);
        let designs = vec![LayerDesign::MINIMAL; points.len()];
        let thr = network_throughput(&net, &designs, &points);
        let per: Vec<f64> = net
            .compute_layers()
            .iter()
            .zip(designs.iter().zip(&points))
            .map(|(l, (d, p))| d.throughput(l, *p))
            .collect();
        assert!((thr - per.iter().cloned().fold(f64::INFINITY, f64::min)).abs() < 1e-18);
    }

    #[test]
    fn bottleneck_is_largest_layer_at_minimal() {
        let (net, points, _) = setup("calibnet", 0.0);
        let designs = vec![LayerDesign::MINIMAL; points.len()];
        let b = bottleneck(&net, &designs, &points);
        // several layers tie at the max MAC count; the bottleneck must be
        // one of them (at MINIMAL design, cycles/image == macs/image)
        let macs: Vec<u64> = net.compute_layers().iter().map(|l| l.macs_per_image()).collect();
        let max_m = *macs.iter().max().unwrap();
        assert_eq!(macs[b], max_m);
    }

    #[test]
    fn useful_n_macs_covers_extremes() {
        let ns = useful_n_macs(144, 1.0);
        assert!(ns.contains(&1));
        assert!(ns.contains(&144));
        assert!(ns.len() < 40, "should be ~2sqrt(M): {}", ns.len());
    }

    #[test]
    fn useful_n_macs_shrinks_with_density() {
        let dense = useful_n_macs(256, 1.0);
        let sparse = useful_n_macs(256, 0.25);
        assert!(sparse.last().unwrap() <= dense.last().unwrap());
    }

    #[test]
    fn useful_n_macs_degenerate_inputs_return_single_mac() {
        // fully pruned layer: no useful pairs, but the design list must
        // still offer the minimal SPE
        assert_eq!(useful_n_macs(144, 0.0), vec![1]);
        // zero-length pair stream (e.g. a degenerate 1x1 geometry probe)
        assert_eq!(useful_n_macs(0, 1.0), vec![1]);
        assert_eq!(useful_n_macs(0, 0.0), vec![1]);
        // out-of-range densities are clamped rather than trusted
        assert_eq!(useful_n_macs(16, -3.0), vec![1]);
        let over = useful_n_macs(16, 7.5);
        assert_eq!(over, useful_n_macs(16, 1.0));
        // NaN density degrades to the fully-pruned case
        assert_eq!(useful_n_macs(16, f64::NAN), vec![1]);
    }

    #[test]
    fn useful_n_macs_always_nonempty_and_sorted() {
        for m in [0usize, 1, 7, 64, 333] {
            for d in [0.0, 0.01, 0.5, 1.0] {
                let ns = useful_n_macs(m, d);
                assert!(!ns.is_empty(), "m={m} d={d}");
                assert!(ns.windows(2).all(|w| w[0] < w[1]), "m={m} d={d}: {ns:?}");
                assert!(ns.iter().all(|&n| n >= 1 && n <= m.max(1)), "m={m} d={d}");
            }
        }
    }

    #[test]
    fn cheapest_design_meets_rate() {
        let (net, points, rm) = setup("calibnet", 0.3);
        // ask for a moderate rate on layer 0
        let target = 1e-5;
        let dev = DeviceBudget::u250();
        let d = cheapest_design_achieving(&net, 0, points[0], &rm, &dev, target).unwrap();
        let l = net.compute_layers()[0];
        assert!(d.throughput(l, points[0]) >= target);
    }

    #[test]
    fn cheapest_design_none_when_impossible() {
        let (net, points, rm) = setup("calibnet", 0.0);
        let dev = DeviceBudget::u250();
        assert!(cheapest_design_achieving(&net, 0, points[0], &rm, &dev, 1.0).is_none());
    }

    #[test]
    fn cheapest_design_is_minimal_for_zero_rate() {
        let (net, points, rm) = setup("calibnet", 0.0);
        let dev = DeviceBudget::u250();
        let d = cheapest_design_achieving(&net, 0, points[0], &rm, &dev, 0.0).unwrap();
        assert_eq!(d, LayerDesign::MINIMAL);
    }

    #[test]
    fn balance_never_lowers_pipeline_throughput() {
        let (net, points, rm) = setup("calibnet", 0.4);
        forall(25, 0xBA1A, |rng| {
            // random feasible design
            let designs: Vec<LayerDesign> = net
                .compute_layers()
                .iter()
                .map(|l| {
                    let is = divisors(l.i_extent());
                    let os = divisors(l.o_extent());
                    let i = *rng.choice(&is);
                    let o = *rng.choice(&os);
                    let d = LayerDesign { i_par: i, o_par: o, n_mac: 1 };
                    let m = d.m_len(l);
                    LayerDesign { n_mac: 1 + rng.below(m), ..d }
                })
                .collect();
            let before = network_throughput(&net, &designs, &points);
            let balanced = balance_rates(&net, &designs, &points, &rm, &DeviceBudget::u250());
            let after = network_throughput(&net, &balanced, &points);
            assert!(
                after >= before * (1.0 - 1e-12),
                "balance lowered throughput {before} -> {after}"
            );
        });
    }

    #[test]
    fn balance_never_raises_resources() {
        let (net, points, rm) = setup("calibnet", 0.4);
        forall(25, 0xBA1B, |rng| {
            let designs: Vec<LayerDesign> = net
                .compute_layers()
                .iter()
                .map(|l| {
                    let os = divisors(l.o_extent());
                    let o = *rng.choice(&os);
                    let d = LayerDesign { i_par: 1, o_par: o, n_mac: 1 };
                    let m = d.m_len(l);
                    LayerDesign { n_mac: 1 + rng.below(m), ..d }
                })
                .collect();
            let before = rm.network(&net, &designs);
            let balanced = balance_rates(&net, &designs, &points, &rm, &DeviceBudget::u250());
            let after = rm.network(&net, &balanced);
            assert!(after.dsp <= before.dsp, "dsp {} -> {}", before.dsp, after.dsp);
        });
    }

    #[test]
    fn explore_fits_budget() {
        let (net, points, rm) = setup("calibnet", 0.3);
        let dev = DeviceBudget::u250();
        let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
        assert!(dev.fits(&d.resources), "{:?}", d.resources);
        assert!(d.throughput > 0.0);
    }

    #[test]
    fn explore_beats_minimal_design() {
        let (net, points, rm) = setup("calibnet", 0.3);
        let dev = DeviceBudget::u250();
        let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
        let minimal = vec![LayerDesign::MINIMAL; points.len()];
        let min_thr = network_throughput(&net, &minimal, &points);
        assert!(
            d.throughput > min_thr * 10.0,
            "DSE barely improved: {} vs {}",
            d.throughput,
            min_thr
        );
    }

    #[test]
    fn explore_uses_more_resources_on_bigger_device() {
        let (net, points, rm) = setup("calibnet", 0.3);
        let small = DeviceBudget {
            name: "small".into(),
            dsp: 64,
            lut: 200_000,
            bram18k: 600,
            uram: 64,
            freq_mhz: 250.0,
        };
        let big = DeviceBudget::u250();
        let ds = explore(&net, &points, &rm, &small, &DseConfig::default());
        let db = explore(&net, &points, &rm, &big, &DseConfig::default());
        assert!(db.throughput >= ds.throughput);
        assert!(small.fits(&ds.resources));
    }

    #[test]
    fn sparser_network_reaches_higher_throughput_per_dsp() {
        // the core sparse-dataflow claim: at a fixed budget, sparsity buys
        // throughput per DSP
        let rm = ResourceModel::default();
        let net = networks::calibnet();
        let dev = DeviceBudget {
            name: "cap".into(),
            dsp: 512,
            lut: 600_000,
            bram18k: 2_000,
            uram: 256,
            freq_mhz: 250.0,
        };
        let n = net.compute_layers().len();
        let dense = explore(
            &net,
            &vec![SparsityPoint::DENSE; n],
            &rm,
            &dev,
            &DseConfig::default(),
        );
        let sparse = explore(
            &net,
            &vec![SparsityPoint { s_w: 0.6, s_a: 0.5 }; n],
            &rm,
            &dev,
            &DseConfig::default(),
        );
        assert!(
            sparse.efficiency() > dense.efficiency() * 1.5,
            "sparse {} vs dense {}",
            sparse.efficiency(),
            dense.efficiency()
        );
    }

    #[test]
    fn explore_is_deterministic() {
        let (net, points, rm) = setup("calibnet", 0.25);
        let dev = DeviceBudget::u250();
        let a = explore(&net, &points, &rm, &dev, &DseConfig::default());
        let b = explore(&net, &points, &rm, &dev, &DseConfig::default());
        assert_eq!(a.designs, b.designs);
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
    }

    #[test]
    fn explore_handles_resnet18_scale() {
        let (net, points, rm) = setup("resnet18", 0.5);
        let dev = DeviceBudget::u250();
        let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
        assert!(dev.fits(&d.resources));
        // ResNet-18 at 224x224 should reach paper-order throughput:
        // thousands of images/s at 250 MHz
        let ips = d.images_per_sec(&dev);
        assert!(ips > 100.0, "unreasonably slow: {ips} img/s");
    }

    #[test]
    fn efficiency_metric_definition() {
        let d = NetworkDesign {
            designs: vec![],
            throughput: 1e-5,
            resources: Resources { dsp: 100, lut: 0, bram18k: 0, uram: 0 },
        };
        assert!((d.efficiency() - 1e-7).abs() < 1e-20);
    }

    // ---- frontier pricing kernel: differential + clamp regression ------

    fn assert_same_design(a: &NetworkDesign, b: &NetworkDesign, what: &str) {
        assert_eq!(a.designs, b.designs, "{what}: designs diverged");
        assert_eq!(
            a.throughput.to_bits(),
            b.throughput.to_bits(),
            "{what}: throughput diverged"
        );
        assert_eq!(a.resources, b.resources, "{what}: resources diverged");
    }

    /// The tentpole contract: frontier-based `explore` is bit-identical to
    /// the seed scan across networks, devices (incl. URAM-less ones whose
    /// costs are all +inf) and sparsity points.
    #[test]
    fn explore_matches_scan_bit_for_bit() {
        let rm = ResourceModel::default();
        let devs = [
            DeviceBudget::u250(),
            DeviceBudget::v7_690t(),
            DeviceBudget {
                name: "small".into(),
                dsp: 64,
                lut: 200_000,
                bram18k: 600,
                uram: 64,
                freq_mhz: 250.0,
            },
        ];
        // calibnet across every device and sparsity; resnet18 once (the
        // scan reference is O(design space) per probe — slow in debug)
        for (name, svals) in
            [("calibnet", &[0.0, 0.3, 0.65][..]), ("resnet18", &[0.3][..])]
        {
            let net = networks::by_name(name).unwrap();
            let n = net.compute_layers().len();
            for &s in svals {
                let points = vec![SparsityPoint { s_w: s, s_a: 0.7 * s }; n];
                for dev in &devs {
                    let fast = explore(&net, &points, &rm, dev, &DseConfig::default());
                    let scan = explore_scan(&net, &points, &rm, dev, &DseConfig::default());
                    assert_same_design(&fast, &scan, &format!("{name}@{}/s={s}", dev.name));
                }
            }
        }
    }

    #[test]
    fn explore_matches_scan_on_random_points() {
        let net = networks::calibnet();
        let n = net.compute_layers().len();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        forall(12, 0xD1FF, |rng| {
            let points: Vec<SparsityPoint> = (0..n)
                .map(|_| SparsityPoint { s_w: rng.f64(), s_a: rng.f64() })
                .collect();
            let cfg = DseConfig { max_iters: 1_500, ..Default::default() };
            let fast = explore(&net, &points, &rm, &dev, &cfg);
            let scan = explore_scan(&net, &points, &rm, &dev, &cfg);
            assert_same_design(&fast, &scan, "random points");
        });
    }

    #[test]
    fn explore_with_prebuilt_frontiers_matches_explore() {
        let (net, points, rm) = setup("calibnet", 0.35);
        let dev = DeviceBudget::u250();
        let frontiers = build_frontiers(&net, &points, &rm, &dev);
        let a = explore_with_frontiers(&net, &points, &rm, &dev, &DseConfig::default(), &frontiers);
        let b = explore(&net, &points, &rm, &dev, &DseConfig::default());
        assert_same_design(&a, &b, "prebuilt frontiers");
    }

    #[test]
    fn balance_rates_matches_scan_reference() {
        let (net, points, rm) = setup("calibnet", 0.4);
        let dev = DeviceBudget::u250();
        forall(20, 0xBA1C, |rng| {
            let designs: Vec<LayerDesign> = net
                .compute_layers()
                .iter()
                .map(|l| {
                    let is = divisors(l.i_extent());
                    let os = divisors(l.o_extent());
                    let d = LayerDesign {
                        i_par: *rng.choice(&is),
                        o_par: *rng.choice(&os),
                        n_mac: 1,
                    };
                    let m = d.m_len(l);
                    LayerDesign { n_mac: 1 + rng.below(m), ..d }
                })
                .collect();
            let balanced = balance_rates(&net, &designs, &points, &rm, &dev);
            // seed reference: one scan query per layer at the pipeline rate
            let thr = network_throughput(&net, &designs, &points);
            let reference: Vec<LayerDesign> = designs
                .iter()
                .enumerate()
                .map(|(i, d)| {
                    cheapest_design_achieving(&net, i, points[i], &rm, &dev, thr)
                        .unwrap_or(*d)
                })
                .collect();
            assert_eq!(balanced, reference, "balance diverged from the scan");
        });
    }

    /// Regression for the bisection clamp: `max_iters` below 16 used to be
    /// silently raised; a caller asking for a coarse exploration must get
    /// one (fewer probes → no better throughput than the converged run).
    #[test]
    fn explore_honors_small_max_iters() {
        let (net, points, rm) = setup("calibnet", 0.3);
        let dev = DeviceBudget::u250();
        let at = |max_iters: usize| {
            explore(&net, &points, &rm, &dev, &DseConfig { max_iters, ..Default::default() })
        };
        let coarse = at(0);
        let few = at(4);
        let full = at(usize::MAX);
        // zero probes: the bracket's feasible lower bound is returned
        assert!(
            coarse.throughput < full.throughput,
            "max_iters=0 must not reach the converged design: {} vs {}",
            coarse.throughput,
            full.throughput
        );
        // probes monotonically refine the feasible bound
        assert!(few.throughput >= coarse.throughput);
        assert!(full.throughput >= few.throughput);
        // the default config still converges exactly as before (64 cap)
        let default = at(DseConfig::default().max_iters);
        assert_same_design(&default, &full, "default max_iters");
        // both implementations honor the clamp identically
        let coarse_scan = explore_scan(
            &net,
            &points,
            &rm,
            &dev,
            &DseConfig { max_iters: 0, ..Default::default() },
        );
        assert_same_design(&coarse, &coarse_scan, "max_iters=0");
    }
}
