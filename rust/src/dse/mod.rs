//! Design Space Exploration of the sparse dataflow accelerator (paper §V-A).
//!
//! The DSE takes a network, its per-layer sparsity operating points, a
//! resource model and a device budget, and produces one [`LayerDesign`]
//! per compute layer:
//!
//! 1. **Performance model** (Eq. 2–3) — layer throughput from the SPE
//!    cycle model; network throughput is the pipeline minimum.
//! 2. **Rate balancing** (Eq. 4–5) — every non-bottleneck layer is
//!    re-fitted to the *cheapest* design that still meets the pipeline
//!    rate, releasing resources ([`balance_rates`]).
//! 3. **Resource-constrained incrementing** (§V-A.3) — from the
//!    resource-minimal design, repeatedly raise the parallelism of the
//!    slowest layer one step, re-balance, and stop when the budget is
//!    exhausted ([`explore`]).
//! 4. **Partitioning & reconfiguration** (§V-A.4) — [`partition`].

pub mod balance;
pub mod partition;

use crate::arch::Network;
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::{ResourceModel, Resources};
use crate::hardware::{divisors, LayerDesign};
use crate::sparsity::SparsityPoint;
use crate::util::{ceil_div, clampf};

/// A complete accelerator design for one network on one device.
#[derive(Clone, Debug)]
pub struct NetworkDesign {
    /// one design per compute layer, in `compute_indices` order
    pub designs: Vec<LayerDesign>,
    /// pipeline throughput, images per cycle (Eq. 3)
    pub throughput: f64,
    pub resources: Resources,
}

impl NetworkDesign {
    /// Images per second at the device clock.
    pub fn images_per_sec(&self, dev: &DeviceBudget) -> f64 {
        self.throughput * dev.freq_hz()
    }

    /// The paper's headline efficiency metric: images / cycle / DSP.
    pub fn efficiency(&self) -> f64 {
        self.throughput / self.resources.dsp.max(1) as f64
    }
}

/// Pipeline throughput of a candidate design — Eq. 3 (min over layers).
pub fn network_throughput(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
) -> f64 {
    let compute = net.compute_layers();
    assert_eq!(compute.len(), designs.len());
    assert_eq!(compute.len(), points.len());
    compute
        .iter()
        .zip(designs.iter().zip(points))
        .map(|(l, (d, p))| d.throughput(l, *p))
        .fold(f64::INFINITY, f64::min)
}

/// Index of the slowest compute layer (the pipeline bottleneck).
pub fn bottleneck(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
) -> usize {
    let compute = net.compute_layers();
    let mut worst = 0;
    let mut worst_th = f64::INFINITY;
    for (i, (l, (d, p))) in compute.iter().zip(designs.iter().zip(points)).enumerate() {
        let th = d.throughput(l, *p);
        if th < worst_th {
            worst_th = th;
            worst = i;
        }
    }
    worst
}

/// Candidate `n_mac` values worth considering for a layer: for every
/// achievable initiation interval `t` there is a unique minimal N, so the
/// whole [1, M] range collapses to ~2·√M distinct useful points.
///
/// Degenerate inputs are guarded: a zero-length pair stream (`m_len == 0`)
/// or a fully-pruned layer (`density == 0.0`, or NaN) still returns `[1]`
/// — a single-MAC SPE is always a valid (if idle) design, and callers
/// iterate over this list assuming it is non-empty.
pub fn useful_n_macs(m_len: usize, density: f64) -> Vec<usize> {
    if m_len == 0 {
        return vec![1];
    }
    let density = clampf(density, 0.0, 1.0); // NaN collapses to 0.0
    let useful = (density * m_len as f64).max(1.0);
    let t_max = useful.ceil() as u64;
    let mut out: Vec<usize> = Vec::new();
    let mut t = 1u64;
    while t <= t_max {
        let n = ((useful / t as f64).ceil() as usize).clamp(1, m_len);
        if out.last() != Some(&n) {
            out.push(n);
        }
        // skip t values that map to the same n
        let t_next = (useful / (n.saturating_sub(1)).max(1) as f64).ceil() as u64;
        t = t.max(t_next).max(t + 1);
        if n == 1 {
            break;
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Budget-normalized scalar cost of a resource bundle: each dimension is
/// divided by the device budget, so "cheapest" tracks whichever resource
/// actually binds on this device (LUTs on a U250 ResNet-18, DSPs on a
/// DSP-starved part, ...).
pub fn norm_cost(r: &Resources, dev: &DeviceBudget) -> f64 {
    let mut c = r.dsp as f64 / dev.dsp.max(1) as f64
        + r.lut as f64 / dev.lut.max(1) as f64
        + r.bram18k as f64 / dev.bram18k.max(1) as f64;
    if dev.uram > 0 {
        c += r.uram as f64 / dev.uram as f64;
    } else if r.uram > 0 {
        c += f64::INFINITY; // no URAM on this device
    }
    c
}

/// Cheapest design (by [`norm_cost`]) for layer `li` of `net` achieving
/// throughput ≥ `min_thr` under sparsity `point` — Eq. 4's inner
/// minimization.  Returns `None` if even full parallelism misses.
pub fn cheapest_design_achieving(
    net: &Network,
    li: usize,
    point: SparsityPoint,
    rm: &ResourceModel,
    dev: &DeviceBudget,
    min_thr: f64,
) -> Option<LayerDesign> {
    let layer = net.compute_layers()[li];
    if min_thr <= 0.0 {
        return Some(LayerDesign::MINIMAL);
    }
    let budget_cycles = (1.0 / min_thr).floor().max(1.0) as u64;
    let mut best: Option<(LayerDesign, f64)> = None;
    for &o in &divisors(layer.o_extent()) {
        let groups = ceil_div(layer.outputs_per_image() as u64, o as u64);
        // SPE must finish one output group within budget_cycles/groups
        let t_budget = budget_cycles / groups;
        if t_budget == 0 {
            continue; // even t=1 per group is too slow at this o
        }
        for &i in &divisors(layer.i_extent()) {
            let probe = LayerDesign { i_par: i, o_par: o, n_mac: 1 };
            let m = probe.m_len(layer);
            let useful = (point.pair_density() * m as f64).max(0.0);
            // minimal N with ceil(useful/N) <= t_budget
            let n = if useful <= t_budget as f64 {
                1
            } else {
                (useful / t_budget as f64).ceil() as usize
            };
            if n > m {
                continue;
            }
            let d = LayerDesign { i_par: i, o_par: o, n_mac: n.max(1) };
            if !d.feasible(layer) || d.throughput(layer, point) < min_thr {
                continue;
            }
            let r = rm.layer(layer, &d);
            let c = norm_cost(&r, dev);
            if best.as_ref().is_none_or(|(_, bc)| c < *bc) {
                best = Some((d, c));
            }
        }
    }
    best.map(|(d, _)| d)
}

/// Total resources of the non-compute streaming nodes (constant per net).
fn aux_total(net: &Network, rm: &ResourceModel) -> Resources {
    net.layers
        .iter()
        .filter(|l| !l.is_compute())
        .map(|l| rm.aux_node(l))
        .sum()
}

/// Rate balancing — Eq. 4–5.  Refit every layer to the cheapest design
/// that still sustains the current pipeline throughput.  The bottleneck
/// layer itself is also refitted (its own rate is the target), which can
/// only shed resources, never lower the pipeline minimum.
pub fn balance_rates(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
) -> Vec<LayerDesign> {
    let thr = network_throughput(net, designs, points);
    designs
        .iter()
        .enumerate()
        .map(|(i, d)| {
            cheapest_design_achieving(net, i, points[i], rm, dev, thr).unwrap_or(*d)
        })
        .collect()
}

/// Configuration of the incrementing loop.
#[derive(Clone, Debug)]
pub struct DseConfig {
    /// hard cap on incrementing iterations (safety)
    pub max_iters: usize,
    /// re-run rate balancing every this many accepted increments
    pub balance_every: usize,
}

impl Default for DseConfig {
    fn default() -> Self {
        DseConfig { max_iters: 100_000, balance_every: 64 }
    }
}

/// Resource-constrained exploration (§V-A.3).  The paper grows the
/// slowest layer step by step and rate-balances after every step; the
/// fixed point of that loop is "the largest pipeline rate λ whose
/// cheapest rate-λ design (Eq. 4 per layer) fits the budget".  Per-layer
/// minimal cost is monotone in λ, so we find that fixed point directly by
/// bisection over λ — same result, deterministic, and orders of magnitude
/// fewer model evaluations than replaying every increment.
pub fn explore(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
) -> NetworkDesign {
    let compute = net.compute_layers();
    assert_eq!(compute.len(), points.len());
    let aux = aux_total(net, rm);
    let minimal = vec![LayerDesign::MINIMAL; compute.len()];
    let min_res = rm.network(net, &minimal);
    // an over-budget minimal design means the network cannot map at all;
    // return it anyway (caller checks `dev.fits`)
    if !dev.fits(&min_res) {
        let throughput = network_throughput(net, &minimal, points);
        return NetworkDesign { designs: minimal, throughput, resources: min_res };
    }

    // cheapest whole-network design at pipeline rate lam (None: infeasible)
    let design_at = |lam: f64| -> Option<(Vec<LayerDesign>, Resources)> {
        let mut designs = Vec::with_capacity(compute.len());
        let mut total = aux;
        for i in 0..compute.len() {
            let d = cheapest_design_achieving(net, i, points[i], rm, dev, lam)?;
            total = total + rm.layer(compute[i], &d);
            designs.push(d);
        }
        if dev.fits(&total) {
            Some((designs, total))
        } else {
            None
        }
    };

    // feasible lower bound: the minimal design's rate
    let mut lo = network_throughput(net, &minimal, points);
    // structural upper bound: full output parallelism, one cycle per group
    let hi_struct = compute
        .iter()
        .map(|l| 1.0 / ceil_div(l.outputs_per_image() as u64, l.o_extent() as u64) as f64)
        .fold(f64::INFINITY, f64::min);
    let mut best = design_at(lo).unwrap_or((minimal.clone(), min_res));
    if let Some(b) = design_at(hi_struct) {
        // the whole structural ceiling fits (device much larger than net)
        let throughput = network_throughput(net, &b.0, points);
        return NetworkDesign { designs: b.0, throughput, resources: b.1 };
    }
    let mut hi = hi_struct;
    // log-space bisection: stop when the bracket is tight or iters are out
    let iters = cfg.max_iters.min(64).max(16);
    for _ in 0..iters {
        if hi / lo < 1.0 + 1e-9 {
            break;
        }
        let mid = (lo * hi).sqrt();
        match design_at(mid) {
            Some(b) => {
                lo = mid;
                best = b;
            }
            None => hi = mid,
        }
    }
    let (designs, resources) = best;
    let throughput = network_throughput(net, &designs, points);
    NetworkDesign { designs, throughput, resources }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::sparsity::SparsityPoint;
    use crate::util::prop::forall;

    fn setup(name: &str, s: f64) -> (Network, Vec<SparsityPoint>, ResourceModel) {
        let net = networks::by_name(name).unwrap();
        let n = net.compute_layers().len();
        let points = vec![SparsityPoint { s_w: s, s_a: s }; n];
        (net, points, ResourceModel::default())
    }

    #[test]
    fn minimal_design_throughput_is_pipeline_min() {
        let (net, points, _) = setup("calibnet", 0.0);
        let designs = vec![LayerDesign::MINIMAL; points.len()];
        let thr = network_throughput(&net, &designs, &points);
        let per: Vec<f64> = net
            .compute_layers()
            .iter()
            .zip(designs.iter().zip(&points))
            .map(|(l, (d, p))| d.throughput(l, *p))
            .collect();
        assert!((thr - per.iter().cloned().fold(f64::INFINITY, f64::min)).abs() < 1e-18);
    }

    #[test]
    fn bottleneck_is_largest_layer_at_minimal() {
        let (net, points, _) = setup("calibnet", 0.0);
        let designs = vec![LayerDesign::MINIMAL; points.len()];
        let b = bottleneck(&net, &designs, &points);
        // several layers tie at the max MAC count; the bottleneck must be
        // one of them (at MINIMAL design, cycles/image == macs/image)
        let macs: Vec<u64> = net.compute_layers().iter().map(|l| l.macs_per_image()).collect();
        let max_m = *macs.iter().max().unwrap();
        assert_eq!(macs[b], max_m);
    }

    #[test]
    fn useful_n_macs_covers_extremes() {
        let ns = useful_n_macs(144, 1.0);
        assert!(ns.contains(&1));
        assert!(ns.contains(&144));
        assert!(ns.len() < 40, "should be ~2sqrt(M): {}", ns.len());
    }

    #[test]
    fn useful_n_macs_shrinks_with_density() {
        let dense = useful_n_macs(256, 1.0);
        let sparse = useful_n_macs(256, 0.25);
        assert!(sparse.last().unwrap() <= dense.last().unwrap());
    }

    #[test]
    fn useful_n_macs_degenerate_inputs_return_single_mac() {
        // fully pruned layer: no useful pairs, but the design list must
        // still offer the minimal SPE
        assert_eq!(useful_n_macs(144, 0.0), vec![1]);
        // zero-length pair stream (e.g. a degenerate 1x1 geometry probe)
        assert_eq!(useful_n_macs(0, 1.0), vec![1]);
        assert_eq!(useful_n_macs(0, 0.0), vec![1]);
        // out-of-range densities are clamped rather than trusted
        assert_eq!(useful_n_macs(16, -3.0), vec![1]);
        let over = useful_n_macs(16, 7.5);
        assert_eq!(over, useful_n_macs(16, 1.0));
        // NaN density degrades to the fully-pruned case
        assert_eq!(useful_n_macs(16, f64::NAN), vec![1]);
    }

    #[test]
    fn useful_n_macs_always_nonempty_and_sorted() {
        for m in [0usize, 1, 7, 64, 333] {
            for d in [0.0, 0.01, 0.5, 1.0] {
                let ns = useful_n_macs(m, d);
                assert!(!ns.is_empty(), "m={m} d={d}");
                assert!(ns.windows(2).all(|w| w[0] < w[1]), "m={m} d={d}: {ns:?}");
                assert!(ns.iter().all(|&n| n >= 1 && n <= m.max(1)), "m={m} d={d}");
            }
        }
    }

    #[test]
    fn cheapest_design_meets_rate() {
        let (net, points, rm) = setup("calibnet", 0.3);
        // ask for a moderate rate on layer 0
        let target = 1e-5;
        let dev = DeviceBudget::u250();
        let d = cheapest_design_achieving(&net, 0, points[0], &rm, &dev, target).unwrap();
        let l = net.compute_layers()[0];
        assert!(d.throughput(l, points[0]) >= target);
    }

    #[test]
    fn cheapest_design_none_when_impossible() {
        let (net, points, rm) = setup("calibnet", 0.0);
        assert!(cheapest_design_achieving(&net, 0, points[0], &rm, &DeviceBudget::u250(), 1.0).is_none());
    }

    #[test]
    fn cheapest_design_is_minimal_for_zero_rate() {
        let (net, points, rm) = setup("calibnet", 0.0);
        let d = cheapest_design_achieving(&net, 0, points[0], &rm, &DeviceBudget::u250(), 0.0).unwrap();
        assert_eq!(d, LayerDesign::MINIMAL);
    }

    #[test]
    fn balance_never_lowers_pipeline_throughput() {
        let (net, points, rm) = setup("calibnet", 0.4);
        forall(25, 0xBA1A, |rng| {
            // random feasible design
            let designs: Vec<LayerDesign> = net
                .compute_layers()
                .iter()
                .map(|l| {
                    let is = divisors(l.i_extent());
                    let os = divisors(l.o_extent());
                    let i = *rng.choice(&is);
                    let o = *rng.choice(&os);
                    let d = LayerDesign { i_par: i, o_par: o, n_mac: 1 };
                    let m = d.m_len(l);
                    LayerDesign { n_mac: 1 + rng.below(m), ..d }
                })
                .collect();
            let before = network_throughput(&net, &designs, &points);
            let balanced = balance_rates(&net, &designs, &points, &rm, &DeviceBudget::u250());
            let after = network_throughput(&net, &balanced, &points);
            assert!(
                after >= before * (1.0 - 1e-12),
                "balance lowered throughput {before} -> {after}"
            );
        });
    }

    #[test]
    fn balance_never_raises_resources() {
        let (net, points, rm) = setup("calibnet", 0.4);
        forall(25, 0xBA1B, |rng| {
            let designs: Vec<LayerDesign> = net
                .compute_layers()
                .iter()
                .map(|l| {
                    let os = divisors(l.o_extent());
                    let o = *rng.choice(&os);
                    let d = LayerDesign { i_par: 1, o_par: o, n_mac: 1 };
                    let m = d.m_len(l);
                    LayerDesign { n_mac: 1 + rng.below(m), ..d }
                })
                .collect();
            let before = rm.network(&net, &designs);
            let balanced = balance_rates(&net, &designs, &points, &rm, &DeviceBudget::u250());
            let after = rm.network(&net, &balanced);
            assert!(after.dsp <= before.dsp, "dsp {} -> {}", before.dsp, after.dsp);
        });
    }

    #[test]
    fn explore_fits_budget() {
        let (net, points, rm) = setup("calibnet", 0.3);
        let dev = DeviceBudget::u250();
        let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
        assert!(dev.fits(&d.resources), "{:?}", d.resources);
        assert!(d.throughput > 0.0);
    }

    #[test]
    fn explore_beats_minimal_design() {
        let (net, points, rm) = setup("calibnet", 0.3);
        let dev = DeviceBudget::u250();
        let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
        let minimal = vec![LayerDesign::MINIMAL; points.len()];
        let min_thr = network_throughput(&net, &minimal, &points);
        assert!(
            d.throughput > min_thr * 10.0,
            "DSE barely improved: {} vs {}",
            d.throughput,
            min_thr
        );
    }

    #[test]
    fn explore_uses_more_resources_on_bigger_device() {
        let (net, points, rm) = setup("calibnet", 0.3);
        let small = DeviceBudget {
            name: "small".into(),
            dsp: 64,
            lut: 200_000,
            bram18k: 600,
            uram: 64,
            freq_mhz: 250.0,
        };
        let big = DeviceBudget::u250();
        let ds = explore(&net, &points, &rm, &small, &DseConfig::default());
        let db = explore(&net, &points, &rm, &big, &DseConfig::default());
        assert!(db.throughput >= ds.throughput);
        assert!(small.fits(&ds.resources));
    }

    #[test]
    fn sparser_network_reaches_higher_throughput_per_dsp() {
        // the core sparse-dataflow claim: at a fixed budget, sparsity buys
        // throughput per DSP
        let rm = ResourceModel::default();
        let net = networks::calibnet();
        let dev = DeviceBudget {
            name: "cap".into(),
            dsp: 512,
            lut: 600_000,
            bram18k: 2_000,
            uram: 256,
            freq_mhz: 250.0,
        };
        let n = net.compute_layers().len();
        let dense = explore(
            &net,
            &vec![SparsityPoint::DENSE; n],
            &rm,
            &dev,
            &DseConfig::default(),
        );
        let sparse = explore(
            &net,
            &vec![SparsityPoint { s_w: 0.6, s_a: 0.5 }; n],
            &rm,
            &dev,
            &DseConfig::default(),
        );
        assert!(
            sparse.efficiency() > dense.efficiency() * 1.5,
            "sparse {} vs dense {}",
            sparse.efficiency(),
            dense.efficiency()
        );
    }

    #[test]
    fn explore_is_deterministic() {
        let (net, points, rm) = setup("calibnet", 0.25);
        let dev = DeviceBudget::u250();
        let a = explore(&net, &points, &rm, &dev, &DseConfig::default());
        let b = explore(&net, &points, &rm, &dev, &DseConfig::default());
        assert_eq!(a.designs, b.designs);
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
    }

    #[test]
    fn explore_handles_resnet18_scale() {
        let (net, points, rm) = setup("resnet18", 0.5);
        let dev = DeviceBudget::u250();
        let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
        assert!(dev.fits(&d.resources));
        // ResNet-18 at 224x224 should reach paper-order throughput:
        // thousands of images/s at 250 MHz
        let ips = d.images_per_sec(&dev);
        assert!(ips > 100.0, "unreasonably slow: {ips} img/s");
    }

    #[test]
    fn efficiency_metric_definition() {
        let d = NetworkDesign {
            designs: vec![],
            throughput: 1e-5,
            resources: Resources { dsp: 100, lut: 0, bram18k: 0, uram: 0 },
        };
        assert!((d.efficiency() - 1e-7).abs() < 1e-20);
    }
}
