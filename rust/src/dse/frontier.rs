//! Per-layer Pareto-frontier pricing kernel — the fast path behind
//! [`super::explore`].
//!
//! The seed DSE priced every bisection probe by rescanning the whole
//! divisor×n_mac design space of every layer
//! ([`super::cheapest_design_achieving`]).  This module collapses that
//! rescan into a one-time reduction: for a fixed (layer shape, sparsity
//! point, resource model, device budget) it enumerates the design space
//! **once**, keeps for every achievable rate the cheapest design reaching
//! it, and sorts the survivors by rate — a [`LayerFrontier`].  A
//! "cheapest design achieving throughput ≥ λ" query then becomes a binary
//! search ([`LayerFrontier::cheapest_achieving`]), so `explore`'s
//! log-space bisection costs O(layers × probes × log |frontier|) instead
//! of O(layers × probes × |design space|).
//!
//! # Bit-identity contract
//!
//! Every query answer is **bit-identical** to what the seed scan returns,
//! including its tie-breaking (first minimal [`super::norm_cost`] in scan
//! order: `o_par` divisors ascending, then `i_par` divisors ascending,
//! then the minimal `n_mac` achieving the rate).  Three properties make
//! this hold:
//!
//! 1. The candidate set per `(o, i)` pair is exactly the image of the
//!    scan's `t_budget → n_mac` map — every design the scan could ever
//!    construct for *any* query, and nothing else.
//! 2. Within a pair, cost is strictly increasing in `n_mac`, so the
//!    scan's per-pair choice (minimal `n_mac` meeting the rate) is also
//!    the pool-wide cheapest member of that pair at the queried rate; the
//!    global `(cost, scan order)`-lexicographic minimum over the rate
//!    suffix therefore coincides with the scan's winner — also when every
//!    cost is `+inf` (URAM-less device), because candidates are ordered
//!    `n_mac`-ascending within a pair.
//! 3. Costs and resources are computed by [`FamilyCoster`] with the exact
//!    same floating-point expression shapes as
//!    [`ResourceModel::layer`] / [`super::norm_cost`] (verified per
//!    candidate by `debug_assert`s, and by the differential tests in this
//!    module and `tests/integration.rs`).
//!
//! # Reuse
//!
//! A frontier depends on the layer only through its *shape* (`op` +
//! `in_hw`) — never its name or graph position — so repeated blocks of a
//! ResNet share one frontier.  [`build_frontiers`] memoizes per
//! `(shape, point)` within a call; the engine's
//! [`crate::engine::DesignCache`] extends the same keying into a
//! lock-striped cross-candidate / cross-generation / cross-shard store.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::arch::{LayerDesc, Network};
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::{log2_ceil, ResourceModel, Resources};
use crate::hardware::{divisors, LayerDesign};
use crate::sparsity::SparsityPoint;
use crate::util::ceil_div;

/// One point of a layer's rate/cost frontier.
#[derive(Clone, Copy, Debug)]
pub struct FrontierEntry {
    /// throughput of this rate level in images/cycle (Eq. 2), `1/cycles`
    pub rate: f64,
    /// cycles per image of this rate level — the integer form of `rate`,
    /// used to replicate the scan's cycle-budget arithmetic exactly
    pub cycles: u64,
    /// [`super::norm_cost`] of `design` on the frontier's device
    pub cost: f64,
    pub design: LayerDesign,
    /// [`ResourceModel::layer`] of `design`, precomputed
    pub resources: Resources,
}

/// The reduced design space of one (layer shape, sparsity point, resource
/// model, device budget): entries sorted by strictly increasing `rate`,
/// each holding the cheapest design whose rate is ≥ its own (the suffix
/// minimum of the full candidate pool), with `cost` non-decreasing along
/// the frontier.
#[derive(Clone, Debug)]
pub struct LayerFrontier {
    entries: Vec<FrontierEntry>,
}

impl LayerFrontier {
    /// The frontier entries, rate-ascending (cost non-decreasing).
    pub fn entries(&self) -> &[FrontierEntry] {
        &self.entries
    }

    /// Rebuild a frontier from entries recorded by [`Self::entries`] —
    /// the deserialization side of the engine's cache snapshots.
    /// `entries` must already satisfy the frontier invariant (`rate`
    /// strictly ascending, `cycles` strictly descending); callers
    /// loading untrusted data validate with [`entries_are_ordered`]
    /// first, and debug builds assert it.
    pub fn from_entries(entries: Vec<FrontierEntry>) -> LayerFrontier {
        debug_assert!(entries_are_ordered(&entries), "frontier entries out of order");
        LayerFrontier { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fastest rate any design of this layer can reach.
    pub fn max_rate(&self) -> f64 {
        self.entries.last().map_or(0.0, |e| e.rate)
    }

    /// Cheapest entry achieving throughput ≥ `min_thr`, or `None` if even
    /// full parallelism misses.  `min_thr` must be positive (callers with
    /// a non-positive target use [`Self::cheapest_design_achieving`],
    /// which mirrors the scan's early return).
    ///
    /// The scan derives an **integer cycle budget** `⌊1/min_thr⌋` first
    /// and only then checks the f64 rate; when `min_thr` is exactly an
    /// achievable rate `1/c`, the `1/(1/c)` roundtrip can floor to `c−1`
    /// and the scan rejects the rate-`min_thr` design.  Replicating both
    /// conditions keeps the lookup bit-identical at those boundaries
    /// (`balance_rates` queries layers at exactly the bottleneck's rate).
    pub fn cheapest_achieving(&self, min_thr: f64) -> Option<&FrontierEntry> {
        let budget_cycles = (1.0 / min_thr).floor().max(1.0) as u64;
        // entries are rate-ascending == cycles-descending, so both
        // rejection conditions are prefix predicates
        let idx = self
            .entries
            .partition_point(|e| e.cycles > budget_cycles || e.rate < min_thr);
        self.entries.get(idx)
    }

    /// Drop-in replacement for [`super::cheapest_design_achieving`] —
    /// same contract, same result, bit for bit.
    pub fn cheapest_design_achieving(&self, min_thr: f64) -> Option<LayerDesign> {
        if min_thr <= 0.0 {
            return Some(LayerDesign::MINIMAL);
        }
        self.cheapest_achieving(min_thr).map(|e| e.design)
    }
}

/// Does `entries` satisfy the [`LayerFrontier`] ordering invariant
/// (`rate` strictly ascending, `cycles` strictly descending, and each
/// entry's `rate`/`cycles` pair consistent)?  The validation gate for
/// [`LayerFrontier::from_entries`] on untrusted (on-disk) data.
pub fn entries_are_ordered(entries: &[FrontierEntry]) -> bool {
    entries.iter().all(|e| e.cycles >= 1 && e.rate.to_bits() == (1.0 / e.cycles as f64).to_bits())
        && entries.windows(2).all(|w| w[0].rate < w[1].rate && w[0].cycles > w[1].cycles)
}

/// A candidate before frontier reduction.
struct Candidate {
    /// cycles per image (rate = 1/cycles); the u64 sort key avoids any
    /// float-comparison subtlety
    cycles: u64,
    /// position in scan order (o asc, i asc, n asc) — the tie-breaker
    order: u32,
    design: LayerDesign,
    cost: f64,
    resources: Resources,
}

/// Incremental coster for one `(i_par, o_par)` family: everything that
/// does not depend on `n_mac` (BRAM, URAM, the per-M LUT terms, the
/// normalization divisors) is computed once via [`ResourceModel::layer`];
/// the `n`-dependent DSP/LUT terms are evaluated with the **exact same
/// floating-point expression shapes** as the model, so results are
/// bit-identical (checked by `debug_assert` on every candidate).
struct FamilyCoster {
    io: usize,
    engines_f: f64,
    /// `lut_spe_base + lut_per_m * M` — the n-free prefix of `lut_spe`
    s1: f64,
    arb: f64,
    per_mac: f64,
    lg: f64,
    layer_base: f64,
    bram18k: u64,
    uram: u64,
    dsp_div: f64,
    lut_div: f64,
    /// precomputed `bram18k / bram_budget` term of [`super::norm_cost`]
    bram_t: f64,
    /// precomputed URAM term: `uram/budget`, `+inf` (URAM-less device
    /// needing URAM) or `0.0` (nothing to add)
    uram_add: f64,
    /// `pair_density * M` exactly as `LayerDesign::spe_cycles` computes it
    useful_raw: f64,
}

impl FamilyCoster {
    fn new(
        layer: &LayerDesc,
        point: SparsityPoint,
        rm: &ResourceModel,
        dev: &DeviceBudget,
        i: usize,
        o: usize,
    ) -> FamilyCoster {
        let d1 = LayerDesign { i_par: i, o_par: o, n_mac: 1 };
        let base = rm.layer(layer, &d1);
        let m_u = d1.m_len(layer) as u64;
        FamilyCoster {
            io: i * o,
            engines_f: d1.engines() as f64,
            s1: rm.lut_spe_base + rm.lut_per_m * m_u as f64,
            arb: rm.lut_arbiter,
            per_mac: rm.lut_per_mac,
            lg: log2_ceil(m_u) as f64,
            layer_base: rm.lut_layer_base,
            bram18k: base.bram18k,
            uram: base.uram,
            dsp_div: dev.dsp.max(1) as f64,
            lut_div: dev.lut.max(1) as f64,
            bram_t: base.bram18k as f64 / dev.bram18k.max(1) as f64,
            uram_add: if dev.uram > 0 {
                base.uram as f64 / dev.uram as f64
            } else if base.uram > 0 {
                f64::INFINITY
            } else {
                0.0
            },
            useful_raw: point.pair_density() * (d1.m_len(layer) as f64),
        }
    }

    /// [`ResourceModel::layer`] for `n_mac = n`, bit for bit.
    fn resources(&self, n: usize) -> Resources {
        let nf = n as f64;
        // same grouping as the model: ((s1 + (arb*n)*lg) + per_mac*n)
        let lut_spe = self.s1 + self.arb * nf * self.lg + self.per_mac * nf;
        Resources {
            dsp: (self.io * n) as u64,
            lut: (self.engines_f * lut_spe + self.layer_base) as u64,
            bram18k: self.bram18k,
            uram: self.uram,
        }
    }

    /// [`super::norm_cost`] on the frontier's device, bit for bit.
    fn cost(&self, r: &Resources) -> f64 {
        let mut c = r.dsp as f64 / self.dsp_div + r.lut as f64 / self.lut_div + self.bram_t;
        c += self.uram_add;
        c
    }

    /// `LayerDesign::spe_cycles` for `n_mac = n`, bit for bit.
    fn spe_cycles(&self, n: usize) -> u64 {
        ((self.useful_raw / n as f64).ceil() as u64).max(1)
    }
}

/// FNV-1a fingerprint of everything a layer's pricing depends on: its
/// operator (all fields) and input spatial size.  Name and branch flag are
/// deliberately excluded — repeated blocks share frontiers.
pub fn shape_fingerprint(layer: &LayerDesc) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in format!("{:?}|{}", layer.op, layer.in_hw).bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Enumerate the layer's divisor×n_mac design space once and reduce it to
/// a [`LayerFrontier`].  Pure function of (layer shape, point, resource
/// model, device budget) — safe to share across candidates and searches.
pub fn build_frontier(
    layer: &LayerDesc,
    point: SparsityPoint,
    rm: &ResourceModel,
    dev: &DeviceBudget,
) -> LayerFrontier {
    let o_divs = divisors(layer.o_extent());
    let i_divs = divisors(layer.i_extent());
    let outputs = layer.outputs_per_image() as u64;
    let mut cands: Vec<Candidate> = Vec::new();
    let mut family: Vec<usize> = Vec::new();
    for &o in &o_divs {
        let groups = ceil_div(outputs, o as u64);
        for &i in &i_divs {
            let probe = LayerDesign { i_par: i, o_par: o, n_mac: 1 };
            let m = probe.m_len(layer);
            // the scan's n-selection input, formula included (`.max(0.0)`)
            let useful = (point.pair_density() * m as f64).max(0.0);
            // distinct minimal-n designs over every possible cycle budget
            // t ≥ 1 — the full image of the scan's t_budget → n_mac map.
            // Walk t upward (n downward), jumping straight to the next t
            // that changes n; start at the first t whose n fits in M.
            family.clear();
            let mut t: u64 = if useful <= m as f64 {
                1
            } else {
                (useful / m as f64).ceil() as u64
            };
            loop {
                let n = if useful <= t as f64 {
                    1
                } else {
                    (useful / t as f64).ceil() as usize
                };
                if n <= m && family.last() != Some(&n) {
                    family.push(n);
                }
                if n <= 1 {
                    break;
                }
                let t_next = (useful / (n - 1) as f64).ceil() as u64;
                t = t.max(t_next).max(t + 1);
            }
            let coster = FamilyCoster::new(layer, point, rm, dev, i, o);
            // n ascending (family was built n-descending): pool order must
            // put cheaper family members first so `(cost, order)` ties on
            // an all-infinite-cost device resolve exactly like the scan
            for &n in family.iter().rev() {
                let d = LayerDesign { i_par: i, o_par: o, n_mac: n.max(1) };
                if !d.feasible(layer) {
                    continue;
                }
                let r = coster.resources(n.max(1));
                debug_assert_eq!(
                    r,
                    rm.layer(layer, &d),
                    "FamilyCoster diverged from ResourceModel::layer for {d:?}"
                );
                let cost = coster.cost(&r);
                debug_assert_eq!(
                    cost.to_bits(),
                    super::norm_cost(&r, dev).to_bits(),
                    "FamilyCoster diverged from norm_cost for {d:?}"
                );
                let cycles = groups * coster.spe_cycles(n.max(1));
                debug_assert_eq!(
                    cycles,
                    d.cycles_per_image(layer, point),
                    "FamilyCoster diverged from cycles_per_image for {d:?}"
                );
                let order = cands.len() as u32;
                cands.push(Candidate { cycles, order, design: d, cost, resources: r });
            }
        }
    }
    reduce(cands)
}

/// Reduce the candidate pool to the frontier: group by rate, compute the
/// suffix `(cost, scan order)`-lexicographic minimum from the fastest rate
/// down, and keep an entry exactly where that minimum changes design.
fn reduce(mut cands: Vec<Candidate>) -> LayerFrontier {
    // cycles descending == rate ascending; ties keep scan order
    cands.sort_unstable_by(|a, b| b.cycles.cmp(&a.cycles).then(a.order.cmp(&b.order)));
    let mut entries_rev: Vec<FrontierEntry> = Vec::new();
    let mut best: Option<usize> = None;
    let mut g_end = cands.len();
    while g_end > 0 {
        let cyc = cands[g_end - 1].cycles;
        let mut g_start = g_end;
        while g_start > 0 && cands[g_start - 1].cycles == cyc {
            g_start -= 1;
            let c = &cands[g_start];
            let better = match best {
                None => true,
                Some(b) => {
                    let bb = &cands[b];
                    c.cost < bb.cost || (c.cost == bb.cost && c.order < bb.order)
                }
            };
            if better {
                best = Some(g_start);
            }
        }
        let b = &cands[best.expect("non-empty rate group")];
        let emit = match entries_rev.last() {
            None => true,
            Some(e) => e.design != b.design,
        };
        if emit {
            entries_rev.push(FrontierEntry {
                rate: 1.0 / cyc as f64,
                cycles: cyc,
                cost: b.cost,
                design: b.design,
                resources: b.resources,
            });
        }
        g_end = g_start;
    }
    entries_rev.reverse();
    LayerFrontier { entries: entries_rev }
}

/// Frontiers for every compute layer of `net` under per-layer `points`,
/// deduplicated by (shape, point): repeated blocks (common in ResNets)
/// share one build and one allocation.
pub fn build_frontiers(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
) -> Vec<Arc<LayerFrontier>> {
    let compute = net.compute_layers();
    assert_eq!(compute.len(), points.len());
    let mut memo: BTreeMap<(u64, u64, u64), Arc<LayerFrontier>> = BTreeMap::new();
    compute
        .iter()
        .zip(points)
        .map(|(l, p)| {
            let key = (shape_fingerprint(l), p.s_w.to_bits(), p.s_a.to_bits());
            memo.entry(key)
                .or_insert_with(|| Arc::new(build_frontier(l, *p, rm, dev)))
                .clone()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::dse::cheapest_design_achieving;
    use crate::util::prop::forall;

    fn devices() -> Vec<DeviceBudget> {
        vec![
            DeviceBudget::u250(),
            // URAM-less: every norm_cost is +inf — the tie-break torture test
            DeviceBudget::v7_690t(),
            DeviceBudget {
                name: "small".into(),
                dsp: 96,
                lut: 150_000,
                bram18k: 500,
                uram: 48,
                freq_mhz: 200.0,
            },
        ]
    }

    /// Query thresholds that probe the decision boundaries of a frontier:
    /// at, just below and just above sampled entry rates, plus extremes.
    /// (Sampled with a stride so the scan-side reference — O(design
    /// space) per query — keeps the test fast in debug builds.)
    fn probe_thresholds(f: &LayerFrontier) -> Vec<f64> {
        let mut out = vec![0.0, -1.0, 1e-300, 1.5, f.max_rate(), f.max_rate() * 2.0];
        let stride = (f.len() / 9).max(1);
        for e in f.entries().iter().step_by(stride) {
            out.push(e.rate);
            out.push(e.rate * (1.0 - 1e-12));
            out.push(e.rate * (1.0 + 1e-12));
            out.push(e.rate * 0.5);
        }
        out
    }

    #[test]
    fn frontier_is_rate_sorted_with_nondecreasing_cost() {
        for dev in devices() {
            for name in ["calibnet", "resnet18"] {
                let net = networks::by_name(name).unwrap();
                let rm = ResourceModel::default();
                for s in [0.0, 0.75] {
                    let p = SparsityPoint { s_w: s, s_a: s * 0.5 };
                    for l in net.compute_layers() {
                        let f = build_frontier(l, p, &rm, &dev);
                        assert!(!f.is_empty(), "{name}/{}: empty frontier", l.name);
                        for w in f.entries().windows(2) {
                            assert!(
                                w[0].rate < w[1].rate,
                                "{name}/{}: rates not strictly increasing",
                                l.name
                            );
                            assert!(
                                w[0].cost <= w[1].cost,
                                "{name}/{}: cost decreased along the frontier",
                                l.name
                            );
                        }
                    }
                }
            }
        }
    }

    /// The tentpole differential contract: every query the bisection (or
    /// rate balancing) could ever issue returns the scan's design, bit for
    /// bit — across networks, devices (including all-infinite-cost ones)
    /// and sparsity points.
    #[test]
    fn frontier_query_matches_scan_at_sampled_boundaries() {
        let rm = ResourceModel::default();
        for dev in devices() {
            for (name, layer_stride) in [("calibnet", 1), ("resnet18", 3)] {
                let net = networks::by_name(name).unwrap();
                let n = net.compute_layers().len();
                for s in [0.0, 0.6] {
                    let points = vec![SparsityPoint { s_w: s, s_a: 0.8 * s }; n];
                    for (li, l) in
                        net.compute_layers().iter().enumerate().step_by(layer_stride)
                    {
                        let f = build_frontier(l, points[li], &rm, &dev);
                        for thr in probe_thresholds(&f) {
                            let scan = cheapest_design_achieving(
                                &net, li, points[li], &rm, &dev, thr,
                            );
                            let fast = f.cheapest_design_achieving(thr);
                            assert_eq!(
                                scan, fast,
                                "{}/{} [{}] diverged at thr={thr:e}",
                                name, l.name, dev.name
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn frontier_query_matches_scan_on_random_points_and_devices() {
        let net = networks::calibnet();
        let rm = ResourceModel::default();
        forall(40, 0xF407, |rng| {
            let dev = DeviceBudget {
                name: "rand".into(),
                dsp: 16 + rng.below(20_000) as u64,
                lut: 10_000 + rng.below(2_000_000) as u64,
                bram18k: 100 + rng.below(10_000) as u64,
                // uram == 0 exercises the +inf cost path
                uram: if rng.bool(0.3) { 0 } else { 16 + rng.below(2_000) as u64 },
                freq_mhz: 250.0,
            };
            let li = rng.below(net.compute_layers().len());
            let p = SparsityPoint { s_w: rng.f64(), s_a: rng.f64() };
            let f = build_frontier(net.compute_layers()[li], p, &rm, &dev);
            for _ in 0..8 {
                // random queries, biased into the achievable range
                let thr = f.max_rate() * rng.f64() * 1.2;
                let scan = cheapest_design_achieving(&net, li, p, &rm, &dev, thr);
                assert_eq!(scan, f.cheapest_design_achieving(thr));
            }
        });
    }

    #[test]
    fn entry_resources_match_resource_model() {
        let net = networks::resnet18();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let p = SparsityPoint { s_w: 0.5, s_a: 0.4 };
        for l in net.compute_layers() {
            let f = build_frontier(l, p, &rm, &dev);
            for e in f.entries() {
                assert_eq!(e.resources, rm.layer(l, &e.design));
                assert_eq!(
                    e.cost.to_bits(),
                    crate::dse::norm_cost(&e.resources, &dev).to_bits()
                );
                // an entry's design comes from the rate suffix, so it
                // reaches at least the rate it is filed under
                assert!(e.design.throughput(l, p) >= e.rate);
            }
        }
    }

    #[test]
    fn nonpositive_threshold_returns_minimal() {
        let net = networks::calibnet();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let f = build_frontier(
            net.compute_layers()[0],
            SparsityPoint { s_w: 0.2, s_a: 0.2 },
            &rm,
            &dev,
        );
        assert_eq!(f.cheapest_design_achieving(0.0), Some(LayerDesign::MINIMAL));
        assert_eq!(f.cheapest_design_achieving(-3.0), Some(LayerDesign::MINIMAL));
    }

    #[test]
    fn unreachable_rate_returns_none() {
        let net = networks::calibnet();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let p = SparsityPoint { s_w: 0.0, s_a: 0.0 };
        let f = build_frontier(net.compute_layers()[0], p, &rm, &dev);
        // at exactly max_rate the ⌊1/thr⌋ cycle-budget roundtrip may floor
        // one below the fastest design's cycles — the scan then returns
        // None too; what matters is agreement, checked differentially
        assert_eq!(
            f.cheapest_design_achieving(f.max_rate()),
            cheapest_design_achieving(&net, 0, p, &rm, &dev, f.max_rate())
        );
        assert!(f.cheapest_design_achieving(f.max_rate() * 1.0001).is_none());
        assert!(f.cheapest_design_achieving(2.0).is_none());
    }

    #[test]
    fn degenerate_density_never_buys_macs() {
        // fully pruned layer: every (o, i) family collapses to n = 1
        let net = networks::calibnet();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let l = net.compute_layers()[0];
        let f = build_frontier(l, SparsityPoint { s_w: 1.0, s_a: 1.0 }, &rm, &dev);
        assert!(!f.is_empty());
        for e in f.entries() {
            assert_eq!(e.design.n_mac, 1, "pruned layer must not buy MACs");
        }
    }

    #[test]
    fn shape_fingerprint_ignores_name_and_branch() {
        let net = networks::resnet18();
        let layers = net.compute_layers();
        let mut a = layers[0].clone();
        let mut b = layers[0].clone();
        a.name = "x".into();
        b.name = "y".into();
        b.branch = !b.branch;
        assert_eq!(shape_fingerprint(&a), shape_fingerprint(&b));
        // distinct shapes must not collide (spot check over the net)
        for (i, x) in layers.iter().enumerate() {
            for y in layers.iter().skip(i + 1) {
                if format!("{:?}|{}", x.op, x.in_hw) != format!("{:?}|{}", y.op, y.in_hw)
                {
                    assert_ne!(shape_fingerprint(x), shape_fingerprint(y));
                }
            }
        }
    }

    #[test]
    fn from_entries_roundtrips_and_order_check_validates() {
        let net = networks::calibnet();
        let rm = ResourceModel::default();
        for dev in [DeviceBudget::u250(), DeviceBudget::v7_690t()] {
            let built = build_frontier(
                net.compute_layers()[0],
                SparsityPoint { s_w: 0.3, s_a: 0.6 },
                &rm,
                &dev,
            );
            assert!(entries_are_ordered(built.entries()), "{}", dev.name);
            let back = LayerFrontier::from_entries(built.entries().to_vec());
            assert_eq!(back.len(), built.len());
            for thr in [0.0, built.max_rate() * 0.5, built.max_rate()] {
                assert_eq!(
                    back.cheapest_design_achieving(thr),
                    built.cheapest_design_achieving(thr),
                    "{} thr={thr:e}",
                    dev.name
                );
            }
            // a reversed (or otherwise disordered) entry list fails the gate
            if built.len() >= 2 {
                let mut rev = built.entries().to_vec();
                rev.reverse();
                assert!(!entries_are_ordered(&rev));
            }
        }
    }

    #[test]
    fn build_frontiers_shares_repeated_shapes() {
        let net = networks::resnet18();
        let n = net.compute_layers().len();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let points = vec![SparsityPoint { s_w: 0.5, s_a: 0.5 }; n];
        let fs = build_frontiers(&net, &points, &rm, &dev);
        assert_eq!(fs.len(), n);
        // ResNet-18 repeats its residual blocks: at least one pair of
        // layers must share the exact same frontier allocation
        let shared = (0..n).any(|i| (i + 1..n).any(|j| Arc::ptr_eq(&fs[i], &fs[j])));
        assert!(shared, "repeated ResNet blocks should share frontiers");
    }
}
