//! Device partitioning & full reconfiguration (paper §V-A.4).
//!
//! When a network does not fit one device, the dataflow pipeline is folded
//! at block level: contiguous layer ranges ("partitions") are computed one
//! after another on the same FPGA with **full reconfiguration** between
//! them.  Reconfiguration costs wall-clock time, amortized by batching:
//!
//! ```text
//! time(batch) = Σ_p batch / θ_p   +   P · T_reconfig
//! ```
//!
//! A simulated-annealing solver picks the number of partitions and the
//! split points, trading reconfiguration overhead against the parallelism
//! each (smaller) partition can afford from the full device.

use std::sync::Arc;

use crate::arch::Network;
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::ResourceModel;
use crate::optim::anneal::{anneal, AnnealSchedule};
use crate::sparsity::SparsityPoint;
use crate::util::rng::Rng;

use super::frontier::{build_frontiers, LayerFrontier};
use super::{explore_with_frontiers, DseConfig, NetworkDesign};

/// U250 full-bitstream reconfiguration time (order of 100 ms via PCIe),
/// the paper amortizes it with large batches [1].
pub const DEFAULT_RECONFIG_SECS: f64 = 0.1;

/// One partitioned mapping of a network.
#[derive(Clone, Debug)]
pub struct Partitioning {
    /// split points: partition p covers compute layers
    /// `bounds[p]..bounds[p+1]`; `bounds[0] == 0`,
    /// `bounds.last() == n_compute_layers`
    pub bounds: Vec<usize>,
    /// per-partition DSE result
    pub designs: Vec<NetworkDesign>,
    /// end-to-end throughput in images/s at `batch`
    pub images_per_sec: f64,
    pub batch: usize,
}

impl Partitioning {
    pub fn n_partitions(&self) -> usize {
        self.bounds.len() - 1
    }
}

/// Sub-network view covering compute layers `[lo, hi)` of `net` (plus the
/// non-compute nodes between them, which belong to the partition's
/// pipeline stretch).
fn slice_network(net: &Network, lo: usize, hi: usize) -> (Network, Vec<usize>) {
    let idx = net.compute_indices();
    let start_node = idx[lo];
    let end_node = if hi < idx.len() { idx[hi] } else { net.layers.len() };
    let sub = slice_node_range(net, start_node, end_node, &format!("{}[{lo}..{hi}]", net.name));
    (sub, (lo..hi).collect())
}

/// Sub-network over the node range `[start_node, end_node)`.  The slice's
/// input geometry comes from its own first main-pipeline node — *not*
/// from the whole network's input: a mid-network slice starting on a
/// streaming node (pool / act / add) carries the preceding compute
/// layer's output channel count, which every streaming op records as its
/// `channels` field.  Falling back to `net.input_channels` there priced
/// mid-network slices as if they read the network input (wrong whenever
/// the widths differ); the whole-network values are now used only for the
/// degenerate all-branch slice, whose main pipeline is empty.
fn slice_node_range(net: &Network, start_node: usize, end_node: usize, name: &str) -> Network {
    use crate::arch::Op;
    let layers: Vec<_> = net.layers[start_node..end_node].to_vec();
    let (input_hw, input_channels) = match layers.iter().find(|l| !l.branch) {
        Some(first) => {
            let ch = match &first.op {
                Op::Conv { cin, .. } | Op::Linear { cin, .. } => *cin,
                Op::Pool { channels, .. }
                | Op::GlobalPool { channels }
                | Op::Add { channels }
                | Op::Act { channels } => *channels,
            };
            (first.in_hw, ch)
        }
        None => (net.input_hw, net.input_channels),
    };
    Network { name: name.to_string(), input_hw, input_channels, layers }
}

/// Evaluate a set of split bounds: DSE each partition on the full device,
/// then combine with the reconfiguration-amortization formula.
///
/// Builds per-layer frontiers for the whole network and delegates to
/// [`evaluate_bounds_with`]; callers evaluating many bound sets over the
/// same `(points, rm, dev)` — the annealer — build the frontiers once.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_bounds(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
    bounds: &[usize],
    batch: usize,
    reconfig_secs: f64,
) -> Option<Partitioning> {
    let frontiers = build_frontiers(net, points, rm, dev);
    evaluate_bounds_with(net, points, rm, dev, cfg, bounds, batch, reconfig_secs, &frontiers)
}

/// [`evaluate_bounds`] against prebuilt whole-network frontiers: a
/// partition covering compute layers `[lo, hi)` prices through
/// `frontiers[lo..hi]` — frontiers are slice-invariant because they
/// depend only on (layer shape, point, resource model, device).
#[allow(clippy::too_many_arguments)]
pub fn evaluate_bounds_with(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
    bounds: &[usize],
    batch: usize,
    reconfig_secs: f64,
    frontiers: &[Arc<LayerFrontier>],
) -> Option<Partitioning> {
    let mut designs = Vec::with_capacity(bounds.len() - 1);
    let mut secs_per_batch = (bounds.len() - 1) as f64 * reconfig_secs;
    for w in bounds.windows(2) {
        let (sub, pt_idx) = slice_network(net, w[0], w[1]);
        let sub_points: Vec<SparsityPoint> = pt_idx.iter().map(|&i| points[i]).collect();
        let d =
            explore_with_frontiers(&sub, &sub_points, rm, dev, cfg, &frontiers[w[0]..w[1]]);
        if !dev.fits(&d.resources) {
            return None; // partition still too large for the device
        }
        secs_per_batch += batch as f64 / d.images_per_sec(dev);
        designs.push(d);
    }
    Some(Partitioning {
        bounds: bounds.to_vec(),
        designs,
        images_per_sec: batch as f64 / secs_per_batch,
        batch,
    })
}

/// SA over split points (paper: "the decisions of where to split the
/// partition and the number of partitions are given by a simulated
/// annealing solver").
pub fn partition(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
    batch: usize,
    reconfig_secs: f64,
    rng: &mut Rng,
) -> Option<Partitioning> {
    let n = net.compute_layers().len();
    assert_eq!(n, points.len());
    // one frontier set serves every SA energy call and every slice: the
    // annealer re-prices slices of the same layers dozens of times
    let frontiers = build_frontiers(net, points, rm, dev);
    // The single-partition mapping (when the whole net fits) and the SA
    // sweep over every partition count compete on end-to-end rate; the
    // best across all of them wins.  Neither the unfolded mapping nor the
    // first feasible count is necessarily the best one — a fold can win
    // when the single-device design is budget-starved, and with cheap
    // reconfiguration and large batches a finer fold gives every
    // partition more of the device and can beat the coarsest feasible
    // split outright.
    let mut best =
        evaluate_bounds_with(net, points, rm, dev, cfg, &[0, n], batch, reconfig_secs, &frontiers);
    for n_parts in [2, 3, 4, 6, 8] {
        if let Some(p) = anneal_partitions(
            net, points, rm, dev, cfg, batch, reconfig_secs, rng, n_parts, &frontiers,
        ) {
            if best.as_ref().is_none_or(|b| p.images_per_sec > b.images_per_sec) {
                best = Some(p);
            }
        }
    }
    best
}

#[allow(clippy::too_many_arguments)]
fn anneal_partitions(
    net: &Network,
    points: &[SparsityPoint],
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
    batch: usize,
    reconfig_secs: f64,
    rng: &mut Rng,
    n_parts: usize,
    frontiers: &[Arc<LayerFrontier>],
) -> Option<Partitioning> {
    let n = net.compute_layers().len();
    if n_parts > n {
        return None;
    }
    // Initial bounds: equal op-count split, kept *strictly increasing* so
    // the requested partition count is honored exactly.  (The previous
    // construction padded with `n` and `dedup()`ed, which silently
    // collapsed duplicate bounds — SA then annealed fewer partitions than
    // asked for, sometimes starting from a degenerate split.)  Each
    // interior bound is the op-count quantile clamped into the band that
    // leaves at least one layer for every partition on both sides; the
    // band is never empty when `n_parts <= n`.
    let ops: Vec<f64> = net.compute_layers().iter().map(|l| l.macs_per_image() as f64).collect();
    let total: f64 = ops.iter().sum();
    let mut bounds = Vec::with_capacity(n_parts + 1);
    bounds.push(0usize);
    let mut acc = 0.0;
    let mut i = 0usize;
    for p in 1..n_parts {
        while i < n && acc < total * p as f64 / n_parts as f64 {
            acc += ops[i];
            i += 1;
        }
        let (lo, hi) = (bounds[p - 1] + 1, n - (n_parts - p));
        bounds.push(i.clamp(lo, hi));
    }
    bounds.push(n);
    debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]));

    let energy = |b: &Vec<usize>| {
        match evaluate_bounds_with(net, points, rm, dev, cfg, b, batch, reconfig_secs, frontiers)
        {
            Some(p) => -p.images_per_sec,
            None => f64::INFINITY, // infeasible split
        }
    };
    let neighbor = |b: &Vec<usize>, r: &mut Rng| {
        let mut c = b.clone();
        if c.len() > 2 {
            // nudge one interior bound by ±1 within its neighbours
            let i = 1 + r.below(c.len() - 2);
            let lo = c[i - 1] + 1;
            let hi = c[i + 1].saturating_sub(1);
            if hi >= lo {
                let delta: i64 = if r.bool(0.5) { 1 } else { -1 };
                let v = (c[i] as i64 + delta).clamp(lo as i64, hi as i64) as usize;
                c[i] = v;
            }
        }
        c
    };
    // DSE per energy call is costly: keep the schedule short
    let schedule = AnnealSchedule { iters: 40, t0: 0.3, t1: 1e-3 };
    let (best, e) = anneal(bounds, energy, neighbor, &schedule, rng);
    if e.is_finite() {
        evaluate_bounds_with(net, points, rm, dev, cfg, &best, batch, reconfig_secs, frontiers)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;

    fn tiny_device() -> DeviceBudget {
        DeviceBudget {
            name: "tiny".into(),
            dsp: 48,
            lut: 120_000,
            bram18k: 400,
            uram: 64,
            freq_mhz: 250.0,
        }
    }

    fn setup() -> (Network, Vec<SparsityPoint>, ResourceModel, DseConfig) {
        let net = networks::calibnet();
        let n = net.compute_layers().len();
        (
            net,
            vec![SparsityPoint { s_w: 0.3, s_a: 0.3 }; n],
            ResourceModel::default(),
            DseConfig { max_iters: 2_000, ..Default::default() },
        )
    }

    /// Regression (initial-bounds construction): the annealer must hand
    /// back exactly the requested number of partitions whenever
    /// `n_parts <= n`.  The old quantile construction padded with `n` and
    /// `dedup()`ed, silently collapsing duplicate bounds — SA then
    /// annealed fewer partitions than asked for.
    #[test]
    fn anneal_honors_requested_partition_count() {
        let (net, points, rm, cfg) = setup();
        let n = net.compute_layers().len();
        let dev = DeviceBudget::u250(); // every split fits: feasibility
        let frontiers = build_frontiers(&net, &points, &rm, &dev);
        for n_parts in [2usize, 3, 4, 6, 8, n] {
            let mut rng = Rng::new(100 + n_parts as u64);
            let p = anneal_partitions(
                &net, &points, &rm, &dev, &cfg, 256, 0.0, &mut rng, n_parts, &frontiers,
            )
            .unwrap_or_else(|| panic!("{n_parts}-way fold must be feasible on the U250"));
            assert_eq!(
                p.n_partitions(),
                n_parts,
                "requested {n_parts} partitions, annealed {}",
                p.n_partitions()
            );
            assert_eq!(*p.bounds.first().unwrap(), 0);
            assert_eq!(*p.bounds.last().unwrap(), n);
            assert!(p.bounds.windows(2).all(|w| w[0] < w[1]), "{:?}", p.bounds);
        }
        // more partitions than compute layers stays unmappable
        let mut rng = Rng::new(99);
        assert!(anneal_partitions(
            &net,
            &points,
            &rm,
            &dev,
            &cfg,
            256,
            0.0,
            &mut rng,
            n + 1,
            &frontiers
        )
        .is_none());
    }

    /// A LUT budget below the whole network's minimal footprint, with
    /// every other resource generous: the net cannot map whole, a 2-way
    /// fold barely fits (little headroom for parallelism), finer folds
    /// leave each partition real headroom.  This is the regime where the
    /// partition-count sweep must not stop at the first feasible count.
    fn lut_capped_device(net: &Network, rm: &ResourceModel) -> DeviceBudget {
        let minimal =
            vec![crate::hardware::LayerDesign::MINIMAL; net.compute_layers().len()];
        let min_res = rm.network(net, &minimal);
        DeviceBudget {
            name: "lutcap".into(),
            dsp: 100_000,
            lut: min_res.lut * 4 / 5, // 80% of the whole-net minimum
            bram18k: 100_000,
            uram: 100_000,
            freq_mhz: 250.0,
        }
    }

    /// Regression (first-feasible sweep): `partition()` must keep the
    /// best end-to-end rate across the whole `[2, 3, 4, 6, 8]` sweep.
    /// On the LUT-capped device the 2-way fold is feasible but starved
    /// (its headroom over the static minimum is a sliver), so a finer
    /// fold with free reconfiguration beats it — the old code returned
    /// the starved first-feasible fold.
    #[test]
    fn sweep_keeps_best_fold_not_first_feasible() {
        let (net, points, rm, cfg) = setup();
        let dev = lut_capped_device(&net, &rm);
        let n = net.compute_layers().len();
        let frontiers = build_frontiers(&net, &points, &rm, &dev);
        // premise: the whole network must not fit this device
        assert!(
            evaluate_bounds_with(
                &net, &points, &rm, &dev, &cfg, &[0, n], 4096, 0.0, &frontiers
            )
            .is_none(),
            "premise violated: whole net fits the LUT-capped device"
        );
        // replay the old first-feasible semantics on a fresh rng: the
        // stream is consumed exactly as `partition()` consumes it, so
        // this IS (bitwise) what the old code returned
        let seed = 21u64;
        let mut rng = Rng::new(seed);
        let first = [2usize, 3, 4, 6, 8]
            .iter()
            .find_map(|&k| {
                anneal_partitions(
                    &net, &points, &rm, &dev, &cfg, 4096, 0.0, &mut rng, k, &frontiers,
                )
            })
            .expect("some fold must be feasible");
        assert_eq!(first.n_partitions(), 2, "2-way fold expected feasible first");
        let mut rng = Rng::new(seed);
        let best = partition(&net, &points, &rm, &dev, &cfg, 4096, 0.0, &mut rng)
            .expect("sweep must find a fold");
        assert!(
            best.images_per_sec >= first.images_per_sec,
            "sweep returned a worse fold than its own first candidate: {} vs {}",
            best.images_per_sec,
            first.images_per_sec
        );
        assert!(
            best.images_per_sec > first.images_per_sec,
            "sweep should beat the starved 2-way fold on this device \
             (best {} img/s across counts vs first {} img/s at {} partitions)",
            best.images_per_sec,
            first.images_per_sec,
            first.n_partitions()
        );
        assert!(best.n_partitions() > 2, "the winning fold should be finer than 2-way");
        for d in &best.designs {
            assert!(dev.fits(&d.resources));
        }
    }

    /// Regression (mid-network slice channels): a slice starting on a
    /// streaming node must inherit the preceding compute layer's output
    /// width, not the whole network's input channels.
    #[test]
    fn slice_starting_on_non_compute_layer_gets_pipeline_channels() {
        let (net, _, _, _) = setup();
        // "b1.relu1" follows b1.conv1 (cout 16) mid-network
        let start = net
            .layers
            .iter()
            .position(|l| l.name == "b1.relu1")
            .expect("calibnet has b1.relu1");
        let sub = slice_node_range(&net, start, net.layers.len(), "calibnet[b1.relu1..]");
        assert_eq!(
            sub.input_channels, 16,
            "slice must carry the preceding conv's output channels"
        );
        assert_ne!(sub.input_channels, net.input_channels);
        assert_eq!(sub.input_hw, 32);
        assert_eq!(sub.layers.len(), net.layers.len() - start);
        sub.validate().expect("mid-network slice must chain");
        // compute-first slices are unchanged by the fix
        let (sub2, idx) = slice_network(&net, 1, 3);
        assert_eq!(idx, vec![1, 2]);
        assert_eq!(sub2.input_channels, 16);
        sub2.validate().expect("compute-first slice must chain");
    }

    #[test]
    fn slice_covers_all_layers_exactly_once() {
        let (net, _, _, _) = setup();
        let n = net.compute_layers().len();
        let bounds = [0usize, 3, 7, n];
        let mut covered = Vec::new();
        for w in bounds.windows(2) {
            let (sub, idx) = slice_network(&net, w[0], w[1]);
            assert_eq!(sub.compute_layers().len(), w[1] - w[0]);
            covered.extend(idx);
        }
        assert_eq!(covered, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn whole_network_single_partition_when_it_fits() {
        let (net, points, rm, cfg) = setup();
        let dev = DeviceBudget::u250();
        let mut rng = Rng::new(1);
        let p = partition(&net, &points, &rm, &dev, &cfg, 256, DEFAULT_RECONFIG_SECS, &mut rng)
            .unwrap();
        assert_eq!(p.n_partitions(), 1);
        assert!(p.images_per_sec > 0.0);
    }

    #[test]
    fn folding_on_tiny_device() {
        let (net, points, rm, cfg) = setup();
        let dev = tiny_device();
        let mut rng = Rng::new(2);
        let p = partition(&net, &points, &rm, &dev, &cfg, 1024, DEFAULT_RECONFIG_SECS, &mut rng)
            .unwrap();
        // every partition must individually fit
        for d in &p.designs {
            assert!(dev.fits(&d.resources));
        }
        // bounds cover [0, n] monotonically
        let n = net.compute_layers().len();
        assert_eq!(*p.bounds.first().unwrap(), 0);
        assert_eq!(*p.bounds.last().unwrap(), n);
        assert!(p.bounds.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn larger_batch_amortizes_reconfiguration() {
        let (net, points, rm, cfg) = setup();
        let dev = tiny_device();
        let mut rng = Rng::new(3);
        let small = partition(&net, &points, &rm, &dev, &cfg, 32, DEFAULT_RECONFIG_SECS, &mut rng)
            .unwrap();
        let mut rng = Rng::new(3);
        let large = partition(&net, &points, &rm, &dev, &cfg, 4096, DEFAULT_RECONFIG_SECS, &mut rng)
            .unwrap();
        assert!(
            large.images_per_sec > small.images_per_sec,
            "batch amortization violated: {} vs {}",
            large.images_per_sec,
            small.images_per_sec
        );
    }

    #[test]
    fn zero_reconfig_time_prefers_more_partitions_or_ties() {
        let (net, points, rm, cfg) = setup();
        let dev = tiny_device();
        let mut rng = Rng::new(4);
        let with_cost =
            partition(&net, &points, &rm, &dev, &cfg, 256, 1.0, &mut rng).unwrap();
        let mut rng = Rng::new(4);
        let free = partition(&net, &points, &rm, &dev, &cfg, 256, 0.0, &mut rng).unwrap();
        assert!(free.images_per_sec >= with_cost.images_per_sec);
    }

    /// Each partition's frontier-priced slice design must equal the seed
    /// scan run on the slice as its own network — frontiers are
    /// slice-invariant.
    #[test]
    fn evaluate_bounds_matches_scan_explore_per_partition() {
        let (net, points, rm, cfg) = setup();
        let dev = DeviceBudget::u250();
        let n = net.compute_layers().len();
        let bounds = [0usize, 3, n];
        let p = evaluate_bounds(&net, &points, &rm, &dev, &cfg, &bounds, 256, 0.1)
            .expect("split fits the U250");
        for (w, d) in bounds.windows(2).zip(&p.designs) {
            let (sub, idx) = slice_network(&net, w[0], w[1]);
            let sub_points: Vec<SparsityPoint> = idx.iter().map(|&i| points[i]).collect();
            let scan = crate::dse::explore_scan(&sub, &sub_points, &rm, &dev, &cfg);
            assert_eq!(d.designs, scan.designs, "slice {w:?} diverged from scan");
            assert_eq!(d.throughput.to_bits(), scan.throughput.to_bits());
            assert_eq!(d.resources, scan.resources);
        }
    }

    #[test]
    fn evaluate_bounds_rejects_oversized_partition() {
        let (net, points, rm, cfg) = setup();
        let bad_dev = DeviceBudget {
            name: "nano".into(),
            dsp: 2,
            lut: 4_000,
            bram18k: 8,
            uram: 0,
            freq_mhz: 100.0,
        };
        let n = net.compute_layers().len();
        assert!(evaluate_bounds(&net, &points, &rm, &bad_dev, &cfg, &[0, n], 64, 0.1).is_none());
    }
}
