//! Sparsity statistics substrate.
//!
//! Two sources feed the DSE with per-layer sparsity:
//!
//! 1. **Measured** — the CalibNet AOT artifact is executed on calibration
//!    data; its per-layer |w|/|a| quantile tables (meta.json) become
//!    [`TransferCurve`]s and its counter output gives exact pair densities.
//! 2. **Synthesized** — for the five target geometries (which we cannot
//!    execute) curves are generated from parametric distributions whose
//!    *form* is validated against the measured ones: Laplace weights with
//!    He-scaled diversity, rectified-Gaussian activations with a natural
//!    zero rate that grows with depth (DESIGN.md §1.1).
//!
//! The paper's S̄ (average sparsity of an activation/weight *pair*,
//! Eq. 1) is derived as `1 − (1−S_w)(1−S_a)` under independence; the
//! measured path replaces this with the exact counter value.

use crate::arch::Network;
use crate::util::rng::Rng;
use crate::util::{clampf, erf};

/// Monotone threshold→sparsity transfer curve: `frac[i]` of the values
/// have magnitude < `taus[i]`.
#[derive(Clone, Debug)]
pub struct TransferCurve {
    pub taus: Vec<f64>,
    pub frac: Vec<f64>,
}

impl TransferCurve {
    /// From a quantile table: `qs[i]` is the |v| quantile at rank `pts[i]`.
    pub fn from_quantiles(pts: &[f64], qs: &[f64]) -> Self {
        assert_eq!(pts.len(), qs.len());
        assert!(!pts.is_empty());
        // enforce monotone taus (quantiles can repeat at 0)
        let mut taus = qs.to_vec();
        for i in 1..taus.len() {
            if taus[i] < taus[i - 1] {
                taus[i] = taus[i - 1];
            }
        }
        TransferCurve { taus, frac: pts.to_vec() }
    }

    /// Laplace(0, b) magnitudes: P(|v| < τ) = 1 − exp(−τ/b).
    pub fn laplace(b: f64, n_pts: usize) -> Self {
        let mut taus = Vec::with_capacity(n_pts);
        let mut frac = Vec::with_capacity(n_pts);
        for i in 0..n_pts {
            let f = i as f64 / (n_pts - 1) as f64 * 0.999;
            taus.push(-b * (1.0 - f).ln());
            frac.push(f);
        }
        TransferCurve { taus, frac }
    }

    /// Post-ReLU activations: a point mass `p0` at exactly zero plus a
    /// half-normal(σ) positive part: S(τ) = p0 + (1−p0)·erf(τ/(σ√2)).
    pub fn rectified_gaussian(p0: f64, sigma: f64, n_pts: usize) -> Self {
        let mut taus = Vec::with_capacity(n_pts);
        let mut frac = Vec::with_capacity(n_pts);
        for i in 0..n_pts {
            let tau = 4.0 * sigma * i as f64 / (n_pts - 1) as f64;
            taus.push(tau);
            frac.push(clampf(
                p0 + (1.0 - p0) * erf(tau / (sigma * std::f64::consts::SQRT_2)),
                0.0,
                1.0,
            ));
        }
        TransferCurve { taus, frac }
    }

    /// Fraction of values with magnitude below `tau` (piecewise linear).
    pub fn sparsity_at(&self, tau: f64) -> f64 {
        let ts = &self.taus;
        if tau <= ts[0] {
            // below the first recorded quantile: only the exact-zero mass
            return if tau > 0.0 { self.frac[0] } else { self.frac_at_zero() };
        }
        if tau >= *ts.last().unwrap() {
            return *self.frac.last().unwrap();
        }
        let mut i = 0;
        while ts[i + 1] < tau {
            i += 1;
        }
        let span = ts[i + 1] - ts[i];
        if span <= 0.0 {
            return self.frac[i + 1];
        }
        let t = (tau - ts[i]) / span;
        self.frac[i] + t * (self.frac[i + 1] - self.frac[i])
    }

    /// Natural sparsity at τ=0 (exact-zero mass: leading flat region).
    pub fn frac_at_zero(&self) -> f64 {
        let mut f = 0.0;
        for i in 0..self.taus.len() {
            if self.taus[i] <= 0.0 {
                f = self.frac[i];
            } else {
                break;
            }
        }
        f
    }

    /// Smallest τ achieving sparsity ≥ s (inverse transfer; clamped).
    pub fn tau_for(&self, s: f64) -> f64 {
        let s = clampf(s, 0.0, *self.frac.last().unwrap());
        if s <= self.frac[0] {
            return self.taus[0];
        }
        let mut i = 0;
        while self.frac[i + 1] < s {
            i += 1;
        }
        let span = self.frac[i + 1] - self.frac[i];
        if span <= 0.0 {
            return self.taus[i + 1];
        }
        let t = (s - self.frac[i]) / span;
        self.taus[i] + t * (self.taus[i + 1] - self.taus[i])
    }
}

/// Sparsity operating point of one layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SparsityPoint {
    /// weight sparsity S_w ∈ [0,1)
    pub s_w: f64,
    /// activation sparsity S_a ∈ [0,1)
    pub s_a: f64,
}

impl SparsityPoint {
    pub const DENSE: SparsityPoint = SparsityPoint { s_w: 0.0, s_a: 0.0 };

    /// Probability that a weight/activation *pair* is computable (both
    /// non-zero), assuming independence — the paper's (1 − S̄).
    pub fn pair_density(&self) -> f64 {
        (1.0 - self.s_w) * (1.0 - self.s_a)
    }

    /// The paper's S̄ — probability at least one operand of a pair is zero.
    pub fn pair_sparsity(&self) -> f64 {
        1.0 - self.pair_density()
    }
}

/// Full per-layer sparsity description of one compute layer.
#[derive(Clone, Debug)]
pub struct LayerProfile {
    pub name: String,
    pub weight_curve: TransferCurve,
    pub act_curve: TransferCurve,
    /// Relative per-input-channel density multipliers (mean 1.0) capturing
    /// the intra-layer imbalance the paper's SA balancing strategy targets.
    pub channel_imbalance: Vec<f64>,
}

impl LayerProfile {
    /// Operating point reached by thresholds (τ_w, τ_a).
    pub fn point(&self, tau_w: f64, tau_a: f64) -> SparsityPoint {
        SparsityPoint {
            s_w: self.weight_curve.sparsity_at(tau_w),
            s_a: self.act_curve.sparsity_at(tau_a),
        }
    }
}

/// Per-network sparsity model: one profile per compute layer, in
/// `Network::compute_indices()` order.
#[derive(Clone, Debug)]
pub struct NetworkSparsity {
    pub network: String,
    pub layers: Vec<LayerProfile>,
}

impl NetworkSparsity {
    /// Operating points for per-layer thresholds.
    pub fn points(&self, tau_w: &[f64], tau_a: &[f64]) -> Vec<SparsityPoint> {
        assert_eq!(tau_w.len(), self.layers.len());
        assert_eq!(tau_a.len(), self.layers.len());
        self.layers
            .iter()
            .zip(tau_w.iter().zip(tau_a))
            .map(|(l, (&tw, &ta))| l.point(tw, ta))
            .collect()
    }

    /// Dense points (no pruning) with only natural activation zeros.
    pub fn natural_points(&self) -> Vec<SparsityPoint> {
        self.layers
            .iter()
            .map(|l| SparsityPoint {
                s_w: l.weight_curve.frac_at_zero(),
                s_a: l.act_curve.frac_at_zero(),
            })
            .collect()
    }
}

/// Synthesize a plausible sparsity model for a target geometry
/// (deterministic in `seed`; see module docs for the distribution family).
pub fn synthesize(net: &Network, seed: u64) -> NetworkSparsity {
    let mut rng = Rng::new(seed ^ hash_name(&net.name));
    let compute = net.compute_layers();
    let depth = compute.len().max(2);
    let mut layers = Vec::with_capacity(depth);
    for (d, l) in compute.iter().enumerate() {
        let fan_in = l.patch_k().max(1) as f64;
        // He-init folded weights: scale b ≈ sqrt(2/fan_in), with layer-
        // level diversity (the per-layer statistic diversity the paper
        // cites [14], [16]).
        let b = (2.0 / fan_in).sqrt() * (0.7 + 0.6 * rng.f64());
        // natural activation zero rate grows with depth: early layers
        // ~0.2–0.4, late layers ~0.5–0.7 (PASS's observation)
        let frac_depth = d as f64 / (depth - 1) as f64;
        let p0 = clampf(0.22 + 0.45 * frac_depth + 0.06 * rng.gauss(), 0.05, 0.85);
        let sigma = 0.5 + 0.5 * rng.f64();
        // per-channel imbalance: lognormal-ish multipliers, mean ≈ 1
        let n_ch = l.i_extent().min(64).max(1);
        let mut imb: Vec<f64> = (0..n_ch)
            .map(|_| (0.25 * rng.gauss()).exp())
            .collect();
        let mean: f64 = imb.iter().sum::<f64>() / imb.len() as f64;
        imb.iter_mut().for_each(|v| *v /= mean);
        layers.push(LayerProfile {
            name: l.name.clone(),
            weight_curve: TransferCurve::laplace(b, 21),
            act_curve: TransferCurve::rectified_gaussian(p0, sigma, 21),
            channel_imbalance: imb,
        });
    }
    NetworkSparsity { network: net.name.clone(), layers }
}

fn hash_name(s: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::util::prop::forall;

    #[test]
    fn laplace_curve_is_monotone_and_bounded() {
        let c = TransferCurve::laplace(0.1, 21);
        for w in c.frac.windows(2) {
            assert!(w[1] >= w[0]);
        }
        for w in c.taus.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!(c.sparsity_at(0.0) < 1e-9);
        assert!(c.sparsity_at(10.0) > 0.99);
    }

    #[test]
    fn laplace_curve_matches_closed_form() {
        let b = 0.2;
        let c = TransferCurve::laplace(b, 101);
        for &tau in &[0.05, 0.1, 0.3] {
            let want = 1.0 - (-tau / b).exp();
            let got = c.sparsity_at(tau);
            assert!((got - want).abs() < 0.01, "tau {tau}: {got} vs {want}");
        }
    }

    #[test]
    fn rectified_gaussian_has_zero_mass() {
        let c = TransferCurve::rectified_gaussian(0.4, 1.0, 21);
        assert!((c.frac_at_zero() - 0.4).abs() < 1e-9);
        assert!(c.sparsity_at(0.0001) >= 0.4);
    }

    #[test]
    fn tau_for_inverts_sparsity_at() {
        let c = TransferCurve::laplace(0.15, 21);
        forall(50, 0xA11CE, |rng| {
            let s = rng.range(0.05, 0.95);
            let tau = c.tau_for(s);
            let back = c.sparsity_at(tau);
            assert!((back - s).abs() < 0.02, "s={s} tau={tau} back={back}");
        });
    }

    #[test]
    fn from_quantiles_roundtrip() {
        // 21-pt quantile table of |v| ~ U(0, 1): quantile(r) = r
        let pts: Vec<f64> = (0..21).map(|i| i as f64 / 20.0).collect();
        let c = TransferCurve::from_quantiles(&pts, &pts);
        assert!((c.sparsity_at(0.5) - 0.5).abs() < 1e-9);
        assert!((c.tau_for(0.25) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn pair_density_independence() {
        let p = SparsityPoint { s_w: 0.5, s_a: 0.5 };
        assert!((p.pair_density() - 0.25).abs() < 1e-12);
        assert!((p.pair_sparsity() - 0.75).abs() < 1e-12);
        assert!((SparsityPoint::DENSE.pair_density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn synthesize_is_deterministic_per_seed_and_network() {
        let net = networks::resnet18();
        let a = synthesize(&net, 7);
        let b = synthesize(&net, 7);
        assert_eq!(a.layers.len(), b.layers.len());
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.weight_curve.taus, y.weight_curve.taus);
            assert_eq!(x.channel_imbalance, y.channel_imbalance);
        }
        let c = synthesize(&net, 8);
        assert_ne!(a.layers[0].weight_curve.taus, c.layers[0].weight_curve.taus);
    }

    #[test]
    fn synthesize_covers_all_compute_layers() {
        for name in networks::ALL_NETWORKS {
            let net = networks::by_name(name).unwrap();
            let prof = synthesize(&net, 1);
            assert_eq!(prof.layers.len(), net.compute_layers().len());
        }
    }

    #[test]
    fn deeper_layers_have_higher_natural_activation_sparsity() {
        let net = networks::resnet18();
        let prof = synthesize(&net, 3);
        let first = prof.layers[0].act_curve.frac_at_zero();
        let last = prof.layers.last().unwrap().act_curve.frac_at_zero();
        assert!(last > first, "depth trend violated: {first} -> {last}");
    }

    #[test]
    fn channel_imbalance_mean_is_one() {
        let net = networks::resnet50();
        let prof = synthesize(&net, 5);
        for l in &prof.layers {
            let m: f64 = l.channel_imbalance.iter().sum::<f64>()
                / l.channel_imbalance.len() as f64;
            assert!((m - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn points_shape_and_monotonicity() {
        let net = networks::calibnet();
        let prof = synthesize(&net, 11);
        let n = prof.layers.len();
        let lo = prof.points(&vec![0.0; n], &vec![0.0; n]);
        let hi = prof.points(&vec![1.0; n], &vec![1.0; n]);
        for (a, b) in lo.iter().zip(&hi) {
            assert!(b.s_w >= a.s_w);
            assert!(b.s_a >= a.s_a);
        }
    }
}
