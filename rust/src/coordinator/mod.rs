//! The HASS search loop (paper §V-B) — the system's L3 contribution.
//!
//! Each iteration: TPE proposes per-layer sparsity targets → thresholds
//! (τ_w, τ_a) via the transfer curves → the *evaluator* measures accuracy
//! and the reached sparsity operating points → the DSE prices the design
//! (throughput, DSPs) on the target geometry → the Eq. 6 objective
//!
//! ```text
//! max  f_acc + λ1·f_spa + λ2·f_thr − λ3·f_dsp
//! ```
//!
//! is fed back to TPE.  Two evaluator backends exist:
//!
//! * [`MeasuredEvaluator`] — executes the AOT CalibNet artifact through
//!   PJRT; accuracy and per-layer pair densities are *measured*, the
//!   paper's real co-design loop (Python never runs).
//! * [`SurrogateEvaluator`] — the DESIGN.md §1.1 substitution for target
//!   geometries we cannot execute (ResNet-18/50, MobileNet): synthesized
//!   transfer curves + a calibrated accuracy-response surrogate.
//!
//! `mode: SearchMode::SoftwareOnly` reproduces the Fig. 5 baseline: the
//! objective sees only accuracy + sparsity, hardware metrics are still
//! *recorded* (to plot efficiency) but do not guide the search.

use crate::arch::Network;
use crate::dse::{explore, DseConfig};
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::ResourceModel;
use crate::metrics::Table;
use crate::optim::tpe::{TpeConfig, TpeOptimizer};
use crate::pruning::{self, PruningPlan};
use crate::runtime::ModelRuntime;
use crate::sparsity::{NetworkSparsity, SparsityPoint};
use crate::util::clampf;

/// Accuracy + reached operating points for one pruning plan.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub accuracy: f64,
    pub points: Vec<SparsityPoint>,
}

/// Measurement backend of the search loop.
pub trait Evaluate {
    /// Sparsity model used to decode optimizer coordinates into thresholds.
    fn sparsity_model(&self) -> &NetworkSparsity;
    /// Evaluate a pruning plan: accuracy + per-layer operating points.
    fn eval(&self, plan: &PruningPlan) -> EvalPoint;
    /// Reference (unpruned) accuracy, for reporting drops.
    fn base_accuracy(&self) -> f64;
}

/// Analytic evaluator for target geometries (no executable model).
pub struct SurrogateEvaluator {
    pub net: Network,
    pub sparsity: NetworkSparsity,
    pub base_acc: f64,
}

impl Evaluate for SurrogateEvaluator {
    fn sparsity_model(&self) -> &NetworkSparsity {
        &self.sparsity
    }

    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        let points = plan.points(&self.sparsity);
        let natural = self.sparsity.natural_points();
        let accuracy =
            pruning::surrogate_accuracy(self.base_acc, &self.net, &points, &natural);
        EvalPoint { accuracy, points }
    }

    fn base_accuracy(&self) -> f64 {
        self.base_acc
    }
}

/// PJRT-backed evaluator: the real measured path over the AOT artifact.
pub struct MeasuredEvaluator {
    pub rt: ModelRuntime,
    sparsity: NetworkSparsity,
    /// calibration batches per evaluation (speed/precision trade-off)
    pub n_batches: usize,
}

impl MeasuredEvaluator {
    pub fn new(rt: ModelRuntime, n_batches: usize) -> Self {
        let sparsity = rt.meta.measured_sparsity();
        MeasuredEvaluator { rt, sparsity, n_batches }
    }
}

impl Evaluate for MeasuredEvaluator {
    fn sparsity_model(&self) -> &NetworkSparsity {
        &self.sparsity
    }

    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        let out = self
            .rt
            .evaluate(&plan.tau_w, &plan.tau_a, self.n_batches)
            .expect("PJRT evaluation failed");
        // fold the *measured* pair density into the operating point: keep
        // the measured S_w and derive the effective S_a that reproduces
        // the exact counter value under the independence formula the
        // hardware model uses
        let points = (0..plan.n_layers())
            .map(|i| {
                let s_w = clampf(out.s_w[i], 0.0, 0.999);
                let dens = clampf(out.pair_density[i], 0.0, 1.0);
                let s_a_eff = 1.0 - clampf(dens / (1.0 - s_w), 0.0, 1.0);
                SparsityPoint { s_w, s_a: s_a_eff }
            })
            .collect();
        EvalPoint { accuracy: out.accuracy * 100.0, points }
    }

    fn base_accuracy(&self) -> f64 {
        self.rt.meta.dense_val_accuracy * 100.0
    }
}

/// Which metrics the objective sees (Fig. 5's two curves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchMode {
    /// Eq. 6: accuracy + sparsity + throughput − DSPs (HASS)
    HardwareAware,
    /// accuracy + sparsity only (the traditional flow of Fig. 2a)
    SoftwareOnly,
}

/// Search hyper-parameters.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub iterations: usize,
    pub mode: SearchMode,
    pub seed: u64,
    /// λ1 (sparsity), λ2 (throughput), λ3 (DSP) of Eq. 6
    pub lambda: [f64; 3],
    /// anchor the optimizer with the dense and two mild uniform plans
    /// before random startup — one-shot pruning response surfaces are
    /// cliff-heavy, and without an anchor a short search may never sample
    /// the high-accuracy region at all
    pub warm_start: bool,
    pub tpe: TpeConfig,
    pub dse: DseConfig,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            iterations: 96, // the paper's Fig. 5 budget
            mode: SearchMode::HardwareAware,
            seed: 0,
            // normalization heuristics (paper §V-B): keep accuracy the
            // dominant term so the search tolerates <1-point drops only,
            // with hardware terms strong enough to steer among equals
            lambda: [0.10, 0.15, 0.10],
            warm_start: true,
            tpe: TpeConfig::default(),
            dse: DseConfig::default(),
        }
    }
}

/// One journal line of the search.
#[derive(Clone, Debug)]
pub struct SearchRecord {
    pub iter: usize,
    pub accuracy: f64,
    pub avg_sparsity: f64,
    pub op_density: f64,
    pub images_per_sec: f64,
    pub dsp: u64,
    /// images / cycle / DSP (the paper's efficiency metric)
    pub efficiency: f64,
    pub objective: f64,
    pub plan: PruningPlan,
}

/// Search output: full journal + index of the best Eq.6 iteration.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub records: Vec<SearchRecord>,
    pub best: usize,
    /// dense reference used for throughput normalization
    pub dense_images_per_sec: f64,
}

impl SearchResult {
    pub fn best_record(&self) -> &SearchRecord {
        &self.records[self.best]
    }

    /// Fig. 5's y-axis: the computation efficiency of the *incumbent* —
    /// the best design so far **by the search's own objective**.  (A
    /// running max of efficiency would credit the software-only search
    /// for efficient points it visits but would never select.)
    pub fn efficiency_trajectory(&self) -> Vec<f64> {
        let mut best_obj = f64::NEG_INFINITY;
        let mut best_eff = 0.0f64;
        self.records
            .iter()
            .map(|r| {
                if r.objective > best_obj {
                    best_obj = r.objective;
                    best_eff = r.efficiency;
                }
                best_eff
            })
            .collect()
    }

    /// Journal as a table (one row per iteration).
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&[
            "iter", "accuracy", "avg_sparsity", "op_density", "images_per_sec", "dsp",
            "images_per_cycle_per_dsp", "objective",
        ]);
        for r in &self.records {
            t.row(vec![
                r.iter.to_string(),
                format!("{:.3}", r.accuracy),
                format!("{:.4}", r.avg_sparsity),
                format!("{:.4}", r.op_density),
                format!("{:.1}", r.images_per_sec),
                r.dsp.to_string(),
                format!("{:.4e}", r.efficiency),
                format!("{:.4}", r.objective),
            ]);
        }
        t
    }
}

/// Run the HASS search: `evaluator` measures software metrics, the DSE
/// prices hardware on `target` (same compute-layer count) under `dev`.
pub fn search(
    evaluator: &dyn Evaluate,
    target: &Network,
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &SearchConfig,
) -> SearchResult {
    let n = evaluator.sparsity_model().layers.len();
    assert_eq!(
        n,
        target.compute_layers().len(),
        "evaluator and target geometry disagree on layer count"
    );
    // dense reference design for throughput normalization (f_thr scale)
    let dense = explore(target, &vec![SparsityPoint::DENSE; n], rm, dev, &cfg.dse);
    let dense_ips = dense.images_per_sec(dev).max(1e-9);
    let base_acc = evaluator.base_accuracy().max(1e-9);

    let mut tpe = TpeOptimizer::new(2 * n, cfg.seed, cfg.tpe.clone());
    let mut records = Vec::with_capacity(cfg.iterations);
    for iter in 0..cfg.iterations {
        let x = if cfg.warm_start && iter < 3 {
            // anchors: dense, mild, moderate uniform plans
            vec![[0.0, 0.15, 0.35][iter]; 2 * n]
        } else {
            tpe.ask()
        };
        let plan = PruningPlan::from_unit_point(&x, evaluator.sparsity_model());
        let ev = evaluator.eval(&plan);
        let m = pruning::metrics(target, &ev.points);
        let design = explore(target, &ev.points, rm, dev, &cfg.dse);
        let ips = design.images_per_sec(dev);

        let f_acc = ev.accuracy / base_acc; // ∈ [0, 1]
        let f_spa = m.avg_sparsity; // ∈ [0, 1)
        // saturating throughput gain: ∈ (0, 2), =1 at the dense reference.
        // An unbounded ratio would swamp the accuracy term on networks
        // where sparsity buys 10-20x (the λ "normalization" of Eq. 6).
        let raw = ips / dense_ips;
        let f_thr = 2.0 * raw / (1.0 + raw);
        let f_dsp = design.resources.dsp as f64 / dev.dsp.max(1) as f64;
        let objective = match cfg.mode {
            SearchMode::HardwareAware => {
                f_acc + cfg.lambda[0] * f_spa + cfg.lambda[1] * f_thr - cfg.lambda[2] * f_dsp
            }
            SearchMode::SoftwareOnly => f_acc + cfg.lambda[0] * f_spa,
        };
        records.push(SearchRecord {
            iter,
            accuracy: ev.accuracy,
            avg_sparsity: m.avg_sparsity,
            op_density: m.op_density,
            images_per_sec: ips,
            dsp: design.resources.dsp,
            efficiency: design.efficiency(),
            objective,
        plan});
        tpe.tell(x, objective);
    }
    let best = records
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.objective.total_cmp(&b.1.objective))
        .map(|(i, _)| i)
        .unwrap();
    SearchResult { records, best, dense_images_per_sec: dense_ips }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::sparsity::synthesize;

    fn quick_cfg(iters: usize, mode: SearchMode, seed: u64) -> SearchConfig {
        SearchConfig {
            iterations: iters,
            mode,
            seed,
            dse: DseConfig { max_iters: 1_500, ..Default::default() },
            ..Default::default()
        }
    }

    fn surrogate(seed: u64) -> SurrogateEvaluator {
        let net = networks::calibnet();
        let sparsity = synthesize(&net, seed);
        SurrogateEvaluator { net, sparsity, base_acc: 85.0 }
    }

    #[test]
    fn search_runs_and_journals_every_iteration() {
        let ev = surrogate(1);
        let net = ev.net.clone();
        let r = search(
            &ev,
            &net,
            &ResourceModel::default(),
            &DeviceBudget::u250(),
            &quick_cfg(12, SearchMode::HardwareAware, 7),
        );
        assert_eq!(r.records.len(), 12);
        assert!(r.best < 12);
        assert!(r.best_record().objective.is_finite());
    }

    #[test]
    fn hardware_aware_beats_software_only_on_efficiency() {
        // Fig. 5's claim, on the surrogate: HW-aware search reaches higher
        // computation efficiency than the accuracy/sparsity-only search
        let ev = surrogate(2);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        // budget-capped device so efficiency is the discriminator
        let dev = DeviceBudget { dsp: 1024, ..DeviceBudget::u250() };
        let hw = search(&ev, &net, &rm, &dev, &quick_cfg(40, SearchMode::HardwareAware, 3));
        let sw = search(&ev, &net, &rm, &dev, &quick_cfg(40, SearchMode::SoftwareOnly, 3));
        let hw_eff = hw.efficiency_trajectory().last().copied().unwrap();
        let sw_eff = sw.efficiency_trajectory().last().copied().unwrap();
        assert!(
            hw_eff >= sw_eff,
            "hardware-aware {hw_eff} < software-only {sw_eff}"
        );
    }

    #[test]
    fn efficiency_trajectory_tracks_incumbent() {
        let ev = surrogate(3);
        let net = ev.net.clone();
        let r = search(
            &ev,
            &net,
            &ResourceModel::default(),
            &DeviceBudget::u250(),
            &quick_cfg(10, SearchMode::HardwareAware, 5),
        );
        let tr = r.efficiency_trajectory();
        assert_eq!(tr.len(), 10);
        // the last trajectory value is the best-objective record's
        assert_eq!(tr[9], r.best_record().efficiency);
        // under the hardware-aware objective the incumbent's efficiency
        // is also the trajectory's end state for every prefix maximum
        let mut best_obj = f64::NEG_INFINITY;
        for (i, rec) in r.records.iter().enumerate() {
            if rec.objective > best_obj {
                best_obj = rec.objective;
                assert_eq!(tr[i], rec.efficiency);
            }
        }
    }

    #[test]
    fn search_is_deterministic_per_seed() {
        let ev = surrogate(4);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let a = search(&ev, &net, &rm, &dev, &quick_cfg(8, SearchMode::HardwareAware, 11));
        let b = search(&ev, &net, &rm, &dev, &quick_cfg(8, SearchMode::HardwareAware, 11));
        assert_eq!(a.best, b.best);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.objective.to_bits(), y.objective.to_bits());
        }
    }

    #[test]
    fn best_record_maximizes_objective() {
        let ev = surrogate(5);
        let net = ev.net.clone();
        let r = search(
            &ev,
            &net,
            &ResourceModel::default(),
            &DeviceBudget::u250(),
            &quick_cfg(15, SearchMode::HardwareAware, 13),
        );
        let best = r.best_record().objective;
        assert!(r.records.iter().all(|rec| rec.objective <= best));
    }

    #[test]
    fn journal_table_shape() {
        let ev = surrogate(6);
        let net = ev.net.clone();
        let r = search(
            &ev,
            &net,
            &ResourceModel::default(),
            &DeviceBudget::u250(),
            &quick_cfg(5, SearchMode::SoftwareOnly, 1),
        );
        let t = r.to_table();
        assert_eq!(t.rows.len(), 5);
        assert_eq!(t.headers.len(), 8);
        assert!(t.to_csv().lines().count() == 6);
    }

    #[test]
    fn surrogate_evaluator_contract() {
        let ev = surrogate(7);
        let n = ev.sparsity_model().layers.len();
        let dense = ev.eval(&PruningPlan::dense(n));
        assert!((dense.accuracy - ev.base_accuracy()).abs() < 6.0);
        let pruned = ev.eval(&PruningPlan::from_unit_point(
            &vec![0.8; 2 * n],
            ev.sparsity_model(),
        ));
        assert!(pruned.accuracy < dense.accuracy);
        assert!(pruned.points.iter().all(|p| p.s_w > 0.5));
    }
}
