//! The HASS search loop (paper §V-B) — evaluator backends + entry point.
//!
//! Each iteration: TPE proposes per-layer sparsity targets → thresholds
//! (τ_w, τ_a) via the transfer curves → the *evaluator* measures accuracy
//! and the reached sparsity operating points → the DSE prices the design
//! (throughput, DSPs) on the target geometry → the Eq. 6 objective
//!
//! ```text
//! max  f_acc + λ1·f_spa + λ2·f_thr − λ3·f_dsp
//! ```
//!
//! is fed back to TPE.  The loop itself lives in [`crate::engine`] — a
//! batched, parallel, cache-backed pipeline; [`search`] is the stable
//! serial-compatible entry point ([`SearchConfig::engine`] selects the
//! generation size / thread count / pricing cache).  This module keeps the
//! two production [`CandidateEvaluator`] backends:
//!
//! * [`MeasuredEvaluator`] — executes the AOT CalibNet artifact through
//!   PJRT; accuracy and per-layer pair densities are *measured*, the
//!   paper's real co-design loop (Python never runs).  Needs the `pjrt`
//!   build feature; without it the runtime loader errors out cleanly.
//! * [`SurrogateEvaluator`] — the DESIGN.md §1.1 substitution for target
//!   geometries we cannot execute (ResNet-18/50, MobileNet): synthesized
//!   transfer curves + a calibrated accuracy-response surrogate.
//!
//! Either backend can be wrapped in the re-exported
//! [`SimulatedEvaluator`] (the fidelity ladder, `hass search --evaluator
//! sim`): the swarm stays analytically priced, each generation's
//! analytic top-k per device is re-scored by the event-driven cycle
//! simulator.  See [`crate::engine::evaluator`].
//!
//! `mode: SearchMode::SoftwareOnly` reproduces the Fig. 5 baseline: the
//! objective sees only accuracy + sparsity, hardware metrics are still
//! *recorded* (to plot efficiency) but do not guide the search.

use std::sync::Mutex;

use crate::arch::Network;
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::ResourceModel;
use crate::pruning::{self, PruningPlan};
use crate::runtime::ModelRuntime;
use crate::sparsity::{NetworkSparsity, SparsityPoint};
use crate::util::clampf;

pub use crate::engine::{
    resume_fingerprint, CandidateEvaluator, Checkpoint, CheckpointSpec, DesignCache,
    DeviceSearchResult, Engine, EngineConfig, EngineStats, EvalCompletion, EvalError,
    EvalPoint, EvalRequest, ParetoPoint, RetryPolicy, SearchConfig, SearchControl,
    SearchMode, SearchProgress, SearchRecord, SearchResult, ShardedEngine,
    ShardedSearchResult, ShardedStats, SimScore, SimulatedEvaluator, SnapshotStats,
    INFEASIBLE_OBJECTIVE, TRANSIENT_PREFIX,
};
/// Historical name of [`CandidateEvaluator`], kept for downstream callers.
pub use crate::engine::CandidateEvaluator as Evaluate;

/// Analytic evaluator for target geometries (no executable model).
pub struct SurrogateEvaluator {
    pub net: Network,
    pub sparsity: NetworkSparsity,
    pub base_acc: f64,
}

impl CandidateEvaluator for SurrogateEvaluator {
    fn sparsity_model(&self) -> &NetworkSparsity {
        &self.sparsity
    }

    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        let points = plan.points(&self.sparsity);
        let natural = self.sparsity.natural_points();
        let accuracy =
            pruning::surrogate_accuracy(self.base_acc, &self.net, &points, &natural);
        EvalPoint { accuracy, points, sim: Vec::new() }
    }

    fn base_accuracy(&self) -> f64 {
        self.base_acc
    }
}

/// PJRT-backed evaluator: the real measured path over the AOT artifact.
///
/// The runtime lives behind a `Mutex` so the compiler — not a comment —
/// enforces that PJRT executions are serialized when the engine evaluates
/// a generation on several threads (the executable handle is a shared
/// C++ resource; see the `Send` rationale on the runtime itself).
///
/// # Serialization under the async pipeline
///
/// This internal mutex is exactly why `EngineConfig::async_eval` matters
/// for the measured path: under the sync two-phase generation loop the
/// engine's pricing threads idle while measurements drain one at a time
/// behind the lock.  `MeasuredEvaluator` keeps the *default*
/// [`CandidateEvaluator::eval_async`] — a serial loop that completes each
/// request the moment it finishes — which is already optimal here: the
/// mutex admits no measurement concurrency anyway, and streaming
/// completions lets the engine price candidate `i` on the DSE threads
/// while the runtime is still measuring candidate `i+1`.  A future
/// multi-client runtime pool would override `eval_async` to measure
/// concurrently and complete out of order; the engine's determinism
/// contract already covers that (completions are slot-addressed).
pub struct MeasuredEvaluator {
    rt: Mutex<ModelRuntime>,
    sparsity: NetworkSparsity,
    base_acc: f64,
    /// calibration batches per evaluation (speed/precision trade-off)
    pub n_batches: usize,
}

impl MeasuredEvaluator {
    pub fn new(rt: ModelRuntime, n_batches: usize) -> Self {
        let sparsity = rt.meta.measured_sparsity();
        let base_acc = rt.meta.dense_val_accuracy * 100.0;
        MeasuredEvaluator { rt: Mutex::new(rt), sparsity, base_acc, n_batches }
    }

    /// Hand the runtime back (e.g. to reuse it outside the search).
    pub fn into_runtime(self) -> ModelRuntime {
        self.rt.into_inner().unwrap_or_else(|p| p.into_inner())
    }
}

impl CandidateEvaluator for MeasuredEvaluator {
    fn sparsity_model(&self) -> &NetworkSparsity {
        &self.sparsity
    }

    /// Degraded sync path: a failed measurement folds to a zero-accuracy
    /// dense point.  The engine itself measures through
    /// [`try_eval`](CandidateEvaluator::try_eval), which carries the real
    /// error and scores the candidate [`INFEASIBLE_OBJECTIVE`] — this
    /// fallback only covers direct callers of `eval`.
    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        self.try_eval(plan).unwrap_or_else(|_| EvalPoint {
            accuracy: 0.0,
            points: vec![SparsityPoint::DENSE; plan.n_layers()],
            sim: Vec::new(),
        })
    }

    /// One PJRT failure must not abort a search (and, in a resident
    /// daemon, must not panic a worker holding shared striped locks): the
    /// error travels back through the completion queue and the engine
    /// scores the candidate infeasible while everything keeps running.
    /// The poison-tolerant lock recovers the runtime mutex even if some
    /// earlier holder panicked — the runtime holds no half-written state
    /// across `evaluate` calls.
    fn try_eval(&self, plan: &PruningPlan) -> Result<EvalPoint, EvalError> {
        let rt = self.rt.lock().unwrap_or_else(|p| p.into_inner());
        let out = rt
            .evaluate(&plan.tau_w, &plan.tau_a, self.n_batches)
            .map_err(|e| format!("PJRT evaluation failed: {e}"))?;
        // fold the *measured* pair density into the operating point: keep
        // the measured S_w and derive the effective S_a that reproduces
        // the exact counter value under the independence formula the
        // hardware model uses
        let points = (0..plan.n_layers())
            .map(|i| {
                let s_w = clampf(out.s_w[i], 0.0, 0.999);
                let dens = clampf(out.pair_density[i], 0.0, 1.0);
                let s_a_eff = 1.0 - clampf(dens / (1.0 - s_w), 0.0, 1.0);
                SparsityPoint { s_w, s_a: s_a_eff }
            })
            .collect();
        Ok(EvalPoint { accuracy: out.accuracy * 100.0, points, sim: Vec::new() })
    }

    fn base_accuracy(&self) -> f64 {
        self.base_acc
    }
}

/// Run the HASS search: `evaluator` measures software metrics, the DSE
/// prices hardware on `target` (same compute-layer count) under `dev`.
/// Thin wrapper over [`Engine::search`]; `cfg.engine` controls batching,
/// threading and the design cache (defaults reproduce the serial loop).
pub fn search(
    evaluator: &dyn Evaluate,
    target: &Network,
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &SearchConfig,
) -> SearchResult {
    Engine::new(evaluator, target, rm, dev).search(cfg)
}

/// Run the HASS search sharded over several device budgets at once: one
/// evaluator, one seed, N devices advancing in lockstep generations and
/// sharing one design cache.  Each device's journal is bit-identical to a
/// standalone [`search`] on that device; see [`crate::engine::shard`].
pub fn search_sharded(
    evaluator: &dyn Evaluate,
    target: &Network,
    rm: &ResourceModel,
    devices: &[DeviceBudget],
    cfg: &SearchConfig,
) -> ShardedSearchResult {
    ShardedEngine::new(evaluator, target, rm, devices).search(cfg)
}

/// [`search`] against a caller-owned design cache — possibly shared with
/// other searches, possibly warm from a [`DesignCache::load`]ed snapshot.
/// The cache never changes results; a warm cache only shifts the
/// hit/miss split in the returned stats (an exact repeat misses zero
/// times).  This is the entry point the `hass search --cache-file` flag
/// and the bench sweep drivers run on.
pub fn search_with_cache(
    evaluator: &dyn Evaluate,
    target: &Network,
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &SearchConfig,
    cache: &DesignCache,
) -> SearchResult {
    Engine::new(evaluator, target, rm, dev).search_with_cache(cfg, cache)
}

/// [`search_sharded`] against a caller-owned (possibly warm) shared
/// design cache; see [`search_with_cache`].
pub fn search_sharded_with_cache(
    evaluator: &dyn Evaluate,
    target: &Network,
    rm: &ResourceModel,
    devices: &[DeviceBudget],
    cfg: &SearchConfig,
    cache: &DesignCache,
) -> ShardedSearchResult {
    ShardedEngine::new(evaluator, target, rm, devices).search_with_cache(cfg, cache)
}

/// [`search_with_cache`] with a [`SearchControl`] (progress observer /
/// cancellation / checkpoint resume).  `None` means the observer
/// cancelled the search.
pub fn search_with_cache_ctrl(
    evaluator: &dyn Evaluate,
    target: &Network,
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &SearchConfig,
    cache: &DesignCache,
    ctrl: &SearchControl<'_>,
) -> Option<SearchResult> {
    Engine::new(evaluator, target, rm, dev).search_with_cache_ctrl(cfg, cache, ctrl)
}

/// [`search_sharded_with_cache`] with a [`SearchControl`]; see
/// [`search_with_cache_ctrl`].
pub fn search_sharded_with_cache_ctrl(
    evaluator: &dyn Evaluate,
    target: &Network,
    rm: &ResourceModel,
    devices: &[DeviceBudget],
    cfg: &SearchConfig,
    cache: &DesignCache,
    ctrl: &SearchControl<'_>,
) -> Option<ShardedSearchResult> {
    ShardedEngine::new(evaluator, target, rm, devices)
        .search_with_cache_ctrl(cfg, cache, ctrl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::dse::DseConfig;
    use crate::sparsity::synthesize;

    fn quick_cfg(iters: usize, mode: SearchMode, seed: u64) -> SearchConfig {
        SearchConfig {
            iterations: iters,
            mode,
            seed,
            dse: DseConfig { max_iters: 1_500, ..Default::default() },
            ..Default::default()
        }
    }

    fn surrogate(seed: u64) -> SurrogateEvaluator {
        let net = networks::calibnet();
        let sparsity = synthesize(&net, seed);
        SurrogateEvaluator { net, sparsity, base_acc: 85.0 }
    }

    #[test]
    fn search_runs_and_journals_every_iteration() {
        let ev = surrogate(1);
        let net = ev.net.clone();
        let r = search(
            &ev,
            &net,
            &ResourceModel::default(),
            &DeviceBudget::u250(),
            &quick_cfg(12, SearchMode::HardwareAware, 7),
        );
        assert_eq!(r.records.len(), 12);
        assert!(r.best < 12);
        assert!(r.best_record().objective.is_finite());
    }

    #[test]
    fn hardware_aware_beats_software_only_on_efficiency() {
        // Fig. 5's claim, on the surrogate: HW-aware search reaches higher
        // computation efficiency than the accuracy/sparsity-only search
        let ev = surrogate(2);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        // budget-capped device so efficiency is the discriminator
        let dev = DeviceBudget { dsp: 1024, ..DeviceBudget::u250() };
        let hw = search(&ev, &net, &rm, &dev, &quick_cfg(40, SearchMode::HardwareAware, 3));
        let sw = search(&ev, &net, &rm, &dev, &quick_cfg(40, SearchMode::SoftwareOnly, 3));
        let hw_eff = hw.efficiency_trajectory().last().copied().unwrap_or(0.0);
        let sw_eff = sw.efficiency_trajectory().last().copied().unwrap_or(0.0);
        assert!(
            hw_eff >= sw_eff,
            "hardware-aware {hw_eff} < software-only {sw_eff}"
        );
    }

    #[test]
    fn efficiency_trajectory_tracks_incumbent() {
        let ev = surrogate(3);
        let net = ev.net.clone();
        let r = search(
            &ev,
            &net,
            &ResourceModel::default(),
            &DeviceBudget::u250(),
            &quick_cfg(10, SearchMode::HardwareAware, 5),
        );
        let tr = r.efficiency_trajectory();
        assert_eq!(tr.len(), 10);
        // the last trajectory value is the best-objective record's
        assert_eq!(tr[9], r.best_record().efficiency);
        // under the hardware-aware objective the incumbent's efficiency
        // is also the trajectory's end state for every prefix maximum
        let mut best_obj = f64::NEG_INFINITY;
        for (i, rec) in r.records.iter().enumerate() {
            if rec.objective > best_obj {
                best_obj = rec.objective;
                assert_eq!(tr[i], rec.efficiency);
            }
        }
    }

    #[test]
    fn search_is_deterministic_per_seed() {
        let ev = surrogate(4);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let a = search(&ev, &net, &rm, &dev, &quick_cfg(8, SearchMode::HardwareAware, 11));
        let b = search(&ev, &net, &rm, &dev, &quick_cfg(8, SearchMode::HardwareAware, 11));
        assert_eq!(a.best, b.best);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.objective.to_bits(), y.objective.to_bits());
        }
    }

    #[test]
    fn best_record_maximizes_objective() {
        let ev = surrogate(5);
        let net = ev.net.clone();
        let r = search(
            &ev,
            &net,
            &ResourceModel::default(),
            &DeviceBudget::u250(),
            &quick_cfg(15, SearchMode::HardwareAware, 13),
        );
        let best = r.best_record().objective;
        assert!(r.records.iter().all(|rec| rec.objective <= best));
    }

    #[test]
    fn journal_table_shape() {
        let ev = surrogate(6);
        let net = ev.net.clone();
        let r = search(
            &ev,
            &net,
            &ResourceModel::default(),
            &DeviceBudget::u250(),
            &quick_cfg(5, SearchMode::SoftwareOnly, 1),
        );
        let t = r.to_table();
        assert_eq!(t.rows.len(), 5);
        assert_eq!(t.headers.len(), 8);
        assert!(t.to_csv().lines().count() == 6);
    }

    #[test]
    fn surrogate_evaluator_contract() {
        let ev = surrogate(7);
        let n = ev.sparsity_model().layers.len();
        let dense = ev.eval(&PruningPlan::dense(n));
        assert!((dense.accuracy - ev.base_accuracy()).abs() < 6.0);
        let pruned = ev.eval(&PruningPlan::from_unit_point(
            &vec![0.8; 2 * n],
            ev.sparsity_model(),
        ));
        assert!(pruned.accuracy < dense.accuracy);
        assert!(pruned.points.iter().all(|p| p.s_w > 0.5));
    }

    #[test]
    fn wrapper_and_engine_agree() {
        // coordinator::search is a thin shim over Engine::search — same
        // config, same evaluator, bit-identical journal
        let ev = surrogate(8);
        let net = ev.net.clone();
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let cfg = quick_cfg(6, SearchMode::HardwareAware, 17);
        let a = search(&ev, &net, &rm, &dev, &cfg);
        let b = Engine::new(&ev, &net, &rm, &dev).search(&cfg);
        assert_eq!(a.best, b.best);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.objective.to_bits(), y.objective.to_bits());
        }
    }
}
