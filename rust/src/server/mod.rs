//! `hass serve` — a resident search daemon over the warm pricing caches.
//!
//! The cache snapshots of `engine::cache` die with the process: every
//! CLI run pays startup plus cache load before its first pricing.  This
//! module keeps the expensive artifact — the shared [`DesignCache`] with
//! its [`FrontierStore`](crate::engine::FrontierStore) of prebuilt
//! per-layer Pareto frontiers — alive in one long-lived process, and
//! multiplexes many clients' searches onto the existing engine thread
//! pool.  One warm process, thousands of searches: the "millions of
//! users" serving shape of the ROADMAP.
//!
//! # Protocol
//!
//! Newline-delimited JSON over TCP ([`protocol`]).  Each request line is
//! `{"id": <any>, "method": "<name>", "params": {...}}`; the daemon
//! answers with zero or more *event* lines (`{"id", "event", ...}`)
//! followed by exactly one terminal line — `{"id", "result": {...}}` or
//! `{"id", "error": "..."}`.  `id` is echoed verbatim.  A malformed line
//! gets `{"id": null, "error": "..."}` and the connection stays open.
//!
//! | method       | params                                                           | result |
//! |--------------|------------------------------------------------------------------|--------|
//! | `search`     | `network`, `device` \| `devices` (csv), `iters`, `seed`, `mode` (`hw`\|`sw`), `batch`, `threads`, `quant`, `async`, `cache`, `retries`, `eval_timeout`, `deadline`, `checkpoint`, `checkpoint_every`, `pipeline_depth` (cross-generation lookahead, 0 = drained), `resume` (host-side checkpoint path to continue from) | per-device `{device, journal_csv, cache_hits, cache_misses, best_*}` + run stats; streams `queued`/`started`/`generation` events |
//! | `price`      | `network`, `device`, `sw`, `sa`, `quant`                         | `{images_per_sec, dsp, efficiency, cached}` via the shared cache |
//! | `stats`      | —                                                                | cache sizes + admission/search counters, incl. cumulative fault-tolerance (`retried_evals`, `reclaimed_stalls`) and pipeline (`pipelined_generations`, `lookahead_proposals`) totals |
//! | `save-cache` | `path`                                                           | `{designs, frontiers}` snapshot written |
//! | `shutdown`   | —                                                                | `{ok: true}`, then the daemon drains and exits |
//!
//! A `search` carrying `resume` validates the checkpoint *before*
//! admission: a missing file or fingerprint mismatch is an ordinary
//! JSON-RPC error line (the daemon keeps serving), never a process
//! exit — the daemon-side twin of `hass search --resume`'s loud
//! validation.
//!
//! # Fair admission
//!
//! Concurrent `search` requests are bounded by
//! [`ServeConfig::max_inflight`]; beyond that, requests queue FIFO (a
//! ticket semaphore — no barging), with a `queued` event telling the
//! client it is waiting.  `price`/`stats`/`save-cache` never queue.
//!
//! # Determinism
//!
//! A daemon search runs the exact same entry path as the CLI
//! ([`ShardedEngine::search_with_cache_ctrl`] over the same evaluator
//! construction), and the shared cache never changes results — so the
//! `journal_csv` streamed back is **bit-identical** to the same `hass
//! search` run, cold or warm, however many clients are connected
//! (enforced in `tests/serve.rs` and the CI serve-smoke job).
//!
//! # Crash containment
//!
//! A resident process cannot tolerate the one-shot CLI's panic-on-error
//! paths: evaluator failures travel through error-carrying
//! [`EvalCompletion`](crate::engine::EvalCompletion)s and score
//! infeasible, client disconnects cancel the search between generations
//! ([`SearchControl`]) and free the admission slot, every residual panic
//! is caught at the request boundary, and the striped cache locks
//! recover from poisoning (`util::memo`) — one bad request never takes
//! the daemon or its warm caches down.
//!
//! # Fault tolerance
//!
//! The engine's fault-tolerance layer (see [`crate::engine`]) is fully
//! reachable through the daemon: a `search` request may carry `retries`
//! (transient-failure retry budget), `eval_timeout` / `deadline` (async
//! stall watchdog, ms) and `checkpoint` / `checkpoint_every` (a
//! host-side path the engine snapshots the run to between generations).
//! Because a cancelled search — client gone, or daemon shutdown kicking
//! the connection — also writes its checkpoint before unwinding, an
//! interrupted daemon search can be continued with `hass search
//! --resume` *or* by a later `search` request carrying `resume`, and
//! journals bit-identically to an uninterrupted run.
//! Deterministic chaos tests drive the daemon through the
//! `server.conn.drop` and `server.search.panic` injection sites
//! ([`crate::util::fault`]): a dropped connection or a panicking search
//! must cost exactly one request, with the resident caches still warm
//! and serving.

pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

use crate::arch::networks;
use crate::coordinator::SurrogateEvaluator;
use crate::dse::frontier::shape_fingerprint;
use crate::engine::{
    quantize_points, resume_fingerprint, Checkpoint, CheckpointSpec, DesignCache,
    EngineConfig, RetryPolicy, SearchConfig, SearchControl, SearchMode, ShardedEngine,
};
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::ResourceModel;
use crate::sparsity::{synthesize, SparsityPoint};
use crate::util::fault;
use crate::util::json::Json;

use protocol::{error_line, event_line, parse_request, result_line, Request};

/// Daemon configuration (the listener itself is passed to [`Server::run`]).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// searches allowed in flight at once; further requests queue FIFO
    pub max_inflight: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_inflight: 2 }
    }
}

// Mutex recovery for daemon state: every lock below guards data with no
// cross-field invariant a panicking holder could corrupt, and the daemon
// must keep serving after any worker panic — see `util::lock_clean`.
use crate::util::lock_clean;

/// FIFO ticket semaphore: at most `max` holders, strictly
/// first-come-first-served beyond that (no barging — a late small
/// request cannot overtake an early one).
struct Admission {
    max: usize,
    state: Mutex<AdmState>,
    cv: Condvar,
}

struct AdmState {
    /// slots currently held
    active: usize,
    /// next ticket to hand out
    next: u64,
    /// lowest ticket not yet admitted
    serving: u64,
    /// set on shutdown: all waiters are released with `false`
    closed: bool,
}

impl Admission {
    fn new(max: usize) -> Self {
        Admission {
            max: max.max(1),
            state: Mutex::new(AdmState { active: 0, next: 0, serving: 0, closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Draw a ticket; the second return is `true` if the caller will have
    /// to wait (so it can tell its client *before* blocking in [`wait`]).
    fn ticket(&self) -> (u64, bool) {
        let mut st = lock_clean(&self.state);
        let t = st.next;
        st.next += 1;
        let waits = st.closed || !(st.serving == t && st.active < self.max);
        (t, waits)
    }

    /// Block until ticket `t` is admitted (FIFO).  Returns `false` if the
    /// daemon shut down instead — the caller must not run its search.
    fn wait(&self, t: u64) -> bool {
        let mut st = lock_clean(&self.state);
        loop {
            if st.closed {
                // the ticket is consumed either way, or serving stalls
                st.serving = st.serving.max(t + 1);
                self.cv.notify_all();
                return false;
            }
            if st.serving == t && st.active < self.max {
                st.serving += 1;
                st.active += 1;
                return true;
            }
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Release a held slot.
    fn release(&self) {
        let mut st = lock_clean(&self.state);
        st.active = st.active.saturating_sub(1);
        self.cv.notify_all();
    }

    /// Release every waiter with `false`; taken slots drain naturally.
    fn close(&self) {
        lock_clean(&self.state).closed = true;
        self.cv.notify_all();
    }

    fn active(&self) -> usize {
        lock_clean(&self.state).active
    }

    /// Tickets drawn but not yet admitted.
    fn queued(&self) -> u64 {
        let st = lock_clean(&self.state);
        st.next - st.serving
    }
}

/// Releases an admission slot on every exit path of a search request.
struct SlotGuard<'a>(&'a Admission);

impl Drop for SlotGuard<'_> {
    fn drop(&mut self) {
        self.0.release();
    }
}

/// The resident search daemon: warm shared caches + fair admission.
/// Construct once, then [`run`](Server::run) on a bound listener.
pub struct Server {
    cache: DesignCache,
    admission: Admission,
    /// control atomic (gates the accept loop): Release store in
    /// [`begin_shutdown`](Self::begin_shutdown), Acquire load in
    /// [`run`](Self::run) — never Relaxed, so everything written before
    /// the flag flip is visible to the loop that observes it
    shutdown: AtomicBool,
    addr: OnceLock<SocketAddr>,
    /// live connections by id, so shutdown can unblock idle readers
    conns: Mutex<Vec<(u64, TcpStream)>>,
    next_conn: AtomicU64,
    completed_searches: AtomicU64,
    // cumulative run-stat totals over every completed search, surfaced by
    // `stats` so operators see fault-tolerance and pipeline activity
    // without scraping per-search results
    retried_evals: AtomicU64,
    reclaimed_stalls: AtomicU64,
    pipelined_generations: AtomicU64,
    lookahead_proposals: AtomicU64,
    rm: ResourceModel,
}

impl Server {
    /// A daemon over `cache` (possibly warm from a snapshot).
    pub fn new(cache: DesignCache, cfg: ServeConfig) -> Self {
        Server {
            cache,
            admission: Admission::new(cfg.max_inflight),
            shutdown: AtomicBool::new(false),
            addr: OnceLock::new(),
            conns: Mutex::new(Vec::new()),
            next_conn: AtomicU64::new(0),
            completed_searches: AtomicU64::new(0),
            retried_evals: AtomicU64::new(0),
            reclaimed_stalls: AtomicU64::new(0),
            pipelined_generations: AtomicU64::new(0),
            lookahead_proposals: AtomicU64::new(0),
            rm: ResourceModel::default(),
        }
    }

    /// The warm shared cache (e.g. to snapshot it after [`run`] returns).
    pub fn cache(&self) -> &DesignCache {
        &self.cache
    }

    /// Accept connections until a `shutdown` request arrives.  Each
    /// connection gets its own handler thread; all handlers are drained
    /// before this returns (in-flight searches are cancelled between
    /// generations by the connection teardown).
    pub fn run(&self, listener: TcpListener) -> std::io::Result<()> {
        let addr = listener.local_addr()?;
        let _ = self.addr.set(addr);
        std::thread::scope(|sc| {
            for conn in listener.incoming() {
                if self.shutdown.load(Ordering::Acquire) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                // uniqueness comes from the atomic RMW itself, nothing
                // else is published under the returned id, so this is
                // relaxed: id allocation, not control flow
                let id = self.next_conn.fetch_add(1, Ordering::Relaxed);
                if let Ok(clone) = stream.try_clone() {
                    lock_clean(&self.conns).push((id, clone));
                }
                sc.spawn(move || {
                    self.handle_conn(stream);
                    lock_clean(&self.conns).retain(|(cid, _)| *cid != id);
                });
            }
            // teardown: kick every live connection so idle readers see
            // EOF, in-flight observers fail their next write (cancelling
            // their searches), and the scope can join all handlers
            for (_, c) in lock_clean(&self.conns).drain(..) {
                let _ = c.shutdown(Shutdown::Both);
            }
        });
        Ok(())
    }

    /// One connection: a line loop over sequential requests.  Never
    /// panics on client input; a malformed line is answered and the
    /// connection survives it.
    fn handle_conn(&self, stream: TcpStream) {
        // chaos site: a connection dropped before the first byte (network
        // blip, proxy reset).  Must cost exactly one request — the client
        // reconnects with backoff, the daemon keeps serving.
        if fault::fire("server.conn.drop") {
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
        let Ok(read_half) = stream.try_clone() else { return };
        let writer = Mutex::new(stream);
        let reader = BufReader::new(read_half);
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            let (resp, is_shutdown) = match parse_request(&line) {
                Err(e) => (error_line(&Json::Null, &e), false),
                Ok(req) => {
                    let id = req.id.clone();
                    if req.method == "shutdown" {
                        let ok = Json::obj(vec![("ok", Json::Bool(true))]);
                        (result_line(&id, ok), true)
                    } else {
                        let resp = match self.dispatch(&req, &writer) {
                            Ok(result) => result_line(&id, result),
                            Err(e) => error_line(&id, &e),
                        };
                        (resp, false)
                    }
                }
            };
            if write_line(&writer, &resp).is_err() {
                break;
            }
            if is_shutdown {
                self.begin_shutdown();
                break;
            }
        }
    }

    /// Route one request.  Every failure is an `Err` string — the
    /// request path contains no unwrap/expect on client-controlled data.
    fn dispatch(&self, req: &Request, writer: &Mutex<TcpStream>) -> Result<Json, String> {
        match req.method.as_str() {
            "search" => self.do_search(&req.id, &req.params, writer),
            "price" => self.do_price(&req.params),
            "stats" => Ok(self.do_stats()),
            "save-cache" => self.do_save_cache(&req.params),
            m => Err(format!(
                "unknown method '{m}' (search | price | stats | save-cache | shutdown)"
            )),
        }
    }

    /// Flip the shutdown flag and wake the accept loop with a one-shot
    /// self-connection (accept has no timeout; this is the portable way
    /// to unblock it without polling).
    fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.admission.close();
        if let Some(addr) = self.addr.get() {
            let _ = TcpStream::connect(addr);
        }
    }

    /// `search`: admission-gated, progress-streamed, cancellable.
    fn do_search(
        &self,
        id: &Json,
        params: &Json,
        writer: &Mutex<TcpStream>,
    ) -> Result<Json, String> {
        let network = str_param(params, "network", "calibnet")?;
        let net = networks::by_name(&network)
            .ok_or_else(|| format!("unknown network '{network}'"))?;
        let devices_spec = str_param(params, "devices", "")?;
        let devices: Vec<DeviceBudget> = if devices_spec.is_empty() {
            let d = str_param(params, "device", "u250")?;
            vec![DeviceBudget::by_name(&d).ok_or_else(|| format!("unknown device '{d}'"))?]
        } else {
            DeviceBudget::parse_list(&devices_spec)?
        };
        let evaluator = str_param(params, "evaluator", "surrogate")?;
        if evaluator != "surrogate" && evaluator != "auto" {
            return Err(format!(
                "daemon searches run the surrogate evaluator (got '{evaluator}')"
            ));
        }
        let mode = match str_param(params, "mode", "hw")?.as_str() {
            "sw" => SearchMode::SoftwareOnly,
            _ => SearchMode::HardwareAware,
        };
        let engine = EngineConfig {
            batch: usize_param(params, "batch", 1)?.max(1),
            threads: usize_param(params, "threads", 0)?,
            cache: bool_param(params, "cache", true)?,
            quant_bits: usize_param(params, "quant", 0)? as u32,
            async_eval: bool_param(params, "async", false)?,
        };
        let ckpt_path = str_param(params, "checkpoint", "")?;
        let ckpt_every = usize_param(params, "checkpoint_every", 1)?.max(1);
        let cfg = SearchConfig {
            iterations: usize_param(params, "iters", 96)?,
            seed: u64_param(params, "seed", 0)?,
            mode,
            engine,
            retry: RetryPolicy {
                max_retries: usize_param(params, "retries", 3)? as u32,
                ..Default::default()
            },
            eval_timeout_ms: u64_param(params, "eval_timeout", 0)?,
            deadline_ms: u64_param(params, "deadline", 0)?,
            checkpoint: (!ckpt_path.is_empty()).then(|| CheckpointSpec {
                path: ckpt_path.clone(),
                every: ckpt_every,
            }),
            pipeline_depth: usize_param(params, "pipeline_depth", 0)?,
            ..Default::default()
        };
        // daemon-side resume: validate before taking an admission slot —
        // a bad checkpoint is this request's error, not a dead daemon
        // (the CLI's exit-2 path, rephrased as a JSON-RPC error)
        let resume_path = str_param(params, "resume", "")?;
        let resume_ck = if resume_path.is_empty() {
            None
        } else {
            let ck = Checkpoint::load(&resume_path)
                .map_err(|e| format!("failed to load checkpoint '{resume_path}': {e}"))?;
            let fp = resume_fingerprint(&cfg, &net, &devices);
            if ck.fingerprint != fp {
                return Err(format!(
                    "checkpoint '{resume_path}' was written by a different search \
                     (fingerprint {:016x}, this request is {fp:016x}); refusing to \
                     resume — resend the original network/devices/seed/params",
                    ck.fingerprint
                ));
            }
            if ck.done > cfg.iterations {
                return Err(format!(
                    "checkpoint '{resume_path}' already covers {} iterations but this \
                     request asks for only {}; refusing to resume",
                    ck.done, cfg.iterations
                ));
            }
            Some(ck)
        };
        // the exact evaluator construction of the CLI surrogate path —
        // this is what makes daemon journals bit-identical to `hass
        // search` runs with the same flags
        let ev = SurrogateEvaluator {
            sparsity: synthesize(&net, cfg.seed),
            net: net.clone(),
            base_acc: 76.0,
        };

        // fair admission: bounded in-flight searches, FIFO beyond that
        let (ticket, waits) = self.admission.ticket();
        if waits
            && write_line(
                writer,
                &event_line(id, "queued", vec![("queued", Json::Num(1.0))]),
            )
            .is_err()
        {
            // the client is already gone; give the ticket back via wait
            // (it still has to be consumed to keep the FIFO moving)
        }
        if !self.admission.wait(ticket) {
            return Err("server is shutting down".to_string());
        }
        let _slot = SlotGuard(&self.admission);
        let _ = write_line(writer, &event_line(id, "started", vec![]));

        // stream per-generation progress; a failed write means the client
        // disconnected → return false → the search cancels between
        // generations and the admission slot frees for the next client
        let observer = |p: crate::engine::SearchProgress| -> bool {
            write_line(
                writer,
                &event_line(
                    id,
                    "generation",
                    vec![
                        ("generation", Json::Num(p.generation as f64)),
                        ("done", Json::Num(p.done as f64)),
                        ("total", Json::Num(p.total as f64)),
                    ],
                ),
            )
            .is_ok()
        };
        let ctrl = SearchControl {
            observer: Some(&observer),
            resume: resume_ck.as_ref(),
        };
        let eng = ShardedEngine::new(&ev, &net, &self.rm, &devices);
        // defense in depth: the satellite fixes make the search itself
        // panic-free on evaluator failure, and the striped caches recover
        // from poisoning — but a residual panic must still cost only this
        // request, never the daemon
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            // chaos site: a panic from inside the search worker — the
            // catch_unwind boundary must contain it with caches intact
            if fault::fire("server.search.panic") {
                // deliberate chaos-injection panic, absorbed by the
                // catch_unwind wrapping this closure (tests/chaos.rs)
                // lint: allow(panic-safety)
                panic!("injected panic at site 'server.search.panic'");
            }
            eng.search_with_cache_ctrl(&cfg, &self.cache, &ctrl)
        }));
        let result = match outcome {
            Err(_) => return Err("search panicked; request aborted, caches intact".into()),
            Ok(None) => return Err("search cancelled (client stopped reading)".into()),
            Ok(Some(r)) => r,
        };
        // Atomics classification (the lint's atomics-relaxed rule): the
        // counters below are pure monotonic stats — read only by the
        // `stats` RPC for reporting, never to gate control flow — so
        // Relaxed is correct (totals stay exact because fetch_add is an
        // atomic RMW).  The daemon's control atomic is `shutdown`, which
        // uses Release stores / Acquire loads (`begin_shutdown`/`run`).
        self.completed_searches.fetch_add(1, Ordering::Relaxed); // relaxed: stats
        let s = &result.stats;
        self.retried_evals.fetch_add(s.retried_evals, Ordering::Relaxed); // relaxed: stats
        self.reclaimed_stalls.fetch_add(s.reclaimed_stalls, Ordering::Relaxed); // relaxed: stats
        self.pipelined_generations
            .fetch_add(s.pipelined_generations as u64, Ordering::Relaxed); // relaxed: stats
        // relaxed: stats
        self.lookahead_proposals.fetch_add(s.lookahead_proposals, Ordering::Relaxed);

        let devices_json: Vec<Json> = result
            .per_device
            .iter()
            .map(|d| {
                let s = &d.result.stats;
                let mut pairs = vec![
                    ("device", Json::Str(d.device.clone())),
                    ("journal_csv", Json::Str(d.result.to_table().to_csv())),
                    ("cache_hits", Json::Num(s.cache_hits as f64)),
                    ("cache_misses", Json::Num(s.cache_misses as f64)),
                ];
                if let Some(b) = d.result.try_best_record() {
                    pairs.push(("best_iter", Json::Num(b.iter as f64)));
                    pairs.push(("best_accuracy", Json::Num(b.accuracy)));
                    pairs.push(("best_images_per_sec", Json::Num(b.images_per_sec)));
                    pairs.push(("best_objective", Json::Num(b.objective)));
                }
                Json::obj(pairs)
            })
            .collect();
        Ok(Json::obj(vec![
            ("devices", Json::Arr(devices_json)),
            ("generations", Json::Num(result.stats.generations as f64)),
            ("evaluations", Json::Num(result.stats.evaluations as f64)),
        ]))
    }

    /// `price`: one design pricing through the shared cache + frontier
    /// store — the cheap resident-cache query path (no admission gate).
    fn do_price(&self, params: &Json) -> Result<Json, String> {
        let network = str_param(params, "network", "calibnet")?;
        let net = networks::by_name(&network)
            .ok_or_else(|| format!("unknown network '{network}'"))?;
        let d = str_param(params, "device", "u250")?;
        let dev =
            DeviceBudget::by_name(&d).ok_or_else(|| format!("unknown device '{d}'"))?;
        let s_w = f64_param(params, "sw", 0.5)?;
        let s_a = f64_param(params, "sa", 0.5)?;
        for (name, s) in [("sw", s_w), ("sa", s_a)] {
            if !(0.0..1.0).contains(&s) {
                return Err(format!("param '{name}' must be in [0, 1), got {s}"));
            }
        }
        let quant = usize_param(params, "quant", 12)? as u32;
        let dse = crate::dse::DseConfig::default();
        let n = net.compute_layers().len();
        let pts = quantize_points(&vec![SparsityPoint { s_w, s_a }; n], quant);
        let shapes: Vec<u64> =
            net.compute_layers().iter().map(|l| shape_fingerprint(l)).collect();
        let handle = self.cache.register(&dev, &net, &self.rm, &dse);
        let cached = self.cache.get(&handle, &pts).is_some();
        let design = self.cache.get_or_compute(&handle, &pts, || {
            self.cache
                .explore_via_frontiers(&handle, &net, &pts, &shapes, &self.rm, &dev, &dse)
        });
        Ok(Json::obj(vec![
            ("images_per_sec", Json::Num(design.images_per_sec(&dev))),
            ("dsp", Json::Num(design.resources.dsp as f64)),
            ("efficiency", Json::Num(design.efficiency())),
            ("cached", Json::Bool(cached)),
        ]))
    }

    fn do_stats(&self) -> Json {
        Json::obj(vec![
            ("designs", Json::Num(self.cache.len() as f64)),
            ("frontiers", Json::Num(self.cache.frontier_store().len() as f64)),
            ("active_searches", Json::Num(self.admission.active() as f64)),
            ("queued_searches", Json::Num(self.admission.queued() as f64)),
            (
                "completed_searches",
                // relaxed: stats counter read for reporting only
                Json::Num(self.completed_searches.load(Ordering::Relaxed) as f64),
            ),
            ("max_inflight", Json::Num(self.admission.max as f64)),
            (
                "retried_evals",
                // relaxed: stats counter read for reporting only
                Json::Num(self.retried_evals.load(Ordering::Relaxed) as f64),
            ),
            (
                "reclaimed_stalls",
                // relaxed: stats counter read for reporting only
                Json::Num(self.reclaimed_stalls.load(Ordering::Relaxed) as f64),
            ),
            (
                "pipelined_generations",
                // relaxed: stats counter read for reporting only
                Json::Num(self.pipelined_generations.load(Ordering::Relaxed) as f64),
            ),
            (
                "lookahead_proposals",
                // relaxed: stats counter read for reporting only
                Json::Num(self.lookahead_proposals.load(Ordering::Relaxed) as f64),
            ),
        ])
    }

    /// `save-cache`: snapshot the warm stores without stopping the daemon.
    fn do_save_cache(&self, params: &Json) -> Result<Json, String> {
        let path = str_param(params, "path", "")?;
        if path.is_empty() {
            return Err("save-cache needs a non-empty 'path' param".to_string());
        }
        let st = self
            .cache
            .save(&path)
            .map_err(|e| format!("failed to write cache snapshot '{path}': {e}"))?;
        Ok(Json::obj(vec![
            ("designs", Json::Num(st.designs as f64)),
            ("frontiers", Json::Num(st.frontiers as f64)),
        ]))
    }
}

/// One response line (single `write_all`, `\n`-terminated).  Only the
/// owning handler thread writes to a connection, but the observer closure
/// needs `Sync` access — hence the mutex.
fn write_line(writer: &Mutex<TcpStream>, line: &str) -> std::io::Result<()> {
    let mut buf = String::with_capacity(line.len() + 1);
    buf.push_str(line);
    buf.push('\n');
    let mut w = lock_clean(writer);
    w.write_all(buf.as_bytes())
}

// ------------------------------------------------------ param accessors
//
// All tolerate an absent key (default) and reject a wrong-typed or
// malformed value with an error naming the key — mirroring the graceful
// `util::cli` getters, and just as unwrap-free.

fn str_param(params: &Json, key: &str, default: &str) -> Result<String, String> {
    match params.get(key) {
        None | Some(Json::Null) => Ok(default.to_string()),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| format!("param '{key}' must be a string")),
    }
}

fn f64_param(params: &Json, key: &str, default: f64) -> Result<f64, String> {
    match params.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v
            .as_f64()
            .filter(|f| f.is_finite())
            .ok_or_else(|| format!("param '{key}' must be a finite number")),
    }
}

fn usize_param(params: &Json, key: &str, default: usize) -> Result<usize, String> {
    let f = f64_param(params, key, default as f64)?;
    if f < 0.0 || f.fract() != 0.0 || f > u32::MAX as f64 {
        return Err(format!("param '{key}' must be a non-negative integer"));
    }
    Ok(f as usize)
}

fn u64_param(params: &Json, key: &str, default: u64) -> Result<u64, String> {
    usize_param(params, key, default as usize).map(|v| v as u64)
}

fn bool_param(params: &Json, key: &str, default: bool) -> Result<bool, String> {
    match params.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_bool().ok_or_else(|| format!("param '{key}' must be a boolean")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_admits_up_to_max_immediately() {
        let a = Admission::new(2);
        let (t0, w0) = a.ticket();
        assert!(!w0);
        assert!(a.wait(t0));
        let (t1, w1) = a.ticket();
        assert!(!w1);
        assert!(a.wait(t1));
        let (_, w2) = a.ticket();
        assert!(w2, "third concurrent search must queue");
        assert_eq!(a.active(), 2);
        assert_eq!(a.queued(), 1);
    }

    #[test]
    fn admission_is_fifo_under_contention() {
        let a = Admission::new(1);
        let (t0, _) = a.ticket();
        assert!(a.wait(t0));
        let order = Mutex::new(Vec::new());
        std::thread::scope(|sc| {
            // draw tickets in a known order on the main thread...
            let tickets: Vec<u64> = (0..4).map(|_| a.ticket().0).collect();
            for t in tickets {
                let (a, order) = (&a, &order);
                sc.spawn(move || {
                    assert!(a.wait(t));
                    lock_clean(order).push(t);
                    a.release();
                });
            }
            a.release(); // free the held slot; the queue drains FIFO
        });
        assert_eq!(*lock_clean(&order), vec![1, 2, 3, 4], "admission must be FIFO");
    }

    #[test]
    fn admission_close_releases_waiters() {
        let a = Admission::new(1);
        let (t0, _) = a.ticket();
        assert!(a.wait(t0));
        std::thread::scope(|sc| {
            let (t1, w1) = a.ticket();
            assert!(w1);
            let a2 = &a;
            let h = sc.spawn(move || a2.wait(t1));
            std::thread::sleep(std::time::Duration::from_millis(20));
            a.close();
            assert!(!h.join().expect("waiter thread"), "closed waiter must get false");
        });
        // tickets drawn after close never wait forever either
        let (t2, w2) = a.ticket();
        assert!(w2);
        assert!(!a.wait(t2));
    }

    #[test]
    fn lock_clean_recovers_a_poisoned_mutex() {
        let m = std::sync::Arc::new(Mutex::new(5i32));
        let m2 = m.clone();
        let poisoner = std::thread::spawn(move || {
            // the poisoner itself locks cleanly; panicking while holding
            // the guard is what poisons the mutex
            let _g = lock_clean(&m2);
            panic!("poison the daemon-state lock");
        });
        assert!(poisoner.join().is_err(), "the poisoner must panic");
        assert!(m.lock().is_err(), "the lock must actually be poisoned");
        // the daemon keeps serving: lock_clean recovers the guarded data
        assert_eq!(*lock_clean(&m), 5);
        *lock_clean(&m) += 1;
        assert_eq!(*lock_clean(&m), 6);
    }

    #[test]
    fn params_reject_wrong_types_gracefully() {
        let p = Json::parse(r#"{"iters": "many", "seed": -1, "async": 3, "sw": "x"}"#)
            .unwrap();
        assert!(usize_param(&p, "iters", 4).unwrap_err().contains("iters"));
        assert!(u64_param(&p, "seed", 0).unwrap_err().contains("seed"));
        assert!(bool_param(&p, "async", false).unwrap_err().contains("async"));
        assert!(f64_param(&p, "sw", 0.5).unwrap_err().contains("sw"));
        // absent keys fall back to defaults
        assert_eq!(usize_param(&p, "batch", 7), Ok(7));
        assert_eq!(str_param(&p, "mode", "hw"), Ok("hw".to_string()));
    }
}
