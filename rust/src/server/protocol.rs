//! Wire protocol of the `hass serve` daemon: newline-delimited JSON-RPC.
//!
//! Every request is one line of JSON; every response line carries the
//! request's `id` back.  See the [`crate::server`] module docs for the
//! full method reference.  Parsing is strictly panic-free: a malformed
//! line becomes an `Err` the connection handler reports and survives —
//! the daemon request path must never unwrap client input.

use crate::util::json::Json;

/// One parsed request line: `{"id": ..., "method": "...", "params": {...}}`.
///
/// `id` is echoed verbatim on every response line (clients use it to
/// match streamed events to requests); `params` defaults to an empty
/// object when absent.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: Json,
    pub method: String,
    pub params: Json,
}

/// Parse one request line.  All failures are `Err` strings suitable for
/// an error response — never a panic, whatever the client sent.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = Json::parse(line.trim()).map_err(|e| format!("bad request: {e}"))?;
    let method = v
        .get("method")
        .and_then(|m| m.as_str())
        .ok_or("bad request: missing string field 'method'")?
        .to_string();
    let id = v.get("id").cloned().unwrap_or(Json::Null);
    let params = v.get("params").cloned().unwrap_or_else(|| Json::obj(vec![]));
    Ok(Request { id, method, params })
}

/// `{"id":...,"error":"..."}` — terminal failure response for a request
/// (or for an unparseable line, with `id` null).
pub fn error_line(id: &Json, msg: &str) -> String {
    Json::obj(vec![("id", id.clone()), ("error", Json::Str(msg.to_string()))]).to_string()
}

/// `{"id":...,"result":{...}}` — terminal success response.
pub fn result_line(id: &Json, result: Json) -> String {
    Json::obj(vec![("id", id.clone()), ("result", result)]).to_string()
}

/// `{"id":...,"event":"...", ...fields}` — non-terminal progress event
/// streamed while a request is in flight (e.g. per-generation search
/// progress, admission queueing).
pub fn event_line(id: &Json, event: &str, fields: Vec<(&str, Json)>) -> String {
    let mut pairs = vec![("id", id.clone()), ("event", Json::Str(event.to_string()))];
    pairs.extend(fields);
    Json::obj(pairs).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_request() {
        let r = parse_request(r#"{"id": 7, "method": "search", "params": {"iters": 4}}"#)
            .unwrap();
        assert_eq!(r.id, Json::Num(7.0));
        assert_eq!(r.method, "search");
        assert_eq!(r.params.get("iters").and_then(|v| v.as_usize()), Some(4));
    }

    #[test]
    fn id_and_params_are_optional() {
        let r = parse_request(r#"{"method": "stats"}"#).unwrap();
        assert_eq!(r.id, Json::Null);
        assert_eq!(r.method, "stats");
        assert!(matches!(r.params, Json::Obj(_)));
    }

    /// Every malformed shape is an `Err`, never a panic — the daemon
    /// answers these with an error line and keeps the connection open.
    #[test]
    fn malformed_lines_are_errors_not_panics() {
        for bad in [
            "",
            "not json at all",
            "{",
            "[1,2,3]",
            "42",
            r#"{"id": 1}"#,
            r#"{"method": 42}"#,
            r#"{"method": null}"#,
        ] {
            assert!(parse_request(bad).is_err(), "accepted malformed line: {bad:?}");
        }
    }

    #[test]
    fn response_lines_are_single_line_json() {
        let id = Json::Num(3.0);
        for line in [
            error_line(&id, "nope\nreally"),
            result_line(&id, Json::obj(vec![("ok", Json::Bool(true))])),
            event_line(&id, "generation", vec![("done", Json::Num(2.0))]),
        ] {
            assert!(!line.contains('\n'), "embedded newline breaks the line protocol");
            let v = Json::parse(&line).unwrap();
            assert_eq!(v.get("id"), Some(&id));
        }
    }

    #[test]
    fn event_line_carries_fields() {
        let l = event_line(
            &Json::Str("a".into()),
            "generation",
            vec![("done", Json::Num(3.0)), ("total", Json::Num(9.0))],
        );
        let v = Json::parse(&l).unwrap();
        assert_eq!(v.get("event").and_then(|e| e.as_str()), Some("generation"));
        assert_eq!(v.get("done").and_then(|d| d.as_usize()), Some(3));
        assert_eq!(v.get("total").and_then(|t| t.as_usize()), Some(9));
    }
}
