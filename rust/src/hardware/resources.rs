//! Resource regression model (paper §V-A.3: "resource utilization of each
//! sparse computation engine is modeled on the basis of the regression
//! model").
//!
//! The coefficients below are calibrated so that full-network designs land
//! in the envelope the paper reports in Table II (e.g. sparse ResNet-18 on
//! a U250: ~12.2k DSP, ~1.68M LUT, ~4.8k BRAM18k at 2819 img/s).  We model:
//!
//! * **DSP**  — one 16-bit MAC per DSP slice: `i·o·N`.
//! * **LUT**  — per-SPE clip/zero-filter front end (∝ M), the round-robin
//!   arbiter (∝ N·log2 M fan-in mux tree), accumulator/adder tree (∝ N),
//!   the skipped-zero counter (∝ log2 M), plus per-layer streaming glue.
//! * **BRAM18k** — sliding-window line buffers for convs, inter-layer
//!   FIFOs (the paper's buffering strategy), and per-SPE non-zero pair
//!   buffers.  Weights live in URAM (U250) — Table II's BRAM columns are
//!   far below what 16-bit weights would need, so the paper's designs
//!   clearly keep weights out of BRAM18k for the big models.
//! * **URAM** — 16-bit weight storage, 288 Kb blocks.

use crate::arch::{LayerDesc, Network, Op};
use crate::util::ceil_div;

use super::LayerDesign;

/// A bundle of FPGA resources.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Resources {
    pub dsp: u64,
    pub lut: u64,
    pub bram18k: u64,
    pub uram: u64,
}

impl std::ops::Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources {
            dsp: self.dsp + o.dsp,
            lut: self.lut + o.lut,
            bram18k: self.bram18k + o.bram18k,
            uram: self.uram + o.uram,
        }
    }
}

impl std::iter::Sum for Resources {
    fn sum<I: Iterator<Item = Resources>>(iter: I) -> Resources {
        iter.fold(Resources::default(), |a, b| a + b)
    }
}

/// Regression coefficients (see module docs).
#[derive(Clone, Debug)]
pub struct ResourceModel {
    /// LUTs per SPE, constant part (control FSM, handshake)
    pub lut_spe_base: f64,
    /// LUTs per clip/zero-filter input lane (∝ M)
    pub lut_per_m: f64,
    /// LUTs per arbiter output port per log2(M) (mux tree)
    pub lut_arbiter: f64,
    /// LUTs per MAC (operand regs + control)
    pub lut_per_mac: f64,
    /// LUTs per layer streaming glue (FIFO handshake, counters)
    pub lut_layer_base: f64,
    /// LUTs per non-compute node (pool/add/act streaming logic)
    pub lut_aux_node: f64,
    /// inter-layer FIFO depth in words (buffering strategy default)
    pub fifo_depth: u64,
    /// datapath bit width
    pub bits: u64,
    /// non-zero pair buffer depth per SPE (arbiter prefetch window)
    pub pair_buffer: u64,
}

impl Default for ResourceModel {
    fn default() -> Self {
        ResourceModel {
            lut_spe_base: 90.0,
            lut_per_m: 3.2,
            lut_arbiter: 11.0,
            lut_per_mac: 38.0,
            lut_layer_base: 850.0,
            lut_aux_node: 600.0,
            fifo_depth: 512,
            bits: 16,
            pair_buffer: 64,
        }
    }
}

const BRAM18K_BITS: u64 = 18 * 1024;
const URAM_BITS: u64 = 288 * 1024;

/// Shared with `dse::frontier`'s incremental coster, which must reproduce
/// the LUT expression of [`ResourceModel::layer`] bit for bit.
pub(crate) fn log2_ceil(x: u64) -> u64 {
    (64 - x.max(1).leading_zeros() as u64).max(1)
}

impl ResourceModel {
    /// Resources of one compute layer under a design point.
    pub fn layer(&self, layer: &LayerDesc, d: &LayerDesign) -> Resources {
        debug_assert!(layer.is_compute());
        let engines = d.engines();
        let m = d.m_len(layer) as u64;
        let n = d.n_mac as u64;

        let dsp = d.dsp();

        let lut_spe = self.lut_spe_base
            + self.lut_per_m * m as f64
            + self.lut_arbiter * (n as f64) * log2_ceil(m) as f64
            + self.lut_per_mac * n as f64;
        let lut = (engines as f64 * lut_spe + self.lut_layer_base) as u64;

        // --- BRAM: line buffers + inter-layer FIFO + pair buffers
        let mut bram_bits = 0u64;
        if let Op::Conv { kernel, cin, .. } = layer.op {
            // sliding window: (k-1) full rows + k pixels, every input channel
            if kernel > 1 {
                bram_bits += ((kernel - 1) * layer.in_hw * cin) as u64 * self.bits;
            }
        }
        // input FIFO: depth x (i_par lanes x bits)
        bram_bits += self.fifo_depth * d.i_par as u64 * self.bits;
        // per-SPE non-zero pair prefetch buffers: two operands per slot
        bram_bits += engines * self.pair_buffer * 2 * self.bits;
        // BRAM granularity: line buffers are per-channel-group banks;
        // approximate banking overhead with a 1.25 packing factor
        let bram18k = ceil_div((bram_bits as f64 * 1.25) as u64, BRAM18K_BITS);

        // --- URAM: 16-bit weights, banked per engine
        let w_bits = layer.weight_count() * self.bits;
        let bank_bits = ceil_div(w_bits, engines);
        let uram = engines * ceil_div(bank_bits, URAM_BITS);

        Resources { dsp, lut, bram18k, uram }
    }

    /// Resources of non-compute streaming nodes (pool/add/act...).
    pub fn aux_node(&self, layer: &LayerDesc) -> Resources {
        let lut = match layer.op {
            Op::Pool { .. } | Op::GlobalPool { .. } => self.lut_aux_node as u64 * 2,
            Op::Add { .. } => self.lut_aux_node as u64,
            Op::Act { .. } => (self.lut_aux_node / 2.0) as u64,
            _ => 0,
        };
        // pooling needs line buffers too
        let bram18k = match layer.op {
            Op::Pool { kernel, channels, .. } if kernel > 1 => ceil_div(
                ((kernel - 1) * layer.in_hw * channels) as u64 * self.bits,
                BRAM18K_BITS,
            ),
            _ => 0,
        };
        Resources { dsp: 0, lut, bram18k, uram: 0 }
    }

    /// Whole-network resources for per-compute-layer designs (in
    /// `compute_indices` order).
    pub fn network(&self, net: &Network, designs: &[LayerDesign]) -> Resources {
        let idx = net.compute_indices();
        assert_eq!(idx.len(), designs.len(), "one design per compute layer");
        let mut total = Resources::default();
        let mut di = 0;
        for (li, l) in net.layers.iter().enumerate() {
            if idx.contains(&li) {
                total = total + self.layer(l, &designs[di]);
                di += 1;
            } else {
                total = total + self.aux_node(l);
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::hardware::LayerDesign;

    fn conv_layer() -> LayerDesc {
        LayerDesc {
            name: "c".into(),
            op: Op::Conv { kernel: 3, stride: 1, pad: 1, cin: 64, cout: 64, groups: 1 },
            in_hw: 14,
            branch: false,
        }
    }

    #[test]
    fn dsp_is_product_of_parallelism() {
        let rm = ResourceModel::default();
        let l = conv_layer();
        let d = LayerDesign { i_par: 2, o_par: 4, n_mac: 8 };
        assert_eq!(rm.layer(&l, &d).dsp, 64);
    }

    #[test]
    fn lut_grows_with_every_knob() {
        let rm = ResourceModel::default();
        let l = conv_layer();
        let base = LayerDesign { i_par: 1, o_par: 1, n_mac: 4 };
        let r0 = rm.layer(&l, &base).lut;
        for d in [
            LayerDesign { i_par: 2, ..base },
            LayerDesign { o_par: 2, ..base },
            LayerDesign { n_mac: 8, ..base },
        ] {
            assert!(rm.layer(&l, &d).lut > r0, "{d:?}");
        }
    }

    #[test]
    fn uram_covers_weights() {
        let rm = ResourceModel::default();
        let l = conv_layer(); // 9*64*64 = 36864 weights = 589824 bits = 2 URAM
        let d = LayerDesign::MINIMAL;
        let r = rm.layer(&l, &d);
        assert_eq!(r.uram, 2);
    }

    #[test]
    fn uram_banking_overhead_with_engines() {
        let rm = ResourceModel::default();
        let l = conv_layer();
        let many = LayerDesign { i_par: 8, o_par: 8, n_mac: 1 };
        // banked into 64 engines: per-bank remainder rounds up per engine
        assert!(rm.layer(&l, &many).uram >= rm.layer(&l, &LayerDesign::MINIMAL).uram);
    }

    #[test]
    fn line_buffer_only_for_spatial_kernels() {
        let rm = ResourceModel::default();
        let l1 = LayerDesc {
            name: "pw".into(),
            op: Op::Conv { kernel: 1, stride: 1, pad: 0, cin: 64, cout: 64, groups: 1 },
            in_hw: 14,
            branch: false,
        };
        let r1 = rm.layer(&l1, &LayerDesign::MINIMAL);
        let r3 = rm.layer(&conv_layer(), &LayerDesign::MINIMAL);
        assert!(r3.bram18k > r1.bram18k);
    }

    #[test]
    fn network_totals_sum_layers() {
        let rm = ResourceModel::default();
        let net = networks::calibnet();
        let designs = vec![LayerDesign::MINIMAL; net.compute_layers().len()];
        let total = rm.network(&net, &designs);
        assert!(total.dsp == net.compute_layers().len() as u64);
        assert!(total.lut > 0 && total.bram18k > 0);
    }

    #[test]
    fn resources_add_and_sum() {
        let a = Resources { dsp: 1, lut: 2, bram18k: 3, uram: 4 };
        let b = Resources { dsp: 10, lut: 20, bram18k: 30, uram: 40 };
        let s: Resources = [a, b].into_iter().sum();
        assert_eq!(s, Resources { dsp: 11, lut: 22, bram18k: 33, uram: 44 });
    }

    #[test]
    #[should_panic(expected = "one design per compute layer")]
    fn network_rejects_wrong_design_count() {
        let rm = ResourceModel::default();
        let net = networks::calibnet();
        rm.network(&net, &[LayerDesign::MINIMAL]);
    }
}
