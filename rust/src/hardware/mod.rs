//! Hardware model of the sparse dataflow accelerator (paper §IV).
//!
//! Each compute layer is implemented by `i_par × o_par` Sparse vector
//! dot-Product Engines (SPEs).  A full dot product of length K (the
//! layer's `patch_k`) is split over `i_par` engines (input-channel
//! parallelism), so each engine consumes `M = ⌈K / i_par⌉` weight/
//! activation pairs per output; `o_par` filters are computed in parallel
//! (output-filter parallelism); `n_mac` MAC units (DSPs) inside each SPE
//! consume the *non-zero* pairs dispatched by the round-robin arbiter.
//!
//! The initiation interval of an SPE is the paper's Eq. 1:
//!
//! ```text
//! t(S̄) = ⌈ (1 − S̄) · M / N ⌉        (≥ 1 cycle to emit)
//! ```
//!
//! and layer throughput (Eq. 2) follows from iterating the SPEs over the
//! `outputs_per_image / o_par` output groups.

pub mod device;
pub mod resources;

use crate::arch::LayerDesc;
use crate::sparsity::SparsityPoint;
use crate::util::ceil_div;

/// Parallelism configuration of one layer (the DSE design variables).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerDesign {
    /// input-channel parallelism i ∈ [1, I]
    pub i_par: usize,
    /// output-filter parallelism o ∈ [1, O]
    pub o_par: usize,
    /// MAC (DSP) units per SPE, N ∈ [1, M]
    pub n_mac: usize,
}

impl LayerDesign {
    /// The fully sequential, resource-minimal starting point (DSE §V-A.3).
    pub const MINIMAL: LayerDesign = LayerDesign { i_par: 1, o_par: 1, n_mac: 1 };

    /// Pairs per output handled by one SPE.
    pub fn m_len(&self, layer: &LayerDesc) -> usize {
        ceil_div(layer.patch_k() as u64, self.i_par as u64) as usize
    }

    /// SPE initiation interval t(S̄) in cycles — Eq. 1.
    pub fn spe_cycles(&self, layer: &LayerDesc, point: SparsityPoint) -> u64 {
        let m = self.m_len(layer) as f64;
        let useful = point.pair_density() * m;
        ((useful / self.n_mac as f64).ceil() as u64).max(1)
    }

    /// Cycles to process one image through this layer.
    pub fn cycles_per_image(&self, layer: &LayerDesc, point: SparsityPoint) -> u64 {
        let groups = ceil_div(layer.outputs_per_image() as u64, self.o_par as u64);
        groups * self.spe_cycles(layer, point)
    }

    /// Layer throughput in images per cycle — Eq. 2.
    pub fn throughput(&self, layer: &LayerDesc, point: SparsityPoint) -> f64 {
        1.0 / self.cycles_per_image(layer, point) as f64
    }

    /// DSPs consumed (one 16-bit MAC per DSP).
    pub fn dsp(&self) -> u64 {
        (self.i_par * self.o_par * self.n_mac) as u64
    }

    /// Number of SPE instances.
    pub fn engines(&self) -> u64 {
        (self.i_par * self.o_par) as u64
    }

    /// Is this design realizable for the layer's extents?
    pub fn feasible(&self, layer: &LayerDesc) -> bool {
        self.i_par >= 1
            && self.o_par >= 1
            && self.n_mac >= 1
            && self.i_par <= layer.i_extent()
            && self.o_par <= layer.o_extent()
            && self.n_mac <= self.m_len(layer)
    }

    /// Enumerate the (strictly more parallel) one-step neighbours used by
    /// the resource-constrained incrementing loop: bump one of i/o/N to
    /// its next feasible value.
    pub fn increments(&self, layer: &LayerDesc) -> Vec<LayerDesign> {
        let mut out = Vec::new();
        if let Some(i2) = next_divisor(layer.i_extent(), self.i_par) {
            let d = LayerDesign { i_par: i2, ..*self };
            // splitting K shrinks M; clamp n_mac into the new M
            let d = LayerDesign { n_mac: d.n_mac.min(d.m_len(layer).max(1)), ..d };
            if d.feasible(layer) {
                out.push(d);
            }
        }
        if let Some(o2) = next_divisor(layer.o_extent(), self.o_par) {
            let d = LayerDesign { o_par: o2, ..*self };
            if d.feasible(layer) {
                out.push(d);
            }
        }
        let m = self.m_len(layer);
        if self.n_mac < m {
            // next value that actually reduces t for dense input:
            // smallest n' > n with ceil(M/n') < ceil(M/n)
            let cur = ceil_div(m as u64, self.n_mac as u64);
            let mut n2 = self.n_mac + 1;
            while n2 < m && ceil_div(m as u64, n2 as u64) >= cur {
                n2 += 1;
            }
            let d = LayerDesign { n_mac: n2.min(m), ..*self };
            if d.feasible(layer) && d != *self {
                out.push(d);
            }
        }
        out
    }
}

/// Smallest divisor of `extent` strictly greater than `cur` (parallelism
/// levels divide the extent so folding is remainder-free).
pub fn next_divisor(extent: usize, cur: usize) -> Option<usize> {
    ((cur + 1)..=extent).find(|v| extent % v == 0)
}

/// All divisors of an extent (ascending) — the feasible parallelism levels.
pub fn divisors(extent: usize) -> Vec<usize> {
    (1..=extent).filter(|v| extent % v == 0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Op;
    use crate::util::prop::forall;

    fn conv_layer() -> LayerDesc {
        LayerDesc {
            name: "c".into(),
            op: Op::Conv { kernel: 3, stride: 1, pad: 1, cin: 16, cout: 32, groups: 1 },
            in_hw: 16,
            branch: false,
        }
    }

    #[test]
    fn eq1_dense_matches_paper_example() {
        // dense: t = M / N exactly when N | M
        let l = conv_layer(); // K = 144
        let d = LayerDesign { i_par: 1, o_par: 1, n_mac: 12 };
        assert_eq!(d.m_len(&l), 144);
        assert_eq!(d.spe_cycles(&l, SparsityPoint::DENSE), 12);
    }

    #[test]
    fn eq1_half_sparse_halves_cycles() {
        let l = conv_layer();
        let d = LayerDesign { i_par: 1, o_par: 1, n_mac: 12 };
        let p = SparsityPoint { s_w: 0.5, s_a: 0.0 };
        assert_eq!(d.spe_cycles(&l, p), 6);
    }

    #[test]
    fn eq1_never_below_one_cycle() {
        let l = conv_layer();
        let d = LayerDesign { i_par: 1, o_par: 1, n_mac: 144 };
        let p = SparsityPoint { s_w: 0.99, s_a: 0.99 };
        assert_eq!(d.spe_cycles(&l, p), 1);
    }

    #[test]
    fn eq2_throughput_scales_with_o_par() {
        let l = conv_layer();
        let p = SparsityPoint::DENSE;
        let d1 = LayerDesign { i_par: 1, o_par: 1, n_mac: 4 };
        let d2 = LayerDesign { i_par: 1, o_par: 4, n_mac: 4 };
        let r = d2.throughput(&l, p) / d1.throughput(&l, p);
        assert!((r - 4.0).abs() < 1e-9, "ratio {r}");
    }

    #[test]
    fn i_par_splits_dot_product() {
        let l = conv_layer(); // K = 144
        let d = LayerDesign { i_par: 4, o_par: 1, n_mac: 1 };
        assert_eq!(d.m_len(&l), 36);
        assert!(d.feasible(&l));
    }

    #[test]
    fn infeasible_when_exceeding_extents() {
        let l = conv_layer();
        assert!(!LayerDesign { i_par: 17, o_par: 1, n_mac: 1 }.feasible(&l));
        assert!(!LayerDesign { i_par: 1, o_par: 33, n_mac: 1 }.feasible(&l));
        assert!(!LayerDesign { i_par: 1, o_par: 1, n_mac: 145 }.feasible(&l));
    }

    #[test]
    fn increments_strictly_increase_dense_throughput_or_dsp() {
        let l = conv_layer();
        forall(100, 0xD5E, |rng| {
            let i = *rng.choice(&divisors(l.i_extent()));
            let o = *rng.choice(&divisors(l.o_extent()));
            let d0 = LayerDesign { i_par: i, o_par: o, n_mac: 1 };
            let m = d0.m_len(&l);
            let d0 = LayerDesign { n_mac: 1 + rng.below(m), ..d0 };
            if !d0.feasible(&l) {
                return;
            }
            for d in d0.increments(&l) {
                assert!(d.feasible(&l), "infeasible increment {d:?} from {d0:?}");
                let t0 = d0.throughput(&l, SparsityPoint::DENSE);
                let t1 = d.throughput(&l, SparsityPoint::DENSE);
                assert!(
                    t1 > t0 * (1.0 - 1e-12),
                    "no gain: {d0:?} -> {d:?} ({t0} -> {t1})"
                );
            }
        });
    }

    #[test]
    fn minimal_design_has_one_dsp() {
        assert_eq!(LayerDesign::MINIMAL.dsp(), 1);
    }

    #[test]
    fn next_divisor_walks_divisor_lattice() {
        assert_eq!(next_divisor(16, 1), Some(2));
        assert_eq!(next_divisor(16, 2), Some(4));
        assert_eq!(next_divisor(16, 16), None);
        assert_eq!(next_divisor(12, 4), Some(6));
    }

    #[test]
    fn throughput_monotone_in_sparsity() {
        let l = conv_layer();
        let d = LayerDesign { i_par: 2, o_par: 4, n_mac: 8 };
        let mut last = 0.0;
        for s in [0.0, 0.2, 0.4, 0.6, 0.8] {
            let p = SparsityPoint { s_w: s, s_a: s };
            let th = d.throughput(&l, p);
            assert!(th >= last);
            last = th;
        }
    }

    #[test]
    fn depthwise_layer_design_space() {
        let l = LayerDesc {
            name: "dw".into(),
            op: Op::Conv { kernel: 3, stride: 1, pad: 1, cin: 32, cout: 32, groups: 32 },
            in_hw: 8,
            branch: false,
        };
        // depthwise: i_extent = 1, K = 9
        assert_eq!(l.i_extent(), 1);
        let d = LayerDesign { i_par: 1, o_par: 8, n_mac: 9 };
        assert!(d.feasible(&l));
        assert_eq!(d.spe_cycles(&l, SparsityPoint::DENSE), 1);
    }
}
