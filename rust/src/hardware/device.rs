//! FPGA device resource budgets (paper §VI platforms).

use super::resources::Resources;

/// Resource envelope + clock of a target device.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceBudget {
    pub name: String,
    pub dsp: u64,
    pub lut: u64,
    pub bram18k: u64,
    pub uram: u64,
    pub freq_mhz: f64,
}

impl DeviceBudget {
    /// AMD Xilinx Alveo U250 (the paper's main platform, 250 MHz designs).
    pub fn u250() -> Self {
        DeviceBudget {
            name: "u250".into(),
            dsp: 12_288,
            lut: 1_728_000,
            bram18k: 5_376,
            uram: 1_280,
            freq_mhz: 250.0,
        }
    }

    /// Xilinx Virtex-7 690T (platform of the non-dataflow comparator [6]).
    pub fn v7_690t() -> Self {
        DeviceBudget {
            name: "7v690t".into(),
            dsp: 3_600,
            lut: 433_200,
            bram18k: 2_940,
            uram: 0,
            freq_mhz: 150.0,
        }
    }

    /// Intel Stratix 10 GX2800 (HPIPE's platform; ALMs ≈ 2 LUT-equivalents).
    pub fn stratix10() -> Self {
        DeviceBudget {
            name: "stratix10".into(),
            dsp: 5_760,
            lut: 1_866_240, // 933,120 ALMs x 2
            bram18k: 11_721, // 2x M20K count in 18k-equivalents (approx)
            uram: 0,
            freq_mhz: 390.0,
        }
    }

    /// Look up a built-in budget by name.  Case-insensitive, tolerant of
    /// the aliases that show up in the paper and in CLI habit
    /// (`V7`, `v7_690t`, `7v690t`, `s10`, …); `None` for anything else.
    pub fn by_name(name: &str) -> Option<Self> {
        match name.trim().to_ascii_lowercase().as_str() {
            "u250" | "alveo-u250" | "alveo_u250" => Some(Self::u250()),
            "7v690t" | "v7" | "v7_690t" | "v7-690t" | "v7690t" => Some(Self::v7_690t()),
            "stratix10" | "s10" | "gx2800" => Some(Self::stratix10()),
            _ => None,
        }
    }

    /// Parse a comma-separated device list (`"u250,v7_690t"`) for the
    /// sharded search CLI.  Empty segments are ignored; duplicates (even
    /// via aliases — `u250,U250` or `v7,7v690t`) are collapsed to the
    /// first occurrence, so `--devices u250,u250` runs one shard per
    /// *distinct* device instead of two shards fighting over one cache
    /// fingerprint.  An unknown name fails the whole list with a message
    /// naming the bad segment.
    pub fn parse_list(s: &str) -> Result<Vec<Self>, String> {
        let mut out: Vec<Self> = Vec::new();
        for seg in s.split(',') {
            let seg = seg.trim();
            if seg.is_empty() {
                continue;
            }
            match Self::by_name(seg) {
                Some(d) => {
                    if !out.iter().any(|o| o.name == d.name) {
                        out.push(d);
                    }
                }
                None => {
                    return Err(format!(
                        "unknown device '{seg}' (u250 | 7v690t | stratix10)"
                    ))
                }
            }
        }
        Ok(out)
    }

    /// Does a design fit this device?
    pub fn fits(&self, r: &Resources) -> bool {
        r.dsp <= self.dsp && r.lut <= self.lut && r.bram18k <= self.bram18k && r.uram <= self.uram
    }

    /// Fraction of the binding resource consumed (for reporting).
    pub fn utilization(&self, r: &Resources) -> f64 {
        let fr = [
            r.dsp as f64 / self.dsp as f64,
            r.lut as f64 / self.lut as f64,
            r.bram18k as f64 / self.bram18k as f64,
            if self.uram > 0 { r.uram as f64 / self.uram as f64 } else { 0.0 },
        ];
        fr.into_iter().fold(0.0, f64::max)
    }

    /// Cycles per second.
    pub fn freq_hz(&self) -> f64 {
        self.freq_mhz * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u250_matches_table2_envelope() {
        let d = DeviceBudget::u250();
        // the paper's largest reported design uses 12234 DSPs / 1728 kLUT /
        // 5376 BRAM18k — all must fit the budget
        assert!(d.dsp >= 12_234);
        assert!(d.lut >= 1_728_000);
        assert!(d.bram18k >= 5_376);
    }

    #[test]
    fn fits_checks_every_dimension() {
        let d = DeviceBudget::u250();
        let ok = Resources { dsp: 100, lut: 1000, bram18k: 10, uram: 0 };
        assert!(d.fits(&ok));
        for bad in [
            Resources { dsp: d.dsp + 1, ..ok },
            Resources { lut: d.lut + 1, ..ok },
            Resources { bram18k: d.bram18k + 1, ..ok },
            Resources { uram: d.uram + 1, ..ok },
        ] {
            assert!(!d.fits(&bad));
        }
    }

    #[test]
    fn utilization_is_max_fraction() {
        let d = DeviceBudget::u250();
        let r = Resources { dsp: d.dsp / 2, lut: d.lut / 4, bram18k: 0, uram: 0 };
        assert!((d.utilization(&r) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(DeviceBudget::by_name("u250").unwrap().name, "u250");
        assert!(DeviceBudget::by_name("nope").is_none());
    }

    #[test]
    fn by_name_rejects_unknown_and_near_miss_names() {
        for bad in ["", " ", "u-250", "u2500", "virtex", "stratix", "u250x"] {
            assert!(DeviceBudget::by_name(bad).is_none(), "accepted '{bad}'");
        }
    }

    #[test]
    fn by_name_is_case_insensitive_and_trims() {
        for (alias, canonical) in [
            ("U250", "u250"),
            (" u250 ", "u250"),
            ("V7", "7v690t"),
            ("v7_690t", "7v690t"),
            ("V7-690T", "7v690t"),
            ("7V690T", "7v690t"),
            ("Stratix10", "stratix10"),
            ("S10", "stratix10"),
        ] {
            assert_eq!(
                DeviceBudget::by_name(alias).map(|d| d.name),
                Some(canonical.to_string()),
                "alias '{alias}'"
            );
        }
    }

    #[test]
    fn parse_list_handles_spacing_empties_and_errors() {
        let devs = DeviceBudget::parse_list("u250, V7_690T,,stratix10,").unwrap();
        assert_eq!(
            devs.iter().map(|d| d.name.as_str()).collect::<Vec<_>>(),
            vec!["u250", "7v690t", "stratix10"]
        );
        assert!(DeviceBudget::parse_list("").unwrap().is_empty());
        let err = DeviceBudget::parse_list("u250,warp9").unwrap_err();
        assert!(err.contains("warp9"), "error must name the bad segment: {err}");
    }

    #[test]
    fn parse_list_collapses_duplicates_to_first_occurrence() {
        // duplicates (even via aliases) dedup instead of erroring, in
        // first-seen order
        let devs = DeviceBudget::parse_list("u250,7v690t,U250,v7,u250").unwrap();
        assert_eq!(
            devs.iter().map(|d| d.name.as_str()).collect::<Vec<_>>(),
            vec!["u250", "7v690t"]
        );
        let devs = DeviceBudget::parse_list("u250,u250").unwrap();
        assert_eq!(devs.len(), 1, "one shard per distinct device");
    }
}
