//! FPGA device resource budgets (paper §VI platforms).

use super::resources::Resources;

/// Resource envelope + clock of a target device.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceBudget {
    pub name: String,
    pub dsp: u64,
    pub lut: u64,
    pub bram18k: u64,
    pub uram: u64,
    pub freq_mhz: f64,
}

impl DeviceBudget {
    /// AMD Xilinx Alveo U250 (the paper's main platform, 250 MHz designs).
    pub fn u250() -> Self {
        DeviceBudget {
            name: "u250".into(),
            dsp: 12_288,
            lut: 1_728_000,
            bram18k: 5_376,
            uram: 1_280,
            freq_mhz: 250.0,
        }
    }

    /// Xilinx Virtex-7 690T (platform of the non-dataflow comparator [6]).
    pub fn v7_690t() -> Self {
        DeviceBudget {
            name: "7v690t".into(),
            dsp: 3_600,
            lut: 433_200,
            bram18k: 2_940,
            uram: 0,
            freq_mhz: 150.0,
        }
    }

    /// Intel Stratix 10 GX2800 (HPIPE's platform; ALMs ≈ 2 LUT-equivalents).
    pub fn stratix10() -> Self {
        DeviceBudget {
            name: "stratix10".into(),
            dsp: 5_760,
            lut: 1_866_240, // 933,120 ALMs x 2
            bram18k: 11_721, // 2x M20K count in 18k-equivalents (approx)
            uram: 0,
            freq_mhz: 390.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "u250" => Some(Self::u250()),
            "7v690t" | "v7" => Some(Self::v7_690t()),
            "stratix10" => Some(Self::stratix10()),
            _ => None,
        }
    }

    /// Does a design fit this device?
    pub fn fits(&self, r: &Resources) -> bool {
        r.dsp <= self.dsp && r.lut <= self.lut && r.bram18k <= self.bram18k && r.uram <= self.uram
    }

    /// Fraction of the binding resource consumed (for reporting).
    pub fn utilization(&self, r: &Resources) -> f64 {
        let fr = [
            r.dsp as f64 / self.dsp as f64,
            r.lut as f64 / self.lut as f64,
            r.bram18k as f64 / self.bram18k as f64,
            if self.uram > 0 { r.uram as f64 / self.uram as f64 } else { 0.0 },
        ];
        fr.into_iter().fold(0.0, f64::max)
    }

    /// Cycles per second.
    pub fn freq_hz(&self) -> f64 {
        self.freq_mhz * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u250_matches_table2_envelope() {
        let d = DeviceBudget::u250();
        // the paper's largest reported design uses 12234 DSPs / 1728 kLUT /
        // 5376 BRAM18k — all must fit the budget
        assert!(d.dsp >= 12_234);
        assert!(d.lut >= 1_728_000);
        assert!(d.bram18k >= 5_376);
    }

    #[test]
    fn fits_checks_every_dimension() {
        let d = DeviceBudget::u250();
        let ok = Resources { dsp: 100, lut: 1000, bram18k: 10, uram: 0 };
        assert!(d.fits(&ok));
        for bad in [
            Resources { dsp: d.dsp + 1, ..ok },
            Resources { lut: d.lut + 1, ..ok },
            Resources { bram18k: d.bram18k + 1, ..ok },
            Resources { uram: d.uram + 1, ..ok },
        ] {
            assert!(!d.fits(&bad));
        }
    }

    #[test]
    fn utilization_is_max_fraction() {
        let d = DeviceBudget::u250();
        let r = Resources { dsp: d.dsp / 2, lut: d.lut / 4, bram18k: 0, uram: 0 };
        assert!((d.utilization(&r) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(DeviceBudget::by_name("u250").unwrap().name, "u250");
        assert!(DeviceBudget::by_name("nope").is_none());
    }
}
