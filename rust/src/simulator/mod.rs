//! Cycle-level simulator of the sparse dataflow pipeline (paper §IV).
//!
//! Validates the analytical model (Eq. 1–3) that the DSE trusts, and
//! exposes the dynamic effects the model abstracts away: per-group
//! sparsity variance, inter-layer FIFO backpressure, and pipeline fill.
//!
//! **Model.**  Each compute layer is a pipeline *stage* with `i×o` SPEs
//! processing one *output group* (`o_par` outputs) at a time.  A group's
//! duration is `max_e ⌈k_e / N⌉` over its engines, where `k_e` is the
//! engine's non-zero pair count — sampled per group around the calibrated
//! density (the run-time dynamism of activation sparsity).  Stages are
//! connected by FIFOs; a stage can start a group only when
//!
//! * its own SPEs are free,
//! * the upstream stage has produced the input the group's window needs
//!   (tracked as a fraction of the upstream image, plus the sliding-window
//!   skew of a k×k kernel), and
//! * the downstream FIFO has space (backpressure).
//!
//! The simulation is discrete-event (completion-time driven), so cost is
//! O(total groups · L), independent of per-cycle idling.

use crate::arch::{LayerDesc, Network, Op};
use crate::hardware::LayerDesign;
use crate::sparsity::SparsityPoint;
use crate::util::ceil_div;
use crate::util::rng::Rng;

/// Per-stage simulation parameters.
#[derive(Clone, Debug)]
pub struct StageConfig {
    pub design: LayerDesign,
    pub point: SparsityPoint,
    /// relative per-engine density multipliers (mean 1.0); length must be
    /// `design.engines()` or empty for perfectly balanced engines
    pub engine_imbalance: Vec<f64>,
    /// inter-layer FIFO capacity, in *output elements* of this stage
    pub fifo_capacity: u64,
}

/// What the simulator measures for one run.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// true if the pipeline wedged (a config error: FIFO smaller than the
    /// consumer's window needs) — results are then meaningless
    pub deadlocked: bool,
    /// total cycles from first input to last output
    pub total_cycles: u64,
    /// steady-state throughput estimate: images/cycle over the back half
    pub throughput: f64,
    /// per-stage busy fraction (cycles computing / total)
    pub busy: Vec<f64>,
    /// per-stage cycles lost waiting for input
    pub starved: Vec<u64>,
    /// per-stage cycles lost blocked on a full output FIFO
    pub blocked: Vec<u64>,
    /// images simulated
    pub images: usize,
}

/// Variance model for the per-group non-zero pair count.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SparsityDynamics {
    /// every group sees exactly the calibrated mean density (validates the
    /// analytical model: simulator must match Eq. 1–3)
    Deterministic,
    /// binomial-like variance around the mean (normal approximation),
    /// modelling run-time activation dynamism
    Stochastic { seed: u64 },
}

struct Stage {
    layer: LayerDesc,
    cfg: StageConfig,
    /// groups per image
    groups: u64,
    /// pairs per output in one SPE
    m_len: usize,
    // dynamic state
    next_group: u64,
    busy_until: u64,
    /// completed groups (over all images)
    done: u64,
    busy_cycles: u64,
    starved_cycles: u64,
    blocked_cycles: u64,
    last_event: u64,
    /// fractional work carried across group boundaries: the SPE's
    /// non-zero-pair prefetch buffer lets the arbiter keep MACs busy
    /// across groups, so per-group rounding does not quantize to whole
    /// cycles (paper §IV: "pre-fetch data in a buffer to keep the
    /// hardware operators busy at each cycle")
    work_carry: f64,
}

impl Stage {
    /// Upstream image fraction needed before group `g` (within an image)
    /// can start: its share of the image plus the sliding-window skew.
    fn input_fraction_needed(&self, g_in_image: u64) -> f64 {
        let frac = (g_in_image + 1) as f64 / self.groups as f64;
        let skew = match self.layer.op {
            Op::Conv { kernel, .. } if kernel > 1 => {
                // need `kernel` rows of input before the first output row
                kernel as f64 / self.layer.in_hw.max(1) as f64
            }
            _ => 0.0,
        };
        (frac + skew).min(1.0)
    }

    /// Sample the group duration in cycles.
    fn group_cycles(&mut self, rng: Option<&mut Rng>) -> u64 {
        let d = self.cfg.point.pair_density();
        let m = self.m_len as f64;
        let n = self.cfg.design.n_mac as f64;
        let engines = self.cfg.design.engines() as usize;
        match rng {
            None => {
                // deterministic: exactly the analytical Eq. 1
                ((d * m / n).ceil() as u64).max(1)
            }
            Some(rng) => {
                // per-engine binomial (normal approx), imbalance-scaled;
                // group waits for its slowest engine
                let mut worst = 1.0f64;
                for e in 0..engines {
                    let imb = self
                        .cfg
                        .engine_imbalance
                        .get(e)
                        .copied()
                        .unwrap_or(1.0);
                    let mean = (d * imb).clamp(0.0, 1.0) * m;
                    let var = (d * imb).clamp(0.0, 1.0) * (1.0 - (d * imb).clamp(0.0, 1.0)) * m;
                    let k = (mean + rng.gauss() * var.sqrt()).round().clamp(0.0, m);
                    worst = worst.max(k / n);
                }
                // work-conserving rounding via the pair-prefetch buffer:
                // leftover fractional cycles carry into the next group
                // instead of quantizing every group up to a whole cycle
                let t_raw = worst + self.work_carry;
                let t = t_raw.floor();
                if t < 1.0 {
                    self.work_carry = 0.0; // emission takes the cycle anyway
                    1
                } else {
                    self.work_carry = t_raw - t;
                    t as u64
                }
            }
        }
    }
}

/// Build stage configs straight from a DSE result (balanced engines,
/// default FIFO depth from the resource model's `fifo_depth`).
pub fn stages_from_design(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
    fifo_depth: u64,
) -> Vec<StageConfig> {
    let compute = net.compute_layers();
    assert_eq!(compute.len(), designs.len());
    assert_eq!(compute.len(), points.len());
    designs
        .iter()
        .zip(points)
        .map(|(d, p)| StageConfig {
            design: *d,
            point: *p,
            engine_imbalance: Vec::new(),
            fifo_capacity: fifo_depth.max(d.o_par as u64 * 2),
        })
        .collect()
}

/// Simulate `images` images through the pipeline.
pub fn simulate(
    net: &Network,
    configs: &[StageConfig],
    images: usize,
    dynamics: SparsityDynamics,
) -> SimReport {
    let compute: Vec<LayerDesc> = net.compute_layers().into_iter().cloned().collect();
    assert_eq!(compute.len(), configs.len());
    assert!(images > 0);
    let mut rng = match dynamics {
        SparsityDynamics::Deterministic => None,
        SparsityDynamics::Stochastic { seed } => Some(Rng::new(seed)),
    };
    let mut stages: Vec<Stage> = compute
        .iter()
        .zip(configs)
        .map(|(l, c)| {
            let groups = ceil_div(l.outputs_per_image() as u64, c.design.o_par as u64);
            let m_len = c.design.m_len(l);
            Stage {
                layer: l.clone(),
                cfg: c.clone(),
                groups,
                m_len,
                next_group: 0,
                busy_until: 0,
                done: 0,
                busy_cycles: 0,
                starved_cycles: 0,
                blocked_cycles: 0,
                last_event: 0,
                work_carry: 0.0,
            }
        })
        .collect();
    let n = stages.len();
    let total_groups: u64 = stages.iter().map(|s| s.groups).sum::<u64>() * images as u64;

    let mut now = 0u64;
    let mut committed = 0u64;
    // steady-state throughput is measured from *image* completion times at
    // the sink: the last stage often bursts through one image's groups, so
    // group-level timing would wildly overestimate throughput.
    let mut image_done: Vec<u64> = vec![0; images];
    let mut deadlocked = false;

    while committed < total_groups {
        // try to start any idle stage
        let mut started = false;
        for i in 0..n {
            if stages[i].busy_until > now {
                continue;
            }
            let img = stages[i].next_group / stages[i].groups;
            if img >= images as u64 {
                continue; // finished all its work
            }
            let g_in_image = stages[i].next_group % stages[i].groups;
            // 1) input availability
            let input_ok = if i == 0 {
                true // source streams freely
            } else {
                let need = stages[i].input_fraction_needed(g_in_image);
                let up = &stages[i - 1];
                let up_done_in_img = up
                    .done
                    .saturating_sub(img * up.groups)
                    .min(up.groups);
                // upstream must already be past this image
                up.done >= img * up.groups
                    && (up_done_in_img as f64 / up.groups as f64) >= need - 1e-12
            };
            // 2) downstream FIFO space: our produced-but-unconsumed output.
            // A k×k downstream conv absorbs its sliding window into its own
            // line buffer, so that window counts as extra capacity; groups
            // the downstream has *started* have already drained their input.
            let space_ok = if i + 1 == n {
                true // sink always drains
            } else {
                let my_out = stages[i].done * stages[i].cfg.design.o_par as u64;
                let down = &stages[i + 1];
                let my_total = stages[i].groups * stages[i].cfg.design.o_par as u64;
                let per_down_group = my_total as f64 / down.groups as f64;
                let consumed = (down.next_group as f64 * per_down_group) as u64;
                let window = (down.input_fraction_needed(0) * my_total as f64) as u64;
                my_out.saturating_sub(consumed)
                    <= stages[i].cfg.fifo_capacity
                        + window
                        + stages[i].cfg.design.o_par as u64
            };
            if input_ok && space_ok {
                let t = stages[i].group_cycles(rng.as_mut());
                stages[i].busy_until = now + t;
                stages[i].busy_cycles += t;
                stages[i].next_group += 1;
                stages[i].last_event = now + t;
                started = true;
            }
        }
        if !started {
            // advance time to the earliest completion
            let next = stages
                .iter()
                .filter(|s| s.busy_until > now)
                .map(|s| s.busy_until)
                .min();
            let Some(next) = next else {
                // pipeline wedged: FIFO capacity below the consumer's
                // window needs — report it instead of spinning forever
                deadlocked = true;
                break;
            };
            // account idle reasons between now and next
            for i in 0..n {
                if stages[i].busy_until <= now {
                    let img = stages[i].next_group / stages[i].groups;
                    if img >= images as u64 {
                        continue;
                    }
                    let g = stages[i].next_group % stages[i].groups;
                    let starving = i > 0 && {
                        let need = stages[i].input_fraction_needed(g);
                        let up = &stages[i - 1];
                        let up_done = up.done.saturating_sub(img * up.groups).min(up.groups);
                        up.done < img * up.groups
                            || (up_done as f64 / up.groups as f64) < need - 1e-12
                    };
                    if starving {
                        stages[i].starved_cycles += next - now;
                    } else {
                        stages[i].blocked_cycles += next - now;
                    }
                }
            }
            now = next;
            // commit completions
            for (i, s) in stages.iter_mut().enumerate() {
                if s.busy_until == now && s.done < s.next_group {
                    let newly = s.next_group - s.done;
                    s.done = s.next_group;
                    committed += newly;
                    if i + 1 == n {
                        // record sink-side image completion times
                        let done_imgs = (s.done / s.groups).min(images as u64) as usize;
                        for t in image_done.iter_mut().take(done_imgs) {
                            if *t == 0 {
                                *t = now;
                            }
                        }
                    }
                }
            }
        } else {
            // commit any zero-latency bookkeeping (done lags next_group
            // until completion time passes)
            for s in stages.iter_mut() {
                if s.busy_until <= now && s.done < s.next_group {
                    committed += s.next_group - s.done;
                    s.done = s.next_group;
                }
            }
        }
    }
    let total_cycles = stages.iter().map(|s| s.busy_until).max().unwrap_or(0);
    for t in image_done.iter_mut() {
        if *t == 0 {
            *t = total_cycles;
        }
    }
    // steady-state throughput: skip the pipeline-fill image(s), measure
    // sink-side inter-image spacing over the rest
    let throughput = if images >= 2 {
        let fill = image_done[0];
        let span = image_done[images - 1].saturating_sub(fill).max(1);
        (images - 1) as f64 / span as f64
    } else {
        1.0 / total_cycles.max(1) as f64
    };
    SimReport {
        deadlocked,
        total_cycles,
        throughput,
        busy: stages
            .iter()
            .map(|s| s.busy_cycles as f64 / total_cycles.max(1) as f64)
            .collect(),
        starved: stages.iter().map(|s| s.starved_cycles).collect(),
        blocked: stages.iter().map(|s| s.blocked_cycles).collect(),
        images,
    }
}

/// Moving-window buffer-size heuristic (paper §IV "Buffering Strategy",
/// after PASS [4]): simulate with stochastic sparsity, find per-stage the
/// FIFO depth that absorbs the observed rate variance — the 99th
/// percentile of the occupancy a window of `window` groups would need.
pub fn buffer_sizes(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
    window: usize,
    seed: u64,
) -> Vec<u64> {
    let compute = net.compute_layers();
    let mut rng = Rng::new(seed);
    compute
        .iter()
        .zip(designs.iter().zip(points))
        .map(|(l, (d, p))| {
            // sample `window` group durations; the depth must cover the
            // excess production of a fast upstream burst: approximate by
            // o_par * (p99 window sum - mean window sum) / mean group time
            let m = d.m_len(l) as f64;
            let n = d.n_mac as f64;
            let dens = p.pair_density();
            let mean_t = (dens * m / n).ceil().max(1.0);
            let mut sums: Vec<f64> = Vec::with_capacity(64);
            for _ in 0..64 {
                let mut s = 0.0;
                for _ in 0..window {
                    let var = dens * (1.0 - dens) * m;
                    let k = (dens * m + rng.gauss() * var.sqrt()).clamp(0.0, m);
                    s += (k / n).ceil().max(1.0);
                }
                sums.push(s);
            }
            sums.sort_by(f64::total_cmp);
            let p99 = sums[(sums.len() * 99 / 100).min(sums.len() - 1)];
            let mean = mean_t * window as f64;
            let excess_groups = ((p99 - mean) / mean_t).ceil().max(1.0);
            (excess_groups as u64 + 1) * d.o_par as u64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::dse::{explore, network_throughput, DseConfig};
    use crate::hardware::device::DeviceBudget;
    use crate::hardware::resources::ResourceModel;

    fn small_net() -> Network {
        // calibnet is the smallest full network we model
        networks::calibnet()
    }

    fn uniform_points(net: &Network, s: f64) -> Vec<SparsityPoint> {
        vec![SparsityPoint { s_w: s, s_a: s }; net.compute_layers().len()]
    }

    fn modest_designs(net: &Network) -> Vec<LayerDesign> {
        // o_par chosen to make the sim fast but non-trivial
        net.compute_layers()
            .iter()
            .map(|l| {
                let o = crate::hardware::divisors(l.o_extent())
                    .into_iter()
                    .filter(|&o| o <= 16)
                    .next_back()
                    .unwrap_or(1);
                LayerDesign { i_par: 1, o_par: o, n_mac: (l.patch_k() / 4).max(1) }
            })
            .collect()
    }

    #[test]
    fn deterministic_sim_matches_analytical_model() {
        let net = small_net();
        let points = uniform_points(&net, 0.4);
        let designs = modest_designs(&net);
        let cfgs = stages_from_design(&net, &designs, &points, 4096);
        let rep = simulate(&net, &cfgs, 6, SparsityDynamics::Deterministic);
        let model = network_throughput(&net, &designs, &points);
        let ratio = rep.throughput / model;
        assert!(
            (0.9..=1.1).contains(&ratio),
            "sim {} vs model {model} (ratio {ratio})",
            rep.throughput
        );
    }

    #[test]
    fn dense_slower_than_sparse_in_sim() {
        let net = small_net();
        let designs = modest_designs(&net);
        let dense = stages_from_design(&net, &designs, &uniform_points(&net, 0.0), 4096);
        let sparse = stages_from_design(&net, &designs, &uniform_points(&net, 0.6), 4096);
        let rd = simulate(&net, &dense, 4, SparsityDynamics::Deterministic);
        let rs = simulate(&net, &sparse, 4, SparsityDynamics::Deterministic);
        assert!(
            rs.throughput > rd.throughput * 1.5,
            "sparse {} dense {}",
            rs.throughput,
            rd.throughput
        );
    }

    #[test]
    fn stochastic_close_to_deterministic_on_average() {
        let net = small_net();
        let points = uniform_points(&net, 0.5);
        let designs = modest_designs(&net);
        let cfgs = stages_from_design(&net, &designs, &points, 4096);
        let det = simulate(&net, &cfgs, 6, SparsityDynamics::Deterministic);
        let sto = simulate(&net, &cfgs, 6, SparsityDynamics::Stochastic { seed: 1 });
        let ratio = sto.throughput / det.throughput;
        // max-over-engines variance costs some throughput; the prefetch
        // buffer's work-conserving rounding can also *beat* Eq. 1's
        // per-group ceil — both effects stay within ~±40%
        assert!((0.6..=1.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn bottleneck_stage_is_busiest() {
        let net = small_net();
        let points = uniform_points(&net, 0.3);
        let designs = modest_designs(&net);
        let cfgs = stages_from_design(&net, &designs, &points, 4096);
        let rep = simulate(&net, &cfgs, 6, SparsityDynamics::Deterministic);
        let b = crate::dse::bottleneck(&net, &designs, &points);
        let busiest = rep
            .busy
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(busiest, b, "busy: {:?}", rep.busy);
    }

    #[test]
    fn tiny_fifo_causes_backpressure() {
        let net = small_net();
        let points = uniform_points(&net, 0.3);
        let designs = modest_designs(&net);
        let mut tight = stages_from_design(&net, &designs, &points, 4096);
        for c in tight.iter_mut() {
            c.fifo_capacity = c.design.o_par as u64; // minimum legal
        }
        let loose = stages_from_design(&net, &designs, &points, 1 << 20);
        let rt = simulate(&net, &tight, 4, SparsityDynamics::Deterministic);
        let rl = simulate(&net, &loose, 4, SparsityDynamics::Deterministic);
        assert!(rt.throughput <= rl.throughput * 1.001);
        assert!(
            rt.blocked.iter().sum::<u64>() >= rl.blocked.iter().sum::<u64>(),
            "tight {:?} loose {:?}",
            rt.blocked,
            rl.blocked
        );
    }

    #[test]
    fn sim_composes_with_dse_result() {
        let net = small_net();
        let points = uniform_points(&net, 0.4);
        let rm = ResourceModel::default();
        let dev = DeviceBudget {
            name: "mini".into(),
            dsp: 256,
            lut: 400_000,
            bram18k: 1500,
            uram: 128,
            freq_mhz: 250.0,
        };
        let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
        let cfgs = stages_from_design(&net, &d.designs, &points, rm.fifo_depth);
        let rep = simulate(&net, &cfgs, 4, SparsityDynamics::Deterministic);
        let ratio = rep.throughput / d.throughput;
        assert!(
            (0.8..=1.2).contains(&ratio),
            "sim {} vs dse {} ratio {ratio}",
            rep.throughput,
            d.throughput
        );
    }

    #[test]
    fn stochastic_deterministic_per_seed() {
        let net = small_net();
        let points = uniform_points(&net, 0.5);
        let designs = modest_designs(&net);
        let cfgs = stages_from_design(&net, &designs, &points, 4096);
        let a = simulate(&net, &cfgs, 3, SparsityDynamics::Stochastic { seed: 9 });
        let b = simulate(&net, &cfgs, 3, SparsityDynamics::Stochastic { seed: 9 });
        assert_eq!(a.total_cycles, b.total_cycles);
        let c = simulate(&net, &cfgs, 3, SparsityDynamics::Stochastic { seed: 10 });
        assert_ne!(a.total_cycles, c.total_cycles);
    }

    #[test]
    fn buffer_sizes_grow_with_variance() {
        let net = small_net();
        let designs = modest_designs(&net);
        // high variance point (density 0.5) vs near-deterministic (0.99)
        let hi_var = vec![SparsityPoint { s_w: 0.3, s_a: 0.3 }; designs.len()];
        let lo_var = vec![SparsityPoint { s_w: 0.0, s_a: 0.0 }; designs.len()];
        let bh = buffer_sizes(&net, &designs, &hi_var, 16, 1);
        let bl = buffer_sizes(&net, &designs, &lo_var, 16, 1);
        let sh: u64 = bh.iter().sum();
        let sl: u64 = bl.iter().sum();
        assert!(sh >= sl, "hi {sh} lo {sl}");
        assert!(bh.iter().all(|&b| b >= 1));
    }

    #[test]
    fn more_images_amortize_pipeline_fill() {
        let net = small_net();
        let points = uniform_points(&net, 0.4);
        let designs = modest_designs(&net);
        let cfgs = stages_from_design(&net, &designs, &points, 4096);
        let short = simulate(&net, &cfgs, 2, SparsityDynamics::Deterministic);
        let long = simulate(&net, &cfgs, 8, SparsityDynamics::Deterministic);
        // fill cost is constant, so avg images/cycle improves with length
        let avg_short = short.images as f64 / short.total_cycles as f64;
        let avg_long = long.images as f64 / long.total_cycles as f64;
        assert!(avg_long >= avg_short * 0.99);
    }
}
