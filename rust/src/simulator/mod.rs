//! Cycle-level simulator of the sparse dataflow pipeline (paper §IV).
//!
//! Validates the analytical model (Eq. 1–3) that the DSE trusts, and
//! exposes the dynamic effects the model abstracts away: per-group
//! sparsity variance, inter-layer FIFO backpressure, and pipeline fill.
//!
//! **Model.**  Each compute layer is a pipeline *stage* with `i×o` SPEs
//! processing one *output group* (`o_par` outputs) at a time.  A group's
//! duration is `max_e ⌈k_e / N⌉` over its engines, where `k_e` is the
//! engine's non-zero pair count — sampled per group around the calibrated
//! density (the run-time dynamism of activation sparsity).  Stages are
//! connected by FIFOs; a stage can start a group only when
//!
//! * its own SPEs are free,
//! * the upstream stage has produced the input the group's window needs
//!   (tracked as a fraction of the upstream image, plus the sliding-window
//!   skew of a k×k kernel), and
//! * the downstream FIFO has space (backpressure).
//!
//! **Engines.**  Two simulation cores share the stage model and produce
//! bit-identical [`SimReport`]s:
//!
//! * [`simulate_scan`] — the reference rescan-and-retry loop: at every
//!   instant it re-examines all stages in index order until a pass starts
//!   nothing, then advances to the earliest completion.  O(events × L)
//!   with a large constant; kept as the differential oracle.
//! * [`simulate`] — a discrete-event core: a completion-event min-heap
//!   plus a ready-set.  When a stage finishes a run, only itself and its
//!   neighbours are re-examined; starved/blocked stages schedule *wake*
//!   events at the exact cycle their predicate flips (computable because
//!   in-flight runs complete on a fixed schedule).  Under
//!   [`SparsityDynamics::Deterministic`] it also performs **group
//!   coalescing**: when input availability and FIFO headroom provably
//!   cover K future groups, all K commit as one run.  K is chosen
//!   pessimistically (neighbours assumed to make no progress beyond their
//!   in-flight runs), which can only *under*-coalesce — runs chain
//!   back-to-back, so the split into runs is unobservable and the result
//!   stays bit-identical to the scan.  Stochastic dynamics force K = 1 so
//!   the RNG draw order matches the scan's pass order exactly.
//!
//! The event core is what makes the simulator cheap enough to sit inside
//! the search loop: `engine::SimulatedEvaluator` re-scores the analytic
//! top-k of each generation with it (the fidelity ladder).
//!
//! **Per-layer parallelism.**  [`simulate_par`] runs the same event core
//! with the deterministic core's dominant inner loop — the per-group
//! feasibility scan of `det_run_len` — chunked over scoped worker
//! threads.  The scan is pure (frozen-neighbour run projections, no
//! mutation), and the run length is the *first failing group*, so the
//! minimum over chunk-local first failures reproduces the serial answer
//! exactly: `simulate_par` is differential-tested bit-identical to
//! [`simulate`] and [`simulate_scan`] at every thread count.  A serial
//! prefix keeps cheap early failures cheap, and threads only engage on
//! scans long enough to amortize the spawn (so `threads = 1`, small
//! pipelines, and [`SparsityDynamics::Stochastic`] — which never
//! coalesces — all take the unthreaded path).  This is what lets a
//! *single* promoted candidate's simulation spread over the engine's
//! idle cores in the fidelity ladder, instead of parallelising across
//! candidates only.
//!
//! **Buffering.**  [`buffer_sizes`] (and the sample-count-parameterised
//! [`buffer_sizes_with`]) implement the paper's moving-window buffer
//! heuristic over stochastic group durations.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::arch::{LayerDesc, Network, Op};
use crate::hardware::LayerDesign;
use crate::sparsity::SparsityPoint;
use crate::util::ceil_div;
use crate::util::rng::Rng;

/// Per-stage simulation parameters.
#[derive(Clone, Debug)]
pub struct StageConfig {
    pub design: LayerDesign,
    pub point: SparsityPoint,
    /// relative per-engine density multipliers (mean 1.0); length must be
    /// `design.engines()` or empty for perfectly balanced engines
    pub engine_imbalance: Vec<f64>,
    /// inter-layer FIFO capacity, in *output elements* of this stage
    pub fifo_capacity: u64,
    /// a k×k conv absorbs its sliding window into its own line buffer, so
    /// the window counts as extra credit on the *upstream* FIFO.  With
    /// line buffering disabled the producer gets no window credit and an
    /// undersized FIFO can genuinely wedge the pipeline (deadlock).
    pub line_buffered: bool,
}

/// What the simulator measures for one run.
#[derive(Clone, Debug, PartialEq)]
pub struct SimReport {
    /// true if the pipeline wedged (a config error: FIFO smaller than the
    /// consumer's window needs) — results are then meaningless
    pub deadlocked: bool,
    /// total cycles from first input to last output
    pub total_cycles: u64,
    /// steady-state throughput estimate: images/cycle over the back half
    pub throughput: f64,
    /// per-stage busy fraction (cycles computing / total)
    pub busy: Vec<f64>,
    /// per-stage cycles lost waiting for input
    pub starved: Vec<u64>,
    /// per-stage cycles lost blocked on a full output FIFO
    pub blocked: Vec<u64>,
    /// images simulated
    pub images: usize,
}

/// Variance model for the per-group non-zero pair count.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SparsityDynamics {
    /// every group sees exactly the calibrated mean density (validates the
    /// analytical model: simulator must match Eq. 1–3)
    Deterministic,
    /// binomial-like variance around the mean (normal approximation),
    /// modelling run-time activation dynamism
    Stochastic { seed: u64 },
}

/// An in-flight coalesced run of `k` back-to-back groups: starts at
/// `t0 + j*dt` and commits at `t0 + (j+1)*dt` for `j = 0..k`.  `done0` /
/// `start0` are the stage's `done` / `next_group` at `t0`, before the
/// first start.
#[derive(Clone, Copy, Debug)]
struct Run {
    t0: u64,
    dt: u64,
    k: u64,
    done0: u64,
    start0: u64,
}

struct Stage {
    layer: LayerDesc,
    cfg: StageConfig,
    /// groups per image
    groups: u64,
    /// pairs per output in one SPE
    m_len: usize,
    // dynamic state
    next_group: u64,
    busy_until: u64,
    /// completed groups (over all images)
    done: u64,
    busy_cycles: u64,
    starved_cycles: u64,
    blocked_cycles: u64,
    /// fractional work carried across group boundaries: the SPE's
    /// non-zero-pair prefetch buffer lets the arbiter keep MACs busy
    /// across groups, so per-group rounding does not quantize to whole
    /// cycles (paper §IV: "pre-fetch data in a buffer to keep the
    /// hardware operators busy at each cycle")
    work_carry: f64,
    // event-core state (unused by the scan reference)
    run: Option<Run>,
    idle_since: u64,
    idle_starved: bool,
    finished: bool,
}

impl Stage {
    /// Upstream image fraction needed before group `g` (within an image)
    /// can start: its share of the image plus the sliding-window skew.
    fn input_fraction_needed(&self, g_in_image: u64) -> f64 {
        let frac = (g_in_image + 1) as f64 / self.groups as f64;
        let skew = match self.layer.op {
            Op::Conv { kernel, .. } if kernel > 1 => {
                // need `kernel` rows of input before the first output row
                kernel as f64 / self.layer.in_hw.max(1) as f64
            }
            _ => 0.0,
        };
        (frac + skew).min(1.0)
    }

    /// Sample the group duration in cycles.
    fn group_cycles(&mut self, rng: Option<&mut Rng>) -> u64 {
        let d = self.cfg.point.pair_density();
        let m = self.m_len as f64;
        let n = self.cfg.design.n_mac as f64;
        let engines = self.cfg.design.engines() as usize;
        match rng {
            None => {
                // deterministic: exactly the analytical Eq. 1
                ((d * m / n).ceil() as u64).max(1)
            }
            Some(rng) => {
                // per-engine binomial (normal approx), imbalance-scaled;
                // group waits for its slowest engine
                let mut worst = 1.0f64;
                for e in 0..engines {
                    let imb = self
                        .cfg
                        .engine_imbalance
                        .get(e)
                        .copied()
                        .unwrap_or(1.0);
                    let mean = (d * imb).clamp(0.0, 1.0) * m;
                    let var = (d * imb).clamp(0.0, 1.0) * (1.0 - (d * imb).clamp(0.0, 1.0)) * m;
                    let k = (mean + rng.gauss() * var.sqrt()).round().clamp(0.0, m);
                    worst = worst.max(k / n);
                }
                // work-conserving rounding via the pair-prefetch buffer:
                // leftover fractional cycles carry into the next group
                // instead of quantizing every group up to a whole cycle
                let t_raw = worst + self.work_carry;
                let t = t_raw.floor();
                if t < 1.0 {
                    self.work_carry = 0.0; // emission takes the cycle anyway
                    1
                } else {
                    self.work_carry = t_raw - t;
                    t as u64
                }
            }
        }
    }
}

/// The input-availability predicate shared by both cores: the upstream
/// stage must already be past image `img` and have produced the fraction
/// this group's window needs.
fn input_ok(up_done: u64, up_groups: u64, img: u64, need: f64) -> bool {
    let in_img = up_done.saturating_sub(img * up_groups).min(up_groups);
    up_done >= img * up_groups && (in_img as f64 / up_groups as f64) >= need - 1e-12
}

/// The downstream-FIFO space predicate shared by both cores, evaluated
/// for producer `me` with `my_done` committed groups against a consumer
/// whose `next_group` is `down_next`.  Groups the consumer has *started*
/// have drained their input; a line-buffered k×k consumer additionally
/// absorbs its sliding window into its own line buffer.
fn space_ok_at(me: &Stage, down: &Stage, my_done: u64, down_next: u64) -> bool {
    let o_par = me.cfg.design.o_par as u64;
    let my_out = my_done * o_par;
    let my_total = me.groups * o_par;
    let per_down_group = my_total as f64 / down.groups as f64;
    let consumed = (down_next as f64 * per_down_group) as u64;
    let window = if down.cfg.line_buffered {
        (down.input_fraction_needed(0) * my_total as f64) as u64
    } else {
        0
    };
    my_out.saturating_sub(consumed) <= me.cfg.fifo_capacity + window + o_par
}

/// Commit groups on a stage (`done` → `new_done`) at time `now`.  The
/// single shared commit path: **every** commit — scan advance, scan
/// same-instant bookkeeping, event-core run progress — goes through here,
/// so sink-side image completion times are stamped no matter which path
/// retires the group (the `image_done` stamps used to live only in the
/// scan's advance branch).
fn commit_groups(
    s: &mut Stage,
    is_sink: bool,
    new_done: u64,
    now: u64,
    images: usize,
    image_done: &mut [u64],
    committed: &mut u64,
) {
    debug_assert!(new_done >= s.done);
    *committed += new_done - s.done;
    s.done = new_done;
    if is_sink {
        // record sink-side image completion times (first stamp wins)
        let done_imgs = (s.done / s.groups).min(images as u64) as usize;
        for t in image_done.iter_mut().take(done_imgs) {
            if *t == 0 {
                *t = now;
            }
        }
    }
}

fn build_stages(compute: &[LayerDesc], configs: &[StageConfig]) -> Vec<Stage> {
    compute
        .iter()
        .zip(configs)
        .map(|(l, c)| {
            let groups = ceil_div(l.outputs_per_image() as u64, c.design.o_par as u64);
            let m_len = c.design.m_len(l);
            Stage {
                layer: l.clone(),
                cfg: c.clone(),
                groups,
                m_len,
                next_group: 0,
                busy_until: 0,
                done: 0,
                busy_cycles: 0,
                starved_cycles: 0,
                blocked_cycles: 0,
                work_carry: 0.0,
                run: None,
                idle_since: 0,
                idle_starved: false,
                finished: false,
            }
        })
        .collect()
}

fn finish_report(
    stages: &[Stage],
    image_done: &mut [u64],
    images: usize,
    deadlocked: bool,
) -> SimReport {
    let total_cycles = stages.iter().map(|s| s.busy_until).max().unwrap_or(0);
    for t in image_done.iter_mut() {
        if *t == 0 {
            *t = total_cycles;
        }
    }
    // steady-state throughput: skip the pipeline-fill image(s), measure
    // sink-side inter-image spacing over the rest
    let throughput = if images >= 2 {
        let fill = image_done[0];
        let span = image_done[images - 1].saturating_sub(fill).max(1);
        (images - 1) as f64 / span as f64
    } else {
        1.0 / total_cycles.max(1) as f64
    };
    SimReport {
        deadlocked,
        total_cycles,
        throughput,
        busy: stages
            .iter()
            .map(|s| s.busy_cycles as f64 / total_cycles.max(1) as f64)
            .collect(),
        starved: stages.iter().map(|s| s.starved_cycles).collect(),
        blocked: stages.iter().map(|s| s.blocked_cycles).collect(),
        images,
    }
}

/// Build stage configs straight from a DSE result (balanced engines,
/// default FIFO depth from the resource model's `fifo_depth`).
pub fn stages_from_design(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
    fifo_depth: u64,
) -> Vec<StageConfig> {
    let compute = net.compute_layers();
    assert_eq!(compute.len(), designs.len());
    assert_eq!(compute.len(), points.len());
    designs
        .iter()
        .zip(points)
        .map(|(d, p)| StageConfig {
            design: *d,
            point: *p,
            engine_imbalance: Vec::new(),
            fifo_capacity: fifo_depth.max(d.o_par as u64 * 2),
            line_buffered: true,
        })
        .collect()
}

/// Simulate `images` images through the pipeline (event-driven core with
/// group coalescing — see the module docs; bit-identical to
/// [`simulate_scan`]).
pub fn simulate(
    net: &Network,
    configs: &[StageConfig],
    images: usize,
    dynamics: SparsityDynamics,
) -> SimReport {
    simulate_events(net, configs, images, dynamics, true)
}

/// The discrete-event core with an explicit coalescing switch
/// (`coalesce = false` forces one-group runs — the pure event-driven
/// baseline the speed bench compares against).
pub fn simulate_events(
    net: &Network,
    configs: &[StageConfig],
    images: usize,
    dynamics: SparsityDynamics,
    coalesce: bool,
) -> SimReport {
    simulate_events_threaded(net, configs, images, dynamics, coalesce, 1)
}

/// [`simulate`] with per-layer parallelism: the deterministic core's
/// per-group feasibility scans (`det_run_len`) are chunked over up to
/// `threads` scoped workers, so a *single* network's simulation spreads
/// over idle cores.  Bit-identical to [`simulate`] at every thread
/// count — the run length is the first failing group, and the minimum
/// over chunk-local first failures is exactly the serial answer.
/// `threads <= 1` is the serial core; stochastic dynamics never coalesce
/// and therefore never engage the workers.
pub fn simulate_par(
    net: &Network,
    configs: &[StageConfig],
    images: usize,
    dynamics: SparsityDynamics,
    threads: usize,
) -> SimReport {
    simulate_events_threaded(net, configs, images, dynamics, true, threads)
}

fn simulate_events_threaded(
    net: &Network,
    configs: &[StageConfig],
    images: usize,
    dynamics: SparsityDynamics,
    coalesce: bool,
    threads: usize,
) -> SimReport {
    let compute: Vec<LayerDesc> = net.compute_layers().into_iter().cloned().collect();
    assert_eq!(compute.len(), configs.len());
    assert!(images > 0);
    let mut rng = match dynamics {
        SparsityDynamics::Deterministic => None,
        SparsityDynamics::Stochastic { seed } => Some(Rng::new(seed)),
    };
    let mut stages = build_stages(&compute, configs);
    let n = stages.len();
    // deterministic group time per stage (Eq. 1) — constant, so coalesced
    // runs have a fixed schedule
    let det_t: Vec<u64> = stages.iter_mut().map(|s| s.group_cycles(None)).collect();
    let total_groups: u64 = stages.iter().map(|s| s.groups).sum::<u64>() * images as u64;

    let mut image_done: Vec<u64> = vec![0; images];
    let mut committed = 0u64;
    let mut deadlocked = false;
    let mut now = 0u64;
    // (time, stage, kind): kind 0 = run end, 1 = wake.  Only time orders
    // processing — all events at one instant are handled together.
    let mut heap: BinaryHeap<Reverse<(u64, usize, u8)>> = BinaryHeap::new();

    // ready-set for the first instant: everything is a candidate
    let mut cur: Vec<bool> = vec![true; n];
    let mut bstart: Vec<bool> = vec![false; n];
    let mut first = true;

    while committed < total_groups {
        if first {
            first = false;
        } else {
            // ---- advance to the next event instant ----
            let Some(&Reverse((t, _, _))) = heap.peek() else {
                // no in-flight run and work remains: the pipeline wedged
                deadlocked = true;
                break;
            };
            now = t;
            for f in cur.iter_mut() {
                *f = false;
            }
            for b in bstart.iter_mut() {
                *b = false;
            }
            while let Some(&Reverse((tt, si, kind))) = heap.peek() {
                if tt != now {
                    break;
                }
                heap.pop();
                cur[si] = true;
                if kind == 0 {
                    if si > 0 {
                        cur[si - 1] = true;
                    }
                    if si + 1 < n {
                        cur[si + 1] = true;
                    }
                }
            }
            // ---- materialize run progress up to `now` (the scan's
            // advance-commit phase) ----
            for i in 0..n {
                let Some(r) = stages[i].run else { continue };
                let c = ((now - r.t0) / r.dt).min(r.k);
                let target_done = r.done0 + c;
                if target_done > stages[i].done {
                    let is_sink = i + 1 == n;
                    commit_groups(
                        &mut stages[i],
                        is_sink,
                        target_done,
                        now,
                        images,
                        &mut image_done,
                        &mut committed,
                    );
                    if i + 1 < n {
                        cur[i + 1] = true;
                    }
                }
                // starts strictly before `now` (the start at an exact
                // boundary belongs to round 1 below, like a scan pass-1
                // start)
                let q_started = (((now - r.t0 - 1) / r.dt) + 1).min(r.k);
                let target_next = r.start0 + q_started;
                if target_next > stages[i].next_group {
                    stages[i].next_group = target_next;
                    if i > 0 {
                        cur[i - 1] = true;
                    }
                }
                if c == r.k {
                    // run complete — stage is idle again
                    stages[i].run = None;
                    stages[i].idle_since = now;
                    if stages[i].next_group >= stages[i].groups * images as u64 {
                        stages[i].finished = true;
                    }
                    cur[i] = true;
                    if i > 0 {
                        cur[i - 1] = true;
                    }
                    if i + 1 < n {
                        cur[i + 1] = true;
                    }
                } else {
                    let rem = (now - r.t0) % r.dt;
                    let q = (now - r.t0) / r.dt;
                    if rem == 0 && q >= 1 && q < r.k && stages[i].next_group == r.start0 + q {
                        // mid-run back-to-back start due exactly now
                        bstart[i] = true;
                    }
                }
            }
            if committed >= total_groups {
                break;
            }
        }

        // ---- rounds: each round replays one scan pass over the ready
        // set; starts enable neighbours for the next round ----
        let mut round = 1u32;
        loop {
            let mut nxt = vec![false; n];
            let mut any = false;
            for i in 0..n {
                if round == 1 && bstart[i] {
                    // implicit start of a coalesced run's next group —
                    // applied at this stage's pass position so earlier
                    // stages see the pre-pass value, like the scan
                    stages[i].next_group += 1;
                    any = true;
                    if i > 0 {
                        nxt[i - 1] = true;
                    }
                    if i + 1 < n {
                        nxt[i + 1] = true;
                    }
                    continue;
                }
                if !cur[i] || stages[i].finished || stages[i].run.is_some() {
                    continue;
                }
                // idle stage examination: settle its idle interval first
                if now > stages[i].idle_since {
                    let idle = now - stages[i].idle_since;
                    if stages[i].idle_starved {
                        stages[i].starved_cycles += idle;
                    } else {
                        stages[i].blocked_cycles += idle;
                    }
                    stages[i].idle_since = now;
                }
                let img = stages[i].next_group / stages[i].groups;
                let g_in = stages[i].next_group % stages[i].groups;
                let in_ok = i == 0 || {
                    let need = stages[i].input_fraction_needed(g_in);
                    let up = &stages[i - 1];
                    input_ok(up.done, up.groups, img, need)
                };
                let sp_ok = i + 1 == n
                    || space_ok_at(
                        &stages[i],
                        &stages[i + 1],
                        stages[i].done,
                        stages[i + 1].next_group,
                    );
                if in_ok && sp_ok {
                    let (k, dt) = match rng.as_mut() {
                        None => {
                            let dt = det_t[i];
                            let k = if coalesce {
                                det_run_len(&stages, i, n, now, dt, threads)
                            } else {
                                1
                            };
                            (k, dt)
                        }
                        // stochastic durations have no closed-form
                        // schedule: one group per run, sampled in scan
                        // pass order
                        Some(rng) => (1, stages[i].group_cycles(Some(rng))),
                    };
                    let end = now + k * dt;
                    let s = &mut stages[i];
                    s.run = Some(Run { t0: now, dt, k, done0: s.done, start0: s.next_group });
                    s.next_group += 1;
                    s.busy_until = end;
                    s.busy_cycles += k * dt;
                    heap.push(Reverse((end, i, 0)));
                    any = true;
                    if i > 0 {
                        nxt[i - 1] = true;
                    }
                    if i + 1 < n {
                        nxt[i + 1] = true;
                    }
                } else {
                    stages[i].idle_starved = !in_ok;
                    // deterministic runs have exact schedules, so the
                    // instant the blocking predicate flips is computable:
                    // wake exactly then (no such instant within the
                    // neighbour's current run → its end event re-examines
                    // us anyway)
                    if coalesce && rng.is_none() {
                        if !in_ok {
                            schedule_input_wake(&stages, i, now, &mut heap);
                        } else if i + 1 < n {
                            schedule_space_wake(&stages, i, now, &mut heap);
                        }
                    }
                }
            }
            if !any {
                break;
            }
            cur = nxt;
            round += 1;
        }
    }

    if deadlocked {
        // settle open idle intervals through the last event instant — the
        // scan accounts exactly up to its final advance target
        for s in stages.iter_mut() {
            if !s.finished && s.run.is_none() && now > s.idle_since {
                let idle = now - s.idle_since;
                if s.idle_starved {
                    s.starved_cycles += idle;
                } else {
                    s.blocked_cycles += idle;
                }
                s.idle_since = now;
            }
        }
    }
    finish_report(&stages, &mut image_done, images, deadlocked)
}

/// Serial prefix scanned before any workers spawn in [`det_run_len`]:
/// early failures (the common case when a neighbour is nearly full or
/// nearly drained) stay as cheap as the fully serial core.
const DET_PAR_PREFIX: u64 = 1024;
/// Minimum tail length worth spawning workers for — below this the
/// spawn overhead dwarfs the scan.
const DET_PAR_MIN_TAIL: u64 = 2048;

/// How many back-to-back groups stage `i` can provably run starting at
/// `t` (deterministic dynamics).  Pessimistic: neighbours are assumed to
/// make no progress beyond their in-flight runs, so a positive answer is
/// a guarantee — the scan would start exactly these groups at exactly
/// these times.  Capped at the image boundary so a run never crosses an
/// image (keeps the input predicate's `img` fixed and sink stamping at
/// run ends).
///
/// With `threads > 1` the per-group scan is chunked over scoped workers.
/// The predicate below is pure — it reads only neighbour runs frozen at
/// their pre-round schedules — and the answer is the index of the first
/// failing group, so the minimum over chunk-local first failures equals
/// the serial first failure bit-for-bit.
fn det_run_len(stages: &[Stage], i: usize, n: usize, t: u64, dt: u64, threads: usize) -> u64 {
    let s = &stages[i];
    let g_in = s.next_group % s.groups;
    let cap = s.groups - g_in;
    if cap == 1 {
        return 1;
    }
    let img = s.next_group / s.groups;
    let done0 = s.done;
    // fast path: if the whole remaining image clears against neighbours
    // frozen at their current state, no per-group checks are needed
    let quick_in = i == 0 || {
        let up = &stages[i - 1];
        input_ok(up.done, up.groups, img, s.input_fraction_needed(g_in + cap - 1))
    };
    let quick_sp =
        i + 1 == n || space_ok_at(s, &stages[i + 1], done0 + cap - 1, stages[i + 1].next_group);
    if quick_in && quick_sp {
        return cap;
    }
    // feasibility of the group `j` positions into the prospective run
    let ok_at = |j: u64| -> bool {
        let tau = t + j * dt;
        let ok_in = i == 0 || {
            let up = &stages[i - 1];
            let up_done = match &up.run {
                // commits at or before `tau` (commits land before passes)
                Some(r) => r.done0 + ((tau - r.t0) / r.dt).min(r.k),
                None => up.done,
            };
            input_ok(up_done, up.groups, img, s.input_fraction_needed(g_in + j))
        };
        let ok_sp = i + 1 == n || {
            let down = &stages[i + 1];
            let down_next = match &down.run {
                // starts strictly before `tau`: the consumer's own start
                // at `tau` sits later in that pass than our stage
                Some(r) => r.start0 + (((tau - r.t0 - 1) / r.dt) + 1).min(r.k),
                None => down.next_group,
            };
            space_ok_at(s, down, done0 + j, down_next)
        };
        ok_in && ok_sp
    };
    // the run length is the first failing j (all of 1..j passed), or cap
    // when every group clears
    let prefix_end = cap.min(1 + DET_PAR_PREFIX);
    for j in 1..prefix_end {
        if !ok_at(j) {
            return j;
        }
    }
    if prefix_end == cap {
        return cap;
    }
    let tail = cap - prefix_end;
    if threads <= 1 || tail < DET_PAR_MIN_TAIL {
        for j in prefix_end..cap {
            if !ok_at(j) {
                return j;
            }
        }
        return cap;
    }
    // chunked parallel first-failure search over the tail; `fetch_min`
    // is commutative, so the final minimum is schedule-independent
    let workers = threads.min(tail.div_ceil(DET_PAR_MIN_TAIL / 2) as usize).max(2);
    let chunk = tail.div_ceil(workers as u64);
    let first_fail = AtomicU64::new(u64::MAX);
    std::thread::scope(|sc| {
        for w in 0..workers {
            let (ok_at, first_fail) = (&ok_at, &first_fail);
            sc.spawn(move || {
                let lo = prefix_end + w as u64 * chunk;
                let hi = (lo + chunk).min(cap);
                for j in lo..hi {
                    // a failure in an earlier chunk makes this one moot;
                    // relaxed: advisory early-exit hint — correctness
                    // comes from the fetch_min reduction + scope join
                    if j & 511 == 0 && first_fail.load(Ordering::Relaxed) <= lo {
                        return;
                    }
                    if !ok_at(j) {
                        // relaxed: commutative min-reduction, read after
                        // the scope joins every worker
                        first_fail.fetch_min(j, Ordering::Relaxed);
                        return;
                    }
                }
            });
        }
    });
    match first_fail.into_inner() {
        u64::MAX => cap,
        j => j,
    }
}

/// Wake a starved stage at the exact cycle its upstream's in-flight run
/// commits enough input (binary search — the predicate is monotone in
/// the commit count).
fn schedule_input_wake(
    stages: &[Stage],
    i: usize,
    now: u64,
    heap: &mut BinaryHeap<Reverse<(u64, usize, u8)>>,
) {
    let s = &stages[i];
    let up = &stages[i - 1];
    let Some(r) = &up.run else { return };
    let img = s.next_group / s.groups;
    let need = s.input_fraction_needed(s.next_group % s.groups);
    let c_now = ((now - r.t0) / r.dt).min(r.k);
    let (mut lo, mut hi) = (c_now + 1, r.k);
    if lo > hi || !input_ok(r.done0 + hi, up.groups, img, need) {
        return;
    }
    while lo < hi {
        let mid = (lo + hi) / 2;
        if input_ok(r.done0 + mid, up.groups, img, need) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let t_wake = r.t0 + lo * r.dt;
    if t_wake > now {
        heap.push(Reverse((t_wake, i, 1)));
    }
}

/// Wake a blocked producer at the exact cycle its consumer's in-flight
/// run starts enough groups to free FIFO space (monotone in the start
/// count, binary searched).
fn schedule_space_wake(
    stages: &[Stage],
    i: usize,
    now: u64,
    heap: &mut BinaryHeap<Reverse<(u64, usize, u8)>>,
) {
    let s = &stages[i];
    let down = &stages[i + 1];
    let Some(r) = &down.run else { return };
    // start boundaries q = 1..k-1 at t0 + q*dt; after the start at q the
    // consumer's next_group is start0 + q + 1
    let q_lo = (now - r.t0) / r.dt + 1;
    let q_hi = r.k.saturating_sub(1);
    if q_lo > q_hi || !space_ok_at(s, down, s.done, r.start0 + q_hi + 1) {
        return;
    }
    let (mut lo, mut hi) = (q_lo, q_hi);
    while lo < hi {
        let mid = (lo + hi) / 2;
        if space_ok_at(s, down, s.done, r.start0 + mid + 1) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let t_wake = r.t0 + lo * r.dt;
    if t_wake > now {
        heap.push(Reverse((t_wake, i, 1)));
    }
}

/// The reference rescan-and-retry loop (the original `simulate`): at each
/// instant, passes over all stages in index order until nothing more
/// starts, then advances time to the earliest completion.  Kept as the
/// differential oracle for the event core — `simulate` must reproduce its
/// `SimReport` bit for bit.
pub fn simulate_scan(
    net: &Network,
    configs: &[StageConfig],
    images: usize,
    dynamics: SparsityDynamics,
) -> SimReport {
    let compute: Vec<LayerDesc> = net.compute_layers().into_iter().cloned().collect();
    assert_eq!(compute.len(), configs.len());
    assert!(images > 0);
    let mut rng = match dynamics {
        SparsityDynamics::Deterministic => None,
        SparsityDynamics::Stochastic { seed } => Some(Rng::new(seed)),
    };
    let mut stages = build_stages(&compute, configs);
    let n = stages.len();
    let total_groups: u64 = stages.iter().map(|s| s.groups).sum::<u64>() * images as u64;

    let mut now = 0u64;
    let mut committed = 0u64;
    // steady-state throughput is measured from *image* completion times at
    // the sink: the last stage often bursts through one image's groups, so
    // group-level timing would wildly overestimate throughput.
    let mut image_done: Vec<u64> = vec![0; images];
    let mut deadlocked = false;

    while committed < total_groups {
        // try to start any idle stage
        let mut started = false;
        for i in 0..n {
            if stages[i].busy_until > now {
                continue;
            }
            let img = stages[i].next_group / stages[i].groups;
            if img >= images as u64 {
                continue; // finished all its work
            }
            let g_in_image = stages[i].next_group % stages[i].groups;
            // 1) input availability
            let in_ok = i == 0 || {
                let need = stages[i].input_fraction_needed(g_in_image);
                let up = &stages[i - 1];
                input_ok(up.done, up.groups, img, need)
            };
            // 2) downstream FIFO space
            let sp_ok = i + 1 == n
                || space_ok_at(
                    &stages[i],
                    &stages[i + 1],
                    stages[i].done,
                    stages[i + 1].next_group,
                );
            if in_ok && sp_ok {
                let t = stages[i].group_cycles(rng.as_mut());
                stages[i].busy_until = now + t;
                stages[i].busy_cycles += t;
                stages[i].next_group += 1;
                started = true;
            }
        }
        if !started {
            // advance time to the earliest completion
            let next = stages
                .iter()
                .filter(|s| s.busy_until > now)
                .map(|s| s.busy_until)
                .min();
            let Some(next) = next else {
                // pipeline wedged: FIFO capacity below the consumer's
                // window needs — report it instead of spinning forever
                deadlocked = true;
                break;
            };
            // account idle reasons between now and next
            for i in 0..n {
                if stages[i].busy_until <= now {
                    let img = stages[i].next_group / stages[i].groups;
                    if img >= images as u64 {
                        continue;
                    }
                    let g = stages[i].next_group % stages[i].groups;
                    let starving = i > 0 && {
                        let need = stages[i].input_fraction_needed(g);
                        let up = &stages[i - 1];
                        !input_ok(up.done, up.groups, img, need)
                    };
                    if starving {
                        stages[i].starved_cycles += next - now;
                    } else {
                        stages[i].blocked_cycles += next - now;
                    }
                }
            }
            now = next;
            // commit completions
            for i in 0..n {
                if stages[i].busy_until == now && stages[i].done < stages[i].next_group {
                    let new_done = stages[i].next_group;
                    let is_sink = i + 1 == n;
                    commit_groups(
                        &mut stages[i],
                        is_sink,
                        new_done,
                        now,
                        images,
                        &mut image_done,
                        &mut committed,
                    );
                }
            }
        } else {
            // commit any zero-latency bookkeeping.  With group times >= 1
            // this branch is provably unreachable (an idle stage always
            // has done == next_group), but it is kept from the original
            // loop — and routed through the shared stamping commit path so
            // that *if* a group ever retired here, sink image completions
            // would still be recorded (they used to be silently dropped).
            for i in 0..n {
                if stages[i].busy_until <= now && stages[i].done < stages[i].next_group {
                    let new_done = stages[i].next_group;
                    let is_sink = i + 1 == n;
                    commit_groups(
                        &mut stages[i],
                        is_sink,
                        new_done,
                        now,
                        images,
                        &mut image_done,
                        &mut committed,
                    );
                }
            }
        }
    }
    finish_report(&stages, &mut image_done, images, deadlocked)
}

/// Moving-window buffer-size heuristic (paper §IV "Buffering Strategy",
/// after PASS [4]): simulate with stochastic sparsity, find per-stage the
/// FIFO depth that absorbs the observed rate variance — the 99th
/// percentile of the occupancy a window of `window` groups would need.
/// Uses the historical default of 64 window samples; see
/// [`buffer_sizes_with`] to control the sample count.
pub fn buffer_sizes(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
    window: usize,
    seed: u64,
) -> Vec<u64> {
    buffer_sizes_with(net, designs, points, window, seed, 64)
}

/// [`buffer_sizes`] with an explicit number of sampled windows per layer
/// (more samples sharpen the p99 estimate at linear cost).
pub fn buffer_sizes_with(
    net: &Network,
    designs: &[LayerDesign],
    points: &[SparsityPoint],
    window: usize,
    seed: u64,
    samples: usize,
) -> Vec<u64> {
    let samples = samples.max(1);
    let compute = net.compute_layers();
    let mut rng = Rng::new(seed);
    compute
        .iter()
        .zip(designs.iter().zip(points))
        .map(|(l, (d, p))| {
            // sample `window` group durations; the depth must cover the
            // excess production of a fast upstream burst: approximate by
            // o_par * (p99 window sum - mean window sum) / mean group time
            let m = d.m_len(l) as f64;
            let n = d.n_mac as f64;
            let dens = p.pair_density();
            let mean_t = (dens * m / n).ceil().max(1.0);
            let mut sums: Vec<f64> = Vec::with_capacity(samples);
            for _ in 0..samples {
                let mut s = 0.0;
                for _ in 0..window {
                    let var = dens * (1.0 - dens) * m;
                    let k = (dens * m + rng.gauss() * var.sqrt()).clamp(0.0, m);
                    s += (k / n).ceil().max(1.0);
                }
                sums.push(s);
            }
            sums.sort_by(f64::total_cmp);
            let p99 = sums[(sums.len() * 99 / 100).min(sums.len() - 1)];
            let mean = mean_t * window as f64;
            let excess_groups = ((p99 - mean) / mean_t).ceil().max(1.0);
            (excess_groups as u64 + 1) * d.o_par as u64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::dse::{explore, network_throughput, DseConfig};
    use crate::hardware::device::DeviceBudget;
    use crate::hardware::resources::ResourceModel;
    use crate::util::prop::forall;

    fn small_net() -> Network {
        // calibnet is the smallest full network we model
        networks::calibnet()
    }

    fn uniform_points(net: &Network, s: f64) -> Vec<SparsityPoint> {
        vec![SparsityPoint { s_w: s, s_a: s }; net.compute_layers().len()]
    }

    fn modest_designs(net: &Network) -> Vec<LayerDesign> {
        // o_par chosen to make the sim fast but non-trivial
        net.compute_layers()
            .iter()
            .map(|l| {
                let o = crate::hardware::divisors(l.o_extent())
                    .into_iter()
                    .filter(|&o| o <= 16)
                    .next_back()
                    .unwrap_or(1);
                LayerDesign { i_par: 1, o_par: o, n_mac: (l.patch_k() / 4).max(1) }
            })
            .collect()
    }

    #[test]
    fn deterministic_sim_matches_analytical_model() {
        let net = small_net();
        let points = uniform_points(&net, 0.4);
        let designs = modest_designs(&net);
        let cfgs = stages_from_design(&net, &designs, &points, 4096);
        let rep = simulate(&net, &cfgs, 6, SparsityDynamics::Deterministic);
        let model = network_throughput(&net, &designs, &points);
        let ratio = rep.throughput / model;
        assert!(
            (0.9..=1.1).contains(&ratio),
            "sim {} vs model {model} (ratio {ratio})",
            rep.throughput
        );
    }

    #[test]
    fn dense_slower_than_sparse_in_sim() {
        let net = small_net();
        let designs = modest_designs(&net);
        let dense = stages_from_design(&net, &designs, &uniform_points(&net, 0.0), 4096);
        let sparse = stages_from_design(&net, &designs, &uniform_points(&net, 0.6), 4096);
        let rd = simulate(&net, &dense, 4, SparsityDynamics::Deterministic);
        let rs = simulate(&net, &sparse, 4, SparsityDynamics::Deterministic);
        assert!(
            rs.throughput > rd.throughput * 1.5,
            "sparse {} dense {}",
            rs.throughput,
            rd.throughput
        );
    }

    #[test]
    fn stochastic_close_to_deterministic_on_average() {
        let net = small_net();
        let points = uniform_points(&net, 0.5);
        let designs = modest_designs(&net);
        let cfgs = stages_from_design(&net, &designs, &points, 4096);
        let det = simulate(&net, &cfgs, 6, SparsityDynamics::Deterministic);
        let sto = simulate(&net, &cfgs, 6, SparsityDynamics::Stochastic { seed: 1 });
        let ratio = sto.throughput / det.throughput;
        // max-over-engines variance costs some throughput; the prefetch
        // buffer's work-conserving rounding can also *beat* Eq. 1's
        // per-group ceil — both effects stay within ~±40%
        assert!((0.6..=1.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn bottleneck_stage_is_busiest() {
        let net = small_net();
        let points = uniform_points(&net, 0.3);
        let designs = modest_designs(&net);
        let cfgs = stages_from_design(&net, &designs, &points, 4096);
        let rep = simulate(&net, &cfgs, 6, SparsityDynamics::Deterministic);
        let b = crate::dse::bottleneck(&net, &designs, &points);
        let busiest = rep
            .busy
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(busiest, b, "busy: {:?}", rep.busy);
    }

    #[test]
    fn tiny_fifo_causes_backpressure() {
        let net = small_net();
        let points = uniform_points(&net, 0.3);
        let designs = modest_designs(&net);
        let mut tight = stages_from_design(&net, &designs, &points, 4096);
        for c in tight.iter_mut() {
            c.fifo_capacity = c.design.o_par as u64; // minimum legal
        }
        let loose = stages_from_design(&net, &designs, &points, 1 << 20);
        let rt = simulate(&net, &tight, 4, SparsityDynamics::Deterministic);
        let rl = simulate(&net, &loose, 4, SparsityDynamics::Deterministic);
        assert!(rt.throughput <= rl.throughput * 1.001);
        assert!(
            rt.blocked.iter().sum::<u64>() >= rl.blocked.iter().sum::<u64>(),
            "tight {:?} loose {:?}",
            rt.blocked,
            rl.blocked
        );
    }

    #[test]
    fn sim_composes_with_dse_result() {
        let net = small_net();
        let points = uniform_points(&net, 0.4);
        let rm = ResourceModel::default();
        let dev = DeviceBudget {
            name: "mini".into(),
            dsp: 256,
            lut: 400_000,
            bram18k: 1500,
            uram: 128,
            freq_mhz: 250.0,
        };
        let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
        let cfgs = stages_from_design(&net, &d.designs, &points, rm.fifo_depth);
        let rep = simulate(&net, &cfgs, 4, SparsityDynamics::Deterministic);
        let ratio = rep.throughput / d.throughput;
        assert!(
            (0.8..=1.2).contains(&ratio),
            "sim {} vs dse {} ratio {ratio}",
            rep.throughput,
            d.throughput
        );
    }

    #[test]
    fn stochastic_deterministic_per_seed() {
        let net = small_net();
        let points = uniform_points(&net, 0.5);
        let designs = modest_designs(&net);
        let cfgs = stages_from_design(&net, &designs, &points, 4096);
        let a = simulate(&net, &cfgs, 3, SparsityDynamics::Stochastic { seed: 9 });
        let b = simulate(&net, &cfgs, 3, SparsityDynamics::Stochastic { seed: 9 });
        assert_eq!(a.total_cycles, b.total_cycles);
        let c = simulate(&net, &cfgs, 3, SparsityDynamics::Stochastic { seed: 10 });
        assert_ne!(a.total_cycles, c.total_cycles);
    }

    #[test]
    fn buffer_sizes_grow_with_variance() {
        let net = small_net();
        let designs = modest_designs(&net);
        // s = 0.3 on both axes gives pair density 0.49 — nearly the
        // variance peak of the per-group binomial — vs the fully dense
        // point (density 1.0), whose group times are exactly deterministic
        let hi_var = vec![SparsityPoint { s_w: 0.3, s_a: 0.3 }; designs.len()];
        let lo_var = vec![SparsityPoint { s_w: 0.0, s_a: 0.0 }; designs.len()];
        let bh = buffer_sizes(&net, &designs, &hi_var, 16, 1);
        let bl = buffer_sizes(&net, &designs, &lo_var, 16, 1);
        // monotone per stage, not just in aggregate: variance can only
        // deepen the required buffer
        for (i, (h, l)) in bh.iter().zip(&bl).enumerate() {
            assert!(h >= l, "stage {i}: hi-var {h} < lo-var {l}");
        }
        let sh: u64 = bh.iter().sum();
        let sl: u64 = bl.iter().sum();
        assert!(sh >= sl, "hi {sh} lo {sl}");
        assert!(bh.iter().all(|&b| b >= 1));
    }

    #[test]
    fn buffer_sizes_sample_count_is_honored() {
        let net = small_net();
        let designs = modest_designs(&net);
        let points = vec![SparsityPoint { s_w: 0.3, s_a: 0.3 }; designs.len()];
        // the default wrapper is exactly 64 samples (the historical value)
        let a = buffer_sizes(&net, &designs, &points, 8, 7);
        let b = buffer_sizes_with(&net, &designs, &points, 8, 7, 64);
        assert_eq!(a, b);
        // zero-variance layers need exactly the minimal 2 * o_par depth at
        // any sample count: every window sums to the mean
        let dense = vec![SparsityPoint { s_w: 0.0, s_a: 0.0 }; designs.len()];
        for samples in [1usize, 8, 64, 256] {
            let bl = buffer_sizes_with(&net, &designs, &dense, 8, 7, samples);
            for (d, b) in designs.iter().zip(&bl) {
                assert_eq!(*b, 2 * d.o_par as u64, "samples {samples}");
            }
        }
    }

    #[test]
    fn more_images_amortize_pipeline_fill() {
        let net = small_net();
        let points = uniform_points(&net, 0.4);
        let designs = modest_designs(&net);
        let cfgs = stages_from_design(&net, &designs, &points, 4096);
        let short = simulate(&net, &cfgs, 2, SparsityDynamics::Deterministic);
        let long = simulate(&net, &cfgs, 8, SparsityDynamics::Deterministic);
        // fill cost is constant, so avg images/cycle improves with length
        let avg_short = short.images as f64 / short.total_cycles as f64;
        let avg_long = long.images as f64 / long.total_cycles as f64;
        assert!(avg_long >= avg_short * 0.99);
    }

    // ===== event core vs scan differential suite =======================

    fn assert_reports_identical(net: &Network, cfgs: &[StageConfig], images: usize, dyn_: SparsityDynamics) {
        let scan = simulate_scan(net, cfgs, images, dyn_);
        let event = simulate_events(net, cfgs, images, dyn_, false);
        let coalesced = simulate_events(net, cfgs, images, dyn_, true);
        assert_eq!(scan, event, "event core diverged from scan ({dyn_:?}, {images} images)");
        assert_eq!(scan, coalesced, "coalescing changed the report ({dyn_:?}, {images} images)");
        for threads in [2usize, 5] {
            let par = simulate_par(net, cfgs, images, dyn_, threads);
            assert_eq!(
                scan, par,
                "per-layer parallel sim diverged ({threads} threads, {dyn_:?}, {images} images)"
            );
        }
    }

    /// The small differential nets never leave `det_run_len`'s serial
    /// prefix, so force the chunked worker path with a pipeline whose
    /// stages have tens of thousands of groups and FIFOs deep enough for
    /// long (but not whole-image, which would take the quick path) runs.
    #[test]
    fn event_core_par_matches_serial_on_long_scans() {
        let layers = vec![
            LayerDesc {
                name: "c0".into(),
                op: Op::Conv { kernel: 3, stride: 1, pad: 1, cin: 2, cout: 8, groups: 1 },
                in_hw: 48,
                branch: false,
            },
            LayerDesc {
                name: "c1".into(),
                op: Op::Conv { kernel: 3, stride: 1, pad: 1, cin: 8, cout: 8, groups: 1 },
                in_hw: 48,
                branch: false,
            },
            LayerDesc {
                name: "c2".into(),
                op: Op::Conv { kernel: 1, stride: 1, pad: 0, cin: 8, cout: 4, groups: 1 },
                in_hw: 48,
                branch: false,
            },
        ];
        let net = Network { name: "par".into(), input_hw: 48, input_channels: 2, layers };
        // o_par 1 → 48*48*cout groups per image (≫ DET_PAR_PREFIX +
        // DET_PAR_MIN_TAIL); mismatched n_mac skews stage rates so
        // producers race ahead until mid-image FIFO limits bite
        let designs: Vec<LayerDesign> = [4usize, 1, 2]
            .iter()
            .map(|&m| LayerDesign { i_par: 1, o_par: 1, n_mac: m })
            .collect();
        let points = uniform_points(&net, 0.35);
        for fifo in [8192u64, 1024] {
            let cfgs = stages_from_design(&net, &designs, &points, fifo);
            let serial = simulate(&net, &cfgs, 2, SparsityDynamics::Deterministic);
            for threads in [2usize, 3, 8] {
                let par = simulate_par(&net, &cfgs, 2, SparsityDynamics::Deterministic, threads);
                assert_eq!(serial, par, "long-scan divergence at {threads} threads, fifo {fifo}");
            }
        }
    }

    #[test]
    fn event_core_matches_scan_deterministic() {
        let net = small_net();
        let designs = modest_designs(&net);
        for s in [0.0, 0.3, 0.6] {
            let points = uniform_points(&net, s);
            for fifo in [4096u64, 64, 1] {
                let mut cfgs = stages_from_design(&net, &designs, &points, fifo.max(1));
                if fifo == 1 {
                    // below stages_from_design's clamp: exercise the
                    // tightest legal FIFO by hand
                    for c in cfgs.iter_mut() {
                        c.fifo_capacity = c.design.o_par as u64;
                    }
                }
                for images in [1usize, 2, 4] {
                    assert_reports_identical(&net, &cfgs, images, SparsityDynamics::Deterministic);
                }
            }
        }
    }

    #[test]
    fn event_core_matches_scan_stochastic_per_seed() {
        let net = small_net();
        let designs = modest_designs(&net);
        let points = uniform_points(&net, 0.5);
        let cfgs = stages_from_design(&net, &designs, &points, 256);
        for seed in [1u64, 2, 9, 42] {
            assert_reports_identical(&net, &cfgs, 2, SparsityDynamics::Stochastic { seed });
        }
        // engine imbalance exercises the full per-engine sampling path
        let mut imb = stages_from_design(&net, &designs, &points, 256);
        for (i, c) in imb.iter_mut().enumerate() {
            c.engine_imbalance =
                (0..c.design.engines()).map(|e| 0.7 + 0.1 * ((e + i as u64) % 7) as f64).collect();
        }
        assert_reports_identical(&net, &imb, 2, SparsityDynamics::Stochastic { seed: 5 });
    }

    /// Randomized differential: small synthetic pipelines, random designs,
    /// FIFO depths (including wedge-inducing ones), line buffering on and
    /// off, both dynamics — the event core must reproduce the scan's
    /// report bit for bit, deadlocks included.
    #[test]
    fn event_core_matches_scan_property() {
        forall(48, 0x51A1, |rng| {
            let n_layers = 2 + rng.below(3);
            let mut layers = Vec::new();
            let mut cfgs = Vec::new();
            for li in 0..n_layers {
                let linear = rng.bool(0.3);
                let l = if linear {
                    let cin = 4 + rng.below(12);
                    let cout = [4usize, 8, 16][rng.below(3)];
                    LayerDesc {
                        name: format!("l{li}"),
                        op: Op::Linear { cin, cout },
                        in_hw: 1,
                        branch: false,
                    }
                } else {
                    let kernel = [1usize, 3][rng.below(2)];
                    let hw = [2usize, 4, 6, 8][rng.below(4)];
                    let cin = [2usize, 4][rng.below(2)];
                    let cout = [2usize, 4, 8][rng.below(3)];
                    LayerDesc {
                        name: format!("c{li}"),
                        op: Op::Conv { kernel, stride: 1, pad: kernel / 2, cin, cout, groups: 1 },
                        in_hw: hw,
                        branch: false,
                    }
                };
                let o_divs = crate::hardware::divisors(l.o_extent());
                let o_par = *rng.choice(&o_divs);
                let d = LayerDesign { i_par: 1, o_par, n_mac: 1 + rng.below(l.patch_k().max(1)) };
                let p = SparsityPoint { s_w: rng.range(0.0, 0.9), s_a: rng.range(0.0, 0.9) };
                let engines = d.engines() as usize;
                let imbalance = if rng.bool(0.5) {
                    Vec::new()
                } else {
                    (0..engines).map(|_| rng.range(0.5, 1.5)).collect()
                };
                cfgs.push(StageConfig {
                    design: d,
                    point: p,
                    engine_imbalance: imbalance,
                    fifo_capacity: (o_par as u64) + rng.below(64) as u64,
                    line_buffered: rng.bool(0.7),
                });
                layers.push(l);
            }
            let net = Network {
                name: "prop".into(),
                input_hw: 8,
                input_channels: 2,
                layers,
            };
            let images = 1 + rng.below(2);
            let dyn_ = if rng.bool(0.5) {
                SparsityDynamics::Deterministic
            } else {
                SparsityDynamics::Stochastic { seed: rng.next_u64() }
            };
            assert_reports_identical(&net, &cfgs, images, dyn_);
        });
    }

    /// An undersized FIFO with line buffering disabled genuinely wedges:
    /// the producer fills the FIFO before the consumer's 3×3 window is
    /// satisfied, both stages go idle, and the report must say so instead
    /// of the simulator spinning forever.
    #[test]
    fn undersized_fifo_without_line_buffer_deadlocks() {
        let mk = |name: &str, kernel: usize| LayerDesc {
            name: name.into(),
            op: Op::Conv { kernel, stride: 1, pad: kernel / 2, cin: 4, cout: 4, groups: 1 },
            in_hw: 4,
            branch: false,
        };
        let net = Network {
            name: "wedge".into(),
            input_hw: 4,
            input_channels: 4,
            layers: vec![mk("p", 1), mk("c", 3)],
        };
        let design = LayerDesign { i_par: 1, o_par: 4, n_mac: 1 };
        let point = SparsityPoint { s_w: 0.0, s_a: 0.0 };
        let cfg = |line_buffered: bool| {
            vec![
                StageConfig {
                    design,
                    point,
                    engine_imbalance: Vec::new(),
                    // producer wedges after 3 groups (12 elements); the
                    // consumer's first 3×3 window needs 13 groups (52)
                    fifo_capacity: 4,
                    line_buffered: true,
                },
                StageConfig {
                    design,
                    point,
                    engine_imbalance: Vec::new(),
                    fifo_capacity: 4,
                    line_buffered,
                },
            ]
        };
        for images in [1usize, 2] {
            for dyn_ in [SparsityDynamics::Deterministic, SparsityDynamics::Stochastic { seed: 3 }] {
                let wedged = simulate(&net, &cfg(false), images, dyn_);
                assert!(wedged.deadlocked, "expected wedge ({dyn_:?})");
                assert!(wedged.starved[1] > 0, "consumer never accounted starved");
                // both cores agree on the deadlock and its partial stats
                assert_reports_identical(&net, &cfg(false), images, dyn_);
                // with the window credit (line buffering) the same FIFO runs
                let ok = simulate(&net, &cfg(true), images, dyn_);
                assert!(!ok.deadlocked, "line-buffered config must not wedge");
            }
        }
    }

    /// Regression for the commit/stamp unification: every commit path goes
    /// through `commit_groups`, which stamps sink image completions — the
    /// old same-instant bookkeeping branch dropped them.
    #[test]
    fn commit_helper_stamps_sink_images_on_any_path() {
        let l = LayerDesc {
            name: "s".into(),
            op: Op::Linear { cin: 8, cout: 8 },
            in_hw: 1,
            branch: false,
        };
        let cfgs = vec![StageConfig {
            design: LayerDesign { i_par: 1, o_par: 4, n_mac: 2 },
            point: SparsityPoint { s_w: 0.0, s_a: 0.0 },
            engine_imbalance: Vec::new(),
            fifo_capacity: 64,
            line_buffered: true,
        }];
        let mut stages = build_stages(&[l], &cfgs);
        let mut image_done = vec![0u64; 2];
        let mut committed = 0u64;
        // retire the first image's 2 groups at t=7 — exactly what the
        // same-`now` bookkeeping path would do if a group ever retired
        // there
        stages[0].next_group = 2;
        commit_groups(&mut stages[0], true, 2, 7, 2, &mut image_done, &mut committed);
        assert_eq!(committed, 2);
        assert_eq!(image_done, vec![7, 0], "first image completion must be stamped");
        // second image retires later; the first stamp must not move
        stages[0].next_group = 4;
        commit_groups(&mut stages[0], true, 4, 19, 2, &mut image_done, &mut committed);
        assert_eq!(image_done, vec![7, 19]);
    }

    /// Sink-side throughput must be derived from stamped image times, not
    /// the end-of-run fallback: with >= 2 images the deterministic sim's
    /// inter-image spacing equals the bottleneck period exactly.
    #[test]
    fn throughput_uses_stamped_image_times() {
        let net = small_net();
        let points = uniform_points(&net, 0.4);
        let designs = modest_designs(&net);
        let cfgs = stages_from_design(&net, &designs, &points, 1 << 20);
        let rep = simulate(&net, &cfgs, 8, SparsityDynamics::Deterministic);
        let model = network_throughput(&net, &designs, &points);
        // generous envelope: fill effects are excluded by the stamping, so
        // the steady-state estimate sits on the model
        let ratio = rep.throughput / model;
        assert!((0.9..=1.1).contains(&ratio), "ratio {ratio}");
    }
}
