//! `hass` — launcher for the HASS system (paper: Yu et al., 2024).
//!
//! Subcommands:
//!
//! * `search`    — hardware-aware (or software-only) TPE sparsity search
//! * `dse`       — design-space exploration at a fixed sparsity
//! * `simulate`  — cycle-level simulation of a DSE result
//! * `partition` — multi-partition mapping with full reconfiguration
//! * `evaluate`  — run the AOT CalibNet artifact at given thresholds (PJRT)
//! * `networks`  — list the built-in network geometries
//! * `serve`     — resident search daemon over warm caches (JSON-RPC/TCP)
//! * `client`    — thin client for a running `hass serve` daemon
//! * `lint`      — repo-native invariant linter (blocking in CI)
//!
//! Run `hass <subcommand> --help` for per-command flags.

use hass::arch::networks;
use hass::baselines;
use hass::coordinator::{
    resume_fingerprint, search_sharded_with_cache_ctrl, search_with_cache_ctrl,
    CandidateEvaluator, Checkpoint, CheckpointSpec, DesignCache, EngineConfig,
    MeasuredEvaluator, RetryPolicy, SearchConfig, SearchControl, SearchMode,
    SimulatedEvaluator, SurrogateEvaluator,
};
use hass::dse::{self, explore, DseConfig};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::metrics::{fmt, Table};
use hass::runtime::ModelRuntime;
use hass::server::{ServeConfig, Server};
use hass::simulator::{simulate, stages_from_design, SparsityDynamics};
use hass::sparsity::{synthesize, SparsityPoint};
use hass::util::cli::Cli;
use hass::util::json::Json;
use hass::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let sub = args.get(1).map(|s| s.as_str()).unwrap_or("help");
    let rest = args.get(2..).unwrap_or(&[]);
    let code = match sub {
        "search" => cmd_search(rest),
        "dse" => cmd_dse(rest),
        "simulate" => cmd_simulate(rest),
        "partition" => cmd_partition(rest),
        "evaluate" => cmd_evaluate(rest),
        "networks" => cmd_networks(),
        "serve" => cmd_serve(rest),
        "client" => cmd_client(rest),
        "lint" => cmd_lint(rest),
        _ => {
            eprintln!(
                "usage: hass <search|dse|simulate|partition|evaluate|networks|serve|client|lint> \
                 [flags]\n\
                 HASS: Hardware-Aware Sparsity Search for dataflow DNN accelerators."
            );
            if sub == "help" || sub == "--help" {
                0
            } else {
                2
            }
        }
    };
    std::process::exit(code);
}

/// Parsed args plus the usage text, so the typed getters below can die
/// with a helpful message instead of panicking: `hass search --iters=abc`
/// prints the error + usage and exits 2 — never a backtrace.
struct Args {
    p: hass::util::cli::Parsed,
    usage: String,
}

impl Args {
    fn get(&self, key: &str) -> &str {
        self.p.get(key)
    }

    fn get_bool(&self, key: &str) -> bool {
        self.p.get_bool(key)
    }

    fn get_usize(&self, key: &str) -> usize {
        self.ok(self.p.get_usize(key))
    }

    fn get_u64(&self, key: &str) -> u64 {
        self.ok(self.p.get_u64(key))
    }

    fn get_f64(&self, key: &str) -> f64 {
        self.ok(self.p.get_f64(key))
    }

    fn ok<T>(&self, r: Result<T, String>) -> T {
        r.unwrap_or_else(|e| {
            eprintln!("{e}\n\n{}", self.usage);
            std::process::exit(2);
        })
    }
}

fn parse_or_die(cli: Cli, args: &[String]) -> Args {
    let usage = cli.usage();
    match cli.parse_from(args) {
        Ok(p) => Args { p, usage },
        Err(e) => {
            eprintln!("{e}\n{usage}");
            std::process::exit(2);
        }
    }
}

fn device_or_die(name: &str) -> DeviceBudget {
    DeviceBudget::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown device '{name}' (u250 | 7v690t | stratix10)");
        std::process::exit(2);
    })
}

fn network_or_die(name: &str) -> hass::arch::Network {
    networks::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown network '{name}'; see `hass networks`");
        std::process::exit(2);
    })
}

fn cmd_search(args: &[String]) -> i32 {
    let cli = Cli::new("hardware-aware sparsity search (TPE, Eq. 6)")
        .opt("network", "calibnet", "target geometry (see `hass networks`)")
        .opt("device", "u250", "device budget")
        .opt(
            "devices",
            "",
            "comma-separated budgets for a sharded multi-device search \
             (e.g. u250,7v690t; overrides --device)",
        )
        .opt("iters", "96", "TPE iterations")
        .opt("seed", "0", "search seed")
        .opt("mode", "hw", "objective: hw (Eq. 6) | sw (accuracy+sparsity)")
        .opt(
            "evaluator",
            "auto",
            "auto | measured (PJRT) | surrogate | sim (fidelity ladder: analytic \
             pricing + cycle-level re-score of the per-generation top-k)",
        )
        .opt("sim-top-k", "4", "candidates per generation per device the sim re-scores")
        .opt("sim-images", "3", "images per promoted cycle-level simulation")
        .opt("batches", "4", "calibration batches per measured evaluation")
        .opt("batch", "1", "candidates per TPE generation, evaluated in parallel")
        .opt("threads", "0", "evaluation worker threads (0 = auto)")
        .opt("quant", "0", "pricing quantization bits (0 = exact; 12 is a good cache grid)")
        .flag(
            "async",
            "async completion-queue pipeline: DSE pricing overlaps in-flight \
             measurements (results are bit-identical either way)",
        )
        .flag("no-cache", "disable the DSE design cache")
        .opt(
            "cache-file",
            "",
            "JSON snapshot path: load a warm design cache before the search \
             and save it back after (created if missing)",
        )
        .opt("journal", "", "CSV path for the per-iteration journal")
        .opt(
            "retries",
            "3",
            "max retries for transient evaluation failures (0 = first failure wins)",
        )
        .opt(
            "eval-timeout",
            "0",
            "async pipeline watchdog: ms without a completion before the \
             generation's outstanding measurements are reclaimed (0 = off)",
        )
        .opt(
            "deadline",
            "0",
            "async pipeline watchdog: ms budget for a whole generation \
             before outstanding measurements are reclaimed (0 = off)",
        )
        .opt(
            "checkpoint",
            "",
            "path for periodic crash-safe search checkpoints \
             (atomic tmp+rename; resume with --resume)",
        )
        .opt("checkpoint-every", "1", "generations between checkpoint writes")
        .opt(
            "resume",
            "",
            "checkpoint file to continue an interrupted search from \
             (the finished journal is bit-identical to an uninterrupted run)",
        )
        .opt(
            "cache-max-entries",
            "0",
            "compact the saved --cache-file to at most this many design and \
             frontier entries each, least-recently-used first (0 = unlimited)",
        )
        .opt(
            "pipeline-depth",
            "0",
            "cross-generation lookahead: propose generation g+1 from \
             observations through g-D while g's tail is still in flight \
             (0 = drained ask/tell; results stay bit-identical across \
             thread counts for any fixed depth)",
        );
    let p = parse_or_die(cli, args);
    let net = network_or_die(p.get("network"));
    let devices = match DeviceBudget::parse_list(p.get("devices")) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // resolve the full device list up front: the sharded branch needs it
    // anyway, and the fidelity ladder (--evaluator sim) simulates on the
    // same devices the search prices
    let all_devices: Vec<DeviceBudget> = if devices.is_empty() {
        vec![device_or_die(p.get("device"))]
    } else {
        devices
    };
    let rm = ResourceModel::default();
    let mode = match p.get("mode") {
        "sw" => SearchMode::SoftwareOnly,
        _ => SearchMode::HardwareAware,
    };
    let want_sim = p.get("evaluator") == "sim";
    let mut engine = EngineConfig {
        batch: p.get_usize("batch").max(1),
        threads: p.get_usize("threads"),
        cache: !p.get_bool("no-cache"),
        quant_bits: p.get_usize("quant") as u32,
        async_eval: p.get_bool("async"),
    };
    if want_sim && !engine.async_eval {
        // the ladder ranks within a generation, which only the async
        // completion-queue pipeline routes through eval_async
        println!("[search] --evaluator sim ranks per generation; enabling the async pipeline");
        engine.async_eval = true;
    }
    let eval_timeout_ms = p.get_u64("eval-timeout");
    let deadline_ms = p.get_u64("deadline");
    if (eval_timeout_ms > 0 || deadline_ms > 0) && !engine.async_eval {
        eprintln!(
            "warning: --eval-timeout/--deadline watch the async completion queue; \
             the sync pipeline has no in-flight measurements to reclaim (add --async)"
        );
    }
    let ckpt_path = p.get("checkpoint");
    let cfg = SearchConfig {
        iterations: p.get_usize("iters"),
        seed: p.get_u64("seed"),
        mode,
        engine,
        retry: RetryPolicy {
            max_retries: p.get_usize("retries") as u32,
            ..Default::default()
        },
        eval_timeout_ms,
        deadline_ms,
        checkpoint: (!ckpt_path.is_empty()).then(|| CheckpointSpec {
            path: ckpt_path.to_string(),
            every: p.get_usize("checkpoint-every").max(1),
        }),
        pipeline_depth: p.get_usize("pipeline-depth"),
        ..Default::default()
    };
    // --resume: load + validate loudly here (the engine silently ignores a
    // mismatched checkpoint; the CLI should explain why instead)
    let resume_path = p.get("resume");
    let resume_ck = if resume_path.is_empty() {
        None
    } else {
        match Checkpoint::load(resume_path) {
            Ok(ck) => {
                let fp = resume_fingerprint(&cfg, &net, &all_devices);
                if ck.fingerprint != fp {
                    eprintln!(
                        "checkpoint '{resume_path}' was written by a different search \
                         (fingerprint {:016x}, this run is {fp:016x}); refusing to \
                         resume — rerun with the original network/devices/seed/flags",
                        ck.fingerprint
                    );
                    return 2;
                }
                if ck.done > cfg.iterations {
                    eprintln!(
                        "checkpoint '{resume_path}' already covers {} iterations but \
                         this run asks for only {}; refusing to resume",
                        ck.done, cfg.iterations
                    );
                    return 2;
                }
                println!(
                    "[search] resume <- {resume_path}: {} of {} iterations already done",
                    ck.done, cfg.iterations
                );
                Some(ck)
            }
            Err(e) => {
                eprintln!("failed to load checkpoint: {e}");
                return 2;
            }
        }
    };
    let want_measured = match p.get("evaluator") {
        "measured" => true,
        "surrogate" => false,
        // "sim" wraps whichever backend "auto" would pick
        _ => net.name == "calibnet" && hass::runtime::available(&hass::runtime::default_dir()),
    };
    let ev: Box<dyn CandidateEvaluator> = if want_measured {
        if net.name != "calibnet" {
            eprintln!("measured evaluator only supports the calibnet geometry");
            return 2;
        }
        let rt = match ModelRuntime::load_default() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("failed to load AOT artifact: {e:#}\nrun `make artifacts` first");
                return 1;
            }
        };
        println!(
            "[search] measured evaluator: {} (dense val acc {:.2}%)",
            rt.meta.model,
            rt.meta.dense_val_accuracy * 100.0
        );
        Box::new(MeasuredEvaluator::new(rt, p.get_usize("batches")))
    } else {
        println!("[search] surrogate evaluator on {}", net.name);
        Box::new(SurrogateEvaluator {
            sparsity: synthesize(&net, cfg.seed),
            net: net.clone(),
            base_acc: 76.0,
        })
    };
    let ev: Box<dyn CandidateEvaluator> = if want_sim {
        let top_k = p.get_usize("sim-top-k").max(1);
        let sim_images = p.get_usize("sim-images").max(1);
        println!(
            "[search] fidelity ladder: analytic top-{} per generation re-scored \
             cycle-level on {} device(s), {} image(s) per sim",
            top_k,
            all_devices.len(),
            sim_images
        );
        Box::new(SimulatedEvaluator {
            inner: ev,
            target: net.clone(),
            rm: rm.clone(),
            devices: all_devices.clone(),
            dse: cfg.dse.clone(),
            top_k,
            sim_images,
        })
    } else {
        ev
    };
    let journal = p.get("journal");
    // --no-cache turns pricing memoization off entirely, so a cache file
    // would be loaded-but-never-consulted and saved back empty — ignore
    // it (and keep any existing snapshot untouched) instead
    let cache_file = if !engine.cache && !p.get("cache-file").is_empty() {
        eprintln!("warning: --no-cache disables the design cache; ignoring --cache-file");
        ""
    } else {
        p.get("cache-file")
    };
    let cache = load_cache(cache_file);
    let cache_cap = p.get_usize("cache-max-entries");
    let ctrl = SearchControl { resume: resume_ck.as_ref(), ..Default::default() };

    // --- sharded multi-device search (--devices a,b,...) --------------
    if all_devices.len() >= 2 {
        let Some(result) = search_sharded_with_cache_ctrl(
            ev.as_ref(),
            &net,
            &rm,
            &all_devices,
            &cfg,
            &cache,
            &ctrl,
        ) else {
            // unreachable for the CLI's observer-less SearchControl, but
            // the panic-free contract means we answer, not abort
            eprintln!("[search] cancelled before completion");
            return 1;
        };
        let s = &result.stats;
        println!(
            "[search] sharded over {} devices: {} generations x batch {} on {} thread(s) | \
             shared cache: {} entries, {} hit / {} miss | frontiers: {} entries, \
             {} hit / {} miss | {} measurements deduped",
            s.devices,
            s.generations,
            cfg.engine.batch.max(1),
            s.threads,
            s.cache_entries,
            s.cache_hits,
            s.cache_misses,
            s.frontier_entries,
            s.frontier_hits,
            s.frontier_misses,
            s.dedup_evals
        );
        if s.async_generations > 0 {
            println!(
                "[search] async pipeline: {} generations | {} pricings overlapped \
                 in-flight measurements | {} completions out of order",
                s.async_generations, s.overlap_pricings, s.ooo_completions
            );
        }
        if s.pipelined_generations > 0 {
            println!(
                "[search] lookahead pipeline: {} generations overlapped | {} proposals \
                 drawn ahead of observations | {:.1} ms at the reduce barrier",
                s.pipelined_generations,
                s.lookahead_proposals,
                s.barrier_wait_ns as f64 / 1e6
            );
        }
        if s.retried_evals > 0 || s.reclaimed_stalls > 0 {
            println!(
                "[search] fault tolerance: {} transient failures retried | {} stalled \
                 measurements reclaimed by the watchdog",
                s.retried_evals, s.reclaimed_stalls
            );
        }
        if s.sim_evals > 0 {
            println!(
                "[search] fidelity ladder: {} records simulator-scored | {} set a new \
                 running best",
                s.sim_evals, s.sim_promotions
            );
        }
        print!("{}", result.summary_table().to_markdown());
        println!(
            "[search] cross-device pareto front ({} points):",
            result.pareto.len()
        );
        print!("{}", result.pareto_table().to_markdown());
        if !journal.is_empty() {
            match result.write_journals(journal) {
                Ok(paths) => {
                    for path in paths {
                        println!("[search] journal -> {path}");
                    }
                }
                Err(e) => {
                    eprintln!("failed to write journals to '{journal}': {e}");
                    return 1;
                }
            }
        }
        return save_cache(&cache, cache_file, cache_cap);
    }

    // --- single-device search (--device, or a 1-entry --devices) ------
    let Some(dev) = all_devices.into_iter().next() else {
        eprintln!("no device resolved (--device/--devices)");
        return 2;
    };
    let Some(result) =
        search_with_cache_ctrl(ev.as_ref(), &net, &rm, &dev, &cfg, &cache, &ctrl)
    else {
        eprintln!("[search] cancelled before completion");
        return 1;
    };
    // --iters 0 is a legal smoke run (e.g. warming a cache file): there
    // is no best record then, not a panic
    match result.try_best_record() {
        Some(b) => println!(
            "[search] best @ iter {}: acc {:.2}% | sparsity {:.3} | {:.0} img/s | {} DSP | {:.3e} img/cyc/DSP",
            b.iter, b.accuracy, b.avg_sparsity, b.images_per_sec, b.dsp, b.efficiency
        ),
        None => println!("[search] no iterations run (--iters 0); journal is header-only"),
    }
    let s = &result.stats;
    println!(
        "[search] engine: {} generations x batch {} on {} thread(s) | design cache \
         {} hit / {} miss ({:.0}% hit rate) | frontiers {} hit / {} miss",
        s.generations,
        s.batch,
        s.threads,
        s.cache_hits,
        s.cache_misses,
        s.cache_hit_rate() * 100.0,
        s.frontier_hits,
        s.frontier_misses
    );
    if s.async_generations > 0 {
        println!(
            "[search] async pipeline: {} generations | {} pricings overlapped \
             in-flight measurements | {} completions out of order",
            s.async_generations, s.overlap_pricings, s.ooo_completions
        );
    }
    if s.pipelined_generations > 0 {
        println!(
            "[search] lookahead pipeline: {} generations overlapped | {} proposals \
             drawn ahead of observations | {:.1} ms at the reduce barrier",
            s.pipelined_generations,
            s.lookahead_proposals,
            s.barrier_wait_ns as f64 / 1e6
        );
    }
    if s.retried_evals > 0 || s.reclaimed_stalls > 0 {
        println!(
            "[search] fault tolerance: {} transient failures retried | {} stalled \
             measurements reclaimed by the watchdog",
            s.retried_evals, s.reclaimed_stalls
        );
    }
    if s.sim_evals > 0 {
        println!(
            "[search] fidelity ladder: {} records simulator-scored | {} set a new \
             running best | {:.1}% mean analytic drift",
            s.sim_evals,
            s.sim_promotions,
            s.sim_disagreement * 100.0
        );
    }
    if !journal.is_empty() {
        // same graceful path as the sharded branch: report and fail the
        // run, don't panic (the search itself already succeeded)
        if let Err(e) = result.write_journal(journal) {
            eprintln!("failed to write journal to '{journal}': {e}");
            return 1;
        }
        println!("[search] journal -> {journal}");
    }
    save_cache(&cache, cache_file, cache_cap)
}

/// Load a warm design cache from `path` (`--cache-file`): empty path or
/// missing file start cold, a corrupt file warns and starts cold too —
/// a sweep must never hard-fail on its own cache.
fn load_cache(path: &str) -> DesignCache {
    if path.is_empty() || !std::path::Path::new(path).exists() {
        return DesignCache::new();
    }
    match DesignCache::load(path) {
        Ok((cache, st)) => {
            println!(
                "[search] cache <- {path}: {} designs, {} frontiers{}",
                st.designs,
                st.frontiers,
                if st.skipped > 0 {
                    format!(" ({} corrupt entries skipped)", st.skipped)
                } else {
                    String::new()
                }
            );
            cache
        }
        Err(e) => {
            eprintln!("warning: starting with a cold cache: {e}");
            DesignCache::new()
        }
    }
}

/// Persist the design cache back to `path` (no-op for an empty path).
/// `max_entries` > 0 compacts the snapshot (LRU eviction per section)
/// on the way out; the save also merges with any snapshot another
/// process wrote concurrently (advisory lock, see `DesignCache::save`).
fn save_cache(cache: &DesignCache, path: &str, max_entries: usize) -> i32 {
    if path.is_empty() {
        return 0;
    }
    match cache.save_compacted(path, max_entries) {
        Ok(st) => {
            println!(
                "[search] cache -> {path}: {} designs, {} frontiers{}",
                st.designs,
                st.frontiers,
                if st.evicted > 0 {
                    format!(" ({} least-recently-used entries evicted)", st.evicted)
                } else {
                    String::new()
                }
            );
            0
        }
        Err(e) => {
            eprintln!("failed to write cache file '{path}': {e}");
            1
        }
    }
}

fn cmd_dse(args: &[String]) -> i32 {
    let cli = Cli::new("design-space exploration at fixed sparsity (Eq. 1-5)")
        .opt("network", "resnet18", "target geometry")
        .opt("device", "u250", "device budget")
        .opt("sw", "0.5", "uniform weight sparsity")
        .opt("sa", "0.5", "uniform activation sparsity")
        .flag("per-layer", "print the per-layer allocation (Fig. 4 view)");
    let p = parse_or_die(cli, args);
    let net = network_or_die(p.get("network"));
    let dev = device_or_die(p.get("device"));
    let rm = ResourceModel::default();
    let n = net.compute_layers().len();
    let pt = SparsityPoint { s_w: p.get_f64("sw"), s_a: p.get_f64("sa") };
    let points = vec![pt; n];
    let t0 = std::time::Instant::now();
    let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
    println!(
        "[dse] {} on {}: {:.0} img/s | {} DSP | {} kLUT | {} BRAM18k | {} URAM | eff {:.3e} (in {:?})",
        net.name,
        dev.name,
        d.images_per_sec(&dev),
        d.resources.dsp,
        d.resources.lut / 1000,
        d.resources.bram18k,
        d.resources.uram,
        d.efficiency(),
        t0.elapsed()
    );
    if p.get_bool("per-layer") {
        let mut t = Table::new(&["layer", "i_par", "o_par", "mac_per_spe", "spes", "dsp", "thr"]);
        for (l, des) in net.compute_layers().iter().zip(&d.designs) {
            t.row(vec![
                l.name.clone(),
                des.i_par.to_string(),
                des.o_par.to_string(),
                des.n_mac.to_string(),
                des.engines().to_string(),
                des.dsp().to_string(),
                fmt(des.throughput(l, pt)),
            ]);
        }
        print!("{}", t.to_markdown());
    }
    0
}

fn cmd_simulate(args: &[String]) -> i32 {
    let cli = Cli::new("cycle-level simulation of a DSE design (validates Eq. 1-3)")
        .opt("network", "calibnet", "target geometry")
        .opt("device", "u250", "device budget")
        .opt("sw", "0.5", "uniform weight sparsity")
        .opt("sa", "0.5", "uniform activation sparsity")
        .opt("images", "4", "images to stream")
        .opt("seed", "0", "stochastic dynamics seed (0 = deterministic)");
    let p = parse_or_die(cli, args);
    let net = network_or_die(p.get("network"));
    let dev = device_or_die(p.get("device"));
    let rm = ResourceModel::default();
    let n = net.compute_layers().len();
    let points = vec![SparsityPoint { s_w: p.get_f64("sw"), s_a: p.get_f64("sa") }; n];
    let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
    let cfgs = stages_from_design(&net, &d.designs, &points, rm.fifo_depth);
    let dynamics = match p.get_u64("seed") {
        0 => SparsityDynamics::Deterministic,
        s => SparsityDynamics::Stochastic { seed: s },
    };
    let t0 = std::time::Instant::now();
    let rep = simulate(&net, &cfgs, p.get_usize("images"), dynamics);
    println!(
        "[sim] {} imgs in {} cycles | sim {:.4e} img/cyc vs model {:.4e} ({:+.1}%) | wall {:?}{}",
        rep.images,
        rep.total_cycles,
        rep.throughput,
        d.throughput,
        (rep.throughput / d.throughput - 1.0) * 100.0,
        t0.elapsed(),
        if rep.deadlocked { " [DEADLOCKED]" } else { "" }
    );
    0
}

fn cmd_partition(args: &[String]) -> i32 {
    let cli = Cli::new("multi-partition mapping with full reconfiguration (§V-A.4)")
        .opt("network", "resnet50", "target geometry")
        .opt("device", "7v690t", "device budget (small devices fold)")
        .opt("sw", "0.5", "uniform weight sparsity")
        .opt("sa", "0.5", "uniform activation sparsity")
        .opt("batch", "1024", "batch size amortizing reconfiguration")
        .opt("seed", "0", "annealing seed");
    let p = parse_or_die(cli, args);
    let net = network_or_die(p.get("network"));
    let dev = device_or_die(p.get("device"));
    let rm = ResourceModel::default();
    let n = net.compute_layers().len();
    let points = vec![SparsityPoint { s_w: p.get_f64("sw"), s_a: p.get_f64("sa") }; n];
    let mut rng = Rng::new(p.get_u64("seed"));
    let cfg = DseConfig { max_iters: 5_000, ..Default::default() };
    match dse::partition::partition(
        &net,
        &points,
        &rm,
        &dev,
        &cfg,
        p.get_usize("batch"),
        dse::partition::DEFAULT_RECONFIG_SECS,
        &mut rng,
    ) {
        Some(part) => {
            println!(
                "[partition] {} on {}: {} partition(s), {:.0} img/s at batch {}",
                net.name,
                dev.name,
                part.n_partitions(),
                part.images_per_sec,
                part.batch
            );
            for (i, (w, d)) in part.bounds.windows(2).zip(&part.designs).enumerate() {
                let &[lo, hi] = w else { continue };
                println!(
                    "  part {i}: layers {lo}..{hi} | {} DSP | {:.0} img/s",
                    d.resources.dsp,
                    d.images_per_sec(&dev)
                );
            }
            0
        }
        None => {
            eprintln!("[partition] could not map {} onto {}", net.name, dev.name);
            1
        }
    }
}

fn cmd_evaluate(args: &[String]) -> i32 {
    let cli = Cli::new("evaluate the AOT CalibNet artifact at thresholds (PJRT)")
        .opt("tau-w", "0.05", "uniform weight threshold")
        .opt("tau-a", "0.05", "uniform activation threshold")
        .opt("batches", "4", "calibration batches");
    let p = parse_or_die(cli, args);
    let rt = match ModelRuntime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("failed to load AOT artifact: {e:#}\nrun `make artifacts` first");
            return 1;
        }
    };
    let l = rt.n_layers();
    let tw = vec![p.get_f64("tau-w"); l];
    let ta = vec![p.get_f64("tau-a"); l];
    let out = match rt.evaluate(&tw, &ta, p.get_usize("batches")) {
        Ok(out) => out,
        Err(e) => {
            eprintln!("evaluation failed: {e:#}");
            return 1;
        }
    };
    println!(
        "[evaluate] {} imgs: accuracy {:.2}% (dense {:.2}%)",
        out.images,
        out.accuracy * 100.0,
        rt.meta.dense_val_accuracy * 100.0
    );
    let mut t = Table::new(&["layer", "S_w", "S_a", "pair_density"]);
    let rows = rt.meta.layers.iter().zip(&out.s_w).zip(&out.s_a).zip(&out.pair_density);
    for (((layer, sw), sa), pd) in rows.take(l) {
        t.row(vec![
            layer.name.clone(),
            format!("{sw:.4}"),
            format!("{sa:.4}"),
            format!("{pd:.4}"),
        ]);
    }
    print!("{}", t.to_markdown());
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    let cli = Cli::new("resident search daemon: warm caches served over newline-JSON-RPC/TCP")
        .opt("addr", "127.0.0.1:4860", "listen address")
        .opt(
            "max-searches",
            "2",
            "searches in flight at once; further requests queue FIFO",
        )
        .opt(
            "cache-file",
            "",
            "JSON snapshot: load a warm design cache before serving and \
             save it back after shutdown (created if missing)",
        );
    let p = parse_or_die(cli, args);
    let cache_file = p.get("cache-file").to_string();
    let cache = load_cache(&cache_file);
    let server = Server::new(
        cache,
        ServeConfig { max_inflight: p.get_usize("max-searches").max(1) },
    );
    let listener = match std::net::TcpListener::bind(p.get("addr")) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("failed to bind '{}': {e}", p.get("addr"));
            return 1;
        }
    };
    let shown = listener
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| p.get("addr").to_string());
    println!(
        "[serve] listening on {shown} ({} concurrent searches; \
         methods: search | price | stats | save-cache | shutdown)",
        p.get_usize("max-searches").max(1)
    );
    if let Err(e) = server.run(listener) {
        eprintln!("[serve] accept loop failed: {e}");
        return 1;
    }
    println!("[serve] shut down");
    save_cache(server.cache(), &cache_file)
}

/// Per-device journal path of the client, matching the daemon-less CLI:
/// a single device writes `base` itself, several devices write
/// `stem.<device>.ext` (the `ShardedSearchResult::write_journals`
/// convention) — so CI can `cmp` client journals against `hass search`.
fn client_journal_path(base: &str, device: &str, n_devices: usize) -> String {
    if n_devices == 1 {
        return base.to_string();
    }
    match base.rsplit_once('.') {
        Some((stem, ext)) if !stem.is_empty() && !ext.contains('/') => {
            format!("{stem}.{device}.{ext}")
        }
        _ => format!("{base}.{device}"),
    }
}

fn cmd_client(args: &[String]) -> i32 {
    use std::io::{BufRead, BufReader, Write};
    let cli = Cli::new(
        "thin client for a running `hass serve` daemon \
         (positional method: search | price | stats | save-cache | shutdown)",
    )
    .opt("addr", "127.0.0.1:4860", "daemon address")
    .opt("network", "calibnet", "search/price: target geometry")
    .opt("device", "u250", "search/price: device budget")
    .opt("devices", "", "search: comma-separated budgets (overrides --device)")
    .opt("iters", "96", "search: TPE iterations")
    .opt("seed", "0", "search: seed")
    .opt("mode", "hw", "search: hw | sw")
    .opt("batch", "1", "search: candidates per generation")
    .opt("threads", "0", "search: evaluation threads (0 = auto)")
    .opt("quant", "0", "search: pricing quantization bits")
    .flag("async", "search: async completion-queue pipeline")
    .opt("pipeline-depth", "0", "search: cross-generation lookahead depth (0 = drained)")
    .opt(
        "resume",
        "",
        "search: checkpoint file on the daemon's host to continue from \
         (a fingerprint mismatch is a JSON-RPC error, not a dead daemon)",
    )
    .opt("sw", "0.5", "price: uniform weight sparsity")
    .opt("sa", "0.5", "price: uniform activation sparsity")
    .opt("journal", "", "search: write the returned per-device journal CSVs here")
    .opt("path", "", "save-cache: snapshot path (on the daemon's host)")
    .opt(
        "connect-retries",
        "3",
        "reconnect attempts after a refused connection (exponential backoff)",
    );
    let p = parse_or_die(cli, args);
    let method =
        p.p.positionals.first().map(String::as_str).unwrap_or("stats").to_string();
    let params = match method.as_str() {
        "search" => Json::obj(vec![
            ("network", Json::Str(p.get("network").to_string())),
            ("device", Json::Str(p.get("device").to_string())),
            ("devices", Json::Str(p.get("devices").to_string())),
            ("iters", Json::Num(p.get_usize("iters") as f64)),
            ("seed", Json::Num(p.get_u64("seed") as f64)),
            ("mode", Json::Str(p.get("mode").to_string())),
            ("batch", Json::Num(p.get_usize("batch") as f64)),
            ("threads", Json::Num(p.get_usize("threads") as f64)),
            ("quant", Json::Num(p.get_usize("quant") as f64)),
            ("async", Json::Bool(p.get_bool("async"))),
            ("pipeline_depth", Json::Num(p.get_usize("pipeline-depth") as f64)),
            ("resume", Json::Str(p.get("resume").to_string())),
        ]),
        "price" => Json::obj(vec![
            ("network", Json::Str(p.get("network").to_string())),
            ("device", Json::Str(p.get("device").to_string())),
            ("sw", Json::Num(p.get_f64("sw"))),
            ("sa", Json::Num(p.get_f64("sa"))),
        ]),
        "save-cache" => Json::obj(vec![("path", Json::Str(p.get("path").to_string()))]),
        "stats" | "shutdown" => Json::obj(vec![]),
        other => {
            eprintln!(
                "unknown method '{other}' (search | price | stats | save-cache | shutdown)"
            );
            return 2;
        }
    };
    let addr = p.get("addr");
    // a daemon mid-restart refuses connections for a moment — retry with
    // bounded exponential backoff instead of failing on the first refusal
    let retries = p.get_usize("connect-retries") as u32;
    let mut attempt = 0u32;
    let stream = loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) if attempt < retries => {
                let ms = 25u64.checked_shl(attempt).unwrap_or(u64::MAX).min(400);
                eprintln!(
                    "[client] connect to '{addr}' failed ({e}); retry {} of {retries} \
                     in {ms}ms",
                    attempt + 1
                );
                std::thread::sleep(std::time::Duration::from_millis(ms));
                attempt += 1;
            }
            Err(e) => {
                eprintln!("failed to connect to '{addr}': {e} (is `hass serve` running?)");
                return 1;
            }
        }
    };
    let request = Json::obj(vec![
        ("id", Json::Num(1.0)),
        ("method", Json::Str(method.clone())),
        ("params", params),
    ]);
    let mut w = &stream;
    if w.write_all(format!("{}\n", request.to_string()).as_bytes()).is_err() {
        eprintln!("failed to send request to '{addr}'");
        return 1;
    }
    // stream: zero or more event lines, then exactly one result or error
    for line in BufReader::new(&stream).lines() {
        let Ok(line) = line else { break };
        let Ok(v) = Json::parse(&line) else {
            eprintln!("unparseable response line: {line}");
            return 1;
        };
        if let Some(ev) = v.get("event").and_then(|e| e.as_str()) {
            match ev {
                "queued" => println!("[client] queued (daemon at max concurrent searches)"),
                "started" => println!("[client] search started"),
                "generation" => {
                    let done = v.get("done").and_then(|d| d.as_usize()).unwrap_or(0);
                    let total = v.get("total").and_then(|t| t.as_usize()).unwrap_or(0);
                    println!("[client] generation done {done}/{total}");
                }
                other => println!("[client] event: {other}"),
            }
            continue;
        }
        if let Some(err) = v.get("error").and_then(|e| e.as_str()) {
            eprintln!("[client] daemon error: {err}");
            return 1;
        }
        let Some(result) = v.get("result") else {
            eprintln!("response line is neither event, error nor result: {line}");
            return 1;
        };
        return client_report(&method, result, p.get("journal"));
    }
    eprintln!("connection closed before a result arrived");
    1
}

/// Print a terminal daemon result (and write search journals).
fn client_report(method: &str, result: &Json, journal: &str) -> i32 {
    let Some(devices) = result.get("devices").and_then(|d| d.as_arr()) else {
        // non-search methods: the result object is small — print it raw
        println!("[client] {method}: {}", result.to_string());
        return 0;
    };
    for d in devices {
        let name = d.get("device").and_then(|n| n.as_str()).unwrap_or("?");
        let hits = d.get("cache_hits").and_then(|h| h.as_usize()).unwrap_or(0);
        let misses = d.get("cache_misses").and_then(|m| m.as_usize()).unwrap_or(0);
        match d.get("best_iter").and_then(|b| b.as_usize()) {
            Some(it) => println!(
                "[client] {name}: best @ iter {it}: acc {:.2}% | {:.0} img/s | cache {hits} hit / {misses} miss",
                d.get("best_accuracy").and_then(|a| a.as_f64()).unwrap_or(0.0),
                d.get("best_images_per_sec").and_then(|i| i.as_f64()).unwrap_or(0.0),
            ),
            None => println!(
                "[client] {name}: no iterations run | cache {hits} hit / {misses} miss"
            ),
        }
        if journal.is_empty() {
            continue;
        }
        let csv = d.get("journal_csv").and_then(|c| c.as_str()).unwrap_or("");
        let path = client_journal_path(journal, name, devices.len());
        if let Some(dir) = std::path::Path::new(&path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).ok();
            }
        }
        if let Err(e) = std::fs::write(&path, csv) {
            eprintln!("failed to write journal to '{path}': {e}");
            return 1;
        }
        println!("[client] journal -> {path}");
    }
    0
}

fn cmd_networks() -> i32 {
    let mut t = Table::new(&["name", "layers", "compute", "GMACs", "params(M)"]);
    for name in networks::ALL_NETWORKS {
        let Some(net) = networks::by_name(name) else { continue };
        t.row(vec![
            net.name.clone(),
            net.layers.len().to_string(),
            net.compute_layers().len().to_string(),
            format!("{:.3}", net.total_macs() as f64 / 1e9),
            format!("{:.2}", net.total_weights() as f64 / 1e6),
        ]);
    }
    print!("{}", t.to_markdown());
    let _ = baselines::MemoryModel::default(); // keep the module linked
    0
}

const LINT_USAGE: &str = "\
hass lint — repo-native invariant linter (see rust/src/analysis/).

usage: hass lint [--json] [--fix-hints] [paths...]

  --json        emit diagnostics as a JSON array instead of text
  --fix-hints   append a one-line remediation hint to each diagnostic
  paths         files or directories to lint; defaults to the repo's
                rust/src, rust/benches and rust/tests (auto-detected
                from the current directory)

exit: 0 clean, 1 violations found, 2 usage/IO error";

fn cmd_lint(args: &[String]) -> i32 {
    let mut json = false;
    let mut hints = false;
    let mut paths: Vec<std::path::PathBuf> = Vec::new();
    for a in args {
        match a.as_str() {
            "--json" => json = true,
            "--fix-hints" => hints = true,
            "--help" | "-h" => {
                println!("{LINT_USAGE}");
                return 0;
            }
            _ if a.starts_with('-') => {
                eprintln!("unknown option {a}\n\n{LINT_USAGE}");
                return 2;
            }
            _ => paths.push(std::path::PathBuf::from(a)),
        }
    }
    if paths.is_empty() {
        // default scope: the whole crate, wherever we're invoked from
        let candidates: &[&str] = if std::path::Path::new("rust/src").is_dir() {
            &["rust/src", "rust/benches", "rust/tests"]
        } else {
            &["src", "benches", "tests"]
        };
        for c in candidates {
            if std::path::Path::new(c).exists() {
                paths.push(std::path::PathBuf::from(c));
            }
        }
        if paths.is_empty() {
            eprintln!("lint: no sources found (run from the repo root or pass paths)");
            return 2;
        }
    }
    let report = match hass::analysis::lint_paths(&paths) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("lint: {e}");
            return 2;
        }
    };
    if json {
        let arr: Vec<Json> = report.diagnostics.iter().map(|d| d.to_json()).collect();
        println!("{}", Json::Arr(arr));
    } else {
        for d in &report.diagnostics {
            println!("{}", d.render());
            if hints {
                if let Some(h) = hass::analysis::fix_hint(d.rule) {
                    println!("    fix: {h}");
                }
            }
        }
        eprintln!(
            "[lint] {} file(s): {} violation(s), {} allowlisted",
            report.files,
            report.diagnostics.len(),
            report.suppressed
        );
    }
    if report.diagnostics.is_empty() {
        0
    } else {
        1
    }
}
