//! One-shot magnitude (L1) pruning (paper §III).
//!
//! Pruning decisions are *thresholds*: per-layer τ_w on |w| and τ_a on |a|.
//! The search space exposed to the optimizer is the unit hypercube
//! [0,1]^(2·L): each coordinate is a target *sparsity* (not a raw
//! threshold), mapped through the layer's [`TransferCurve`] to the τ that
//! achieves it.  Searching in sparsity space keeps the TPE geometry
//! uniform across layers whose weight scales differ by orders of
//! magnitude (the per-layer statistic diversity of [14], [16]).
//!
//! Uniform-threshold mode (one τ_w, one τ_a shared by every layer) is the
//! paper's simple baseline; per-layer mode is what HASS searches.

use crate::arch::Network;
use crate::sparsity::{NetworkSparsity, SparsityPoint};
use crate::util::clampf;

/// Upper bound on searchable sparsity per tensor: pruning everything in a
/// layer destroys the network and wastes search budget, so the optimizer's
/// unit interval maps onto [0, MAX_SPARSITY].
pub const MAX_SPARSITY: f64 = 0.95;

/// A concrete one-shot pruning decision for a whole network.
#[derive(Clone, Debug, PartialEq)]
pub struct PruningPlan {
    /// per-compute-layer weight thresholds τ_w
    pub tau_w: Vec<f64>,
    /// per-compute-layer activation thresholds τ_a
    pub tau_a: Vec<f64>,
}

impl PruningPlan {
    /// The no-op plan (dense network, natural activation zeros only).
    pub fn dense(n_layers: usize) -> Self {
        PruningPlan { tau_w: vec![0.0; n_layers], tau_a: vec![0.0; n_layers] }
    }

    /// Uniform thresholds across all layers (paper's baseline mode).
    pub fn uniform(n_layers: usize, tau_w: f64, tau_a: f64) -> Self {
        PruningPlan { tau_w: vec![tau_w; n_layers], tau_a: vec![tau_a; n_layers] }
    }

    /// Decode an optimizer point `x ∈ [0,1]^(2L)` into thresholds via the
    /// per-layer transfer curves: `x[2i]` is layer i's weight-sparsity
    /// target, `x[2i+1]` its activation-sparsity target.
    pub fn from_unit_point(x: &[f64], sparsity: &NetworkSparsity) -> Self {
        let n = sparsity.layers.len();
        assert_eq!(x.len(), 2 * n, "expect 2 coords per compute layer");
        let mut tau_w = Vec::with_capacity(n);
        let mut tau_a = Vec::with_capacity(n);
        for (i, prof) in sparsity.layers.iter().enumerate() {
            let sw = clampf(x[2 * i], 0.0, 1.0) * MAX_SPARSITY;
            let sa_target = clampf(x[2 * i + 1], 0.0, 1.0) * MAX_SPARSITY;
            tau_w.push(prof.weight_curve.tau_for(sw));
            // activation threshold may not reduce sparsity below natural
            let sa = sa_target.max(prof.act_curve.frac_at_zero());
            tau_a.push(prof.act_curve.tau_for(sa));
        }
        PruningPlan { tau_w, tau_a }
    }

    /// Sparsity operating points this plan reaches under a sparsity model.
    pub fn points(&self, sparsity: &NetworkSparsity) -> Vec<SparsityPoint> {
        sparsity.points(&self.tau_w, &self.tau_a)
    }

    pub fn n_layers(&self) -> usize {
        self.tau_w.len()
    }
}

/// Software pruning metrics (paper's f_spa and the Fig. 1 x-axis).
#[derive(Clone, Copy, Debug, Default)]
pub struct SparsityMetrics {
    /// average of (S_w + S_a)/2 across layers, op-weighted — f_spa
    pub avg_sparsity: f64,
    /// op-weighted mean pair density (1 − S̄) — Fig. 1's operation density
    pub op_density: f64,
    /// fraction of weight parameters pruned (storage view)
    pub weight_sparsity: f64,
}

/// Compute software metrics of a pruning operating point over a network.
/// `points` must be in `Network::compute_indices()` order.
pub fn metrics(net: &Network, points: &[SparsityPoint]) -> SparsityMetrics {
    let compute = net.compute_layers();
    assert_eq!(compute.len(), points.len());
    let mut ops_total = 0.0;
    let mut ops_dense_weighted_spa = 0.0;
    let mut density_weighted = 0.0;
    let mut w_total = 0.0;
    let mut w_pruned = 0.0;
    for (l, p) in compute.iter().zip(points) {
        let ops = l.macs_per_image() as f64;
        ops_total += ops;
        ops_dense_weighted_spa += ops * 0.5 * (p.s_w + p.s_a);
        density_weighted += ops * p.pair_density();
        let w = l.weight_count() as f64;
        w_total += w;
        w_pruned += w * p.s_w;
    }
    SparsityMetrics {
        avg_sparsity: ops_dense_weighted_spa / ops_total.max(1.0),
        op_density: density_weighted / ops_total.max(1.0),
        weight_sparsity: w_pruned / w_total.max(1.0),
    }
}

/// Accuracy-response surrogate for target geometries we cannot execute
/// (DESIGN.md §1.1): accuracy degrades smoothly with the op-weighted
/// fraction of values pruned *beyond the natural zeros* (post-ReLU zeros
/// are already zero — removing them costs nothing, which is exactly
/// PASS's free lunch), with a cliff once any single layer loses almost
/// everything.  The *measured* path (CalibNet via PJRT) replaces this in
/// the HASS loop; baselines and target-geometry benches rank with it.
pub fn surrogate_accuracy(
    base_acc: f64,
    net: &Network,
    points: &[SparsityPoint],
    natural: &[SparsityPoint],
) -> f64 {
    assert_eq!(points.len(), natural.len());
    let compute = net.compute_layers();
    let mut ops_total = 0.0;
    let mut excess_weighted = 0.0;
    let mut layer_damage = 0.0;
    let mut worst_excess = 0.0f64;
    for ((l, p), nat) in compute.iter().zip(points).zip(natural) {
        let ops = l.macs_per_image() as f64;
        // fraction of *previously non-zero* values removed
        let ew = clampf((p.s_w - nat.s_w) / (1.0 - nat.s_w).max(1e-9), 0.0, 1.0);
        let ea = clampf((p.s_a - nat.s_a) / (1.0 - nat.s_a).max(1e-9), 0.0, 1.0);
        ops_total += ops;
        excess_weighted += ops * 0.5 * (ew + ea);
        // per-layer collapse: losing >85% of a layer's live pairs damages
        // the features it feeds forward, proportionally to the layer's
        // share of the network's compute
        let pair_excess = 1.0 - (1.0 - ew) * (1.0 - ea);
        let over = ((pair_excess - 0.85).max(0.0) / 0.15).powi(2);
        layer_damage += ops * over * 30.0;
        worst_excess = worst_excess.max(pair_excess);
    }
    let s = excess_weighted / ops_total.max(1.0);
    // smooth part: quadratic loss in aggregate *excess* sparsity
    let smooth = 1.45 * s.powi(2) + 0.12 * s;
    // total-collapse backstop: even a tiny layer at ~complete pruning
    // severs the network
    let backstop = if worst_excess > 0.97 { (worst_excess - 0.97) * 400.0 } else { 0.0 };
    (base_acc - smooth * 12.0 - layer_damage / ops_total.max(1.0) - backstop).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::sparsity::synthesize;
    use crate::util::prop::forall;

    #[test]
    fn dense_plan_is_all_zero_thresholds() {
        let p = PruningPlan::dense(4);
        assert_eq!(p.tau_w, vec![0.0; 4]);
        assert_eq!(p.tau_a, vec![0.0; 4]);
        assert_eq!(p.n_layers(), 4);
    }

    #[test]
    fn unit_point_decodes_to_target_sparsity() {
        let net = networks::resnet18();
        let prof = synthesize(&net, 1);
        let n = prof.layers.len();
        let mut x = vec![0.0; 2 * n];
        x[0] = 0.5; // first layer weight-sparsity target = 0.475
        let plan = PruningPlan::from_unit_point(&x, &prof);
        let pts = plan.points(&prof);
        assert!((pts[0].s_w - 0.5 * MAX_SPARSITY).abs() < 0.02, "{:?}", pts[0]);
        // untouched layers stay at zero weight sparsity
        assert!(pts[1].s_w < 1e-6);
    }

    #[test]
    fn activation_sparsity_never_below_natural() {
        let net = networks::calibnet();
        let prof = synthesize(&net, 2);
        let n = prof.layers.len();
        let plan = PruningPlan::from_unit_point(&vec![0.0; 2 * n], &prof);
        for (p, l) in plan.points(&prof).iter().zip(&prof.layers) {
            assert!(p.s_a >= l.act_curve.frac_at_zero() - 1e-9);
        }
    }

    #[test]
    fn unit_point_monotone_in_coordinates() {
        let net = networks::calibnet();
        let prof = synthesize(&net, 3);
        let n = prof.layers.len();
        forall(40, 0x9121, |rng| {
            let x: Vec<f64> = (0..2 * n).map(|_| rng.f64()).collect();
            let mut y = x.clone();
            let i = rng.below(2 * n);
            y[i] = (y[i] + 0.3).min(1.0);
            let px = PruningPlan::from_unit_point(&x, &prof).points(&prof);
            let py = PruningPlan::from_unit_point(&y, &prof).points(&prof);
            let li = i / 2;
            if i % 2 == 0 {
                assert!(py[li].s_w >= px[li].s_w - 1e-9);
            } else {
                assert!(py[li].s_a >= px[li].s_a - 1e-9);
            }
        });
    }

    #[test]
    fn metrics_dense_network() {
        let net = networks::calibnet();
        let pts = vec![SparsityPoint::DENSE; net.compute_layers().len()];
        let m = metrics(&net, &pts);
        assert!((m.op_density - 1.0).abs() < 1e-12);
        assert!(m.avg_sparsity.abs() < 1e-12);
        assert!(m.weight_sparsity.abs() < 1e-12);
    }

    #[test]
    fn metrics_weighted_by_ops() {
        let net = networks::calibnet();
        let n = net.compute_layers().len();
        // sparsify only the largest layer -> metrics move more than for
        // the smallest layer
        let ops: Vec<u64> = net.compute_layers().iter().map(|l| l.macs_per_image()).collect();
        let big = ops.iter().enumerate().max_by_key(|(_, &o)| o).unwrap().0;
        let small = ops.iter().enumerate().min_by_key(|(_, &o)| o).unwrap().0;
        let mk = |idx: usize| {
            let mut pts = vec![SparsityPoint::DENSE; n];
            pts[idx] = SparsityPoint { s_w: 0.8, s_a: 0.0 };
            metrics(&net, &pts).avg_sparsity
        };
        assert!(mk(big) > mk(small));
    }

    #[test]
    fn op_density_is_one_minus_pair_sparsity_for_uniform() {
        let net = networks::resnet18();
        let n = net.compute_layers().len();
        let pts = vec![SparsityPoint { s_w: 0.5, s_a: 0.5 }; n];
        let m = metrics(&net, &pts);
        assert!((m.op_density - 0.25).abs() < 1e-12);
    }

    #[test]
    fn surrogate_accuracy_monotone_decreasing() {
        let net = networks::resnet18();
        let n = net.compute_layers().len();
        let natural = vec![SparsityPoint::DENSE; n];
        let mut last = f64::INFINITY;
        for s in [0.0, 0.2, 0.4, 0.6, 0.8] {
            let pts = vec![SparsityPoint { s_w: s, s_a: s }; n];
            let a = surrogate_accuracy(70.0, &net, &pts, &natural);
            assert!(a <= last + 1e-9, "not monotone at {s}");
            last = a;
        }
    }

    #[test]
    fn surrogate_accuracy_cliff_on_layer_collapse() {
        let net = networks::resnet18();
        let n = net.compute_layers().len();
        let natural = vec![SparsityPoint::DENSE; n];
        // collapse the biggest layer: near-total pruning of a major layer
        // must cost far more than mild uniform pruning of everything
        let ops: Vec<u64> = net.compute_layers().iter().map(|l| l.macs_per_image()).collect();
        let big = ops.iter().enumerate().max_by_key(|(_, &o)| o).unwrap().0;
        let mut pts = vec![SparsityPoint::DENSE; n];
        pts[big] = SparsityPoint { s_w: 0.97, s_a: 0.95 }; // pair sparsity ~0.9985
        let collapsed = surrogate_accuracy(70.0, &net, &pts, &natural);
        let mild = surrogate_accuracy(
            70.0,
            &net,
            &vec![SparsityPoint { s_w: 0.3, s_a: 0.3 }; n],
            &natural,
        );
        assert!(collapsed < mild - 8.0, "collapsed {collapsed} vs mild {mild}");
    }

    #[test]
    fn surrogate_accuracy_natural_zeros_are_free() {
        // pruning exactly at the natural activation zero-rate must not
        // cost anything (PASS's free lunch)
        let net = networks::resnet18();
        let n = net.compute_layers().len();
        let natural = vec![SparsityPoint { s_w: 0.0, s_a: 0.5 }; n];
        let at_natural = vec![SparsityPoint { s_w: 0.0, s_a: 0.5 }; n];
        let a = surrogate_accuracy(70.0, &net, &at_natural, &natural);
        assert!((a - 70.0).abs() < 1e-9, "natural zeros cost accuracy: {a}");
        // pruning beyond natural does cost
        let beyond = vec![SparsityPoint { s_w: 0.0, s_a: 0.8 }; n];
        assert!(surrogate_accuracy(70.0, &net, &beyond, &natural) < 70.0);
    }

    #[test]
    fn uniform_plan_broadcasts() {
        let p = PruningPlan::uniform(3, 0.1, 0.2);
        assert_eq!(p.tau_w, vec![0.1; 3]);
        assert_eq!(p.tau_a, vec![0.2; 3]);
    }
}
