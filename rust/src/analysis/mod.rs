//! `hass lint` — the repo-native invariant linter.
//!
//! Several of this repo's correctness guarantees are *conventions*, not
//! types: journal determinism, the PR 7 panic-free daemon contract,
//! poison-tolerant locking, structured concurrency, classified atomics.
//! The compiler cannot enforce them, and a human reviewer forgets.  This
//! module is a zero-dependency static analysis over the repo's own Rust
//! sources — a hand-rolled lexer ([`lexer`]) feeding token-sequence
//! rules ([`rules`]) — wired up as `hass lint` and run as a blocking CI
//! job, so a regression against any of these contracts fails the build
//! with a `file:line: [rule] message` diagnostic.
//!
//! # Rule reference
//!
//! | rule | scope | what it catches |
//! |------|-------|-----------------|
//! | `determinism` | `engine/`, `dse/`, `optim/`, `simulator/` | `HashMap`/`HashSet` (hashed iteration order), `Instant`/`SystemTime`/`UNIX_EPOCH` (wall clock), `thread::current`/`ThreadId` (thread identity), `env!`/`env::var*` (environment reads) in journaled search paths — anything that could make a replay diverge from its journal |
//! | `panic-safety` | `server/`, `engine/shard.rs`, `main.rs`, `util/cli.rs`, `analysis/` | `.unwrap()`/`.expect()` and `panic!`-family macros on CLI/daemon-reachable paths (the PR 7 contract: malformed input exits with an error, a resident `hass serve` never dies on one request) |
//! | `index-panic` | same as `panic-safety` | `x[i]` indexing/slicing, which panics out-of-bounds; use `.get()`, iterators, or slice patterns |
//! | `lock-discipline` | everywhere, *including* tests and benches | raw `.lock().unwrap()` (and `.read()`/`.write()` + `unwrap`/`expect`), which propagates mutex poisoning; use [`crate::util::lock_clean`] or handle `into_inner` explicitly |
//! | `thread-spawn` | `src/` except `util/` | detached `thread::spawn`; use `std::thread::scope` so worker lifetimes and panics stay structured |
//! | `atomics-relaxed` | `src/` | `Ordering::Relaxed` without a `relaxed:` classification comment within two lines — stats counters must say why Relaxed is safe, control atomics (shutdown/cancel/admission) must use Acquire/Release |
//!
//! All rules except `lock-discipline` skip `#[test]`/`#[cfg(test)]`
//! items and `use` declarations.  Scoping is by *module key* (the path
//! from the last `src/`, `tests/` or `benches/` component), so results
//! do not depend on the directory the linter is invoked from.
//!
//! # Suppression
//!
//! Two escape hatches, both designed to leave an audit trail:
//!
//! * `// lint: allow(<rule>[, <rule>...])` on the offending line or up
//!   to two lines above it.  House style is a short justification
//!   comment ending in the directive — the waiver and its reason travel
//!   together.
//! * [`DEFAULT_ALLOWLIST`](rules::DEFAULT_ALLOWLIST): module-keyed
//!   waivers with a recorded reason, for contracts that hold for a whole
//!   file (e.g. slot-addressed indexing in `engine/shard.rs`).
//!
//! Suppressed findings still count: `hass lint` reports `N violation(s),
//! M allowlisted`, and the self-hosting test pins the repo at zero
//! violations while asserting the waiver count stays visible.
//!
//! # Exit codes
//!
//! `hass lint` exits 0 on a clean tree, 1 if any violation is printed,
//! 2 on usage or I/O errors — so CI can gate on it directly.

mod lexer;
mod rules;

pub use lexer::{lex, Lexed, Tok, TokKind};
pub use rules::{lint_source, module_key, Diagnostic, DEFAULT_ALLOWLIST};

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Aggregated result of linting a set of paths.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Unsuppressed violations, in deterministic (path, token) order.
    pub diagnostics: Vec<Diagnostic>,
    /// Number of `.rs` files linted.
    pub files: usize,
    /// Findings waived by `lint: allow` or the default allowlist.
    pub suppressed: usize,
}

impl Diagnostic {
    /// The grep-stable CI line: `file:line: [rule] message`.
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }

    /// Machine-readable form for `hass lint --json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("file", Json::Str(self.file.clone())),
            ("line", Json::Num(f64::from(self.line))),
            ("rule", Json::Str(self.rule.to_string())),
            ("message", Json::Str(self.message.clone())),
        ])
    }
}

/// One-line remediation hint per rule (`hass lint --fix-hints`).
pub fn fix_hint(rule: &str) -> Option<&'static str> {
    match rule {
        "determinism" => Some(
            "swap HashMap/HashSet for BTreeMap/BTreeSet (derive Ord on the key if \
             needed); move clocks/thread-ids/env reads out of the journaled path or \
             justify with `// lint: allow(determinism)`",
        ),
        "panic-safety" => Some(
            "return Result/Option, or use let-else with an eprintln + error exit; a \
             true structural invariant gets a justification comment ending in \
             `lint: allow(panic-safety)`",
        ),
        "index-panic" => Some(
            "use .get()/.get_mut() with let-else, iterators (zip/windows/chunks), or \
             slice patterns instead of x[i]",
        ),
        "lock-discipline" => Some(
            "replace m.lock().unwrap() with util::lock_clean(&m) (poison-tolerant); \
             .expect() on a lock result is the same hazard",
        ),
        "thread-spawn" => Some(
            "use std::thread::scope so worker lifetimes and panics stay structured; \
             util/ owns the rare justified detached helpers",
        ),
        "atomics-relaxed" => Some(
            "stats counter? add a `// relaxed: <why>` comment within two lines; \
             control atomic? upgrade to Acquire/Release",
        ),
        _ => None,
    }
}

/// Deterministic file discovery: explicit files are taken as-is,
/// directories are walked recursively with entries sorted by name and
/// only `.rs` files kept — the same order on every machine.
fn walk(paths: &[PathBuf]) -> Result<Vec<PathBuf>, String> {
    fn collect(p: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
        let rd = std::fs::read_dir(p).map_err(|e| format!("read dir {}: {e}", p.display()))?;
        let mut entries: Vec<PathBuf> = rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
        entries.sort();
        for e in entries {
            if e.is_dir() {
                collect(&e, out)?;
            } else if e.extension().is_some_and(|x| x == "rs") {
                out.push(e);
            }
        }
        Ok(())
    }
    let mut files = Vec::new();
    for p in paths {
        if p.is_file() {
            files.push(p.clone());
        } else {
            collect(p, &mut files)?;
        }
    }
    Ok(files)
}

/// Lint every `.rs` file under `paths`.  Errs only on I/O problems
/// (unreadable path), never on source content.
pub fn lint_paths(paths: &[PathBuf]) -> Result<LintReport, String> {
    let files = walk(paths)?;
    let mut report = LintReport { files: files.len(), ..Default::default() };
    for f in &files {
        let src =
            std::fs::read_to_string(f).map_err(|e| format!("read {}: {e}", f.display()))?;
        let shown = f.to_string_lossy();
        for d in lint_source(&shown, &src) {
            if d.suppressed {
                report.suppressed += 1;
            } else {
                report.diagnostics.push(d);
            }
        }
    }
    Ok(report)
}
