//! Hand-rolled token scanner for [`hass lint`](crate::analysis).
//!
//! Not a parser — a lossy lexer that is exactly strong enough for the
//! rules in [`super::rules`]: it distinguishes identifiers, punctuation,
//! numbers, string/char literals and lifetimes, tracks line numbers, and
//! **never** yields tokens from inside comments or literals (which is
//! what makes the rules immune to the classic grep false-positive of a
//! pattern appearing in a doc comment or an error message).  Along the
//! way it collects the two comment-borne side channels the rules consume:
//! `lint: allow(<rule>, ...)` escape hatches and `relaxed:` atomics
//! classifications.
//!
//! The scanner understands everything that could otherwise desynchronize
//! a token stream taken from real Rust source: line (`//`) and *nested*
//! block (`/* /* */ */`) comments, raw strings `r#"..."#` with any hash
//! count, raw identifiers `r#ident`, byte strings/chars, escaped
//! characters (including `\`-newline line continuations inside string
//! literals, which shift line numbers), and the `'a` lifetime vs `'a'`
//! char-literal ambiguity.
//!
//! Like everything under `src/analysis/`, this module is itself subject
//! to the panic-safety rule: the cursor is driven entirely through
//! `get`-style lookups, so malformed input can mislex but never panic.

use std::collections::{BTreeMap, BTreeSet};

/// Token classes — only as fine-grained as the rules need.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// identifier or keyword (rules that care check the text)
    Ident,
    /// one punctuation character
    Punct,
    /// numeric literal (int or float, any base/suffix)
    Num,
    /// string literal of any flavor (text is dropped)
    Str,
    /// char or byte-char literal (text is dropped)
    Char,
    /// lifetime such as `'a` (text keeps the quote)
    Lifetime,
}

/// One lexed token with the 1-based line it starts on.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// The lexer's full output for one source file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    /// line -> rule names allowed by a `lint: allow(...)` comment there
    pub allows: BTreeMap<u32, BTreeSet<String>>,
    /// lines whose comments carry a `relaxed:` atomics classification
    pub annotated: BTreeSet<u32>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Record a comment's side channels: `relaxed:` marks the line annotated
/// (for the atomics rule), `lint: allow(a, b)` registers rule names.
fn note_comment(text: &str, at_line: u32, out: &mut Lexed) {
    if text.contains("relaxed:") {
        out.annotated.insert(at_line);
    }
    const DIRECTIVE: &str = "lint: allow(";
    if let Some(idx) = text.find(DIRECTIVE) {
        let rest = text.get(idx + DIRECTIVE.len()..).unwrap_or("");
        if let Some(close) = rest.find(')') {
            for rule in rest.get(..close).unwrap_or("").split(',') {
                let rule = rule.trim();
                if !rule.is_empty() {
                    out.allows.entry(at_line).or_default().insert(rule.to_string());
                }
            }
        }
    }
}

/// Lex one file.  Never fails: unexpected input degrades to stray
/// `Punct` tokens, which no rule pattern matches.
pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let at = |k: usize| -> Option<char> { cs.get(k).copied() };
    let text_of = |s: usize, e: usize| -> String {
        cs.get(s..e).map(|seg| seg.iter().collect()).unwrap_or_default()
    };

    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;

    while i < n {
        let Some(c) = at(i) else { break };
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // ---- line comment -------------------------------------------
        if c == '/' && at(i + 1) == Some('/') {
            let start = i;
            while i < n && at(i) != Some('\n') {
                i += 1;
            }
            note_comment(&text_of(start, i), line, &mut out);
            continue;
        }
        // ---- block comment (nested) ---------------------------------
        if c == '/' && at(i + 1) == Some('*') {
            let start = i;
            let start_line = line;
            let mut depth = 0i32;
            while i < n {
                if at(i) == Some('/') && at(i + 1) == Some('*') {
                    depth += 1;
                    i += 2;
                } else if at(i) == Some('*') && at(i + 1) == Some('/') {
                    depth -= 1;
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    if at(i) == Some('\n') {
                        line += 1;
                    }
                    i += 1;
                }
            }
            note_comment(&text_of(start, i), start_line, &mut out);
            continue;
        }
        // ---- raw strings, raw idents, byte strings/chars ------------
        if c == 'r' || c == 'b' {
            let prefix_len = if c == 'b' && at(i + 1) == Some('r') { 2 } else { 1 };
            let has_r = c == 'r' || prefix_len == 2;
            let mut k = i + prefix_len;
            let kc = at(k);
            if kc == Some('"') || (has_r && kc == Some('#')) {
                if has_r {
                    let mut hashes = 0usize;
                    while at(k) == Some('#') {
                        hashes += 1;
                        k += 1;
                    }
                    if at(k) == Some('"') {
                        // raw (byte) string: runs to `"` + the same
                        // number of hashes; no escapes exist inside
                        k += 1;
                        let start_line = line;
                        while k < n {
                            if at(k) == Some('\n') {
                                line += 1;
                            }
                            if at(k) == Some('"')
                                && (0..hashes).all(|h| at(k + 1 + h) == Some('#'))
                            {
                                k += 1 + hashes;
                                break;
                            }
                            k += 1;
                        }
                        out.toks.push(Tok {
                            kind: TokKind::Str,
                            text: String::new(),
                            line: start_line,
                        });
                        i = k;
                        continue;
                    } else if hashes > 0 && at(k).is_some_and(is_ident_start) {
                        // raw identifier r#ident: token text drops `r#`
                        let s = k;
                        while at(k).is_some_and(is_ident_cont) {
                            k += 1;
                        }
                        out.toks.push(Tok {
                            kind: TokKind::Ident,
                            text: text_of(s, k),
                            line,
                        });
                        i = k;
                        continue;
                    }
                    // neither: plain identifier starting with r/b below
                } else if at(k) == Some('"') {
                    // byte string b"...": same escape rules as a normal
                    // string (incl. `\`-newline line continuations)
                    let start_line = line;
                    i = k + 1;
                    while i < n {
                        if at(i) == Some('\\') {
                            if at(i + 1) == Some('\n') {
                                line += 1;
                            }
                            i += 2;
                            continue;
                        }
                        if at(i) == Some('\n') {
                            line += 1;
                        }
                        if at(i) == Some('"') {
                            i += 1;
                            break;
                        }
                        i += 1;
                    }
                    out.toks.push(Tok {
                        kind: TokKind::Str,
                        text: String::new(),
                        line: start_line,
                    });
                    continue;
                }
            }
            if c == 'b' && at(i + 1) == Some('\'') {
                // byte char b'x' / b'\n'
                let start_line = line;
                i += 2;
                if at(i) == Some('\\') {
                    i += 2;
                } else {
                    i += 1;
                }
                while i < n && at(i) != Some('\'') {
                    i += 1;
                }
                i += 1;
                out.toks.push(Tok {
                    kind: TokKind::Char,
                    text: String::new(),
                    line: start_line,
                });
                continue;
            }
            // plain identifier that happens to start with r/b
            let s = i;
            while at(i).is_some_and(is_ident_cont) {
                i += 1;
            }
            out.toks.push(Tok { kind: TokKind::Ident, text: text_of(s, i), line });
            continue;
        }
        // ---- string literal -----------------------------------------
        if c == '"' {
            let start_line = line;
            i += 1;
            while i < n {
                if at(i) == Some('\\') {
                    // an escaped newline continues the literal on the
                    // next line — the line counter must still advance
                    if at(i + 1) == Some('\n') {
                        line += 1;
                    }
                    i += 2;
                    continue;
                }
                if at(i) == Some('\n') {
                    line += 1;
                    i += 1;
                    continue;
                }
                if at(i) == Some('"') {
                    i += 1;
                    break;
                }
                i += 1;
            }
            out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line: start_line });
            continue;
        }
        // ---- char literal vs lifetime -------------------------------
        if c == '\'' {
            if at(i + 1) == Some('\\') {
                // escaped char literal '\n', '\u{1F600}', ...
                let mut j = i + 3;
                while j < n && at(j) != Some('\'') {
                    j += 1;
                }
                out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                i = j + 1;
                continue;
            }
            if at(i + 1).is_some_and(is_ident_start) && at(i + 2) == Some('\'') {
                // 'x' — a closing quote right after one ident char
                out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                i += 3;
                continue;
            }
            if at(i + 1).is_some_and(is_ident_start) {
                // 'name with no closing quote: a lifetime
                let s = i;
                let mut j = i + 1;
                while at(j).is_some_and(is_ident_cont) {
                    j += 1;
                }
                out.toks.push(Tok { kind: TokKind::Lifetime, text: text_of(s, j), line });
                i = j;
                continue;
            }
            // anything else ('0', '.', a stray quote) degrades to punct
            // tokens — harmless, since no rule pattern contains them
            out.toks.push(Tok { kind: TokKind::Punct, text: "'".to_string(), line });
            i += 1;
            continue;
        }
        // ---- number -------------------------------------------------
        if c.is_ascii_digit() {
            let s = i;
            let mut seen_dot = false;
            while let Some(ch) = at(i) {
                if is_ident_cont(ch) {
                    i += 1;
                } else if ch == '.' && at(i + 1).is_some_and(|d| d.is_ascii_digit()) {
                    seen_dot = true;
                    i += 1;
                } else if (ch == '+' || ch == '-')
                    && i > s
                    && matches!(at(i.wrapping_sub(1)), Some('e') | Some('E'))
                    && seen_dot
                {
                    // exponent sign of a float like 1.5e-3
                    i += 1;
                } else {
                    break;
                }
            }
            out.toks.push(Tok { kind: TokKind::Num, text: text_of(s, i), line });
            continue;
        }
        // ---- identifier / keyword -----------------------------------
        if is_ident_start(c) {
            let s = i;
            while at(i).is_some_and(is_ident_cont) {
                i += 1;
            }
            out.toks.push(Tok { kind: TokKind::Ident, text: text_of(s, i), line });
            continue;
        }
        // ---- single punctuation char --------------------------------
        out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}
