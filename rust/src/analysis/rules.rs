//! The invariant rules behind [`hass lint`](crate::analysis).
//!
//! Each rule is a short token-sequence pattern over [`super::lexer`]
//! output, scoped to the modules whose contracts it protects (see the
//! scope constants below and the rule reference in the module docs).
//! Rules run per file; a file's *module key* — the path from its last
//! `src/`, `tests/` or `benches/` component — decides which scopes
//! apply, so results are identical whether the linter is invoked from
//! the repo root, from `rust/`, or on absolute paths.
//!
//! Suppression has exactly two forms, both deliberate and auditable:
//!
//! * an inline `// lint: allow(<rule>)` comment on the offending line
//!   or up to two lines above it (so a justification comment fits), and
//! * [`DEFAULT_ALLOWLIST`] — module-keyed waivers with a recorded
//!   reason, for contracts that hold module-wide.
//!
//! Suppressed findings are still produced (with
//! [`Diagnostic::suppressed`] set) so the CLI can report how many
//! waivers are live; the self-hosting test in `tests/lint.rs` asserts
//! that count stays small and intentional.

use std::collections::BTreeSet;

use super::lexer::{lex, Lexed, Tok, TokKind};

/// Rust keywords that may legitimately precede `[` without forming an
/// index expression (`let [a, b] = ..`, `for x in ..`, `match v[..]`
/// arms are *not* in this set — only non-expression positions are).
const KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut",
    "pub", "ref", "return", "static", "struct", "trait", "type", "unsafe", "use", "where",
    "while", "yield",
];

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
const ENV_READS: &[&str] = &["var", "var_os", "vars", "vars_os"];

/// Journaled/deterministic paths: same inputs must replay to the same
/// journal bytes, so no hashed iteration order or wall-clock reads.
const DETERMINISM_SCOPE: &[&str] =
    &["src/engine/", "src/dse/", "src/optim/", "src/simulator/"];
/// CLI/daemon-reachable paths under the PR 7 panic-free contract.
const PANIC_SCOPE: &[&str] = &[
    "src/server/",
    "src/engine/shard.rs",
    "src/main.rs",
    "src/util/cli.rs",
    "src/analysis/",
];
/// Detached threads are banned everywhere in the library crate...
const THREAD_SCOPE: &[&str] = &["src/"];
/// ...except util/, which owns the rare justified detached helpers.
const THREAD_EXCLUDE: &[&str] = &["src/util/"];
/// Every `Ordering::Relaxed` in the crate must be classified.
const ATOMICS_SCOPE: &[&str] = &["src/"];

/// Module-keyed waivers: `(rule, module-key prefix, reason)`.  The
/// reason is part of the record — a waiver without one does not land.
pub const DEFAULT_ALLOWLIST: &[(&str, &str, &str)] = &[(
    "index-panic",
    "src/engine/shard.rs",
    "slot-addressed indexing: indices come from enumerate() over the same \
     index-addressed slot vectors (PR 5 contract)",
)];

/// One finding.  `suppressed` findings were matched but waived by an
/// inline `lint: allow` or the [`DEFAULT_ALLOWLIST`]; the CLI counts
/// them instead of printing them.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
    pub suppressed: bool,
}

/// Path portion from the last `src/`, `tests/` or `benches/` component —
/// the key rules and allowlist entries are scoped by.
pub fn module_key(path: &str) -> String {
    let p = path.replace('\\', "/");
    for marker in ["/src/", "/tests/", "/benches/"] {
        if let Some(idx) = p.rfind(marker) {
            return p.get(idx + 1..).unwrap_or_default().to_string();
        }
    }
    for marker in ["src/", "tests/", "benches/"] {
        if p.starts_with(marker) {
            return p;
        }
    }
    p
}

fn in_scope(module: &str, prefixes: &[&str], excludes: &[&str]) -> bool {
    prefixes.iter().any(|p| module.starts_with(p))
        && !excludes.iter().any(|e| module.starts_with(e))
}

/// Token-index ranges covered by `#[test]` / `#[cfg(test)]` items or by
/// `use ...;` declarations — every rule except lock-discipline skips
/// those (tests may exercise panics; imports name types they don't use).
fn mark_skipped(toks: &[Tok]) -> Vec<bool> {
    let n = toks.len();
    let mut skip = vec![false; n];
    let mut i = 0usize;
    while i < n {
        let Some(t) = toks.get(i) else { break };
        // `use` at statement position starts a use-declaration
        if t.kind == TokKind::Ident && t.text == "use" {
            let ok = match i.checked_sub(1).and_then(|p| toks.get(p)) {
                None => true,
                Some(prev) => {
                    (prev.kind == TokKind::Punct
                        && matches!(prev.text.as_str(), ";" | "{" | "}" | "]"))
                        || (prev.kind == TokKind::Ident && prev.text == "pub")
                }
            };
            if ok {
                let mut j = i;
                while let Some(tj) = toks.get(j) {
                    let done = tj.kind == TokKind::Punct && tj.text == ";";
                    if let Some(s) = skip.get_mut(j) {
                        *s = true;
                    }
                    j += 1;
                    if done {
                        break;
                    }
                }
                i = j;
                continue;
            }
        }
        // `#[...]` attribute: collect its identifiers to classify it
        if t.kind == TokKind::Punct
            && t.text == "#"
            && toks.get(i + 1).is_some_and(|a| a.text == "[")
        {
            let mut depth = 0i32;
            let mut j = i + 1;
            let mut names: Vec<&str> = Vec::new();
            while let Some(tk) = toks.get(j) {
                if tk.kind == TokKind::Punct && tk.text == "[" {
                    depth += 1;
                } else if tk.kind == TokKind::Punct && tk.text == "]" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if depth >= 1 && tk.kind == TokKind::Ident {
                    names.push(tk.text.as_str());
                }
                j += 1;
            }
            let is_test_attr = matches!(names.as_slice(), ["test"])
                || matches!(names.as_slice(), ["cfg", "test", ..])
                || (matches!(names.as_slice(), ["cfg", "all", ..])
                    && names.iter().skip(2).any(|nm| *nm == "test"));
            if is_test_attr {
                // further attributes stacked on the same item
                let mut k = j + 1;
                while toks.get(k).is_some_and(|a| a.kind == TokKind::Punct && a.text == "#")
                    && toks.get(k + 1).is_some_and(|b| b.text == "[")
                {
                    let mut d2 = 0i32;
                    while let Some(tk) = toks.get(k) {
                        if tk.text == "[" {
                            d2 += 1;
                        } else if tk.text == "]" {
                            d2 -= 1;
                            if d2 == 0 {
                                break;
                            }
                        }
                        k += 1;
                    }
                    k += 1;
                }
                // the item itself: to the matching `}` of its first
                // brace, or a `;` at brace depth 0
                let mut bd = 0i32;
                let mut end = k;
                while let Some(tk) = toks.get(end) {
                    if tk.kind == TokKind::Punct {
                        if tk.text == "{" {
                            bd += 1;
                        } else if tk.text == "}" {
                            bd -= 1;
                            if bd == 0 {
                                break;
                            }
                        } else if tk.text == ";" && bd == 0 {
                            break;
                        }
                    }
                    end += 1;
                }
                for s in skip.iter_mut().take((end + 1).min(n)).skip(i) {
                    *s = true;
                }
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
    skip
}

/// Per-file rule state: dedup set + accumulated findings.
struct Sink<'a> {
    path: &'a str,
    module: &'a str,
    lexed: &'a Lexed,
    seen: BTreeSet<(u32, &'static str)>,
    diags: Vec<Diagnostic>,
}

impl Sink<'_> {
    /// An inline `lint: allow(rule)` on any of `lines` or up to two
    /// lines above one (room for a justification comment) waives it.
    fn allowed(&self, rule: &str, lines: &[u32]) -> bool {
        lines.iter().any(|&ln| {
            (0..=2u32).any(|d| {
                ln.checked_sub(d)
                    .and_then(|probe| self.lexed.allows.get(&probe))
                    .is_some_and(|set| set.contains(rule))
            })
        })
    }

    fn module_allowed(&self, rule: &str) -> bool {
        DEFAULT_ALLOWLIST
            .iter()
            .any(|(r, pfx, _)| *r == rule && self.module.starts_with(pfx))
    }

    /// Record a finding, deduplicating on `(line, rule)`.
    fn push(&mut self, rule: &'static str, line: u32, message: String, lines: &[u32]) {
        if !self.seen.insert((line, rule)) {
            return;
        }
        let one = [line];
        let lines = if lines.is_empty() { one.as_slice() } else { lines };
        let suppressed = self.allowed(rule, lines) || self.module_allowed(rule);
        self.diags.push(Diagnostic {
            file: self.path.to_string(),
            line,
            rule,
            message,
            suppressed,
        });
    }
}

/// `ident :: <seg>` — the path segment right after a `::`, if any.
fn path_seg(toks: &[Tok], j: usize) -> Option<&str> {
    let a = toks.get(j + 1)?;
    let b = toks.get(j + 2)?;
    let c = toks.get(j + 3)?;
    (a.text == ":" && b.text == ":" && c.kind == TokKind::Ident).then_some(c.text.as_str())
}

/// Lint one file's source.  `path` is only used for scoping (via
/// [`module_key`]) and for the `file` field of diagnostics; the source
/// itself is passed in so tests can lint fixture strings directly.
pub fn lint_source(path: &str, src: &str) -> Vec<Diagnostic> {
    let module = module_key(path);
    let lexed = lex(src);
    let skip = mark_skipped(&lexed.toks);
    let toks = &lexed.toks;

    let det = in_scope(&module, DETERMINISM_SCOPE, &[]);
    let pan = in_scope(&module, PANIC_SCOPE, &[]);
    let thr = in_scope(&module, THREAD_SCOPE, THREAD_EXCLUDE);
    let atom = in_scope(&module, ATOMICS_SCOPE, &[]);

    let mut sink =
        Sink { path, module: &module, lexed: &lexed, seen: BTreeSet::new(), diags: Vec::new() };

    for (j, t) in toks.iter().enumerate() {
        let tests_skipped = skip.get(j).copied().unwrap_or(false);

        // --- lock-discipline: applies everywhere, even inside tests ---
        if t.kind == TokKind::Ident && matches!(t.text.as_str(), "lock" | "read" | "write") {
            let prev_dot =
                j.checked_sub(1).and_then(|p| toks.get(p)).is_some_and(|p| p.text == ".");
            let d2 = toks.get(j + 4);
            if prev_dot
                && toks.get(j + 1).is_some_and(|x| x.text == "(")
                && toks.get(j + 2).is_some_and(|x| x.text == ")")
                && toks.get(j + 3).is_some_and(|x| x.text == ".")
                && d2.is_some_and(|x| {
                    x.kind == TokKind::Ident && matches!(x.text.as_str(), "unwrap" | "expect")
                })
            {
                let call = d2.map(|x| x.text.as_str()).unwrap_or("unwrap");
                let dl = d2.map(|x| x.line).unwrap_or(t.line);
                sink.push(
                    "lock-discipline",
                    t.line,
                    format!(
                        ".{}().{}() panics on a poisoned lock; recover with \
                         util::lock_clean (or into_inner)",
                        t.text, call
                    ),
                    &[t.line, dl],
                );
            }
        }
        if tests_skipped {
            continue;
        }

        // --- determinism -------------------------------------------------
        if det && t.kind == TokKind::Ident {
            match t.text.as_str() {
                "HashMap" | "HashSet" => sink.push(
                    "determinism",
                    t.line,
                    format!(
                        "{} in a journaled path: iteration order is nondeterministic; \
                         use BTreeMap/BTreeSet or allow with a why-deterministic \
                         justification",
                        t.text
                    ),
                    &[],
                ),
                "Instant" => sink.push(
                    "determinism",
                    t.line,
                    "wall-clock time in a journaled path (Instant)".to_string(),
                    &[],
                ),
                "SystemTime" | "UNIX_EPOCH" => sink.push(
                    "determinism",
                    t.line,
                    format!("wall-clock time in a journaled path ({})", t.text),
                    &[],
                ),
                "ThreadId" => sink.push(
                    "determinism",
                    t.line,
                    "thread identity in a journaled path".to_string(),
                    &[],
                ),
                "thread" => {
                    if path_seg(toks, j) == Some("current") {
                        sink.push(
                            "determinism",
                            t.line,
                            "thread identity in a journaled path".to_string(),
                            &[],
                        );
                    }
                }
                "env" => {
                    if toks.get(j + 1).is_some_and(|a| a.text == "!") {
                        sink.push(
                            "determinism",
                            t.line,
                            "env! read in a journaled path".to_string(),
                            &[],
                        );
                    } else if let Some(seg) = path_seg(toks, j) {
                        if ENV_READS.contains(&seg) {
                            sink.push(
                                "determinism",
                                t.line,
                                format!("environment read (env::{seg}) in a journaled path"),
                                &[],
                            );
                        }
                    }
                }
                _ => {}
            }
        }

        // --- panic-safety ------------------------------------------------
        if pan && t.kind == TokKind::Ident {
            if matches!(t.text.as_str(), "unwrap" | "expect" | "unwrap_err" | "expect_err") {
                let prev_dot =
                    j.checked_sub(1).and_then(|p| toks.get(p)).is_some_and(|p| p.text == ".");
                if prev_dot && toks.get(j + 1).is_some_and(|a| a.text == "(") {
                    // `.lock().unwrap()` is lock-discipline's finding
                    let is_lock = j >= 4
                        && toks.get(j - 2).is_some_and(|x| x.text == ")")
                        && toks.get(j - 3).is_some_and(|x| x.text == "(")
                        && toks.get(j - 4).is_some_and(|x| {
                            x.kind == TokKind::Ident
                                && matches!(x.text.as_str(), "lock" | "read" | "write")
                        });
                    if !is_lock {
                        sink.push(
                            "panic-safety",
                            t.line,
                            format!(
                                ".{}() on a CLI/daemon-reachable path (the PR 7 \
                                 panic-free contract); return an error instead",
                                t.text
                            ),
                            &[],
                        );
                    }
                }
            } else if PANIC_MACROS.contains(&t.text.as_str())
                && toks.get(j + 1).is_some_and(|a| a.text == "!")
            {
                sink.push(
                    "panic-safety",
                    t.line,
                    format!("{}! on a CLI/daemon-reachable path; return an error instead", t.text),
                    &[],
                );
            }
        }

        // --- index-panic -------------------------------------------------
        if pan && t.kind == TokKind::Punct && t.text == "[" {
            let indexable = j.checked_sub(1).and_then(|p| toks.get(p)).is_some_and(|p| {
                (p.kind == TokKind::Ident && !KEYWORDS.contains(&p.text.as_str()))
                    || (p.kind == TokKind::Punct && matches!(p.text.as_str(), ")" | "]"))
            });
            if indexable {
                sink.push(
                    "index-panic",
                    t.line,
                    "indexing/slicing can panic on a CLI/daemon-reachable path; \
                     use .get()/.get_mut() or an iterator"
                        .to_string(),
                    &[],
                );
            }
        }

        // --- thread-spawn ------------------------------------------------
        if thr
            && t.kind == TokKind::Ident
            && t.text == "thread"
            && path_seg(toks, j) == Some("spawn")
        {
            sink.push(
                "thread-spawn",
                t.line,
                "detached thread::spawn outside util/: use std::thread::scope \
                 so joins and panics are structured"
                    .to_string(),
                &[],
            );
        }

        // --- atomics-relaxed ---------------------------------------------
        if atom && t.kind == TokKind::Ident && t.text == "Relaxed" {
            let noted = (0..=2u32).any(|d| {
                t.line.checked_sub(d).is_some_and(|l| lexed.annotated.contains(&l))
            });
            if !noted {
                sink.push(
                    "atomics-relaxed",
                    t.line,
                    "Ordering::Relaxed without a `relaxed:` classification comment: \
                     stats counters annotate why; control atomics (shutdown/cancel/\
                     admission) must use Acquire/Release"
                        .to_string(),
                    &[],
                );
            }
        }
    }
    sink.diags
}
