//! # HASS — Hardware-Aware Sparsity Search for Dataflow DNN Accelerators
//!
//! Reproduction of Yu et al., *HASS: Hardware-Aware Sparsity Search for
//! Dataflow DNN Accelerator* (2024), as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L1 (Pallas)** — the Sparse vector dot-Product Engine (SPE) hot spot
//!   (clip → zero-filter/count → MAC) as a Pallas kernel, compiled at
//!   build time (`python/compile/kernels/spe.py`).
//! * **L2 (JAX)** — the calibration CNN forward pass with per-layer clip
//!   thresholds as *runtime inputs*, AOT-lowered to HLO text
//!   (`python/compile/model.py`, `aot.py`).
//! * **L3 (this crate)** — everything the paper's system contributes:
//!   the TPE multi-objective search (Eq. 6), the accelerator design-space
//!   exploration (Eq. 1–5: SPE cycle model, rate balancing, incremental
//!   parallelism growth, device partitioning), the cycle-level dataflow
//!   simulator that validates the analytical model, the resource model
//!   calibrated to the paper's Table II, and baseline design generators
//!   (dense / PASS-like / HPIPE-like / non-dataflow).
//!
//! Python never runs on the search path: the Rust coordinator executes the
//! AOT artifact through PJRT (`runtime`) to measure accuracy and sparsity,
//! then prices candidate designs with the hardware model (`hardware`,
//! `dse`).
//!
//! ## The search engine (`engine`)
//!
//! All search entry points run on the batched candidate-evaluation
//! pipeline in [`engine`]: the [`engine::CandidateEvaluator`] trait makes
//! measurement backends pluggable, [`engine::DesignCache`] memoizes DSE
//! pricings in a lock-striped, multi-device store keyed by (device
//! fingerprint, quantized operating points), TPE proposes whole
//! generations at once (`suggest_batch`/`observe_batch`), and each
//! generation is evaluated concurrently with scoped threads.  With
//! `EngineConfig::async_eval`, generations run through an **async
//! completion queue** instead of the measure-all-then-price-all barrier:
//! measurement requests go to [`engine::CandidateEvaluator::eval_async`]
//! as a batch, completions stream back over an `mpsc` channel in any
//! order, and DSE pricing overlaps the still-in-flight measurements —
//! which is what hides the latency of the serialized measured (PJRT)
//! backend.
//! [`engine::ShardedEngine`] fans one search out over several
//! [`hardware::device::DeviceBudget`]s — per-device shards advance in
//! lockstep generations over a shared thread pool and design cache, which
//! is how Table II / Fig. 6 cross-device sweeps run in one pass.  Both
//! pricing stores are thin typed layers over one generic lock-striped
//! single-compute memo ([`util::memo::StripedMemo`]), and both persist:
//! [`engine::DesignCache::save`] / [`engine::DesignCache::load`] snapshot
//! them to versioned JSON (`hass search --cache-file`, the bench sweep
//! drivers), so repeat sweeps start warm and miss zero times.  Thread
//! count, cache state — in-memory or warm from disk — shard count and
//! the generation pipeline (sync barrier or async completion queue, even
//! with out-of-order evaluators) never change results — each device's
//! journal is bit-for-bit the journal of a standalone serial run (see
//! the module docs for the exact determinism contract).
//! [`coordinator`] keeps the production evaluators and the stable
//! `search()` / `search_sharded()` entry points on top of the engine.
//!
//! With [`engine::SearchConfig::pipeline_depth`] > 0 the generation
//! barrier itself is removed: up to D+1 generations are in flight
//! concurrently, with generation g+1 proposed from observations through
//! g−D on a fixed optimizer RNG schedule (**lookahead ask/tell** — TPE's
//! `suggest_batch`/`observe_batch` are deliberately decoupled).  The
//! pipelined trajectory differs from the drained one — depth is an
//! algorithmic knob, reported next to the seed — but for a *fixed* depth
//! results remain bit-identical across thread counts, sync/async
//! pipelines, cache states and kill/resume, and depth 0 **is** the
//! drained engine, byte for byte (`tests/integration.rs`, the CI
//! pipeline-smoke job).  `EngineStats` reports
//! `pipelined_generations` / `lookahead_proposals` / `barrier_wait_ns`;
//! `benches/pipeline_depth.rs` quantifies the wall-time gain when
//! evaluation latency dominates.
//!
//! ## The search daemon (`server`)
//!
//! `hass serve` keeps all of the above resident: a long-lived process
//! holding the warm [`engine::DesignCache`] (designs + frontier store)
//! in memory and serving `search` / `price` / `stats` / `save-cache`
//! requests over a newline-delimited JSON-RPC TCP protocol
//! ([`server::protocol`]), with FIFO-fair admission bounding concurrent
//! searches and per-generation progress streamed to each client.
//! Daemon searches run the same [`engine::ShardedEngine`] path as the
//! CLI, so streamed journals are bit-identical to `hass search` runs;
//! `hass client` is the matching thin client.  Every failure on the
//! request path — malformed lines, unknown networks, evaluator errors,
//! client disconnects mid-search — is answered or absorbed without
//! taking the process (or its caches) down.
//!
//! ## Fault tolerance (`engine::retry`, `engine::ckpt`, `util::fault`)
//!
//! Long sweeps on real measurement backends meet transient failures,
//! stalls and crashes, so the search runtime is chaos-hardened end to
//! end: evaluator errors prefixed [`engine::TRANSIENT_PREFIX`] are
//! retried with bounded exponential backoff
//! ([`engine::RetryPolicy`], `hass search --retries`) before a
//! candidate scores infeasible; the async completion queue carries a
//! **stall watchdog** (`--eval-timeout`, `--deadline`) that reclaims
//! in-flight measurements which never complete as infeasible-scored
//! journal records instead of hanging the run; and `--checkpoint`
//! snapshots the search atomically (temp file + rename) every N
//! generations so a killed run resumes with `--resume` and journals
//! **bit-identically** to an uninterrupted one.  All of it is tested
//! deterministically through [`util::fault`]: a seeded
//! [`util::fault::FaultPlan`] makes injected failures and stalls a pure
//! function of the fault seed (independent of thread schedule), and
//! named injection sites cover snapshot IO and daemon connections
//! (`tests/chaos.rs`, the CI chaos-smoke job).  None of these knobs
//! enter the determinism fingerprint: a zero-fault run with retry,
//! watchdog or checkpointing enabled journals bit-identically to the
//! seed configuration.
//!
//! ## The event-driven simulator and the fidelity ladder (`simulator`)
//!
//! The cycle-level dataflow simulator runs on a discrete-event core — a
//! completion-event heap plus a ready set, with closed-form **group
//! coalescing** under deterministic dynamics — that is differential-tested
//! bit-identical to the exhaustive scan reference (kept as
//! [`simulator::simulate_scan`]) and an order of magnitude faster on
//! paper geometries (`benches/sim_speed.rs`).  That speed is what makes
//! [`engine::SimulatedEvaluator`] affordable: a fidelity **ladder** that
//! prices every candidate analytically, then re-scores only each
//! generation's analytic top-k per device with the simulator, overriding
//! their throughput in the journal ([`engine::SearchRecord::simulated`],
//! `analytic_images_per_sec`) — `hass search --evaluator sim`.
//!
//! ## The frontier pricing kernel (`dse::frontier`)
//!
//! Every consumer of [`dse::explore`] — the engine, the sharded search,
//! [`dse::partition`]'s annealer, the figure/table bench drivers — prices
//! through per-layer [`dse::LayerFrontier`]s: the divisor×n_mac design
//! space of a layer is enumerated **once** per (layer shape, sparsity
//! point, resource model, device) and reduced to a rate-sorted Pareto
//! frontier, so "cheapest design achieving rate λ" is a binary search
//! instead of a rescan.  Results are bit-identical to the seed scan
//! (kept as [`dse::explore_scan`] / [`dse::cheapest_design_achieving`]
//! and differential-tested against it); the engine's design cache carries
//! an [`engine::FrontierStore`] so frontiers are shared across
//! candidates, generations, shards and searches.
//!
//! ## Module map
//!
//! | module        | role |
//! |---------------|------|
//! | [`arch`]      | dataflow-graph IR + the paper's network geometries |
//! | [`sparsity`]  | operating points, transfer curves, synthesis |
//! | [`pruning`]   | plans, thresholds, software sparsity metrics |
//! | [`hardware`]  | SPE cycle model (Eq. 1–2), resource model, devices |
//! | [`dse`]       | Eq. 3–5 DSE: frontier kernel, bisection, balancing, partitioning |
//! | [`optim`]     | TPE and simulated annealing |
//! | [`engine`]    | batched/parallel/sharded search, lookahead pipeline, pricing caches |
//! | [`coordinator`] | production evaluators + stable search entry points |
//! | [`simulator`] | event-driven cycle-level dataflow simulator, per-layer parallel core (model validation, fidelity ladder) |
//! | [`baselines`] | dense / PASS-like / HPIPE-like / non-dataflow designs |
//! | [`runtime`]   | PJRT execution of the AOT CalibNet artifact |
//! | [`server`]    | resident `hass serve` search daemon + JSON-RPC protocol |
//! | [`metrics`]   | tables, CSV/markdown, Pareto fronts |
//! | [`util`]      | offline stand-ins: rng, prop testing, json, cli; [`util::memo`] striped memo; [`util::fault`] chaos harness |
//! | [`analysis`]  | `hass lint`: repo-native invariant linter (determinism, panic-safety, lock discipline, atomics audit) |

pub mod analysis;
pub mod arch;
pub mod baselines;
pub mod coordinator;
pub mod dse;
pub mod engine;
pub mod hardware;
pub mod metrics;
pub mod optim;
pub mod pruning;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod sparsity;
pub mod util;
