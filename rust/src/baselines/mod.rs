//! Baseline accelerator generators (paper Table I / Table II comparators).
//!
//! Each baseline is HASS with exactly one axis disabled, so relative
//! numbers measure the axis itself (DESIGN.md §1):
//!
//! * [`dense_dataflow`] — layer-pipelined, **no sparsity exploitation**:
//!   every SPE computes all M pairs (Table II's "Dense" columns).
//! * [`pass_like`] — PASS [4]: dataflow + **activation sparsity only**
//!   (natural, post-activation zeros; no pruning, no hardware-aware search).
//! * [`hpipe_like`] — HPIPE [5]: dataflow + **weight sparsity only**
//!   (software-metric magnitude pruning at a fixed target).
//! * [`non_dataflow_sparse`] — [6]-style: a single time-multiplexed
//!   sparse engine; layers run sequentially, weights stream from off-chip.

use crate::arch::Network;
use crate::dse::{explore, DseConfig, NetworkDesign};
use crate::hardware::device::DeviceBudget;
use crate::hardware::resources::{ResourceModel, Resources};
use crate::pruning::{self, PruningPlan};
use crate::sparsity::{NetworkSparsity, SparsityPoint};
use crate::util::ceil_div;

/// A fully evaluated comparator design.
#[derive(Clone, Debug)]
pub struct BaselineResult {
    pub name: String,
    /// top-1 accuracy (surrogate for target geometries; see DESIGN.md §1.1)
    pub accuracy: f64,
    pub images_per_sec: f64,
    pub resources: Resources,
    /// op-weighted pair density (Fig. 1's x-axis)
    pub op_density: f64,
    /// images / cycle / DSP — the paper's headline efficiency metric
    pub efficiency: f64,
}

fn from_design(
    name: &str,
    accuracy: f64,
    net: &Network,
    d: &NetworkDesign,
    points: &[SparsityPoint],
    dev: &DeviceBudget,
) -> BaselineResult {
    BaselineResult {
        name: name.into(),
        accuracy,
        images_per_sec: d.images_per_sec(dev),
        resources: d.resources,
        op_density: pruning::metrics(net, points).op_density,
        efficiency: d.efficiency(),
    }
}

/// Dense dataflow: no pruning, no zero skipping — the hardware pays for
/// every pair (`SparsityPoint::DENSE` in the cycle model).
pub fn dense_dataflow(
    net: &Network,
    base_acc: f64,
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
) -> BaselineResult {
    let n = net.compute_layers().len();
    let points = vec![SparsityPoint::DENSE; n];
    let d = explore(net, &points, rm, dev, cfg);
    from_design("dense", base_acc, net, &d, &points, dev)
}

/// PASS-like [4]: exploits the *natural* activation sparsity the network
/// already has (no pruning at all, so accuracy is preserved), and no
/// weight-sparsity support in the engines.
pub fn pass_like(
    net: &Network,
    sparsity: &NetworkSparsity,
    base_acc: f64,
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
) -> BaselineResult {
    let points: Vec<SparsityPoint> = sparsity
        .natural_points()
        .into_iter()
        .map(|p| SparsityPoint { s_w: 0.0, ..p }) // engines ignore weight zeros
        .collect();
    let d = explore(net, &points, rm, dev, cfg);
    from_design("pass", base_acc, net, &d, &points, dev)
}

/// HPIPE-like [5]: magnitude weight pruning at a fixed software-side
/// target (`w_target`), no activation-sparsity support, no hardware in
/// the pruning loop.
pub fn hpipe_like(
    net: &Network,
    sparsity: &NetworkSparsity,
    base_acc: f64,
    w_target: f64,
    rm: &ResourceModel,
    dev: &DeviceBudget,
    cfg: &DseConfig,
) -> BaselineResult {
    let n = sparsity.layers.len();
    // uniform sparsity target decoded through per-layer curves
    let mut x = vec![0.0; 2 * n];
    for i in 0..n {
        x[2 * i] = w_target / pruning::MAX_SPARSITY;
    }
    let plan = PruningPlan::from_unit_point(&x, sparsity);
    let full = plan.points(sparsity);
    let acc = pruning::surrogate_accuracy(base_acc, net, &full, &sparsity.natural_points());
    // engines only skip weight zeros
    let points: Vec<SparsityPoint> =
        full.iter().map(|p| SparsityPoint { s_a: 0.0, ..*p }).collect();
    let d = explore(net, &points, rm, dev, cfg);
    from_design("hpipe", acc, net, &d, &points, dev)
}

/// Off-chip memory interface of the non-dataflow engine.
#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    /// sustained off-chip bandwidth in bits per cycle (e.g. DDR4 x72 at
    /// an accelerator clock: ~512 bits/cycle)
    pub bits_per_cycle: f64,
    /// bits per weight after sparse encoding (value + index)
    pub bits_per_nz_weight: f64,
}

impl Default for MemoryModel {
    fn default() -> Self {
        MemoryModel { bits_per_cycle: 512.0, bits_per_nz_weight: 24.0 }
    }
}

/// Non-dataflow sparse accelerator ([6]-style): one engine with `n_mac`
/// MACs time-multiplexed over layers; weights stream from off-chip every
/// image (the paper's motivation: such designs are bandwidth-bound, which
/// sparsity relieves by shrinking the encoded weight stream).
pub fn non_dataflow_sparse(
    net: &Network,
    sparsity: &NetworkSparsity,
    base_acc: f64,
    w_target: f64,
    n_mac: u64,
    mem: &MemoryModel,
    rm: &ResourceModel,
    dev: &DeviceBudget,
) -> BaselineResult {
    let n = sparsity.layers.len();
    let mut x = vec![0.0; 2 * n];
    for i in 0..n {
        x[2 * i] = w_target / pruning::MAX_SPARSITY;
    }
    let plan = PruningPlan::from_unit_point(&x, sparsity);
    let full = plan.points(sparsity);
    let acc = pruning::surrogate_accuracy(base_acc, net, &full, &sparsity.natural_points());
    // engines skip weight zeros only ([6] has no activation support)
    let points: Vec<SparsityPoint> =
        full.iter().map(|p| SparsityPoint { s_a: 0.0, ..*p }).collect();

    let mut cycles = 0u64;
    for (l, p) in net.compute_layers().iter().zip(&points) {
        let useful = (l.macs_per_image() as f64 * p.pair_density()).ceil() as u64;
        let compute = ceil_div(useful, n_mac);
        let nz_weights = (l.weight_count() as f64 * (1.0 - p.s_w)).ceil();
        let memory = (nz_weights * mem.bits_per_nz_weight / mem.bits_per_cycle).ceil() as u64;
        // double-buffered weight streaming overlaps with compute
        cycles += compute.max(memory);
        // per-layer reconfiguration of the engine (weights/act swap)
        cycles += 2_000;
    }
    let throughput = 1.0 / cycles as f64;
    // resource model: the engine itself plus activation double buffers
    let lut = (n_mac as f64 * rm.lut_per_mac
        + n_mac as f64 * rm.lut_arbiter * 8.0
        + 40_000.0) as u64; // scheduler, DMA, decoder
    let biggest_act = net
        .compute_layers()
        .iter()
        .map(|l| (l.in_hw * l.in_hw) as u64 * l.i_extent() as u64)
        .max()
        .unwrap_or(0);
    let bram18k = ceil_div(2 * biggest_act * rm.bits, 18 * 1024);
    let resources = Resources { dsp: n_mac, lut, bram18k: bram18k.min(dev.bram18k), uram: 0 };
    BaselineResult {
        name: "non-dataflow".into(),
        accuracy: acc,
        images_per_sec: throughput * dev.freq_hz(),
        resources,
        op_density: pruning::metrics(net, &points).op_density,
        efficiency: throughput / n_mac.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::sparsity::synthesize;

    fn setup() -> (Network, NetworkSparsity, ResourceModel, DeviceBudget, DseConfig) {
        let net = networks::calibnet();
        let sp = synthesize(&net, 1);
        (net, sp, ResourceModel::default(), DeviceBudget::u250(), DseConfig::default())
    }

    #[test]
    fn dense_has_full_density_and_base_accuracy() {
        let (net, _, rm, dev, cfg) = setup();
        let b = dense_dataflow(&net, 70.0, &rm, &dev, &cfg);
        assert!((b.op_density - 1.0).abs() < 1e-12);
        assert_eq!(b.accuracy, 70.0);
        assert!(b.images_per_sec > 0.0);
    }

    #[test]
    fn pass_preserves_accuracy_and_beats_dense_efficiency() {
        let (net, sp, rm, dev, cfg) = setup();
        // cap the device so efficiency differences show up
        let dev = DeviceBudget { dsp: 512, ..dev };
        let dense = dense_dataflow(&net, 70.0, &rm, &dev, &cfg);
        let pass = pass_like(&net, &sp, 70.0, &rm, &dev, &cfg);
        assert_eq!(pass.accuracy, 70.0, "PASS does not prune");
        assert!(
            pass.efficiency > dense.efficiency,
            "pass {} dense {}",
            pass.efficiency,
            dense.efficiency
        );
    }

    #[test]
    fn hpipe_trades_accuracy_for_efficiency() {
        let (net, sp, rm, dev, cfg) = setup();
        let dev = DeviceBudget { dsp: 512, ..dev };
        let dense = dense_dataflow(&net, 70.0, &rm, &dev, &cfg);
        let hpipe = hpipe_like(&net, &sp, 70.0, 0.6, &rm, &dev, &cfg);
        assert!(hpipe.accuracy < 70.0, "pruning must cost accuracy");
        assert!(hpipe.accuracy > 50.0, "0.6 pruning should not collapse");
        assert!(hpipe.efficiency > dense.efficiency);
    }

    #[test]
    fn hpipe_more_pruning_more_efficiency_less_accuracy() {
        let (net, sp, rm, dev, cfg) = setup();
        let dev = DeviceBudget { dsp: 512, ..dev };
        let mild = hpipe_like(&net, &sp, 70.0, 0.3, &rm, &dev, &cfg);
        let hard = hpipe_like(&net, &sp, 70.0, 0.8, &rm, &dev, &cfg);
        assert!(hard.accuracy < mild.accuracy);
        assert!(hard.efficiency >= mild.efficiency);
        assert!(hard.op_density < mild.op_density);
    }

    #[test]
    fn non_dataflow_much_slower_than_dataflow() {
        let (net, sp, rm, dev, cfg) = setup();
        let nd =
            non_dataflow_sparse(&net, &sp, 70.0, 0.5, 1024, &MemoryModel::default(), &rm, &dev);
        let pass = pass_like(&net, &sp, 70.0, &rm, &dev, &cfg);
        // the paper's core claim: dataflow pipelining wins throughput
        assert!(
            pass.images_per_sec > nd.images_per_sec,
            "dataflow {} vs non-dataflow {}",
            pass.images_per_sec,
            nd.images_per_sec
        );
        assert!(nd.images_per_sec > 0.0);
    }

    #[test]
    fn non_dataflow_uses_far_fewer_resources() {
        // the paper's counterpoint: non-dataflow is lean (up to 3x fewer
        // DSPs, 5x fewer LUTs in Table II)
        let (net, sp, rm, dev, cfg) = setup();
        let nd = non_dataflow_sparse(&net, &sp, 70.0, 0.5, 512, &MemoryModel::default(), &rm, &dev);
        let dense = dense_dataflow(&net, 70.0, &rm, &dev, &cfg);
        assert!(nd.resources.dsp < dense.resources.dsp);
        assert!(nd.resources.lut < dense.resources.lut);
    }

    #[test]
    fn non_dataflow_sparsity_relieves_bandwidth() {
        let (net, sp, rm, dev, _) = setup();
        let lean = MemoryModel { bits_per_cycle: 64.0, ..Default::default() };
        let dense_w = non_dataflow_sparse(&net, &sp, 70.0, 0.0, 1024, &lean, &rm, &dev);
        let sparse_w = non_dataflow_sparse(&net, &sp, 70.0, 0.7, 1024, &lean, &rm, &dev);
        assert!(
            sparse_w.images_per_sec > dense_w.images_per_sec,
            "sparse {} dense {}",
            sparse_w.images_per_sec,
            dense_w.images_per_sec
        );
    }

    #[test]
    fn baselines_work_on_all_target_networks() {
        let rm = ResourceModel::default();
        let dev = DeviceBudget::u250();
        let cfg = DseConfig { max_iters: 3_000, ..Default::default() };
        for name in ["resnet18", "mobilenet_v3_small"] {
            let net = networks::by_name(name).unwrap();
            let sp = synthesize(&net, 2);
            let d = dense_dataflow(&net, 70.0, &rm, &dev, &cfg);
            let p = pass_like(&net, &sp, 70.0, &rm, &dev, &cfg);
            assert!(d.images_per_sec > 0.0, "{name}");
            assert!(p.images_per_sec > 0.0, "{name}");
            assert!(dev.fits(&d.resources), "{name}");
        }
    }
}
