//! In-tree utility substrates.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (serde_json,
//! clap, rand, proptest, criterion) are unavailable.  Everything the
//! system needs from them is implemented here, tested like any other
//! module:
//!
//! * [`json`] — a minimal, strict JSON parser/serializer (for `meta.json`,
//!   config files, journals, cache snapshots and result artifacts),
//! * [`rng`] — deterministic `SplitMix64`/`Xoshiro256**` RNG with the
//!   distributions the search stack needs,
//! * [`cli`] — flag parsing for the launcher and examples,
//! * [`prop`] — a tiny property-based-testing harness (seed-reporting
//!   random-case runner) standing in for proptest,
//! * [`memo`] — the generic lock-striped single-compute memo table the
//!   engine's pricing caches are built on,
//! * [`fault`] — deterministic fault injection (seeded evaluator fault
//!   plans + named global injection sites) for chaos tests and CI.

pub mod cli;
pub mod fault;
pub mod json;
pub mod memo;
pub mod prop;
pub mod rng;

/// Poison-tolerant mutex lock: the repo-wide replacement for
/// `.lock().unwrap()` (banned by `hass lint`'s `lock-discipline` rule).
///
/// Every mutex in this crate guards data with no invariant a panicking
/// holder could half-write (independent map entries, counters, queues),
/// and a resident `hass serve` process must keep answering after one
/// worker panic rather than fail every later request — so poisoning is
/// recovered by taking the guarded data as-is.  If a future mutex *does*
/// guard a multi-step invariant, handle its `PoisonError` explicitly at
/// the call site instead of using this helper.
pub fn lock_clean<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Ceiling division for unsigned integers.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Clamp a float into `[lo, hi]`.
#[inline]
pub fn clampf(v: f64, lo: f64, hi: f64) -> f64 {
    v.max(lo).min(hi)
}

/// Abramowitz–Stegun 7.1.26 approximation of erf (|err| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn erf_reference_values() {
        // against known table values
        assert!((erf(0.0) - 0.0).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(2.0) - 0.9953222650).abs() < 1e-6);
    }

    #[test]
    fn norm_cdf_symmetry() {
        // the A&S 7.1.26 approximation has |err| < 1.5e-7 (e.g. erf(0)
        // evaluates to ~7.5e-8, not exactly 0), so tolerances follow that
        for &x in &[0.0, 0.5, 1.0, 2.5] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 2e-7);
        }
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
    }

    #[test]
    fn clampf_bounds() {
        assert_eq!(clampf(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clampf(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clampf(0.5, 0.0, 1.0), 0.5);
    }
}
