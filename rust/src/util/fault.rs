//! Deterministic, seeded fault injection for chaos tests and CI.
//!
//! Robustness code is only trustworthy if every failure mode it guards
//! against can be reproduced on demand.  This module provides two
//! injection mechanisms, both deterministic:
//!
//! * [`FaultPlan`] + [`FaultyEvaluator`] — evaluator-level faults that
//!   are a *pure function of the pruning plan* (hashed with the fault
//!   seed through the shared [`crate::util::rng`] stream).  A fixed
//!   `FaultPlan` injects the same transient failures and stalls into the
//!   same candidates regardless of thread count, shard count or
//!   pipeline (sync vs async), so chaos journals stay bit-identical
//!   across executions — the engine's determinism contract extends to
//!   faulty runs.
//! * a process-global **site registry** ([`arm`] / [`fire`] /
//!   [`io_error`]) — named injection points compiled into snapshot IO,
//!   checkpoint IO and server connection handling.  Tests arm a site
//!   with a count; the next `count` passes through that site fail.
//!   Sites are global state: tests using them must serialize through
//!   [`exclusive`] and disarm via the [`armed`] guard.
//!
//! Nothing here fires unless explicitly armed or wrapped: production
//! runs pay one `HashMap` lookup per armed-site check and nothing else.

use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::engine::evaluator::{
    CandidateEvaluator, EvalCompletion, EvalError, EvalPoint, EvalRequest,
};
use crate::engine::retry::TRANSIENT_PREFIX;
use crate::pruning::PruningPlan;
use crate::sparsity::NetworkSparsity;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------
// seeded per-plan faults
// ---------------------------------------------------------------------

/// A reproducible schedule of evaluator faults, drawn per pruning plan
/// from the fault seed.  Which plans fail (and how often), and which
/// async measurements stall, depend only on `(seed, plan)` — never on
/// timing, thread count or evaluation order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// fault stream seed (independent of the search seed)
    pub seed: u64,
    /// probability a plan's measurement fails transiently at least once
    pub fail_rate: f64,
    /// upper bound on consecutive transient failures per faulty plan
    pub max_failures: u32,
    /// probability an async measurement stalls: its completion never
    /// arrives and the engine's watchdog must reclaim the slot
    pub stall_rate: f64,
}

impl FaultPlan {
    /// A plan that injects nothing (useful as a baseline in tests).
    pub fn none(seed: u64) -> Self {
        FaultPlan { seed, fail_rate: 0.0, max_failures: 0, stall_rate: 0.0 }
    }

    /// FNV-1a over the fault seed and the plan's threshold bits: the
    /// deterministic identity faults are keyed by.
    pub fn plan_hash(&self, plan: &PruningPlan) -> u64 {
        let mut h = 0xcbf29ce484222325u64 ^ self.seed;
        for &t in plan.tau_w.iter().chain(plan.tau_a.iter()) {
            h ^= t.to_bits();
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// Number of transient failures this plan's measurement sees before
    /// it is allowed to succeed.
    pub fn failures_for(&self, plan: &PruningPlan) -> u32 {
        if self.fail_rate <= 0.0 || self.max_failures == 0 {
            return 0;
        }
        let mut rng = Rng::new(self.plan_hash(plan));
        if rng.bool(self.fail_rate) {
            1 + rng.below(self.max_failures as usize) as u32
        } else {
            0
        }
    }

    /// Whether this plan's *async* measurement stalls (no completion is
    /// ever sent; sync evaluation is unaffected).  Drawn from a stream
    /// independent of [`failures_for`](Self::failures_for).
    pub fn stalls(&self, plan: &PruningPlan) -> bool {
        if self.stall_rate <= 0.0 {
            return false;
        }
        let mut rng = Rng::new(self.plan_hash(plan) ^ 0x5354_414c_4c45_4421);
        rng.bool(self.stall_rate)
    }
}

/// Evaluator wrapper injecting the faults a [`FaultPlan`] schedules.
///
/// * [`try_eval`](CandidateEvaluator::try_eval) fails with a
///   [`TRANSIENT_PREFIX`]-tagged error for the plan's first
///   [`failures_for`](FaultPlan::failures_for) attempts, then delegates
///   — so an engine retry budget ≥ the fault budget recovers every
///   candidate and the journal is bit-identical to a zero-fault run.
/// * [`eval_async`](CandidateEvaluator::eval_async) silently *drops*
///   the completion of any plan [`stalls`](FaultPlan::stalls) selects,
///   modelling a measurement that never returns; the engine's watchdog
///   (`SearchConfig::eval_timeout_ms`) must reclaim those slots.
///
/// Attempt counts are shared across threads (one mutexed map), so which
/// attempt finally succeeds depends only on how many times the engine
/// has asked about that plan — deterministic under the engine's
/// fixed retry cadence.
pub struct FaultyEvaluator<'a> {
    inner: &'a dyn CandidateEvaluator,
    plan: FaultPlan,
    attempts: Mutex<HashMap<u64, u32>>,
}

impl<'a> FaultyEvaluator<'a> {
    pub fn new(inner: &'a dyn CandidateEvaluator, plan: FaultPlan) -> Self {
        FaultyEvaluator { inner, plan, attempts: Mutex::new(HashMap::new()) }
    }

    /// The schedule this wrapper injects.
    pub fn fault_plan(&self) -> FaultPlan {
        self.plan
    }
}

impl CandidateEvaluator for FaultyEvaluator<'_> {
    fn sparsity_model(&self) -> &NetworkSparsity {
        self.inner.sparsity_model()
    }

    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        self.inner.eval(plan)
    }

    fn base_accuracy(&self) -> f64 {
        self.inner.base_accuracy()
    }

    fn try_eval(&self, plan: &PruningPlan) -> Result<EvalPoint, EvalError> {
        let budget = self.plan.failures_for(plan);
        if budget > 0 {
            let key = self.plan.plan_hash(plan);
            let mut attempts = self.attempts.lock().unwrap_or_else(|p| p.into_inner());
            let n = attempts.entry(key).or_insert(0);
            if *n < budget {
                *n += 1;
                return Err(format!(
                    "{TRANSIENT_PREFIX} injected fault (attempt {n} of {budget})"
                ));
            }
        }
        self.inner.try_eval(plan)
    }

    fn eval_async(&self, requests: Vec<EvalRequest>, completions: Sender<EvalCompletion>) {
        for req in requests {
            if self.plan.stalls(&req.plan) {
                continue; // completion never arrives; the watchdog reclaims it
            }
            let result = self.try_eval(&req.plan);
            if completions.send(EvalCompletion { slot: req.slot, result }).is_err() {
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------
// global injection sites (snapshot IO, checkpoints, server connections)
// ---------------------------------------------------------------------

fn sites() -> &'static Mutex<HashMap<String, u32>> {
    static SITES: OnceLock<Mutex<HashMap<String, u32>>> = OnceLock::new();
    SITES.get_or_init(|| Mutex::new(HashMap::new()))
}

fn lock_sites() -> MutexGuard<'static, HashMap<String, u32>> {
    sites().lock().unwrap_or_else(|p| p.into_inner())
}

/// Arm `site`: the next `count` [`fire`] calls there report a fault.
pub fn arm(site: &str, count: u32) {
    lock_sites().insert(site.to_string(), count);
}

/// Disarm one site (idempotent).
pub fn disarm(site: &str) {
    lock_sites().remove(site);
}

/// Disarm every site (test teardown).
pub fn disarm_all() {
    lock_sites().clear();
}

/// Should a fault fire at `site` right now?  Consumes one armed count.
/// Unarmed sites always answer `false`, so production code pays only
/// this lookup.
pub fn fire(site: &str) -> bool {
    let mut s = lock_sites();
    match s.get_mut(site) {
        Some(0) | None => false,
        Some(n) => {
            *n -= 1;
            true
        }
    }
}

/// [`fire`] dressed as an IO failure, for injection into snapshot and
/// checkpoint writes: `if let Some(e) = fault::io_error("ckpt.save") {
/// return Err(e); }`.
pub fn io_error(site: &str) -> Option<std::io::Error> {
    fire(site).then(|| {
        std::io::Error::other(format!("injected fault at site '{site}'"))
    })
}

/// RAII arming: the site disarms when the guard drops, even if the test
/// panics midway.
pub struct Armed {
    site: String,
}

pub fn armed(site: &str, count: u32) -> Armed {
    arm(site, count);
    Armed { site: site.to_string() }
}

impl Drop for Armed {
    fn drop(&mut self) {
        disarm(&self.site);
    }
}

/// Serialize tests touching the global site registry: hold this guard
/// for the duration of any test that arms sites, so parallel tests
/// never see each other's faults.
pub fn exclusive() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::networks;
    use crate::sparsity::synthesize;

    #[test]
    fn fault_plan_is_a_pure_function_of_the_pruning_plan() {
        let net = networks::calibnet();
        let sp = synthesize(&net, 5);
        let n = sp.layers.len();
        let fp = FaultPlan { seed: 9, fail_rate: 0.5, max_failures: 3, stall_rate: 0.3 };
        for s in [0.0, 0.2, 0.55, 0.9] {
            let plan = PruningPlan::from_unit_point(&vec![s; 2 * n], &sp);
            let again = PruningPlan::from_unit_point(&vec![s; 2 * n], &sp);
            assert_eq!(fp.failures_for(&plan), fp.failures_for(&again));
            assert_eq!(fp.stalls(&plan), fp.stalls(&again));
            assert!(fp.failures_for(&plan) <= fp.max_failures);
        }
    }

    #[test]
    fn fault_rates_roughly_hold_over_many_plans() {
        let net = networks::calibnet();
        let sp = synthesize(&net, 6);
        let n = sp.layers.len();
        let fp = FaultPlan { seed: 10, fail_rate: 0.4, max_failures: 2, stall_rate: 0.25 };
        let total = 400;
        let mut failing = 0;
        let mut stalling = 0;
        for i in 0..total {
            let s = i as f64 / total as f64;
            let plan = PruningPlan::from_unit_point(&vec![s; 2 * n], &sp);
            if fp.failures_for(&plan) > 0 {
                failing += 1;
            }
            if fp.stalls(&plan) {
                stalling += 1;
            }
        }
        let f = failing as f64 / total as f64;
        let st = stalling as f64 / total as f64;
        assert!((0.25..=0.55).contains(&f), "fail fraction {f}");
        assert!((0.12..=0.40).contains(&st), "stall fraction {st}");
    }

    #[test]
    fn zero_rates_inject_nothing() {
        let net = networks::calibnet();
        let sp = synthesize(&net, 7);
        let n = sp.layers.len();
        let fp = FaultPlan::none(3);
        for s in [0.0, 0.3, 0.7] {
            let plan = PruningPlan::from_unit_point(&vec![s; 2 * n], &sp);
            assert_eq!(fp.failures_for(&plan), 0);
            assert!(!fp.stalls(&plan));
        }
    }

    #[test]
    fn armed_sites_fire_exactly_count_times_and_guard_disarms() {
        let _x = exclusive();
        {
            let _g = armed("test.site", 2);
            assert!(fire("test.site"));
            assert!(fire("test.site"));
            assert!(!fire("test.site"), "count exhausted");
        }
        arm("test.site", 1);
        assert!(io_error("test.site").is_some());
        assert!(io_error("test.site").is_none());
        assert!(!fire("never.armed"));
        disarm_all();
    }
}
