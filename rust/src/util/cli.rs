//! Minimal CLI flag parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, and generates usage text from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Opt {
    pub name: String,
    pub help: String,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// Declarative argument parser: register options, then parse.
#[derive(Debug, Default)]
pub struct Cli {
    pub about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(about: &str) -> Self {
        Cli { about: about.to_string(), ..Default::default() }
    }

    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\noptions:\n", self.about);
        for o in &self.opts {
            let d = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        s
    }

    /// Parse from an explicit argv slice (no program name).  Returns Err
    /// with usage text on unknown options or `--help`.
    pub fn parse_from(mut self, args: &[String]) -> Result<Parsed, String> {
        let mut i = 0;
        while i < args.len() {
            let Some(a) = args.get(i) else { break };
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?
                    .clone();
                let val = if opt.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    "true".to_string()
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    args.get(i)
                        .ok_or_else(|| format!("option --{key} needs a value"))?
                        .clone()
                };
                self.values.insert(key, val);
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        let mut values = self.values;
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.entry(o.name.clone()).or_insert_with(|| d.clone());
            }
        }
        Ok(Parsed { values, positionals: self.positionals })
    }

    /// Parse the process argv (skipping program name and subcommand count).
    pub fn parse_env(self, skip: usize) -> Result<Parsed, String> {
        let args: Vec<String> = std::env::args().skip(skip).collect();
        self.parse_from(&args)
    }
}

/// Parsed argument values.
///
/// The typed getters (`get_usize` / `get_u64` / `get_f64`) return `Err`
/// with a user-facing message when the value does not parse — malformed
/// *input* must never panic (the CLI prints the error + usage and exits
/// 2, a daemon reports it to the client).  [`get`](Parsed::get) still
/// panics on a key that was never registered: that is a programming
/// error, not input.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, key: &str) -> &str {
        // a missing key is a programmer error (the option was never
        // registered with the spec), not user input — panicking here is
        // the documented contract of this accessor
        // lint: allow(panic-safety)
        self.values.get(key).unwrap_or_else(|| panic!("option --{key} not registered"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, String> {
        let v = self.get(key);
        v.parse().map_err(|_| format!("--{key} expects an integer, got '{v}'"))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64, String> {
        let v = self.get(key);
        v.parse().map_err(|_| format!("--{key} expects an integer, got '{v}'"))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, String> {
        let v = self.get(key);
        v.parse().map_err(|_| format!("--{key} expects a number, got '{v}'"))
    }

    pub fn get_bool(&self, key: &str) -> bool {
        self.values.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = Cli::new("t")
            .opt("iters", "96", "iterations")
            .parse_from(&args(&[]))
            .unwrap();
        assert_eq!(p.get_usize("iters"), Ok(96));
    }

    #[test]
    fn parses_separate_and_inline_values() {
        let p = Cli::new("t")
            .opt("a", "0", "")
            .opt("b", "0", "")
            .parse_from(&args(&["--a", "3", "--b=7"]))
            .unwrap();
        assert_eq!(p.get_usize("a"), Ok(3));
        assert_eq!(p.get_usize("b"), Ok(7));
    }

    #[test]
    fn flags_and_positionals() {
        let p = Cli::new("t")
            .flag("verbose", "")
            .parse_from(&args(&["pos1", "--verbose", "pos2"]))
            .unwrap();
        assert!(p.get_bool("verbose"));
        assert_eq!(p.positionals, vec!["pos1", "pos2"]);
    }

    #[test]
    fn unknown_option_is_error() {
        let r = Cli::new("t").parse_from(&args(&["--nope", "1"]));
        assert!(r.is_err());
    }

    #[test]
    fn help_returns_usage() {
        let r = Cli::new("about-text")
            .opt("x", "1", "the x")
            .parse_from(&args(&["--help"]));
        let u = r.unwrap_err();
        assert!(u.contains("about-text") && u.contains("--x"));
    }

    #[test]
    fn missing_value_is_error() {
        let r = Cli::new("t").opt("x", "1", "").parse_from(&args(&["--x"]));
        assert!(r.is_err());
    }

    #[test]
    fn flag_with_value_is_error() {
        let r = Cli::new("t").flag("f", "").parse_from(&args(&["--f=1"]));
        assert!(r.is_err());
    }

    #[test]
    fn get_f64_parses() {
        let p = Cli::new("t")
            .opt("lam", "0.5", "")
            .parse_from(&args(&["--lam", "2.25"]))
            .unwrap();
        assert_eq!(p.get_f64("lam"), Ok(2.25));
    }

    #[test]
    fn get_u64_parses() {
        let p = Cli::new("t")
            .opt("seed", "0", "")
            .parse_from(&args(&["--seed=42"]))
            .unwrap();
        assert_eq!(p.get_u64("seed"), Ok(42));
    }

    /// Malformed values are an `Err` naming the option and the value —
    /// never a panic (`hass search --iters=abc` must exit gracefully).
    #[test]
    fn get_usize_rejects_malformed_input() {
        let p = Cli::new("t")
            .opt("iters", "96", "")
            .parse_from(&args(&["--iters=abc"]))
            .unwrap();
        let e = p.get_usize("iters").unwrap_err();
        assert!(e.contains("--iters") && e.contains("abc"), "unhelpful error: {e}");
        // a negative value is also not a usize
        let p = Cli::new("t").opt("iters", "96", "").parse_from(&args(&["--iters=-3"]));
        assert!(p.unwrap().get_usize("iters").is_err());
    }

    #[test]
    fn get_u64_rejects_malformed_input() {
        let p = Cli::new("t")
            .opt("seed", "0", "")
            .parse_from(&args(&["--seed", "1.5"]))
            .unwrap();
        let e = p.get_u64("seed").unwrap_err();
        assert!(e.contains("--seed") && e.contains("1.5"), "unhelpful error: {e}");
    }

    #[test]
    fn get_f64_rejects_malformed_input() {
        let p = Cli::new("t")
            .opt("sw", "0.5", "")
            .parse_from(&args(&["--sw", "half"]))
            .unwrap();
        let e = p.get_f64("sw").unwrap_err();
        assert!(e.contains("--sw") && e.contains("half"), "unhelpful error: {e}");
    }
}
