//! Tiny property-based-testing harness (proptest is unavailable offline).
//!
//! `forall(n, seed, f)` runs `f` against `n` independently seeded RNG
//! streams; on failure it reports the failing case seed so the case can be
//! replayed exactly (`forall_one(seed, f)`).  No shrinking — failing seeds
//! are deterministic and the generators used in this codebase produce
//! small cases by construction.

use super::rng::Rng;

/// Run a property over `n` random cases.  Panics (with the case seed) on
/// the first failing case.
pub fn forall<F: Fn(&mut Rng)>(n: usize, seed: u64, f: F) {
    for case in 0..n {
        let case_seed = seed ^ (case as u64).wrapping_mul(0xA076_1D64_78BD_642F);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property failed on case {case}/{n} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by its reported seed.
pub fn forall_one<F: Fn(&mut Rng)>(case_seed: u64, f: F) {
    let mut rng = Rng::new(case_seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        forall(50, 1, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            forall(100, 2, |rng| {
                assert!(rng.f64() < 0.5, "value too large");
            });
        });
        let e = r.unwrap_err();
        // the re-panic message is a formatted String; Box<dyn Any>'s Debug
        // impl hides it, so downcast explicitly
        let msg = e.downcast_ref::<String>().expect("string panic payload");
        assert!(msg.contains("replay seed"), "got: {msg}");
    }

    #[test]
    fn cases_use_distinct_streams() {
        use std::cell::RefCell;
        let seen = RefCell::new(Vec::new());
        forall(20, 3, |rng| {
            seen.borrow_mut().push(rng.next_u64());
        });
        let mut v = seen.into_inner();
        v.sort_unstable();
        v.dedup();
        assert_eq!(v.len(), 20);
    }
}
