//! Minimal strict JSON parser/serializer (serde_json is unavailable
//! offline).  Supports the full JSON grammar; numbers are f64 (adequate
//! for `meta.json`, configs and journals).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the key name (for meta.json).
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key: {key}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<Option<Vec<_>>>()
    }

    // ----------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ------------------------------------------------------- serializer

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    nl(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    nl(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Lossless `u64 -> JSON` encoding: 16 lower-case hex digits.  JSON
/// numbers are f64, which silently rounds integers above 2^53 — 64-bit
/// fingerprints and f64 bit patterns therefore travel as hex strings
/// (see the cache-snapshot format in `engine::cache`).
pub fn u64_to_hex(v: u64) -> String {
    format!("{v:016x}")
}

/// Inverse of [`u64_to_hex`]; `None` unless `s` is exactly 16 hex digits.
pub fn u64_from_hex(s: &str) -> Option<u64> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

fn nl(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        out.extend(std::iter::repeat(' ').take(w * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.req("c").as_str().unwrap(), "x\ny");
        let arr = v.req("a").as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].req("b"), &Json::Null);
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é中""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é中");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"a\"b","t":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn f64_vec_helper() {
        let v = Json::parse("[0.1, 0.2, 3]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![0.1, 0.2, 3.0]);
        assert!(Json::parse("[1, \"x\"]").unwrap().as_f64_vec().is_none());
    }

    #[test]
    fn integer_formatting_stays_integral() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn u64_hex_roundtrips_and_rejects_garbage() {
        for v in [0u64, 1, 0x8000_0000_0000_0000, u64::MAX, 0xcbf29ce484222325] {
            let s = u64_to_hex(v);
            assert_eq!(s.len(), 16);
            assert_eq!(u64_from_hex(&s), Some(v), "roundtrip of {v:#x}");
        }
        for bad in ["", "abc", "00000000000000000", "000000000000000g", "0x00000000000000"] {
            assert_eq!(u64_from_hex(bad), None, "accepted '{bad}'");
        }
    }

    #[test]
    fn parses_real_meta_like_structure() {
        let src = r#"{"layers":[{"name":"stem","w_offset":0,"macs_per_image":442368}],
                      "quantile_pts":[0,0.05],"dense_val_accuracy":0.9917}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(
            v.req("layers").as_arr().unwrap()[0]
                .req("macs_per_image")
                .as_usize()
                .unwrap(),
            442368
        );
    }
}
