//! Deterministic RNG substrate (no `rand` crate offline).
//!
//! `Xoshiro256**` seeded via `SplitMix64` — the standard, well-studied
//! combination.  Every stochastic component of the system (TPE, simulated
//! annealing, the cycle simulator, synthetic sparsity profiles) takes an
//! explicit `Rng` so that every experiment is exactly reproducible from a
//! seed recorded in its journal.

/// Xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-component seeding).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // rejection-free for our purposes (n << 2^64, bias negligible)
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Laplace(0, b) sample.
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.f64() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Pick an element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var {var}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn laplace_moments() {
        let mut r = Rng::new(13);
        let b = 0.7;
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.laplace(b)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02);
        assert!((var - 2.0 * b * b).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut root = Rng::new(23);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn int_range_inclusive() {
        let mut r = Rng::new(29);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }
}
