//! Generic lock-striped, single-compute memo table.
//!
//! The engine's pricing caches (`engine::cache`) grew two copies of the
//! same concurrency core: a map of placeholder cells spread over
//! independent mutex stripes, where a miss installs an empty
//! [`OnceLock`] under the stripe lock and fills it *outside* the lock,
//! so racing threads block on the in-flight cell instead of recomputing.
//! [`StripedMemo`] is that core, once, generic over key and value —
//! `DesignCache`'s per-device design memo and its `FrontierStore` are
//! thin typed layers over it.
//!
//! # Single-compute contract
//!
//! [`get_or_compute`](StripedMemo::get_or_compute) runs `compute` **at
//! most once per key**, even under contention: exactly one caller ever
//! observes `fresh == true` for a key (the one that installed the
//! placeholder cell), and every other concurrent caller blocks on the
//! cell's `OnceLock` until the value is ready.  The stripe lock is held
//! only for the map lookup/insert, never across `compute`, so long
//! computations of different keys proceed in parallel — also within one
//! stripe.
//!
//! The memo never changes results: a hit returns a clone of exactly what
//! the first compute produced, so callers whose `compute` is a pure
//! function get bit-identical values whether or not the memo is warm.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex, OnceLock};

// Stripe locks recover from poisoning via `util::lock_clean`: the maps
// hold no invariant a panicking holder could half-write (lookup/insert
// of independent entries), and a resident `hass serve` process must keep
// answering after a worker panic rather than fail every later request.
use crate::util::lock_clean;

/// Lock-striped map of `K -> OnceLock<V>` cells: keys are spread over
/// independent mutexes by key hash, values are computed at most once per
/// key (see the module docs).
pub struct StripedMemo<K, V> {
    stripes: Vec<Mutex<HashMap<K, Arc<OnceLock<V>>>>>,
}

impl<K: Eq + Hash, V: Clone> StripedMemo<K, V> {
    /// An empty memo with `stripes` independent locks (must be ≥ 1).
    pub fn new(stripes: usize) -> Self {
        assert!(stripes >= 1, "a memo needs at least one stripe");
        StripedMemo { stripes: (0..stripes).map(|_| Mutex::new(HashMap::new())).collect() }
    }

    fn stripe_of(&self, key: &K) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.stripes.len()
    }

    /// Return the memoized value of `key`, or run `compute` and remember
    /// the result.  The second return is `true` iff this call installed
    /// the key's cell (a miss); callers use it for hit/miss accounting.
    /// `compute` runs at most once per key across all threads; late
    /// arrivals block on the in-flight cell.
    pub fn get_or_compute<F>(&self, key: K, compute: F) -> (V, bool)
    where
        F: FnOnce() -> V,
    {
        let (cell, fresh) = {
            let stripe = &self.stripes[self.stripe_of(&key)];
            let mut map = lock_clean(stripe);
            match map.get(&key) {
                Some(c) => (c.clone(), false),
                None => {
                    let c: Arc<OnceLock<V>> = Arc::new(OnceLock::new());
                    map.insert(key, c.clone());
                    (c, true)
                }
            }
        };
        // OnceLock guarantees a single execution even if the placeholder
        // inserter loses the race to reach get_or_init first.
        (cell.get_or_init(compute).clone(), fresh)
    }

    /// Completed-entries-only lookup: an entry still being computed by
    /// another thread reads as absent.  Never counts as a hit or miss —
    /// callers recompute, which is benign when `compute` is pure.
    pub fn get(&self, key: &K) -> Option<V> {
        let cell = lock_clean(&self.stripes[self.stripe_of(key)]).get(key).cloned();
        cell.and_then(|c| c.get().cloned())
    }

    /// Pre-seed (or overwrite) an entry with an already-computed value.
    pub fn insert(&self, key: K, value: V) {
        let stripe = &self.stripes[self.stripe_of(&key)];
        lock_clean(stripe).insert(key, Arc::new(OnceLock::from(value)));
    }

    /// Total entries across all stripes (including in-flight cells).
    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| lock_clean(s).len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entry count per stripe (for balance diagnostics and tests).
    pub fn stripe_lens(&self) -> Vec<usize> {
        self.stripes.iter().map(|s| lock_clean(s).len()).collect()
    }

    /// Visit every **completed** entry (in-flight cells are skipped) —
    /// the read side of snapshotting.  Iteration order is unspecified;
    /// one stripe is locked at a time, so `f` must not call back into
    /// this memo.
    pub fn for_each_complete(&self, mut f: impl FnMut(&K, &V)) {
        for stripe in &self.stripes {
            for (k, cell) in lock_clean(stripe).iter() {
                if let Some(v) = cell.get() {
                    f(k, v);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn miss_then_hit_returns_memoized_value() {
        let memo: StripedMemo<u64, u64> = StripedMemo::new(4);
        let (a, fresh_a) = memo.get_or_compute(7, || 42);
        let (b, fresh_b) = memo.get_or_compute(7, || 999); // must not run
        assert_eq!((a, fresh_a), (42, true));
        assert_eq!((b, fresh_b), (42, false));
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn distinct_keys_are_distinct_entries() {
        let memo: StripedMemo<(u64, u64), u64> = StripedMemo::new(4);
        assert!(memo.is_empty());
        memo.get_or_compute((1, 2), || 1);
        memo.get_or_compute((2, 1), || 2);
        assert_eq!(memo.len(), 2);
        assert_eq!(memo.get(&(1, 2)), Some(1));
        assert_eq!(memo.get(&(2, 1)), Some(2));
        assert_eq!(memo.get(&(9, 9)), None);
    }

    #[test]
    fn insert_preseeds_and_overwrites() {
        let memo: StripedMemo<u8, &'static str> = StripedMemo::new(2);
        memo.insert(1, "seeded");
        let (v, fresh) = memo.get_or_compute(1, || "computed");
        assert_eq!(v, "seeded");
        assert!(!fresh, "a pre-seeded entry must read as a hit");
        memo.insert(1, "overwritten");
        assert_eq!(memo.get(&1), Some("overwritten"));
        assert_eq!(memo.len(), 1);
    }

    /// Regression for the double-compute race (formerly in
    /// `engine::cache`, re-pointed at the generic core): many threads
    /// missing the same key simultaneously must still run `compute`
    /// exactly once, and exactly one of them may observe `fresh`.
    #[test]
    fn contended_miss_computes_exactly_once() {
        const THREADS: usize = 8;
        let memo: StripedMemo<u64, u64> = StripedMemo::new(4);
        let computes = AtomicUsize::new(0);
        let fresh_count = AtomicUsize::new(0);
        let gate = Barrier::new(THREADS);
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    gate.wait(); // maximize overlap on the first lookup
                    let (v, fresh) = memo.get_or_compute(3, || {
                        computes.fetch_add(1, Ordering::SeqCst);
                        // widen the race window: late arrivals must block
                        // on the in-flight cell, not recompute
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        5
                    });
                    if fresh {
                        fresh_count.fetch_add(1, Ordering::SeqCst);
                    }
                    assert_eq!(v, 5);
                });
            }
        });
        assert_eq!(computes.load(Ordering::SeqCst), 1, "duplicate compute");
        assert_eq!(fresh_count.load(Ordering::SeqCst), 1, "one miss only");
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn concurrent_lookups_are_consistent() {
        let memo: StripedMemo<u64, u64> = StripedMemo::new(4);
        let fresh_total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        let (v, fresh) = memo.get_or_compute(11, || 7);
                        assert_eq!(v, 7);
                        if fresh {
                            fresh_total.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                });
            }
        });
        assert_eq!(memo.len(), 1);
        assert_eq!(fresh_total.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn for_each_complete_sees_all_finished_entries() {
        let memo: StripedMemo<u64, u64> = StripedMemo::new(4);
        for k in 0..20u64 {
            memo.get_or_compute(k, || k * k);
        }
        memo.insert(100, 1_000_000);
        let mut seen: Vec<(u64, u64)> = Vec::new();
        memo.for_each_complete(|&k, &v| seen.push((k, v)));
        seen.sort_unstable();
        assert_eq!(seen.len(), 21);
        for (k, v) in &seen[..20] {
            assert_eq!(*v, k * k);
        }
        assert_eq!(seen[20], (100, 1_000_000));
    }

    #[test]
    fn stripes_spread_entries() {
        let memo: StripedMemo<(u64, u64), u64> = StripedMemo::new(16);
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            memo.get_or_compute((rng.next_u64(), rng.next_u64()), || 1);
        }
        assert_eq!(memo.len(), 200);
        // with 200 random keys over 16 stripes, no stripe should hold more
        // than half of everything (a loose check that striping is active)
        let max_stripe = memo.stripe_lens().into_iter().max().unwrap();
        assert!(max_stripe < 100, "stripe imbalance: {max_stripe}/200");
    }

    #[test]
    #[should_panic(expected = "at least one stripe")]
    fn zero_stripes_is_rejected() {
        let _ = StripedMemo::<u64, u64>::new(0);
    }
}
