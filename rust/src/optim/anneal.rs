//! Generic simulated annealing — the paper uses SA twice: for the
//! intra-layer balancing assignment (§IV "Balancing Strategy") and for
//! device partitioning / reconfiguration trade-offs (§V-A.4).

use crate::util::rng::Rng;

/// Geometric cooling schedule.
#[derive(Clone, Debug)]
pub struct AnnealSchedule {
    pub iters: usize,
    pub t0: f64,
    pub t1: f64,
}

impl Default for AnnealSchedule {
    fn default() -> Self {
        AnnealSchedule { iters: 2000, t0: 1.0, t1: 1e-3 }
    }
}

impl AnnealSchedule {
    fn temp(&self, i: usize) -> f64 {
        let f = i as f64 / self.iters.max(1) as f64;
        self.t0 * (self.t1 / self.t0).powf(f)
    }
}

/// Minimize `energy` over states reachable via `neighbor`.
/// Returns the best state seen and its energy.
pub fn anneal<S: Clone>(
    init: S,
    energy: impl Fn(&S) -> f64,
    neighbor: impl Fn(&S, &mut Rng) -> S,
    schedule: &AnnealSchedule,
    rng: &mut Rng,
) -> (S, f64) {
    let mut cur = init.clone();
    let mut cur_e = energy(&cur);
    let mut best = cur.clone();
    let mut best_e = cur_e;
    for i in 0..schedule.iters {
        let t = schedule.temp(i);
        let cand = neighbor(&cur, rng);
        let cand_e = energy(&cand);
        let accept = cand_e <= cur_e || rng.bool(((cur_e - cand_e) / t.max(1e-300)).exp());
        if accept {
            cur = cand;
            cur_e = cand_e;
            if cur_e < best_e {
                best = cur.clone();
                best_e = cur_e;
            }
        }
    }
    (best, best_e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        let mut rng = Rng::new(1);
        let (x, e) = anneal(
            5.0f64,
            |x| (x - 2.0) * (x - 2.0),
            |x, r| x + r.normal(0.0, 0.3),
            &AnnealSchedule::default(),
            &mut rng,
        );
        assert!(e < 0.01, "x={x} e={e}");
    }

    #[test]
    fn best_energy_never_worse_than_init() {
        let mut rng = Rng::new(2);
        let init = 100.0f64;
        let init_e = init * init;
        let (_, e) = anneal(
            init,
            |x| x * x,
            |x, r| x + r.normal(0.0, 1.0),
            &AnnealSchedule { iters: 100, ..Default::default() },
            &mut rng,
        );
        assert!(e <= init_e);
    }

    #[test]
    fn escapes_local_minimum() {
        // double well: local min at x=-1 (e=0.5), global at x=1 (e=0)
        let well = |x: &f64| {
            let a = (x + 1.0) * (x + 1.0) + 0.5;
            let b = (x - 1.0) * (x - 1.0);
            a.min(b)
        };
        let mut rng = Rng::new(3);
        let (x, e) = anneal(
            -1.0f64,
            well,
            |x, r| x + r.normal(0.0, 0.5),
            &AnnealSchedule { iters: 5000, t0: 2.0, t1: 1e-4 },
            &mut rng,
        );
        assert!(e < 0.05, "stuck at x={x} e={e}");
    }

    #[test]
    fn deterministic_for_seed() {
        let run = |seed| {
            let mut rng = Rng::new(seed);
            anneal(
                0.0f64,
                |x| x.sin() + x * x * 0.01,
                |x, r| x + r.normal(0.0, 0.5),
                &AnnealSchedule::default(),
                &mut rng,
            )
            .1
        };
        assert_eq!(run(9).to_bits(), run(9).to_bits());
    }

    #[test]
    fn discrete_state_assignment() {
        // assign 10 weights to 3 bins minimizing max bin load
        let weights = [5.0, 3.0, 8.0, 2.0, 7.0, 1.0, 4.0, 6.0, 2.0, 5.0];
        let energy = |assign: &Vec<usize>| {
            let mut loads = [0.0f64; 3];
            for (w, &b) in weights.iter().zip(assign) {
                loads[b] += w;
            }
            loads.iter().cloned().fold(0.0, f64::max)
        };
        let neighbor = |a: &Vec<usize>, r: &mut Rng| {
            let mut b = a.clone();
            let i = r.below(b.len());
            b[i] = r.below(3);
            b
        };
        let mut rng = Rng::new(4);
        let init = vec![0; 10];
        let (_, e) = anneal(init, energy, neighbor, &AnnealSchedule::default(), &mut rng);
        // total = 43, perfect balance ≈ 14.33; SA should get close
        assert!(e <= 17.0, "max load {e}");
    }
}
