//! Search algorithms: Tree-structured Parzen Estimator (the paper's §V-B
//! multi-objective search engine, [17]), generic simulated annealing (the
//! paper's solver for intra-layer SPE balancing and device partitioning),
//! and a random-search baseline used in tests and ablations.

pub mod anneal;
pub mod tpe;

pub use anneal::{anneal, AnnealSchedule};
pub use tpe::TpeOptimizer;

use crate::util::rng::Rng;

/// Random search over the unit hypercube — baseline for TPE ablations.
pub struct RandomSearch {
    pub dim: usize,
    rng: Rng,
}

impl RandomSearch {
    pub fn new(dim: usize, seed: u64) -> Self {
        RandomSearch { dim, rng: Rng::new(seed) }
    }

    pub fn ask(&mut self) -> Vec<f64> {
        (0..self.dim).map(|_| self.rng.f64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_search_in_bounds() {
        let mut rs = RandomSearch::new(5, 1);
        for _ in 0..100 {
            let x = rs.ask();
            assert_eq!(x.len(), 5);
            assert!(x.iter().all(|v| (0.0..1.0).contains(v)));
        }
    }
}
