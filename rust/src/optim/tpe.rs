//! Tree-structured Parzen Estimator (Bergstra et al., NeurIPS'11 [17]).
//!
//! Maximization over the unit hypercube [0,1]^d.  After `n_startup`
//! random trials, observations are split at the γ-quantile into *good*
//! and *bad* sets; each dimension is modelled with a 1-D Parzen window
//! (truncated Gaussians, per-point bandwidths); `n_candidates` samples are
//! drawn from the good density l(x) and the one maximizing the expected-
//! improvement proxy l(x)/g(x) is proposed.  This matches the structure of
//! Hyperopt's default TPE (independent-dimension KDEs, uniform prior).

use crate::util::clampf;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TpeConfig {
    /// fraction of observations considered "good" (γ)
    pub gamma: f64,
    /// random trials before the model kicks in
    pub n_startup: usize,
    /// candidates drawn from l(x) per ask
    pub n_candidates: usize,
}

impl Default for TpeConfig {
    fn default() -> Self {
        TpeConfig { gamma: 0.25, n_startup: 10, n_candidates: 24 }
    }
}

/// TPE optimizer state: observations (x, y) with y to be *maximized*.
pub struct TpeOptimizer {
    pub dim: usize,
    pub cfg: TpeConfig,
    obs: Vec<(Vec<f64>, f64)>,
    rng: Rng,
}

impl TpeOptimizer {
    pub fn new(dim: usize, seed: u64, cfg: TpeConfig) -> Self {
        assert!(dim > 0);
        TpeOptimizer { dim, cfg, obs: Vec::new(), rng: Rng::new(seed) }
    }

    pub fn with_defaults(dim: usize, seed: u64) -> Self {
        Self::new(dim, seed, TpeConfig::default())
    }

    pub fn len(&self) -> usize {
        self.obs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.obs.is_empty()
    }

    /// Best observation so far.
    pub fn best(&self) -> Option<(&[f64], f64)> {
        self.obs
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(x, y)| (x.as_slice(), *y))
    }

    /// Record an evaluated point.
    pub fn tell(&mut self, x: Vec<f64>, y: f64) {
        assert_eq!(x.len(), self.dim);
        assert!(y.is_finite(), "objective must be finite");
        self.obs.push((x, y));
    }

    /// Record a whole generation of evaluated points, in candidate order.
    /// Equivalent to calling [`tell`](Self::tell) for each pair.
    pub fn observe_batch(&mut self, batch: Vec<(Vec<f64>, f64)>) {
        for (x, y) in batch {
            self.tell(x, y);
        }
    }

    /// Fit the good/bad Parzen models from the current observations.
    /// `None` during the random-startup phase.  Deterministic (no RNG).
    fn fit(&self) -> Option<ParzenModel> {
        if self.obs.len() < self.cfg.n_startup {
            return None;
        }
        // split observations: top γ fraction (at least 1) are "good"
        let mut order: Vec<usize> = (0..self.obs.len()).collect();
        order.sort_by(|&a, &b| self.obs[b].1.total_cmp(&self.obs[a].1));
        let n_good = ((self.obs.len() as f64 * self.cfg.gamma).ceil() as usize)
            .clamp(1, self.obs.len() - 1);
        let good: Vec<&Vec<f64>> = order[..n_good].iter().map(|&i| &self.obs[i].0).collect();
        let bad: Vec<&Vec<f64>> = order[n_good..].iter().map(|&i| &self.obs[i].0).collect();

        // per-dimension Parzen models
        let good_kdes: Vec<Kde> = (0..self.dim)
            .map(|d| Kde::fit(good.iter().map(|x| x[d]).collect()))
            .collect();
        let bad_kdes: Vec<Kde> = (0..self.dim)
            .map(|d| Kde::fit(bad.iter().map(|x| x[d]).collect()))
            .collect();
        Some(ParzenModel { good: good_kdes, bad: bad_kdes })
    }

    /// Draw one proposal from a fitted model (uniform when `None`).
    fn propose(&mut self, model: Option<&ParzenModel>) -> Vec<f64> {
        let Some(m) = model else {
            return (0..self.dim).map(|_| self.rng.f64()).collect();
        };
        let mut best_x = None;
        let mut best_score = f64::NEG_INFINITY;
        for _ in 0..self.cfg.n_candidates {
            let x: Vec<f64> = m.good.iter().map(|k| k.sample(&mut self.rng)).collect();
            let mut score = 0.0;
            for d in 0..self.dim {
                score += m.good[d].log_pdf(x[d]) - m.bad[d].log_pdf(x[d]);
            }
            if score > best_score {
                best_score = score;
                best_x = Some(x);
            }
        }
        best_x.unwrap()
    }

    /// Propose the next point to evaluate.
    pub fn ask(&mut self) -> Vec<f64> {
        let model = self.fit();
        self.propose(model.as_ref())
    }

    /// Propose `k` points for one generation, with the Parzen model
    /// *frozen* at the current observation set (synchronous batch BO).
    ///
    /// Because [`ask`](Self::ask) refits from the same observations when
    /// nothing is told in between, `suggest_batch(k)` consumes the RNG
    /// exactly like `k` successive `ask()` calls and returns the identical
    /// proposals — the batch API is a pure fast path, not a different
    /// algorithm, until observations land between proposals.
    ///
    /// This also pins down the engine's **lookahead pipeline schedule**
    /// (`SearchConfig::pipeline_depth`): proposals depend only on (seed,
    /// observations so far, RNG draws so far), never on wall-clock time or
    /// caller threading.  The pipelined engine calls `suggest_batch` for
    /// generation *g+1* before *g*'s results are observed — i.e. it simply
    /// *defers* some [`observe_batch`](Self::observe_batch) calls — and as
    /// long as every engine replays the same interleaving of
    /// `suggest_batch`/`observe_batch` calls in generation order, the
    /// proposal stream is bit-identical across thread counts, sync/async
    /// evaluation, cache states, and kill/resume.
    pub fn suggest_batch(&mut self, k: usize) -> Vec<Vec<f64>> {
        let model = self.fit();
        (0..k).map(|_| self.propose(model.as_ref())).collect()
    }
}

/// Frozen per-dimension good/bad KDEs used to score one generation.
struct ParzenModel {
    good: Vec<Kde>,
    bad: Vec<Kde>,
}

/// 1-D Parzen window on [0,1]: mixture of truncated Gaussians centred on
/// the points plus a uniform prior component.
struct Kde {
    pts: Vec<f64>,
    bw: f64,
}

impl Kde {
    fn fit(pts: Vec<f64>) -> Kde {
        let n = pts.len().max(1) as f64;
        // Scott-style rule on the unit interval, floored to stay explorative
        let bw = (1.0 / n.powf(0.2) * 0.3).max(0.05);
        Kde { pts, bw }
    }

    /// Uniform-prior mixture weight: one virtual point among the fitted
    /// ones (Hyperopt's convention).  A fixed large weight (say 10%) per
    /// dimension would mean that in a 100-dim space *every* candidate has
    /// ~10 coordinates drawn blind, which keeps re-triggering bad regions
    /// the model already learned to avoid.
    fn prior_w(&self) -> f64 {
        1.0 / (self.pts.len() as f64 + 1.0)
    }

    fn sample(&self, rng: &mut Rng) -> f64 {
        if self.pts.is_empty() || rng.bool(self.prior_w()) {
            return rng.f64(); // uniform prior component
        }
        let c = *rng.choice(&self.pts);
        clampf(rng.normal(c, self.bw), 0.0, 1.0 - 1e-12)
    }

    fn log_pdf(&self, x: f64) -> f64 {
        let prior = 1.0; // uniform on [0,1]
        if self.pts.is_empty() {
            return 0.0;
        }
        let norm = 1.0 / ((2.0 * std::f64::consts::PI).sqrt() * self.bw);
        let mut p = self.prior_w() * prior; // prior weight mirrors sample()
        let w = (1.0 - self.prior_w()) / self.pts.len() as f64;
        for &c in &self.pts {
            let z = (x - c) / self.bw;
            p += w * norm * (-0.5 * z * z).exp();
        }
        p.max(1e-300).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A smooth multimodal surrogate with max at x = (0.7, 0.2, ...).
    fn surrogate(x: &[f64]) -> f64 {
        let targets = [0.7, 0.2, 0.5, 0.9];
        -x.iter()
            .enumerate()
            .map(|(i, &v)| (v - targets[i % 4]).powi(2))
            .sum::<f64>()
    }

    fn run(optimizer_iters: usize, dim: usize, seed: u64) -> f64 {
        let mut tpe = TpeOptimizer::with_defaults(dim, seed);
        for _ in 0..optimizer_iters {
            let x = tpe.ask();
            let y = surrogate(&x);
            tpe.tell(x, y);
        }
        tpe.best().unwrap().1
    }

    #[test]
    fn proposals_stay_in_unit_cube() {
        let mut tpe = TpeOptimizer::with_defaults(3, 1);
        for i in 0..60 {
            let x = tpe.ask();
            assert!(x.iter().all(|v| (0.0..1.0).contains(v)), "iter {i}: {x:?}");
            let y = surrogate(&x);
            tpe.tell(x, y);
        }
    }

    #[test]
    fn beats_random_search_on_surrogate() {
        // paired comparison over several seeds, 60 evals each
        let mut tpe_wins = 0;
        for seed in 0..5u64 {
            let tpe_best = run(60, 4, seed);
            let mut rs = super::super::RandomSearch::new(4, seed);
            let mut rs_best = f64::NEG_INFINITY;
            for _ in 0..60 {
                let x = rs.ask();
                rs_best = rs_best.max(surrogate(&x));
            }
            if tpe_best >= rs_best {
                tpe_wins += 1;
            }
        }
        assert!(tpe_wins >= 3, "TPE won only {tpe_wins}/5 seeds");
    }

    #[test]
    fn improves_with_budget() {
        let short = run(15, 2, 42);
        let long = run(120, 2, 42);
        assert!(long >= short, "long {long} < short {short}");
        assert!(long > -0.02, "did not converge: {long}");
    }

    #[test]
    fn deterministic_for_seed() {
        assert_eq!(run(40, 3, 7).to_bits(), run(40, 3, 7).to_bits());
    }

    #[test]
    fn best_tracks_maximum() {
        let mut tpe = TpeOptimizer::with_defaults(1, 3);
        tpe.tell(vec![0.1], 1.0);
        tpe.tell(vec![0.2], 5.0);
        tpe.tell(vec![0.3], 3.0);
        let (x, y) = tpe.best().unwrap();
        assert_eq!(y, 5.0);
        assert_eq!(x, &[0.2]);
    }

    #[test]
    #[should_panic(expected = "objective must be finite")]
    fn rejects_nan_objective() {
        let mut tpe = TpeOptimizer::with_defaults(1, 3);
        tpe.tell(vec![0.1], f64::NAN);
    }

    #[test]
    fn suggest_batch_matches_successive_asks() {
        // same seed, same telling history: a frozen-model batch of k must
        // reproduce k back-to-back asks bit for bit (no tells in between)
        let seed = 21;
        let mut a = TpeOptimizer::with_defaults(3, seed);
        let mut b = TpeOptimizer::with_defaults(3, seed);
        // get both past startup with identical histories *and* identical
        // RNG consumption (both must ask)
        for _ in 0..12 {
            let xa = a.ask();
            let xb = b.ask();
            assert_eq!(xa, xb);
            let y = surrogate(&xa);
            a.tell(xa, y);
            b.tell(xb, y);
        }
        let batch = a.suggest_batch(4);
        let serial: Vec<Vec<f64>> = (0..4).map(|_| b.ask()).collect();
        for (xa, xb) in batch.iter().zip(&serial) {
            for (va, vb) in xa.iter().zip(xb) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn deferred_observe_schedule_is_reproducible() {
        // the engine's lookahead pipeline proposes generation g+1 before
        // observing generation g's results: the proposal stream must be a
        // pure function of the (suggest, observe) call interleaving, so
        // two optimizers replaying the same depth-1 schedule — however
        // the evaluations behind it were threaded — agree bit for bit
        let seed = 33;
        let (dim, batch, gens) = (3usize, 4usize, 5usize);
        let run = |seed: u64| -> Vec<Vec<Vec<f64>>> {
            let mut tpe = TpeOptimizer::with_defaults(dim, seed);
            let mut proposed: Vec<Vec<Vec<f64>>> = Vec::new();
            let mut pending: Option<Vec<Vec<f64>>> = None;
            for _ in 0..gens {
                let xs = tpe.suggest_batch(batch);
                proposed.push(xs.clone());
                // observe the *previous* generation only after the next
                // one was proposed (depth-1 lookahead)
                if let Some(prev) = pending.take() {
                    tpe.observe_batch(
                        prev.into_iter().map(|x| { let y = surrogate(&x); (x, y) }).collect(),
                    );
                }
                pending = Some(xs);
            }
            proposed
        };
        let a = run(seed);
        let b = run(seed);
        for (ga, gb) in a.iter().zip(&b) {
            for (xa, xb) in ga.iter().zip(gb) {
                for (va, vb) in xa.iter().zip(xb) {
                    assert_eq!(va.to_bits(), vb.to_bits());
                }
            }
        }
        // and the deferred schedule genuinely differs from the drained
        // one once the model engages — lookahead is a schedule, not a
        // no-op relabeling
        let mut drained = TpeOptimizer::with_defaults(dim, seed);
        let mut drained_prop: Vec<Vec<Vec<f64>>> = Vec::new();
        for _ in 0..gens {
            let xs = drained.suggest_batch(batch);
            drained.observe_batch(
                xs.iter().map(|x| (x.clone(), surrogate(x))).collect(),
            );
            drained_prop.push(xs);
        }
        assert_ne!(a, drained_prop);
    }

    #[test]
    fn suggest_batch_is_random_during_startup() {
        let mut tpe = TpeOptimizer::with_defaults(2, 9);
        let xs = tpe.suggest_batch(5);
        assert_eq!(xs.len(), 5);
        for x in &xs {
            assert_eq!(x.len(), 2);
            assert!(x.iter().all(|v| (0.0..1.0).contains(v)));
        }
        // startup proposals must differ from each other (fresh RNG draws)
        assert_ne!(xs[0], xs[1]);
    }

    #[test]
    fn observe_batch_equals_sequential_tells() {
        let mut a = TpeOptimizer::with_defaults(2, 4);
        let mut b = TpeOptimizer::with_defaults(2, 4);
        let pts: Vec<(Vec<f64>, f64)> =
            (0..6).map(|i| (vec![0.1 * i as f64, 0.5], i as f64)).collect();
        a.observe_batch(pts.clone());
        for (x, y) in pts {
            b.tell(x, y);
        }
        assert_eq!(a.len(), b.len());
        assert_eq!(a.best().unwrap().1, b.best().unwrap().1);
        // subsequent proposals agree (same obs, same rng state)
        assert_eq!(a.ask(), b.ask());
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let mut tpe = TpeOptimizer::with_defaults(2, 8);
        let before = tpe.len();
        let xs = tpe.suggest_batch(0);
        assert!(xs.is_empty());
        tpe.observe_batch(Vec::new());
        assert_eq!(tpe.len(), before);
        // and the RNG was not touched: next ask matches a fresh twin's
        let mut twin = TpeOptimizer::with_defaults(2, 8);
        assert_eq!(tpe.ask(), twin.ask());
    }

    #[test]
    fn kde_pdf_integrates_to_one_ish() {
        let kde = Kde::fit(vec![0.3, 0.5, 0.7]);
        let n = 2000;
        let integral: f64 = (0..n)
            .map(|i| kde.log_pdf((i as f64 + 0.5) / n as f64).exp())
            .sum::<f64>()
            / n as f64;
        // truncation at the borders loses a little mass
        assert!((0.8..1.1).contains(&integral), "integral {integral}");
    }
}
