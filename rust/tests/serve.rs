//! Daemon protocol tests: a real [`Server`] on an OS-assigned port,
//! driven over TCP exactly like `hass client` would.
//!
//! The invariants pinned here are the serve tentpole's acceptance
//! criteria: malformed requests are answered (never crash the daemon or
//! the connection), concurrent searches stream journals bit-identical to
//! the same search through the library entry points, a client
//! disconnecting mid-search frees its admission slot for the next
//! client, and `shutdown` drains the accept loop.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use hass::arch::networks;
use hass::coordinator::{
    search_sharded_with_cache, Checkpoint, DesignCache, EngineConfig, SearchConfig,
    SurrogateEvaluator,
};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::server::{ServeConfig, Server};
use hass::sparsity::synthesize;
use hass::util::fault;
use hass::util::json::Json;

fn start_server(max_inflight: usize) -> (Arc<Server>, SocketAddr, std::thread::JoinHandle<()>) {
    let server = Arc::new(Server::new(
        DesignCache::new(),
        ServeConfig { max_inflight },
    ));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind test port");
    let addr = listener.local_addr().expect("local addr");
    let s = server.clone();
    let handle = std::thread::spawn(move || s.run(listener).expect("accept loop"));
    (server, addr, handle)
}

fn send_line(stream: &TcpStream, line: &str) {
    let mut w = stream;
    w.write_all(format!("{line}\n").as_bytes()).expect("send request line");
}

/// Read one response line (blocking) and parse it.
fn read_json(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    let n = reader.read_line(&mut line).expect("read response line");
    assert!(n > 0, "connection closed while a response was expected");
    Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
}

/// Read lines until the terminal result/error for `id`; returns
/// (events seen, terminal line).
fn read_until_result(reader: &mut BufReader<TcpStream>, id: f64) -> (Vec<Json>, Json) {
    let mut events = Vec::new();
    loop {
        let v = read_json(reader);
        assert_eq!(
            v.get("id").and_then(|i| i.as_f64()),
            Some(id),
            "response for a different request interleaved: {v:?}"
        );
        if v.get("event").is_some() {
            events.push(v);
            continue;
        }
        return (events, v);
    }
}

fn shutdown_and_join(addr: SocketAddr, handle: std::thread::JoinHandle<()>) {
    let stream = TcpStream::connect(addr).expect("connect for shutdown");
    send_line(&stream, r#"{"id": 99, "method": "shutdown"}"#);
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let (_, v) = read_until_result(&mut reader, 99.0);
    assert!(v.get("result").is_some(), "shutdown must be acknowledged: {v:?}");
    handle.join().expect("accept loop must drain and exit");
}

/// The canonical search request the bit-identity tests use; must mirror
/// `reference_csv` below flag for flag.
fn search_request(id: u64, iters: usize, seed: u64) -> String {
    format!(
        r#"{{"id": {id}, "method": "search", "params": {{"network": "calibnet", "device": "u250", "iters": {iters}, "seed": {seed}, "batch": 4, "quant": 12}}}}"#
    )
}

/// The same search through the library entry points — what `hass search
/// --network calibnet --device u250 --batch 4 --quant 12` runs.
fn reference_csv(iters: usize, seed: u64) -> String {
    let net = networks::calibnet();
    let ev = SurrogateEvaluator {
        sparsity: synthesize(&net, seed),
        net: net.clone(),
        base_acc: 76.0,
    };
    let cfg = SearchConfig {
        iterations: iters,
        seed,
        engine: EngineConfig {
            batch: 4,
            threads: 0,
            cache: true,
            quant_bits: 12,
            async_eval: false,
        },
        ..Default::default()
    };
    let devices = [DeviceBudget::u250()];
    let r = search_sharded_with_cache(
        &ev,
        &net,
        &ResourceModel::default(),
        &devices,
        &cfg,
        &DesignCache::new(),
    );
    r.per_device[0].result.to_table().to_csv()
}

fn run_search(addr: SocketAddr, id: u64, iters: usize, seed: u64) -> (Vec<Json>, Json) {
    let stream = TcpStream::connect(addr).expect("connect");
    send_line(&stream, &search_request(id, iters, seed));
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    read_until_result(&mut reader, id as f64)
}

fn journal_of(terminal: &Json) -> String {
    let devices = terminal
        .get("result")
        .and_then(|r| r.get("devices"))
        .and_then(|d| d.as_arr())
        .unwrap_or_else(|| panic!("search failed: {terminal:?}"));
    assert_eq!(devices.len(), 1);
    devices[0]
        .get("journal_csv")
        .and_then(|c| c.as_str())
        .expect("journal_csv in device result")
        .to_string()
}

#[test]
fn malformed_lines_are_answered_and_the_connection_survives() {
    let (_server, addr, handle) = start_server(2);
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    for bad in ["not json at all", "{", "[1, 2, 3]", r#"{"id": 1}"#, r#"{"method": 42}"#] {
        send_line(&stream, bad);
        let v = read_json(&mut reader);
        assert!(
            v.get("error").and_then(|e| e.as_str()).is_some(),
            "malformed line {bad:?} must get an error response: {v:?}"
        );
    }
    // an unknown method and broken params are errors too, echoing the id
    send_line(&stream, r#"{"id": 5, "method": "frobnicate"}"#);
    let v = read_json(&mut reader);
    assert_eq!(v.get("id").and_then(|i| i.as_f64()), Some(5.0));
    assert!(v.get("error").and_then(|e| e.as_str()).unwrap().contains("unknown method"));
    send_line(
        &stream,
        r#"{"id": 6, "method": "search", "params": {"network": "no-such-net"}}"#,
    );
    let v = read_json(&mut reader);
    assert!(v.get("error").and_then(|e| e.as_str()).unwrap().contains("no-such-net"));
    send_line(&stream, r#"{"id": 7, "method": "search", "params": {"iters": "many"}}"#);
    let v = read_json(&mut reader);
    assert!(v.get("error").and_then(|e| e.as_str()).unwrap().contains("iters"));
    // the same connection still serves valid requests after all that
    send_line(&stream, r#"{"id": 8, "method": "stats"}"#);
    let v = read_json(&mut reader);
    let stats = v.get("result").expect("stats result");
    assert_eq!(stats.get("completed_searches").and_then(|c| c.as_usize()), Some(0));
    drop(stream);
    shutdown_and_join(addr, handle);
}

/// Two clients searching concurrently each get, streamed back, the
/// bit-identical journal of the same search run through the library (and
/// therefore of the `hass search` CLI, which prints exactly this CSV) —
/// the cache being shared and contended never changes results.
#[test]
fn concurrent_daemon_searches_are_bit_identical_to_the_library() {
    let want = reference_csv(6, 3);
    let (_server, addr, handle) = start_server(2);
    let (a, b) = std::thread::scope(|s| {
        let ta = s.spawn(|| run_search(addr, 1, 6, 3));
        let tb = s.spawn(|| run_search(addr, 2, 6, 3));
        (ta.join().expect("client a"), tb.join().expect("client b"))
    });
    for (events, terminal) in [&a, &b] {
        assert!(
            events.iter().any(|e| {
                e.get("event").and_then(|v| v.as_str()) == Some("generation")
            }),
            "per-generation progress must stream to each client"
        );
        assert_eq!(journal_of(terminal), want, "daemon journal diverged from library");
    }
    // a warm repeat on the now-hot shared cache: still bit-identical,
    // and every pricing is served from memory (zero misses)
    let (_, warm) = run_search(addr, 3, 6, 3);
    assert_eq!(journal_of(&warm), want, "warm daemon journal diverged");
    let dev = &warm.get("result").unwrap().get("devices").unwrap().as_arr().unwrap()[0];
    assert_eq!(
        dev.get("cache_misses").and_then(|m| m.as_usize()),
        Some(0),
        "a warm repeat must serve every pricing from the resident cache"
    );
    assert!(dev.get("cache_hits").and_then(|h| h.as_usize()).unwrap() > 0);
    shutdown_and_join(addr, handle);
}

/// With a single admission slot, a client that disconnects mid-search
/// must have its search cancelled (between generations) and the slot
/// released — the next client's search completes instead of queueing
/// forever.
#[test]
fn disconnect_mid_search_frees_the_admission_slot() {
    let (_server, addr, handle) = start_server(1);
    // client A: many cheap generations, so the disconnect lands mid-run
    let a = TcpStream::connect(addr).expect("connect a");
    send_line(&a, &search_request(10, 48, 5));
    let mut ra = BufReader::new(a.try_clone().expect("clone"));
    // wait for evidence the search is actually running...
    loop {
        let v = read_json(&mut ra);
        if v.get("event").and_then(|e| e.as_str()) == Some("generation") {
            break;
        }
        assert!(v.get("error").is_none(), "search a failed to start: {v:?}");
    }
    // ...then vanish without reading the rest
    drop(ra);
    drop(a);
    // client B: must be admitted once A's slot frees, and complete
    let (_events, terminal) = run_search(addr, 11, 2, 6);
    assert!(
        terminal.get("result").is_some(),
        "client b's search must complete after a's disconnect: {terminal:?}"
    );
    shutdown_and_join(addr, handle);
}

/// `iters: 0` over the wire: a legal no-op search — header-only journal,
/// no best fields, no panic.
#[test]
fn zero_iteration_daemon_search_returns_an_empty_journal() {
    let (_server, addr, handle) = start_server(2);
    let (_events, terminal) = run_search(addr, 20, 0, 1);
    let result = terminal.get("result").expect("zero-iteration search must succeed");
    let devices = result.get("devices").and_then(|d| d.as_arr()).unwrap();
    assert_eq!(devices.len(), 1);
    assert!(devices[0].get("best_iter").is_none(), "no iterations -> no best");
    let csv = devices[0].get("journal_csv").and_then(|c| c.as_str()).unwrap();
    assert_eq!(csv.lines().count(), 1, "journal must be header-only: {csv:?}");
    shutdown_and_join(addr, handle);
}

/// `price` and `save-cache` round-trip through the resident cache: the
/// second identical pricing is served cached, and the snapshot written
/// by `save-cache` loads back with the priced design in it.
#[test]
fn price_and_save_cache_use_the_resident_stores() {
    let (_server, addr, handle) = start_server(2);
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let price = r#"{"id": 1, "method": "price", "params": {"network": "calibnet", "device": "u250", "sw": 0.5, "sa": 0.5, "quant": 12}}"#;
    send_line(&stream, price);
    let (_, cold) = read_until_result(&mut reader, 1.0);
    let cold = cold.get("result").expect("price result").clone();
    assert_eq!(cold.get("cached").and_then(|c| c.as_bool()), Some(false));
    assert!(cold.get("images_per_sec").and_then(|i| i.as_f64()).unwrap() > 0.0);
    let price2 = r#"{"id": 2, "method": "price", "params": {"network": "calibnet", "device": "u250", "sw": 0.5, "sa": 0.5, "quant": 12}}"#;
    send_line(&stream, price2);
    let (_, warm) = read_until_result(&mut reader, 2.0);
    let warm = warm.get("result").expect("price result").clone();
    assert_eq!(warm.get("cached").and_then(|c| c.as_bool()), Some(true));
    assert_eq!(
        warm.get("images_per_sec").and_then(|i| i.as_f64()).unwrap().to_bits(),
        cold.get("images_per_sec").and_then(|i| i.as_f64()).unwrap().to_bits(),
        "a cache hit must return the identical design"
    );
    // snapshot the warm store and load it back
    let path = std::env::temp_dir().join("hass_serve_save_cache_test.json");
    let req = format!(
        r#"{{"id": 3, "method": "save-cache", "params": {{"path": {}}}}}"#,
        Json::Str(path.to_string_lossy().into_owned()).to_string()
    );
    send_line(&stream, &req);
    let (_, saved) = read_until_result(&mut reader, 3.0);
    let saved = saved.get("result").expect("save-cache result").clone();
    assert!(saved.get("designs").and_then(|d| d.as_usize()).unwrap() >= 1);
    let (loaded, st) = DesignCache::load(&path).expect("snapshot loads");
    std::fs::remove_file(&path).ok();
    assert!(st.designs >= 1);
    assert!(loaded.len() >= 1);
    drop(stream);
    shutdown_and_join(addr, handle);
}

/// Daemon-side resume (the PR 8 follow-on): a pipelined, checkpointed
/// daemon search leaves a mid-run checkpoint behind; a later `search`
/// request carrying `resume` must continue it to a journal bit-identical
/// to the uninterrupted run, a fingerprint mismatch must be answered as
/// a request-scoped JSON-RPC error (the connection and the daemon
/// survive), and `stats` must surface the cumulative fault-tolerance and
/// pipeline counters.
#[test]
fn daemon_resume_continues_a_checkpoint_and_mismatches_are_request_errors() {
    let (_server, addr, handle) = start_server(1);
    let path = std::env::temp_dir().join("hass_serve_resume_param_test.json");
    std::fs::remove_file(&path).ok();
    let ck_json = Json::Str(path.to_string_lossy().into_owned()).to_string();
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    // the uninterrupted reference: 12 iters at depth 1, checkpointing
    // every generation — the last mid-run write sits at done = 8
    let req = format!(
        r#"{{"id": 1, "method": "search", "params": {{"network": "calibnet", "device": "u250", "iters": 12, "seed": 9, "batch": 4, "quant": 12, "pipeline_depth": 1, "checkpoint": {ck_json}}}}}"#,
    );
    send_line(&stream, &req);
    let (_, terminal) = read_until_result(&mut reader, 1.0);
    assert!(terminal.get("result").is_some(), "pipelined search failed: {terminal:?}");
    let want = journal_of(&terminal);
    let ck = Checkpoint::load(path.to_str().unwrap()).expect("daemon checkpoint loads");
    assert_eq!(ck.done, 8, "last mid-run checkpoint must sit at the done=8 boundary");
    // a resume under a different seed is a different search: the request
    // must be refused with an error line, not take the daemon down
    let bad = format!(
        r#"{{"id": 2, "method": "search", "params": {{"network": "calibnet", "device": "u250", "iters": 12, "seed": 10, "batch": 4, "quant": 12, "pipeline_depth": 1, "resume": {ck_json}}}}}"#,
    );
    send_line(&stream, &bad);
    let (_, refused) = read_until_result(&mut reader, 2.0);
    let err = refused.get("error").and_then(|e| e.as_str()).unwrap_or_default();
    assert!(
        err.contains("different search"),
        "fingerprint mismatch must be a request-scoped error: {refused:?}"
    );
    // the matching resume continues from done = 8 and must journal
    // bit-identically to the uninterrupted run (warm cache and all)
    let good = format!(
        r#"{{"id": 3, "method": "search", "params": {{"network": "calibnet", "device": "u250", "iters": 12, "seed": 9, "batch": 4, "quant": 12, "pipeline_depth": 1, "resume": {ck_json}}}}}"#,
    );
    send_line(&stream, &good);
    let (_, resumed) = read_until_result(&mut reader, 3.0);
    assert!(resumed.get("result").is_some(), "resumed search failed: {resumed:?}");
    assert_eq!(
        journal_of(&resumed),
        want,
        "daemon-side resume diverged from the uninterrupted run"
    );
    std::fs::remove_file(&path).ok();
    // stats: cumulative fault-tolerance + pipeline counters are surfaced
    send_line(&stream, r#"{"id": 4, "method": "stats"}"#);
    let v = read_json(&mut reader);
    let stats = v.get("result").expect("stats result").clone();
    assert_eq!(stats.get("retried_evals").and_then(|x| x.as_usize()), Some(0));
    assert_eq!(stats.get("reclaimed_stalls").and_then(|x| x.as_usize()), Some(0));
    assert!(
        stats.get("pipelined_generations").and_then(|x| x.as_usize()).unwrap() >= 4,
        "both depth-1 runs must count their overlapped generations: {stats:?}"
    );
    assert!(
        stats.get("lookahead_proposals").and_then(|x| x.as_usize()).unwrap() > 0,
        "lookahead proposals must accumulate across searches: {stats:?}"
    );
    drop(stream);
    shutdown_and_join(addr, handle);
}

// ===== chaos: injected daemon faults ====================================

/// A search that panics inside the worker (injected at the
/// `server.search.panic` site) must cost exactly one request: the client
/// gets an error line, the admission slot frees, and the resident caches
/// stay warm and serving — the next price hits, the next search runs.
#[test]
fn a_panicking_search_costs_one_request_and_leaves_the_caches_warm() {
    let _x = fault::exclusive();
    let (_server, addr, handle) = start_server(1);
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    // prime the resident cache with one pricing
    let price = r#"{"id": 1, "method": "price", "params": {"network": "calibnet", "device": "u250", "sw": 0.4, "sa": 0.4, "quant": 12}}"#;
    send_line(&stream, price);
    let (_, cold) = read_until_result(&mut reader, 1.0);
    assert!(cold.get("result").is_some(), "priming price failed: {cold:?}");
    // a panicking search: error line, connection survives
    {
        let _g = fault::armed("server.search.panic", 1);
        send_line(&stream, &search_request(2, 4, 9));
        let (_, v) = read_until_result(&mut reader, 2.0);
        let err = v.get("error").and_then(|e| e.as_str()).unwrap_or_default();
        assert!(err.contains("panicked"), "expected a panic error line, got {v:?}");
    }
    // the caches are still warm: the identical pricing now hits
    let price2 = r#"{"id": 3, "method": "price", "params": {"network": "calibnet", "device": "u250", "sw": 0.4, "sa": 0.4, "quant": 12}}"#;
    send_line(&stream, price2);
    let (_, warm) = read_until_result(&mut reader, 3.0);
    let warm = warm.get("result").expect("price after panic").clone();
    assert_eq!(
        warm.get("cached").and_then(|c| c.as_bool()),
        Some(true),
        "the panic must not have taken the resident cache down"
    );
    // and the single admission slot was released: a real search completes
    send_line(&stream, &search_request(4, 4, 9));
    let (_, done) = read_until_result(&mut reader, 4.0);
    assert!(done.get("result").is_some(), "search after panic failed: {done:?}");
    drop(stream);
    shutdown_and_join(addr, handle);
}

/// A connection dropped by the daemon before the first byte (injected at
/// `server.conn.drop` — a network blip) closes that one socket and
/// nothing else: the next connection is served normally.
#[test]
fn a_dropped_connection_costs_one_socket_not_the_daemon() {
    let _x = fault::exclusive();
    let (_server, addr, handle) = start_server(1);
    {
        let _g = fault::armed("server.conn.drop", 1);
        let stream = TcpStream::connect(addr).expect("connect");
        send_line(&stream, r#"{"id": 1, "method": "stats"}"#);
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "the dropped connection must answer nothing: {line:?}");
    }
    // the site is disarmed; a fresh connection works
    let stream = TcpStream::connect(addr).expect("reconnect");
    send_line(&stream, r#"{"id": 2, "method": "stats"}"#);
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let v = read_json(&mut reader);
    assert!(v.get("result").is_some(), "reconnect must be served: {v:?}");
    drop(stream);
    shutdown_and_join(addr, handle);
}

/// The daemon's `checkpoint` search param reaches the engine: a
/// checkpointed daemon search leaves a loadable mid-run checkpoint
/// behind, generation-aligned with the request's batch size.
#[test]
fn daemon_searches_honor_the_checkpoint_param() {
    let (_server, addr, handle) = start_server(1);
    let path = std::env::temp_dir().join("hass_serve_ckpt_param_test.json");
    std::fs::remove_file(&path).ok();
    let req = format!(
        r#"{{"id": 1, "method": "search", "params": {{"network": "calibnet", "device": "u250", "iters": 8, "seed": 9, "batch": 4, "quant": 12, "checkpoint": {}}}}}"#,
        Json::Str(path.to_string_lossy().into_owned()).to_string()
    );
    let stream = TcpStream::connect(addr).expect("connect");
    send_line(&stream, &req);
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let (_, terminal) = read_until_result(&mut reader, 1.0);
    assert!(terminal.get("result").is_some(), "search failed: {terminal:?}");
    // 8 iters / batch 4 = 2 generations: the mid-run write at done=4
    // is on disk (the final generation is never checkpointed)
    let ck = Checkpoint::load(path.to_str().unwrap()).expect("daemon checkpoint loads");
    std::fs::remove_file(&path).ok();
    assert_eq!(ck.done, 4, "checkpoint must sit on the mid-run generation boundary");
    assert_eq!(ck.devices.len(), 1);
    assert_eq!(ck.devices[0].device, "u250");
    assert_eq!(ck.devices[0].records.len(), 4);
    drop(stream);
    shutdown_and_join(addr, handle);
}
