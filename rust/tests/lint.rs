//! Fixture tests for `hass lint` (`src/analysis/`) plus the self-hosting
//! gate: the repo's own tree must lint clean, with every waiver counted.
//!
//! Fixtures are linted as in-memory strings via [`hass::analysis::lint_source`]
//! under a synthetic path, so each test pins one rule's behavior — what
//! it catches, what it must *not* catch, and how suppression works.

use std::path::PathBuf;

use hass::analysis::{fix_hint, lint_paths, lint_source, module_key, Diagnostic};

/// Rules (with suppression flag) fired for `src` at `path`.
fn fired(path: &str, src: &str) -> Vec<(&'static str, bool)> {
    lint_source(path, src).into_iter().map(|d| (d.rule, d.suppressed)).collect()
}

/// Unsuppressed rule names only.
fn violations(path: &str, src: &str) -> Vec<&'static str> {
    lint_source(path, src)
        .into_iter()
        .filter(|d| !d.suppressed)
        .map(|d| d.rule)
        .collect()
}

// ---------------------------------------------------------- determinism

#[test]
fn determinism_flags_hashed_collections_in_engine_scope() {
    let src = r#"
        use std::collections::HashMap;
        fn f() {
            let m: HashMap<u32, u32> = HashMap::new();
            drop(m);
        }
    "#;
    // the `use` line is skipped; the two body mentions dedup to one per line
    let v = violations("src/engine/foo.rs", src);
    assert_eq!(v, vec!["determinism"], "HashMap in engine/ must fire once: {v:?}");
    // out of scope: metrics/ may hash freely
    assert!(violations("src/metrics/foo.rs", src).is_empty());
}

#[test]
fn determinism_flags_clocks_thread_identity_and_env_reads() {
    let clock = "fn f() { let t = Instant::now(); drop(t); }";
    assert_eq!(violations("src/dse/x.rs", clock), vec!["determinism"]);

    let sys = "fn f() { let t = SystemTime::now(); drop(t); }";
    assert_eq!(violations("src/optim/x.rs", sys), vec!["determinism"]);

    let tid = "fn f() -> u64 { hash(thread::current().id()) }";
    assert_eq!(violations("src/simulator/x.rs", tid), vec!["determinism"]);

    let env = "fn f() -> String { std::env::var(\"HASS_SEED\").unwrap_or_default() }";
    assert_eq!(violations("src/engine/x.rs", env), vec!["determinism"]);

    // env in a path that is not a read accessor is fine
    let ok = "fn f() { let p = env::args(); drop(p); }";
    assert!(violations("src/engine/x.rs", ok).is_empty());
}

#[test]
fn determinism_skips_test_items() {
    let src = r#"
        #[cfg(test)]
        mod tests {
            fn helper() {
                let m: std::collections::HashMap<u32, u32> = Default::default();
                drop(m);
            }
        }
    "#;
    assert!(violations("src/engine/foo.rs", src).is_empty());
    // but #[cfg(not(test))] is NOT a test attribute — still linted
    let not_test = r#"
        #[cfg(not(test))]
        fn helper() {
            let m: std::collections::HashMap<u32, u32> = Default::default();
            drop(m);
        }
    "#;
    assert_eq!(violations("src/engine/foo.rs", not_test), vec!["determinism"]);
}

// --------------------------------------------------------- panic-safety

#[test]
fn panic_safety_flags_unwrap_expect_and_panic_macros() {
    let src = r#"
        fn f(x: Option<u32>) -> u32 {
            let a = x.unwrap();
            let b = x.expect("present");
            if a + b > 100 { panic!("boom"); }
            a + b
        }
    "#;
    let v = violations("src/server/x.rs", src);
    assert_eq!(v, vec!["panic-safety", "panic-safety", "panic-safety"], "{v:?}");
    // same code outside the panic scope is not this rule's business
    assert!(violations("src/dse/x.rs", src).is_empty());
}

#[test]
fn panic_safety_ignores_non_panicking_cousins() {
    let src = r#"
        fn f(x: Option<u32>) -> u32 {
            x.unwrap_or_else(|| 7).max(x.unwrap_or_default())
        }
    "#;
    assert!(violations("src/server/x.rs", src).is_empty());
}

#[test]
fn panic_safety_inline_allow_suppresses_and_is_counted() {
    let src = r#"
        fn f(x: Option<u32>) -> u32 {
            // invariant: caller checked is_some (fixture justification)
            // lint: allow(panic-safety)
            x.unwrap()
        }
    "#;
    let f = fired("src/server/x.rs", src);
    assert_eq!(f, vec![("panic-safety", true)], "suppressed but still recorded: {f:?}");
}

#[test]
fn allow_directive_reaches_two_lines_and_takes_a_rule_list() {
    // directive two lines above the offending line, naming two rules
    let src = r#"
        fn f(xs: &[u32]) -> u32 {
            // lint: allow(panic-safety, index-panic)
            // (justification prose may sit between directive and code)
            xs[0] + xs.iter().next().copied().unwrap()
        }
    "#;
    let f = fired("src/server/x.rs", src);
    assert!(
        f.iter().all(|(_, suppressed)| *suppressed),
        "both rules on the line should be waived: {f:?}"
    );
    assert_eq!(f.len(), 2);
}

// ---------------------------------------------------------- index-panic

#[test]
fn index_panic_flags_indexing_and_slicing() {
    let src = r#"
        fn f(xs: &[u32], i: usize) -> u32 {
            let a = xs[i];
            let tail = &xs[1..];
            a + tail.len() as u32
        }
    "#;
    let v = violations("src/main.rs", src);
    assert_eq!(v, vec!["index-panic", "index-panic"], "{v:?}");
}

#[test]
fn index_panic_ignores_patterns_literals_and_macros() {
    let src = r#"
        fn f(xs: [u32; 2]) -> Vec<u32> {
            let [a, b] = xs;          // slice pattern: `let` precedes `[`
            let v = vec![a, b];       // macro bang precedes `[`
            let t: [u32; 2] = [a, b]; // type + literal
            drop(t);
            v
        }
    "#;
    assert!(violations("src/main.rs", src).is_empty());
}

#[test]
fn index_panic_module_allowlist_covers_shard_rs() {
    let src = "fn f(xs: &[u32]) -> u32 { xs[0] }";
    // shard.rs carries a module-keyed waiver (slot-addressed indexing)
    let f = fired("src/engine/shard.rs", src);
    assert_eq!(f, vec![("index-panic", true)]);
    // ...which does not extend to unwrap there
    let uw = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
    assert_eq!(violations("src/engine/shard.rs", uw), vec!["panic-safety"]);
}

// ------------------------------------------------------ lock-discipline

#[test]
fn lock_discipline_flags_raw_lock_unwrap_everywhere() {
    let src = r#"
        fn f(m: &std::sync::Mutex<u32>) -> u32 {
            *m.lock().unwrap()
        }
    "#;
    // fires even outside the panic scope...
    assert_eq!(violations("src/metrics/x.rs", src), vec!["lock-discipline"]);
    // ...and in benches and tests
    assert_eq!(violations("benches/x.rs", src), vec!["lock-discipline"]);
    let in_test = r#"
        #[test]
        fn t() {
            let m = std::sync::Mutex::new(1u32);
            let g = m.lock().unwrap();
            drop(g);
        }
    "#;
    assert_eq!(violations("tests/x.rs", in_test), vec!["lock-discipline"]);
}

#[test]
fn lock_discipline_accepts_lock_clean_and_into_inner() {
    let src = r#"
        fn f(m: &std::sync::Mutex<u32>) -> u32 {
            let a = *crate::util::lock_clean(m);
            let b = *m.lock().unwrap_or_else(|p| p.into_inner());
            a + b
        }
    "#;
    assert!(violations("src/metrics/x.rs", src).is_empty());
}

#[test]
fn lock_discipline_subsumes_panic_safety_on_the_same_call() {
    // in panic scope, `.lock().unwrap()` must fire lock-discipline only —
    // not a second panic-safety diagnostic for the same `.unwrap()`
    let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }";
    assert_eq!(violations("src/server/x.rs", src), vec!["lock-discipline"]);
}

// --------------------------------------------------------- thread-spawn

#[test]
fn thread_spawn_banned_outside_util() {
    let src = "fn f() { std::thread::spawn(|| {}); }";
    assert_eq!(violations("src/engine/pool.rs", src), vec!["thread-spawn"]);
    // util/ owns the justified detached helpers
    assert!(violations("src/util/pool.rs", src).is_empty());
    // scoped threads are the sanctioned pattern
    let scoped = "fn f() { std::thread::scope(|s| { let _ = s; }); }";
    assert!(violations("src/engine/pool.rs", scoped).is_empty());
}

// ------------------------------------------------------ atomics-relaxed

#[test]
fn atomics_relaxed_requires_a_classification_comment() {
    let bare = r#"
        use std::sync::atomic::{AtomicU64, Ordering};
        fn f(c: &AtomicU64) -> u64 {
            c.load(Ordering::Relaxed)
        }
    "#;
    assert_eq!(violations("src/server/stats.rs", bare), vec!["atomics-relaxed"]);

    let classified = r#"
        use std::sync::atomic::{AtomicU64, Ordering};
        fn f(c: &AtomicU64) -> u64 {
            // relaxed: stats counter read for reporting only
            c.load(Ordering::Relaxed)
        }
    "#;
    // a `relaxed:` classification silences the rule entirely (it is the
    // documentation the rule exists to demand, not a waiver)
    assert!(lint_source("src/server/stats.rs", classified).is_empty());
}

// ------------------------------------------- lexer robustness (no FPs)

#[test]
fn strings_and_comments_never_produce_findings() {
    let src = r##"
        // this comment mentions .unwrap() and panic!() and xs[0]
        /* block comment: HashMap, Instant, thread::spawn */
        fn f() -> String {
            let a = "calls .unwrap() and panic!(\"x\") in a string";
            let b = r#"raw string: m.lock().unwrap() and Ordering::Relaxed"#;
            format!("{a}{b}")
        }
    "##;
    assert!(lint_source("src/server/x.rs", src).is_empty());
    assert!(lint_source("src/engine/x.rs", src).is_empty());
}

#[test]
fn escaped_newlines_in_strings_keep_line_numbers_aligned() {
    // the `\`-newline continuation spans two source lines; the unwrap
    // after it must be reported on its true line (7), which also proves
    // the `lint: allow` window arithmetic stays aligned after literals
    let src = "fn f(x: Option<u32>) -> u32 {\n\
               let s = \"a\\\n\
               b\";\n\
               drop(s);\n\
               x.unwrap()\n\
               }\n";
    let d = lint_source("src/server/x.rs", src);
    let lines: Vec<u32> = d.iter().map(|d| d.line).collect();
    assert_eq!(lines, vec![5], "unwrap is on physical line 5: {d:?}");
}

#[test]
fn lifetimes_and_char_literals_do_not_desync_the_lexer() {
    let src = r#"
        fn f<'a>(xs: &'a [char]) -> Option<&'a char> {
            let c = 'x';
            let nl = '\n';
            drop((c, nl));
            xs.first()
        }
    "#;
    assert!(lint_source("src/server/x.rs", src).is_empty());
}

// ------------------------------------------------------------ plumbing

#[test]
fn module_key_is_invocation_point_independent() {
    assert_eq!(module_key("rust/src/engine/shard.rs"), "src/engine/shard.rs");
    assert_eq!(module_key("/abs/path/repo/rust/src/server/mod.rs"), "src/server/mod.rs");
    assert_eq!(module_key("src/main.rs"), "src/main.rs");
    assert_eq!(module_key("rust/tests/lint.rs"), "tests/lint.rs");
    assert_eq!(module_key("rust/benches/engine_scaling.rs"), "benches/engine_scaling.rs");
}

#[test]
fn diagnostics_render_and_serialize_stably() {
    let d = lint_source("src/server/x.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }");
    let Some(first) = d.first() else {
        panic!("fixture must produce a diagnostic");
    };
    let line = first.render();
    assert!(
        line.starts_with("src/server/x.rs:1: [panic-safety]"),
        "render format drifted: {line}"
    );
    let json = first.to_json().to_string();
    for key in ["\"file\"", "\"line\"", "\"rule\"", "\"message\""] {
        assert!(json.contains(key), "json missing {key}: {json}");
    }
    assert!(json.contains("panic-safety"));
}

#[test]
fn every_rule_has_a_fix_hint() {
    for rule in [
        "determinism",
        "panic-safety",
        "index-panic",
        "lock-discipline",
        "thread-spawn",
        "atomics-relaxed",
    ] {
        assert!(fix_hint(rule).is_some(), "no fix hint for {rule}");
    }
    assert!(fix_hint("no-such-rule").is_none());
}

// --------------------------------------------------------- self-hosting

/// The linter's reason to exist: the repo's own tree is clean, and the
/// waivers that keep it clean are visible and few.
#[test]
fn self_hosting_repo_tree_is_lint_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let paths: Vec<PathBuf> = ["src", "benches", "tests"]
        .iter()
        .map(|d| root.join(d))
        .filter(|p| p.is_dir())
        .collect();
    assert!(!paths.is_empty(), "no source dirs under {}", root.display());

    let report = lint_paths(&paths).unwrap_or_else(|e| panic!("lint_paths failed: {e}"));
    assert!(report.files > 30, "walked only {} files — walker broke?", report.files);

    let rendered: Vec<String> = report.diagnostics.iter().map(Diagnostic::render).collect();
    assert!(
        rendered.is_empty(),
        "repo tree has lint violations:\n{}",
        rendered.join("\n")
    );
    // waivers exist (shard.rs slot indexing, cli.rs contract panic, ...)
    // but must stay bounded, not become an escape valve
    assert!(report.suppressed > 0, "expected some allowlisted findings");
    assert!(
        report.suppressed < 120,
        "{} allowlisted findings — waivers are growing unchecked",
        report.suppressed
    );
}
